"""Post-round streaming attachment through the Session lifecycle
(``fed/api.py``, DESIGN.md §9–§10).

One k-FED communication round finishes; from then on late devices
stream in with heterogeneous (n, k') shapes and are served in batches —
local Algorithm 1 solve vmapped over the batch, Theorem 3.2 attachment
against the cached tau centers, each report folded back into the
incremental server by the plan's admission policy so a periodic refresh
keeps tau tracking the population. Mid-stream the session checkpoints
and a restored replica proves bitwise-identical serving (crash
recovery).

The second half demonstrates the serve plane (DESIGN.md §11): the same
stream served with ``serve_axes`` sharding the request batch over a
mesh and ``refresh="async"`` double-buffering the tau swap — every
label comes back stamped with the tau version that produced it.

  PYTHONPATH=src python examples/streaming_attach.py
  # shard the serve plane over 8 forced host devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/streaming_attach.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.compat import make_mesh
from repro.utils.metrics import clustering_accuracy


def main():
    k, kp, d = 16, 4, 24
    fm = structured_devices(jax.random.PRNGKey(0), k=k, d=d, k_prime=kp,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    # One plan declares the round AND the serving layer behind it.
    plan = FederationPlan(k=k, k_prime=kp, d=d, capacity=1024,
                          batch_size=4, bucket_sizes=(32, 128),
                          refresh_every=8)
    sess = Session(plan)
    rr = sess.run(jax.random.PRNGKey(1), fm.data)
    print(f"round finalized: Z={fm.data.shape[0]}, accuracy "
          f"{100 * clustering_accuracy(np.asarray(rr.labels), np.asarray(fm.labels), k):.2f}%")

    # A stream of late devices: random component subsets, ragged n, k'.
    stream = late_device_stream(fm.means, kp, 12, seed=7,
                                n_range=(16, 120))
    reqs = [r[0] for r in stream]
    truths = [r[1] for r in stream]
    kvs = [r[2] for r in stream]

    out = sess.serve(reqs[:6], kvs[:6])
    accs = [clustering_accuracy(l, t, k) for l, t in zip(out, truths)]
    print(f"served 6 late devices (ragged n, k'): mean accuracy "
          f"{100 * float(np.mean(accs)):.2f}%")

    path = os.path.join(tempfile.mkdtemp(), "attach.npz")
    sess.save(path)
    replica = Session.restore(path, plan)
    a = sess.serve(reqs[6:], kvs[6:])
    b = replica.serve(reqs[6:], kvs[6:])
    same = all(np.array_equal(x, y) for x, y in zip(a, b))
    print(f"checkpoint -> restore -> serve bitwise identical: {same}")
    assert same
    print(f"stats: {sess.stats()}")

    # -- The serve plane: sharded batch axis + async versioned refresh.
    # serve_axes shard_maps the (batch, n_pad, d) step over the mesh
    # (tau replicated); refresh="async" builds the standby tau buffer
    # while serving continues and commits the swap — one atomic version
    # bump — at the next flush boundary. Labels are bitwise identical
    # to single-host serving for a fixed tau version.
    mesh = make_mesh((jax.device_count(),), ("data",))
    plane_plan = FederationPlan(k=k, k_prime=kp, d=d, capacity=1024,
                                batch_size=4 * jax.device_count(),
                                bucket_sizes=(32, 128),
                                refresh_every=6, refresh="async",
                                serve_axes=("data",))
    psess = Session.from_round(plane_plan, rr.detail, mesh=mesh)
    late = late_device_stream(fm.means, kp, 16, seed=23,
                              n_range=(16, 120))
    first = psess.serve_versioned([r[0] for r in late[:8]],
                                  [r[2] for r in late[:8]])
    second = psess.serve_versioned([r[0] for r in late[8:]],
                                   [r[2] for r in late[8:]])
    st = psess.stats()
    print(f"serve plane: {st['serve_shards']} shard(s) over "
          f"{mesh.shape}, async refresh -> versions "
          f"{sorted({v for _, v in first})} then "
          f"{sorted({v for _, v in second})} "
          f"(tau version now {st['tau_version']})")
    acc = float(np.mean([clustering_accuracy(l, t[1], k)
                         for (l, _), t in zip(first + second, late)]))
    print(f"serve-plane mean accuracy: {100 * acc:.2f}%")


if __name__ == "__main__":
    main()
