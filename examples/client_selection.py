"""Client-selection demo (Figure 4 pipeline): k-FED cluster ids as a
de-duplication prior on top of power-of-choice selection.

  PYTHONPATH=src python examples/client_selection.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fig4_selection import run


def main():
    print("strategy comparison (quick mode):")
    for r in run(full=False):
        print(" ", r)


if __name__ == "__main__":
    main()
