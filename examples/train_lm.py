"""End-to-end driver: train a ~small LM (reduced granite config) for a few
hundred steps on synthetic token streams, then serve it with batched
requests — exercising the same train_step / serve_step the production
dry-run lowers.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.launch.train import train_loop
from repro.models import DistCtx, build_model


def synthetic_batches(key, vocab, B, S, steps):
    """Order-2 synthetic language: next token = (3 * tok + 7) % vocab with
    occasional noise — learnable, so loss should drop fast."""
    for i in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        first = jax.random.randint(k1, (B, 1), 0, vocab)
        toks = [first]
        for _ in range(S - 1):
            toks.append((3 * toks[-1] + 7) % vocab)
        toks = jnp.concatenate(toks, axis=1)
        noise = jax.random.bernoulli(k2, 0.02, (B, S))
        toks = jnp.where(noise, (toks + 1) % vocab, toks)
        yield {"tokens": toks[:, :-1],
               "labels": toks[:, 1:].astype(jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True).replace(microbatch=1)
    model = build_model(cfg)
    print(f"training reduced {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")
    batches = synthetic_batches(jax.random.PRNGKey(0), cfg.vocab_size,
                                B=8, S=65, steps=args.steps)
    state, history = train_loop(model, batches, steps=args.steps, lr=3e-3,
                                log_every=20)
    for step, loss in history:
        print(f"  step {step:4d}  loss {loss:.4f}")
    assert history[-1][1] < history[0][1], "loss did not improve"

    # Serve a batch of requests.
    prompt = {"tokens": jnp.arange(16, dtype=jnp.int32)[None].repeat(4, 0)}
    out = generate(model, state.params, prompt, steps=8,
                   ctx=DistCtx.local())
    print("generated continuations:", out.tolist())


if __name__ == "__main__":
    main()
