"""Quickstart: one-shot federated clustering through the declarative
federation API (``FederationPlan`` + ``Session``, DESIGN.md §10).

Builds the paper's Section 4.1 setup (mixture of k Gaussians, k' = sqrt(k)
components per device, m0 devices per component group), declares the
deployment as a plan, runs the one communication round through a
Session, and reports accuracy against the target clustering plus the
exact communication cost. The same Session then serves a straggler
device that joins AFTER clustering (Theorem 3.2) — no network-wide
recomputation, just O(k' k) distance computations.

  PYTHONPATH=src python examples/quickstart.py
"""
import math

import jax
import numpy as np

from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy


def main():
    k, d, m0 = 25, 60, 4
    kp = int(math.isqrt(k))
    fm = structured_devices(jax.random.PRNGKey(0), k=k, d=d, k_prime=kp,
                            m0=m0, n_per_comp_dev=40, sep=40.0)
    Z, n, _ = fm.data.shape
    print(f"network: Z={Z} devices, {n} points each, k={k}, k'={kp}")

    # The whole deployment is ONE declarative spec; the Session owns the
    # lifecycle (run -> attach/serve -> save/restore).
    plan = FederationPlan(k=k, k_prime=kp, d=d)
    sess = Session(plan)
    out = sess.run(jax.random.PRNGKey(1), fm.data)
    acc = clustering_accuracy(np.asarray(out.labels),
                              np.asarray(fm.labels), k)
    upload = Z * kp * d * 4
    print(f"k-FED accuracy vs target clustering: {100 * acc:.2f}%")
    print(f"one-shot communication: {upload / 1024:.1f} KiB total uplink "
          f"({kp * d * 4} B per device)")

    # A straggler device joins AFTER clustering (Theorem 3.2): the same
    # Session attaches it against the retained tau centers.
    late = structured_devices(jax.random.PRNGKey(2), k=k, d=d, k_prime=kp,
                              m0=1, n_per_comp_dev=40, sep=40.0)
    pts = sess.attach(np.asarray(late.data[0]))
    late_acc = clustering_accuracy(np.asarray(pts),
                                   np.asarray(late.labels[0]), k)
    print(f"late-joining device assigned with {100 * late_acc:.2f}% "
          f"consistency, zero extra rounds")


if __name__ == "__main__":
    main()
