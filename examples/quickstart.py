"""Quickstart: one-shot federated clustering with k-FED.

Builds the paper's Section 4.1 setup (mixture of k Gaussians, k' = sqrt(k)
components per device, m0 devices per component group), runs k-FED, and
reports accuracy against the target clustering plus the exact
communication cost of the single round.

  PYTHONPATH=src python examples/quickstart.py
"""
import math

import jax
import numpy as np

from repro.core.kfed import assign_new_device, induced_labels, kfed
from repro.core.local_kmeans import local_kmeans
from repro.data.gaussian import structured_devices
from repro.utils.metrics import clustering_accuracy


def main():
    k, d, m0 = 25, 60, 4
    kp = int(math.isqrt(k))
    fm = structured_devices(jax.random.PRNGKey(0), k=k, d=d, k_prime=kp,
                            m0=m0, n_per_comp_dev=40, sep=40.0)
    Z, n, _ = fm.data.shape
    print(f"network: Z={Z} devices, {n} points each, k={k}, k'={kp}")

    out = kfed(jax.random.PRNGKey(1), fm.data, k=k, k_prime=kp)
    acc = clustering_accuracy(np.asarray(out.labels),
                              np.asarray(fm.labels), k)
    upload = Z * kp * d * 4
    print(f"k-FED accuracy vs target clustering: {100 * acc:.2f}%")
    print(f"one-shot communication: {upload / 1024:.1f} KiB total uplink "
          f"({kp * d * 4} B per device)")

    # A straggler device joins AFTER clustering (Theorem 3.2): no
    # network-wide recomputation, just O(k' k) distance computations.
    late = structured_devices(jax.random.PRNGKey(2), k=k, d=d, k_prime=kp,
                              m0=1, n_per_comp_dev=40, sep=40.0)
    loc = local_kmeans(jax.random.PRNGKey(3), late.data[0], k_max=kp)
    lbl = assign_new_device(loc.centers, loc.center_mask,
                            out.agg.tau_centers)
    pts = induced_labels(lbl[None], loc.assign[None])[0]
    late_acc = clustering_accuracy(np.asarray(pts),
                                   np.asarray(late.labels[0]), k)
    print(f"late-joining device assigned with {100 * late_acc:.2f}% "
          f"consistency, zero extra rounds")


if __name__ == "__main__":
    main()
