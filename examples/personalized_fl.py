"""End-to-end personalized federated learning driver (Table 2 pipeline):

  1. every device computes a summary vector of its local data;
  2. k-FED clusters devices in ONE round;
  3. one model per cluster is trained with FedAvg over its members;
  4. compare against a single global FedAvg model and IFCA.

  PYTHONPATH=src python examples/personalized_fl.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._models import init_mlp, mlp_accuracy, mlp_loss
from repro.data.synthetic_tasks import rotation_tasks
from repro.fed.fedavg import FedAvgConfig, fedavg_round
from repro.fed.ifca import ifca_round
from repro.fed.personalize import kfed_personalize


def main():
    rng = np.random.default_rng(0)
    Z, k = 32, 4
    data = rotation_tasks(rng, Z=Z, n_per_dev=48, d=32, k=k, k_prime=1)
    dev = {"x": jnp.asarray(data.x), "y": jnp.asarray(data.y),
           "mask": jnp.asarray(data.point_mask)}
    cfg = FedAvgConfig(lr=0.1, local_epochs=3, rounds=8)
    init = init_mlp(jax.random.PRNGKey(0), 32, 64, 10)

    # global baseline
    gp = init
    for r in range(cfg.rounds):
        gp, loss = fedavg_round(mlp_loss, gp, dev, cfg,
                                point_mask=dev["mask"])
    acc_g = np.mean([float(mlp_accuracy(gp, dev["x"][z], dev["y"][z]))
                     for z in range(Z)])
    print(f"global FedAvg: {100 * acc_g:.1f}%")

    # IFCA baseline (k models broadcast every round)
    keys = jax.random.split(jax.random.PRNGKey(1), k)
    models = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[init_mlp(kk, 32, 64, 10) for kk in keys])
    for r in range(cfg.rounds):
        models, choice, _ = ifca_round(mlp_loss, models, dev, cfg,
                                       point_mask=dev["mask"])
    acc_i = np.mean([float(mlp_accuracy(
        jax.tree.map(lambda l: l[int(choice[z])], models),
        dev["x"][z], dev["y"][z])) for z in range(Z)])
    print(f"IFCA:          {100 * acc_i:.1f}%  "
          f"(ships {k} models/device/round)")

    # k-FED + per-cluster FedAvg (one model/device/round after clustering)
    feats = jnp.asarray(data.x.mean(axis=1, keepdims=True))  # (Z, 1, d)
    models_kf, assign, _ = kfed_personalize(
        jax.random.PRNGKey(2), mlp_loss, init, dev, feats, k, cfg,
        point_mask=dev["mask"])
    acc_k = np.mean([float(mlp_accuracy(
        jax.tree.map(lambda l: l[int(assign[z])], models_kf),
        dev["x"][z], dev["y"][z])) for z in range(Z)])
    match = np.mean(np.asarray(assign) >= 0)
    print(f"k-FED+FedAvg:  {100 * acc_k:.1f}%  "
          f"(one-shot clustering, 1 model/device/round)")


if __name__ == "__main__":
    main()
