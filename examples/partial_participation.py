"""Partial participation, asynchronous arrival, and straggler serving
through the declarative federation API (DESIGN.md §4, §10).

Simulates the failure modes the paper's one-shot protocol tolerates:
  * a cohort of devices misses the round (network partition) — they are
    excluded from aggregation and re-attached post-hoc (Theorem 3.2);
  * the remaining cohorts report asynchronously, out of order, with one
    retry — ``Session.fold``/``finalize`` yields a clustering bitwise
    identical to the synchronous ``Session.run``;
  * a brand-new device arrives at serving time and is labeled by the
    session's jitted attach step with zero extra communication rounds.

  PYTHONPATH=src python examples/partial_participation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy


def main():
    k, kp, m0 = 16, 4, 4
    fm = structured_devices(jax.random.PRNGKey(0), k=k, d=24, k_prime=kp,
                            m0=m0, n_per_comp_dev=25, sep=60.0)
    Z = fm.data.shape[0]
    plan = FederationPlan(k=k, k_prime=kp, d=24,
                          weight_by_core_counts=True)

    # --- Synchronous reference round. ------------------------------------
    full = Session(plan).run(jax.random.PRNGKey(1), fm.data)
    acc = clustering_accuracy(np.asarray(full.labels),
                              np.asarray(fm.labels), k)
    print(f"network: Z={Z} devices, k={k}, k'={kp} "
          f"(core-count-weighted aggregation)")
    print(f"all devices report:          accuracy {100 * acc:.2f}%")

    # --- Two devices miss the round entirely. -----------------------------
    missing = np.array([3, Z - 2])
    part = jnp.asarray(~np.isin(np.arange(Z), missing))
    dropped = Session(plan).run(jax.random.PRNGKey(1), fm.data,
                                participation=part)
    acc_d = clustering_accuracy(np.asarray(dropped.labels),
                                np.asarray(fm.labels), k)
    print(f"devices {missing.tolist()} offline: accuracy {100 * acc_d:.2f}% "
          f"(absentees re-attached via Theorem 3.2, zero extra rounds)")

    # --- The same round, asynchronously, cohorts out of order + a retry. --
    ids = [z for z in range(Z) if z not in missing]
    cohorts = [ids[2::3], ids[0::3], ids[2::3], ids[1::3]]  # retry of [2::3]
    sess = Session(plan).begin(jax.random.PRNGKey(1), fm.data)
    for cohort in cohorts:
        sess.fold(cohort)
    staged = sess.finalize()
    same = bool(np.array_equal(np.asarray(staged.labels),
                               np.asarray(dropped.labels)))
    print(f"async staged arrival ({len(cohorts)} folds, shuffled, 1 retry): "
          f"bitwise identical to sync round: {same}")
    assert same

    # --- A brand-new device at serving time (same mixture, unseen
    # component combination). ----------------------------------------------
    comps = jnp.asarray([0, 5, 10, 15])
    late_labels = jnp.repeat(comps, 25)
    late_data = fm.means[late_labels] + jax.random.normal(
        jax.random.PRNGKey(7), (late_labels.shape[0], fm.means.shape[1]))
    attach = sess.attach_fn()
    pts = attach(jax.random.PRNGKey(8), late_data)
    acc_l = clustering_accuracy(np.asarray(pts), np.asarray(late_labels), k)
    print(f"late device via serving path: accuracy {100 * acc_l:.2f}% "
          f"(O(k'k) distances, no recomputation)")


if __name__ == "__main__":
    main()
