"""Figure 4: client selection. Random vs pow-d (Cho et al., 2020) vs
k-FED-filtered pow-d on a FEMNIST-like synthetic federation (power-law
device sizes, 2 classes/device). Reports rounds-to-target-accuracy and
final variance across devices (the paper's fairness note)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._models import init_mlp, mlp_accuracy, mlp_loss
from benchmarks.common import row
from repro.fed.api import FederationPlan, Session
from repro.data.partition import _pack
from repro.data.synthetic_tasks import femnist_like
from repro.fed.client import local_sgd
from repro.fed.fedavg import weighted_average
from repro.fed.selection import kfed_pow_d, pow_d, random_selection


def run(full: bool = False):
    rng = np.random.default_rng(3)
    Z = 100 if full else 40
    d = 32
    n_classes = 10
    xs, ys, _ = femnist_like(rng, Z=Z, d=d, n_classes=n_classes,
                             mean_n=60 if full else 30)
    part = _pack(xs, ys, n_classes)
    X = jnp.asarray(part.data)
    Y = jnp.asarray(part.labels)
    M = jnp.asarray(part.point_mask)
    rounds = 30 if full else 15
    m, dd = (10, 30) if full else (6, 18)
    hidden = 64 if full else 32

    # One-shot k-FED clustering of devices by mean feature (k' = 1).
    feats = (X * M[..., None]).sum(1) / jnp.maximum(
        M.sum(1), 1)[:, None]
    res = Session(FederationPlan(k=8, k_prime=1, d=d)).run(
        jax.random.PRNGKey(5), feats[:, None, :])
    clusters = np.asarray(res.labels[:, 0])

    def run_strategy(strategy):
        params = init_mlp(jax.random.PRNGKey(0), d, hidden, n_classes)
        rng_s = np.random.default_rng(11)
        accs = []
        for r in range(rounds):
            losses = np.array([float(mlp_loss(
                params, {"x": X[z], "y": Y[z], "mask": M[z]}))
                for z in range(Z)])
            if strategy == "random":
                sel = random_selection(rng_s, Z, m)
            elif strategy == "pow_d":
                sel = pow_d(rng_s, losses, m, dd)
            else:
                sel = kfed_pow_d(rng_s, losses, clusters, m, dd)
            upds, ws = [], []
            for z in sel:
                u = local_sgd(mlp_loss, params,
                              {"x": X[z], "y": Y[z], "mask": M[z]},
                              lr=0.1, epochs=3)
                upds.append(u.params)
                ws.append(float(M[z].sum()))
            stack = jax.tree.map(lambda *xs: jnp.stack(xs), *upds)
            params = weighted_average(stack, jnp.asarray(ws))
            acc = np.array([float(mlp_accuracy(params, X[z], Y[z], M[z]))
                            for z in range(Z)])
            accs.append(acc)
        return np.stack(accs)   # (rounds, Z)

    rows = []
    for strat in ("random", "pow_d", "kfed_pow_d"):
        t0 = time.perf_counter()
        accs = run_strategy(strat)
        us = (time.perf_counter() - t0) * 1e6
        mean_final = 100 * accs[-1].mean()
        var_final = float(np.var(100 * accs[-1]))
        target = 0.75 if full else 0.6
        hit = np.where(accs.mean(1) >= target)[0]
        t2t = int(hit[0]) + 1 if len(hit) else -1
        rows.append(row(f"fig4_{strat}", us,
                        f"final_acc={mean_final:.1f};var={var_final:.1f};"
                        f"rounds_to_{int(target*100)}pct={t2t}"))
    return rows
