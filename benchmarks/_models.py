"""Tiny supervised models for the personalization / selection benches
(structural stand-in for the paper's one-hidden-layer CNN: one hidden
layer, 200 units in full mode, fewer in quick mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mlp(key, d_in: int, d_hidden: int, n_classes: int):
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / jnp.sqrt(d_in)
    s2 = 1.0 / jnp.sqrt(d_hidden)
    return {"w1": jax.random.normal(k1, (d_in, d_hidden)) * s1,
            "b1": jnp.zeros((d_hidden,)),
            "w2": jax.random.normal(k2, (d_hidden, n_classes)) * s2,
            "b2": jnp.zeros((n_classes,))}


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, data):
    """data: {"x": (n, d), "y": (n,), "mask": (n,)}"""
    logits = mlp_logits(params, data["x"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, data["y"][:, None].astype(jnp.int32),
                               axis=1)[:, 0]
    nll = lse - gold
    m = data.get("mask")
    if m is None:
        return jnp.mean(nll)
    mf = m.astype(jnp.float32)
    return jnp.sum(nll * mf) / jnp.maximum(jnp.sum(mf), 1.0)


def mlp_accuracy(params, x, y, mask=None):
    pred = jnp.argmax(mlp_logits(params, x), axis=-1)
    ok = (pred == y).astype(jnp.float32)
    if mask is not None:
        mf = mask.astype(jnp.float32)
        return jnp.sum(ok * mf) / jnp.maximum(jnp.sum(mf), 1.0)
    return jnp.mean(ok)
