"""Ingestion-encoder serving benchmark (DESIGN.md §17): the fused
batched encode+solve+attach step vs the naive front-end that encodes
each request in its own dispatch and only then runs the serve step.
Both paths run the SAME encoder forward and the SAME fused solve+attach
on identical inputs, so the measured gap is the batching win of folding
the encode stage into the one jitted serve dispatch: 1 call per batch
instead of B encode calls + 1 serve call.

Rows:
  * ``encode_step_fused`` / ``encode_step_unbatched`` — median us per
    batch on identical inputs, with pts_per_s derived.
  * ``encode_speedup`` — unbatched_us / fused_us, asserted >= 3.0
    in-row (the PR's acceptance bar, bench_route idiom: a regression
    errors the bench into zero rows and the CI ``--require encode_``
    gate fails).
  * ``encode_session`` — end-to-end encoded serving through the
    streaming stack (submit raw (n, seq, d) sequences, bucketed over
    (n_pad, seq_rung)), with the steady-state recompile count across
    the post-warmup waves asserted zero in-row.

The speedup row is compared against the committed baseline
(``benchmarks/baselines/BENCH_encode_ci.json``) by the CI perf gate.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.fed import plane as plane_mod
from repro.fed.api import FederationPlan, Session
from repro.fed.stream import StreamConfig
from repro.models.encoder import apply_encoder, init_encoder

# Small per-request shapes: the unbatched baseline pays one dispatch +
# jit-call sync per request, which is exactly the overhead the fused
# plane amortizes — the realistic serving regime for many small
# devices, not one giant batch.
K, KP, D = 16, 3, 16
B, N, S = 32, 4, 8
ENC = "qwen1.5-0.5b"


def _cfg():
    return StreamConfig(k=K, k_prime=KP, d=D, capacity=64, batch_size=B,
                        bucket_sizes=(N,), encoder=ENC,
                        encode_seq_len=S)


def _step_inputs(cfg):
    key = jax.random.PRNGKey(0)
    kt, kd, ke, kk = jax.random.split(key, 4)
    tau = jax.random.normal(kt, (K, D), jnp.float32) * 8.0
    data = jax.random.normal(kd, (B, N, S, D), jnp.float32)
    pmask = jnp.ones((B, N), jnp.bool_)
    tmask = jnp.ones((B, N, S), jnp.bool_)
    keys = jax.random.split(kk, B).astype(jnp.uint32).reshape(B, 2)
    kv = jnp.full((B,), KP, jnp.int32)
    enc = init_encoder(ke, cfg.encoder_spec())
    return tau, enc, keys, data, pmask, tmask, kv


def _unbatched(cfg):
    """The naive front-end: B separate jitted encode dispatches, then
    the identical serve step on the stacked embeddings."""
    spec = cfg.encoder_spec()
    enc_fn = jax.jit(lambda p, x, m: apply_encoder(
        p, x, m, spec, encode_dtype=cfg.encode_dtype))
    serve = jax.jit(plane_mod._make_step(cfg))

    def step(tau, enc, keys, data, pmask, tmask, kv):
        embs = [enc_fn(enc, data[i], tmask[i]) for i in range(B)]
        return serve(tau, keys, jnp.stack(embs), pmask, kv)

    return step


def _session_leg(full: bool):
    """End-to-end encoded serving through the streaming stack; returns
    (pts_per_s, steady-state recompiles past wave 1, tau_version)."""
    waves = 6 if full else 3
    rng = np.random.default_rng(0)
    tau = np.asarray(rng.normal(size=(K, D)) * 8, np.float32)
    plan = FederationPlan(k=K, k_prime=KP, d=D, capacity=256,
                          batch_size=B, bucket_sizes=(N,), encoder=ENC,
                          encode_seq_len=S)
    sess = Session.from_tau(plan, tau)
    reqs = [np.asarray(rng.normal(size=(N, S, D)), np.float32)
            for _ in range(waves * B)]
    sess.serve(reqs[:B])                               # compile warmup
    warm = sess.stats()["plane_compiles"]
    served, t0 = 0, time.perf_counter()
    for lo in range(B, waves * B, B):
        out = sess.serve(reqs[lo:lo + B])
        served += sum(lbl.shape[0] for lbl in out)
    dt = time.perf_counter() - t0
    steady = sess.stats()["plane_compiles"] - warm
    return served / dt, steady, sess.tau_version


def run(full: bool):
    cfg = _cfg()
    args = _step_inputs(cfg)
    repeats = 11 if full else 5
    fused = jax.jit(plane_mod._make_encode_step(cfg))
    unbatched = _unbatched(cfg)
    pts = B * N
    rows = []
    us = {}
    for name, fn in (("fused", fused), ("unbatched", unbatched)):
        u, out = time_call(fn, *args, repeats=repeats, warmup=2)
        us[name] = u
        labels = np.asarray(out[0])
        rows.append(row(f"encode_step_{name}", u,
                        f"pts_per_s={pts / (u / 1e6):.0f};"
                        f"labels_in_k={int((labels < K).all())}"))
    # Both paths must be the same computation — the speedup is pure
    # dispatch amortization, not a different answer.
    np.testing.assert_array_equal(
        np.asarray(fused(*args)[0]), np.asarray(unbatched(*args)[0]))
    speedup = us["unbatched"] / us["fused"]
    # §17 acceptance bar: the fused encode+serve pipeline >= 3x the
    # per-request unbatched front-end's points/sec on identical inputs.
    assert speedup >= 3.0, (speedup, us)
    rows.append(row("encode_speedup", 0.0,
                    f"speedup={speedup:.2f};B={B};N={N};S={S};d={D};"
                    f"enc={ENC}"))
    pps, steady, tv = _session_leg(full)
    assert steady == 0, f"steady-state recompiles: {steady}"
    rows.append(row("encode_session", 0.0,
                    f"pts_per_s={pps:.0f};steady_recompiles={steady};"
                    f"tau_version={tv}"))
    return rows
