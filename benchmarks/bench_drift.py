"""Drift-adaptation benchmark (DESIGN.md §14): a piecewise-stationary
request stream — phase 1 drawn from the round's own mixture, phase 2
from a freshly resampled mixture (same k, new means) — served by a
frozen-tau session vs a ``drift="split_merge"`` session refreshing on
its fold cadence. Rows report serving throughput (pts_per_s) and the
tail mislabel rate (1 - Hungarian clustering accuracy over the second
half of phase 2, after the drift layer has had evidence to act on);
``drift_adaptation`` distills the comparison into one gate-able
``mislabel_gain`` ratio (frozen/drift, > 1 means adaptation helped —
the PR's acceptance criterion, asserted in-row like the autoscaler's
steady-state recompile count). Both the throughput rows and the gain
ratio are compared against the committed baseline by the CI perf gate
(``benchmarks/compare.py``); the gain is deterministic (fixed seeds,
no timing in its definition), so regressions in it are structural,
never noise."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy

K, KP, D = 16, 4, 24


def _phase_stream(means, count, seed):
    s = late_device_stream(means, KP, count, seed, n_range=(20, 60))
    return ([r[0] for r in s], [r[1] for r in s], [r[2] for r in s])


def _serve_phase(sess, reqs, truths, kvs, chunk):
    """Timed chunked serve; returns (tail mislabel rate, pts/sec)."""
    labels = []
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), chunk):
        labels += sess.serve(reqs[lo:lo + chunk], kvs[lo:lo + chunk])
    dt = time.perf_counter() - t0
    errs = [1.0 - clustering_accuracy(lbl, tr, K)
            for lbl, tr in zip(labels, truths)]
    tail = errs[len(errs) // 2:]  # judge after refreshes had evidence
    pts = sum(r.shape[0] for r in reqs)
    return float(np.mean(tail)), pts / dt, dt


def run(full: bool):
    chunk = 8
    p1, p2 = (16, 96) if full else (16, 48)
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    rng = np.random.default_rng(7)
    new_means = rng.normal(size=(K, D)).astype(np.float32) * 40.0
    reqs1, _, kvs1 = _phase_stream(np.asarray(fm.means), p1, 5)
    reqs2, truths2, kvs2 = _phase_stream(new_means, p2, 11)
    configs = (
        ("frozen", dict(refresh_every=0)),
        ("split_merge", dict(refresh_every=chunk, drift="split_merge",
                             drift_half_life=4 * chunk,
                             drift_retire_frac=0.2)),
    )
    rows, mis = [], {}
    for name, kw in configs:
        plan = FederationPlan(k=K, k_prime=KP, d=D, capacity=512,
                              batch_size=chunk, bucket_sizes=(64,), **kw)
        sess = Session.from_round(plan, rr)
        # Phase 1 (stationary): compile warmup + the stale evidence the
        # drift layer must later decay away. Untimed.
        for lo in range(0, p1, chunk):
            sess.serve(reqs1[lo:lo + chunk], kvs1[lo:lo + chunk])
        m, pps, dt = _serve_phase(sess, reqs2, truths2, kvs2, chunk)
        mis[name] = m
        rows.append(row(
            f"drift_serve_{name}", dt / p2 * 1e6,
            f"pts_per_s={pps:.0f};mislabel={m:.4f};"
            f"tau_version={sess.tau_version}"))
    eps = 1e-3  # keep the ratio finite when drift mislabels nothing
    gain = (mis["frozen"] + eps) / (mis["split_merge"] + eps)
    assert mis["split_merge"] <= mis["frozen"], mis  # acceptance bar
    rows.append(row("drift_adaptation", 0, f"mislabel_gain={gain:.2f}"))
    return rows
