"""Shared benchmark utilities: timing + CSV row contract.

Every bench returns rows (name, us_per_call, derived) where ``derived``
is the paper-comparable number (accuracy, cost ratio, ...).
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) in microseconds (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6, out


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
