"""Benchmark harness — one bench per paper table/figure (+ kernels +
roofline). Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale
  PYTHONPATH=src python -m benchmarks.run --only table1,fig3
  PYTHONPATH=src python -m benchmarks.run --list     # valid bench keys
  PYTHONPATH=src python -m benchmarks.run --json .   # + BENCH_<ts>.json

``--json OUT`` additionally writes a structured ``BENCH_<timestamp>.json``
perf record (rows + per-bench wall time + environment) next to the
unchanged CSV stdout; OUT may be a directory or an explicit .json path.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = {
    "table1": "benchmarks.bench_table1_gaussian",
    "fig1": "benchmarks.bench_fig1_separation",
    "fig2": "benchmarks.bench_fig2_heterogeneity",
    "fig3": "benchmarks.bench_fig3_communication",
    "table2": "benchmarks.bench_table2_personalization",
    "fig4": "benchmarks.bench_fig4_selection",
    "kernels": "benchmarks.bench_kernels",
    "attach": "benchmarks.bench_attach_throughput",
    "ablation_moe": "benchmarks.bench_ablation_moe",
    "roofline": "benchmarks.bench_roofline",
    "drift": "benchmarks.bench_drift",
    "route": "benchmarks.bench_route_serve",
    "encode": "benchmarks.bench_encode_serve",
}


def _parse_row(bench: str, row: str) -> dict:
    """CSV row -> structured record (derived may itself contain commas)."""
    parts = row.split(",", 2)
    rec = {"bench": bench, "name": parts[0]}
    try:
        rec["us_per_call"] = float(parts[1]) if len(parts) > 1 else None
    except ValueError:
        rec["us_per_call"] = None
    rec["derived"] = parts[2] if len(parts) > 2 else ""
    return rec


def _json_path(out: str, stamp: str) -> str:
    if out.endswith(".json"):
        parent = os.path.dirname(out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        return out
    os.makedirs(out, exist_ok=True)
    return os.path.join(out, f"BENCH_{stamp}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench keys")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write a BENCH_<timestamp>.json perf record to "
                         "the OUT directory (or exact .json path)")
    ap.add_argument("--list", action="store_true",
                    help="print the valid bench keys and exit")
    args = ap.parse_args()
    if args.list:
        for key in BENCHES:
            print(key)
        return
    keys = list(BENCHES) if not args.only else args.only.split(",")
    unknown = [key for key in keys if key not in BENCHES]
    if unknown:
        print(f"error: unknown bench key(s): {', '.join(unknown)}\n"
              f"valid keys: {', '.join(BENCHES)}", file=sys.stderr)
        sys.exit(2)

    import importlib
    t_start = time.time()
    records, durations = [], {}
    print("name,us_per_call,derived")
    for key in keys:
        mod = importlib.import_module(BENCHES[key])
        t0 = time.time()
        try:
            rows = mod.run(full=args.full)
        except Exception as e:  # keep the harness running
            rows = [f"{key},0,ERROR:{e!r}"]
        for r in rows:
            print(r)
            records.append(_parse_row(key, r))
        durations[key] = round(time.time() - t0, 2)
        print(f"# {key} done in {durations[key]:.1f}s", file=sys.stderr)

    if args.json:
        import jax
        stamp = time.strftime("%Y%m%d_%H%M%S", time.gmtime(t_start))
        record = {
            "timestamp": stamp,
            "full": args.full,
            "benches": keys,
            "rows": records,
            "durations_s": durations,
            "total_s": round(time.time() - t_start, 2),
            "env": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                # Interprets the serve-plane speedup rows: sharding the
                # batch axis over forced host devices is bounded by the
                # physical core count, not the device count.
                "cpu_count": os.cpu_count(),
                "xla_flags": os.environ.get("XLA_FLAGS", ""),
            },
        }
        path = _json_path(args.json, stamp)
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# perf record -> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
