"""Benchmark harness — one bench per paper table/figure (+ kernels +
roofline). Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale
  PYTHONPATH=src python -m benchmarks.run --only table1,fig3
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = {
    "table1": "benchmarks.bench_table1_gaussian",
    "fig1": "benchmarks.bench_fig1_separation",
    "fig2": "benchmarks.bench_fig2_heterogeneity",
    "fig3": "benchmarks.bench_fig3_communication",
    "table2": "benchmarks.bench_table2_personalization",
    "fig4": "benchmarks.bench_fig4_selection",
    "kernels": "benchmarks.bench_kernels",
    "ablation_moe": "benchmarks.bench_ablation_moe",
    "roofline": "benchmarks.bench_roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench keys")
    args = ap.parse_args()
    keys = list(BENCHES) if not args.only else args.only.split(",")

    import importlib
    print("name,us_per_call,derived")
    for key in keys:
        mod = importlib.import_module(BENCHES[key])
        t0 = time.time()
        try:
            rows = mod.run(full=args.full)
        except Exception as e:  # keep the harness running
            rows = [f"{key},0,ERROR:{e!r}"]
        for r in rows:
            print(r)
        print(f"# {key} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
