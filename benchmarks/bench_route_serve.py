"""Cluster-routed serving benchmark (DESIGN.md §16): the fused
label -> dispatch -> per-cluster-head -> combine step vs the IFCA-shaped
baseline that runs EVERY cluster's head over the full batch and selects
by the vote afterwards. Both steps share the label body bitwise, so the
measured gap is purely the routing win: S = k * C queue-slot forwards
instead of k * B.

Rows:
  * ``route_step_routed`` / ``route_step_allk`` — median us per jitted
    step call on identical inputs, with pts_per_s derived.
  * ``route_speedup`` — allk_us / routed_us, asserted >= 5.0 in-row
    (the PR's acceptance bar, bench_drift idiom: a regression errors
    the bench into zero rows and the CI ``--require route_`` fails).
  * ``route_session`` — end-to-end ``Session.serve_predict`` through
    the streaming stack, with the steady-state recompile count across
    two serve waves asserted zero in-row.

The speedup row is compared against the committed baseline
(``benchmarks/baselines/BENCH_route_ci.json``) by the CI perf gate.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session
from repro.fed import plane as plane_mod
from repro.fed.stream import StreamConfig
from repro.models import heads as heads_mod

# Shapes where per-request head compute dominates the shared label
# body (the label body is identical in both steps, so it dilutes the
# measured ratio): wide-ish d and the transformer head arch. At
# B=64, k=16 the all-k baseline runs 1024 head forwards per step vs
# the routed step's k*C = 80 queue slots.
K, KP, D = 16, 4, 128
B, N = 64, 64
HEADS = "qwen1.5-0.5b"
ARCH = "transformer"


def _cfg():
    return StreamConfig(k=K, k_prime=KP, d=D, capacity=64, batch_size=B,
                        bucket_sizes=(N,), heads=HEADS, head_arch=ARCH)


def _step_inputs(cfg):
    key = jax.random.PRNGKey(0)
    kt, kd, kh, kk = jax.random.split(key, 4)
    tau = jax.random.normal(kt, (K, D), jnp.float32) * 8.0
    data = jax.random.normal(kd, (B, N, D), jnp.float32)
    # Spread requests over the tau rows so the vote routes to many
    # distinct queues (the realistic mix, not one hot cluster).
    owner = jnp.arange(B, dtype=jnp.int32) % K
    data = data + tau[owner][:, None, :]
    pmask = jnp.ones((B, N), jnp.bool_)
    keys = jax.random.split(kk, B).astype(jnp.uint32).reshape(B, 2)
    kv = jnp.full((B,), K, jnp.int32)
    heads = heads_mod.init_heads(kh, K, cfg.head_spec())
    return tau, heads, keys, data, pmask, kv


def _session_leg(full: bool):
    """End-to-end serve_predict through the streaming stack; returns
    (pts_per_s, steady-state recompiles across wave 2, tau_version)."""
    waves = 6 if full else 3
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    plan = FederationPlan(k=K, k_prime=KP, d=D, capacity=256,
                          batch_size=B, bucket_sizes=(N,), heads=HEADS,
                          head_arch=ARCH)
    sess = Session.from_round(plan, rr)
    s = late_device_stream(np.asarray(fm.means), KP, waves * B, 3,
                           n_range=(20, 60))
    reqs, kvs = [r[0] for r in s], [r[2] for r in s]
    sess.serve_predict(reqs[:B], kvs[:B])              # compile warmup
    warm = sess.stats()["plane_compiles"]
    served, t0 = 0, time.perf_counter()
    for lo in range(B, waves * B, B):
        out = sess.serve_predict(reqs[lo:lo + B], kvs[lo:lo + B])
        served += sum(p.labels.shape[0] for p in out)
    dt = time.perf_counter() - t0
    steady = sess.stats()["plane_compiles"] - warm
    return served / dt, steady, sess.tau_version


def run(full: bool):
    cfg = _cfg()
    args = _step_inputs(cfg)
    repeats = 11 if full else 5
    routed = jax.jit(plane_mod._make_routed_step(cfg))
    allk = jax.jit(plane_mod._make_allk_step(cfg))
    pts = B * N
    rows = []
    us = {}
    for name, fn in (("routed", routed), ("allk", allk)):
        u, out = time_call(fn, *args, repeats=repeats, warmup=2)
        us[name] = u
        rows.append(row(f"route_step_{name}", u,
                        f"pts_per_s={pts / (u / 1e6):.0f};"
                        f"kept={int(np.asarray(out[6]).sum())}/{B}"))
    speedup = us["allk"] / us["routed"]
    C = plane_mod.route_capacity(B, K, cfg.head_capacity)
    # §16 acceptance bar: routed serving >= 5x the all-k baseline's
    # points/sec on identical inputs (same label body, so this is the
    # dispatch win alone). Asserted in-row like drift_adaptation.
    assert speedup >= 5.0, (speedup, us)
    rows.append(row("route_speedup", 0.0,
                    f"speedup={speedup:.2f};k={K};C={C};"
                    f"queue_slots={K * C};allk_forwards={K * B}"))
    pps, steady, tv = _session_leg(full)
    assert steady == 0, f"steady-state recompiles: {steady}"
    rows.append(row("route_session", 0.0,
                    f"pts_per_s={pps:.0f};steady_recompiles={steady};"
                    f"tau_version={tv}"))
    return rows
