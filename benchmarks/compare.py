"""Perf-regression comparison over BENCH_*.json records — the CI
perf-gate's comparator (``.github/workflows/ci.yml``), kept as plain
unit-testable functions (tests/test_compare.py).

  python -m benchmarks.compare CURRENT.json BASELINE.json \
      --metric pts_per_s --tolerance 0.40 --require attach_bs,autoscale_

``--metric`` takes a comma-separated list (e.g. ``ai,bytes_saved_frac``
for the analytic roofline gate): each metric is compared independently
over the rows that carry it, and the gate fails if ANY regresses.
Rows are matched by name; the metric is parsed out of each row's
``derived`` string (the ``k=v;k=v`` contract of benchmarks/common.py).
The gate fails (exit 1) when the current value falls more than
``tolerance`` below the baseline, when a baseline row with the metric
disappeared from the current record (a silent rename must force a
baseline refresh, not a vacuous pass), or when a ``--require`` prefix
matches no compared row (a bench that errored into zero rows must not
pass the gate). The tolerance is deliberately wide: CI runners are
2-core machines with real run-to-run drift — the gate exists to catch
structural regressions (a dead fast path, an accidental recompile per
flush), not 10% noise.

To refresh the committed baseline after an intentional perf change:
  python -m benchmarks.run --only attach --json \
      benchmarks/baselines/BENCH_quick_ci.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, NamedTuple, Tuple

__all__ = ["Comparison", "compare_records", "main", "metric_rows",
           "parse_derived"]


def parse_derived(derived: str) -> Dict[str, float]:
    """``"a=1.5;b=2;note=text"`` -> ``{"a": 1.5, "b": 2.0}`` (entries
    that don't parse as floats are simply not metrics)."""
    out: Dict[str, float] = {}
    for part in str(derived).split(";"):
        key, sep, val = part.partition("=")
        if not sep:
            continue
        try:
            out[key.strip()] = float(val)
        except ValueError:
            pass
    return out


def metric_rows(record: dict, metric: str) -> Dict[str, float]:
    """name -> metric value for every row of a BENCH json record that
    carries the metric in its derived string."""
    rows: Dict[str, float] = {}
    for r in record.get("rows", []):
        vals = parse_derived(r.get("derived", ""))
        if metric in vals:
            rows[str(r.get("name"))] = vals[metric]
    return rows


class Comparison(NamedTuple):
    name: str
    baseline: float
    current: float
    ratio: float          # current / baseline (higher metric = better)
    regressed: bool       # current < baseline * (1 - tolerance)


def compare_records(current: dict, baseline: dict, *,
                    metric: str = "pts_per_s",
                    tolerance: float = 0.40
                    ) -> Tuple[List[Comparison], List[str]]:
    """Compare two BENCH records on one higher-is-better metric.
    Returns ``(comparisons, missing)``: one :class:`Comparison` per row
    present in BOTH records (sorted by name), and the baseline row
    names that vanished from the current record."""
    base = metric_rows(baseline, metric)
    cur = metric_rows(current, metric)
    comps = [Comparison(name, base[name], cur[name],
                        (cur[name] / base[name]) if base[name]
                        else float("inf"),
                        cur[name] < base[name] * (1.0 - tolerance))
             for name in sorted(set(base) & set(cur))]
    return comps, sorted(set(base) - set(cur))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if a bench metric regressed vs a baseline")
    ap.add_argument("current", help="BENCH json of this run")
    ap.add_argument("baseline", help="committed baseline BENCH json")
    ap.add_argument("--metric", default="pts_per_s",
                    help="comma-separated higher-is-better derived "
                         "key(s) (default pts_per_s)")
    ap.add_argument("--tolerance", type=float, default=0.40,
                    help="allowed fractional drop below baseline "
                         "(default 0.40)")
    ap.add_argument("--require", default="",
                    help="comma-separated row-name prefixes that must "
                         "each match at least one compared row")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    metrics = [m for m in args.metric.split(",") if m]
    failures: List[str] = []
    all_comps: List[Comparison] = []
    for metric in metrics:
        comps, missing = compare_records(current, baseline,
                                         metric=metric,
                                         tolerance=args.tolerance)
        all_comps += comps
        width = max([len(c.name) for c in comps] + [4])
        print(f"[{metric}]")
        print(f"{'row'.ljust(width)}  {'baseline':>12}  {'current':>12}  "
              f"ratio")
        for c in comps:
            flag = "  << REGRESSED" if c.regressed else ""
            print(f"{c.name.ljust(width)}  {c.baseline:>12.1f}  "
                  f"{c.current:>12.1f}  {c.ratio:5.2f}x{flag}")
        failures += [f"{c.name}: {metric} {c.current:.1f} vs baseline "
                     f"{c.baseline:.1f} ({c.ratio:.2f}x < "
                     f"{1 - args.tolerance:.2f}x floor)"
                     for c in comps if c.regressed]
        failures += [f"{name}: baseline row with {metric} missing from "
                     f"the current record (renamed/removed? refresh "
                     f"the baseline)"
                     for name in missing]
    for prefix in filter(None, args.require.split(",")):
        if not any(c.name.startswith(prefix) for c in all_comps):
            failures.append(
                f"--require {prefix!r}: no compared row matches (did "
                f"the bench error out into zero rows?)")
    if failures:
        print(f"\nperf gate FAILED ({args.metric}, tolerance "
              f"{args.tolerance:.0%}):", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    print(f"\nperf gate OK: {len(all_comps)} row comparison(s) within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
