"""Figure 1: clustering accuracy vs the separation constant c. The paper
shows recovery far below the c >= 100 the theory prescribes."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row, time_call
from repro.core.separation import separation_report
from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy

C_VALUES_QUICK = [0.5, 1.0, 2.0, 6.0]
C_VALUES_FULL = [0.25, 0.5, 1.0, 2.0, 4.0, 6.0, 10.0]


def run(full: bool = False, seeds: int = 3):
    k, d, kp, m0 = (64, 100, 8, 5) if full else (16, 50, 4, 3)
    cs = C_VALUES_FULL if full else C_VALUES_QUICK
    rows = []
    for c in cs:
        accs = []
        for s in range(seeds):
            # sep scales the *mean placement*; measure the achieved c_rs.
            fm = structured_devices(jax.random.PRNGKey(s), k=k, d=d,
                                    k_prime=kp, m0=m0, n_per_comp_dev=30,
                                    sep=c * np.sqrt(d))
            sess = Session(FederationPlan(k=k, k_prime=kp, d=d))
            fn = jax.jit(lambda data: sess.run(
                jax.random.PRNGKey(100 + s), data))
            us, out = time_call(fn, fm.data, repeats=1)
            accs.append(clustering_accuracy(np.asarray(out.labels),
                                            np.asarray(fm.labels), k))
        rep = separation_report(fm.data.reshape(-1, d),
                                fm.labels.reshape(-1), k, fm.presence,
                                fm.data.shape[1], k_prime=kp, m0=m0, c=c)
        c_eff = float(np.median(np.asarray(rep.c_rs)[np.asarray(rep.active)]))
        acc = 100 * float(np.mean(accs))
        sd = 100 * float(np.std(accs))
        rows.append(row(f"fig1_c{c}", us,
                        f"acc={acc:.2f}±{sd:.2f};c_rs_active={c_eff:.2f}"))
    return rows
