"""Figure 2: benefits of heterogeneity. k-means cost of k-FED under
structured partitions (k' clusters per device) vs IID random partitions,
relative to the oracle clustering cost:

    ratio = (phi(k') - phi*) / (phi(k) - phi*)    (< 1 is a win)

On FEMNIST-like and Shakespeare-like synthetic proxies (Appendix B.1
structure; LEAF itself is not downloadable offline — DESIGN.md §7)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core.kfed import kmeans_cost_of_labels
from repro.fed.api import FederationPlan, Session
from repro.core.lloyd import kmeans_pp_init, lloyd
from repro.data.partition import partition_iid, partition_structured
from repro.data.synthetic_tasks import femnist_like, shakespeare_like


def _oracle(key, X, k):
    """Centralized clustering = the paper's oracle target T."""
    init, cm = kmeans_pp_init(key, X, k)
    res = lloyd(jnp.asarray(X), init, center_mask=cm)
    return np.asarray(res.assign), float(
        kmeans_cost_of_labels(jnp.asarray(X), res.assign, k))


def _run_dataset(name, xs, ys, k, k_primes, Z, seeds=2):
    X = np.concatenate(xs).astype(np.float32)
    rows = []
    orc_lbl, phi_star = _oracle(jax.random.PRNGKey(0), X, k)
    rng = np.random.default_rng(0)
    for kp in k_primes:
        ratios, us = [], 0.0
        for s in range(seeds):
            st = partition_structured(rng, X, orc_lbl, k=k, Z=Z, k_prime=kp)
            ii = partition_iid(rng, X, orc_lbl, k=k, Z=Z)

            def cost_of(part, kp_eff):
                plan = FederationPlan(k=k, k_prime=kp_eff,
                                      d=int(part.data.shape[-1]))
                res = Session(plan).run(
                    jax.random.PRNGKey(10 + s), jnp.asarray(part.data),
                    k_valid=jnp.asarray(part.k_valid),
                    point_mask=jnp.asarray(part.point_mask))
                lbl = jnp.where(jnp.asarray(part.point_mask),
                                res.labels, -1)
                return float(kmeans_cost_of_labels(
                    jnp.asarray(part.data), lbl, k))

            phi_kp = cost_of(st, kp)
            phi_k = cost_of(ii, min(k, int(ii.k_valid.max())))
            ratios.append((phi_kp - phi_star) /
                          max(phi_k - phi_star, 1e-9))
        r = float(np.mean(ratios))
        rows.append(row(f"fig2_{name}_kprime{kp}", us,
                        f"cost_ratio={r:.3f}"))
    return rows


def run(full: bool = False):
    rng = np.random.default_rng(1)
    rows = []
    Z = 60 if full else 24
    xs, ys, _ = femnist_like(rng, Z=Z, d=32 if not full else 64,
                             mean_n=40 if not full else 80)
    rows += _run_dataset("femnist", xs, ys, k=10,
                         k_primes=[1, 2, 3] if not full else [1, 2, 3, 5],
                         Z=Z)
    xs, ys, _ = shakespeare_like(rng, Z=Z, n_per_dev=60)
    rows += _run_dataset("shakespeare", xs, ys, k=8, k_primes=[1, 2],
                         Z=Z)
    return rows
