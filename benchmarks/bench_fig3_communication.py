"""Figure 3: communication efficiency. k-FED (ONE round: each device ships
O(d k') floats once) vs naive distributed k-means (T rounds, each
all-reducing (k, d) sums + (k,) counts), at matched clustering quality
(k-means cost). We report both the cost ratio and the exact bytes each
protocol moves."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core.kfed import kmeans_cost_of_labels
from repro.core.lloyd import assign_points, kmeans_pp_init, update_centers
from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session


def _central_lloyd_sim(key, data, k, iters):
    """Numerically identical to distributed Lloyd (assignment is
    embarrassingly parallel; the update is one all-reduce per round)."""
    X = data.reshape(-1, data.shape[-1])
    sub = X[:: max(1, X.shape[0] // (32 * k))][: 32 * k]
    c, _ = kmeans_pp_init(key, sub, k)
    for _ in range(iters):
        a, _ = assign_points(X, c)
        c, _ = update_centers(X, a, k, c)
    a, _ = assign_points(X, c)
    return a


def run(full: bool = False):
    k, d, kp, m0 = (36, 60, 6, 4) if full else (16, 40, 4, 3)
    n_per = 40
    lloyd_rounds = 25
    rows = []
    for s, kp_i in enumerate([1, kp // 2, kp][:(3 if full else 3)]):
        kp_eff = max(1, kp_i)
        fm = structured_devices(jax.random.PRNGKey(s), k=k, d=d,
                                k_prime=kp_eff, m0=m0 * (kp // kp_eff),
                                n_per_comp_dev=n_per, sep=25.0)
        Z = fm.data.shape[0]
        sess = Session(FederationPlan(k=k, k_prime=kp_eff, d=d))
        fn = jax.jit(lambda data: sess.run(jax.random.PRNGKey(7 + s),
                                           data))
        us, out = time_call(fn, fm.data, repeats=1)
        phi_kfed = float(kmeans_cost_of_labels(fm.data.reshape(-1, d),
                                               out.labels.reshape(-1), k))
        bl = _central_lloyd_sim(jax.random.PRNGKey(17 + s), fm.data, k,
                                lloyd_rounds)
        phi_lloyd = float(kmeans_cost_of_labels(
            fm.data.reshape(-1, d), bl, k))
        # Exact protocol bytes (f32): k-FED = one upload of k^(z) centers
        # per device (+ k broadcast); distributed = T rounds of (k,d)+k
        # all-reduce contributions per device.
        kfed_bytes = Z * kp_eff * d * 4 + k * d * 4
        lloyd_bytes = lloyd_rounds * Z * (k * d + k) * 4
        rows.append(row(
            f"fig3_kprime{kp_eff}", us,
            f"cost_ratio_kfed_vs_lloyd={phi_kfed / max(phi_lloyd, 1e-9):.3f};"
            f"bytes_kfed={kfed_bytes};bytes_lloyd={lloyd_bytes};"
            f"comm_reduction={lloyd_bytes / kfed_bytes:.1f}x"))
    return rows
