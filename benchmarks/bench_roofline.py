"""Roofline report: reads the dry-run JSONL artifacts (produced by
``python -m repro.launch.dryrun --all --out results_single.jsonl``) and
emits one row per (arch x shape) with the three terms + bottleneck."""
from __future__ import annotations

import json
import os

from benchmarks.common import row

ARTIFACTS = ["results_single.jsonl", "results_multipod.jsonl",
             "results_kfed.jsonl", "results_perf.jsonl"]


def run(full: bool = False):
    rows = []
    for path in ARTIFACTS:
        if not os.path.exists(path):
            continue
        best = {}
        for line in open(path):
            r = json.loads(line)
            best[(r["arch"], r["shape"], r["mesh"])] = r
        for (arch, shape, mesh), r in sorted(best.items()):
            if r["status"] == "skipped":
                rows.append(row(f"roofline_{arch}_{shape}_{mesh}", 0,
                                "SKIPPED_BY_DESIGN"))
                continue
            if r["status"] != "ok":
                rows.append(row(f"roofline_{arch}_{shape}_{mesh}", 0,
                                f"ERROR"))
                continue
            derived = (f"compute={r['compute_s']:.4f};"
                       f"memory={r['memory_s']:.4f};"
                       f"collective={r['collective_s']:.4f};"
                       f"bottleneck={r['bottleneck']}")
            if "useful_flops_ratio" in r:
                derived += (";useful_flops_ratio="
                            f"{r['useful_flops_ratio']:.3f}")
            if "variant" in r:
                derived += f";variant={r['variant']}"
            rows.append(row(
                f"roofline_{arch}_{shape}_{mesh}",
                r.get("t_compile_s", 0) * 1e6, derived))
    if not rows:
        rows.append(row("roofline", 0, "no_artifacts_found_run_dryrun"))
    return rows
