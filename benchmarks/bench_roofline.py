"""Analytic roofline bench — the deterministic half of the CI perf gate.

Two row families, both instruction-count-deterministic (no timing, so
they are meaningful even on the noisy 2-core CI box):

1. ``roofline_serve_*``: the serve step (``fed.plane._make_step``) is
   compiled at a fixed shape and fed through
   ``launch.hlo_analysis.analyze`` — FLOPs and bytes-accessed per
   attached point and their arithmetic intensity ``ai``. A drop in ai
   means the compiled step got more HBM-bound (a dead fusion, a new
   materialization, an accidental f64 upcast); instruction counts do
   not jitter run-to-run, so the gate tolerance can be tight.
2. ``roofline_attach_kernel_*`` + ``roofline_serve_fusion_gain``: the
   kernel-boundary HBM traffic model of ``kernels/solve_attach``
   (``hbm_bytes`` vs ``hbm_bytes_legacy``) — bytes per attached point
   of the fused solve+attach kernel vs the pre-fusion three-dispatch
   Lloyd loop at the same iteration bound, and the saved fraction
   (``bytes_saved_frac``) the acceptance gate pins at >= 25%.

The historical dry-run artifact report (one row per arch x shape from
``results_*.jsonl``) is kept when those files are present.

Refresh the committed baseline after an intentional change:
  PYTHONPATH=src python -m benchmarks.run --only roofline --json \
      benchmarks/baselines/BENCH_roofline_ci.json
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import row

ARTIFACTS = ["results_single.jsonl", "results_multipod.jsonl",
             "results_kfed.jsonl", "results_perf.jsonl"]

# (B, n, d, k, k_prime, max_iters) — smoke is the committed-baseline /
# CI shape; full is closer to a production serve bucket.
_SMOKE = (8, 256, 64, 16, 4, 8)
_FULL = (8, 1024, 256, 64, 8, 8)


def _artifact_rows():
    rows = []
    for path in ARTIFACTS:
        if not os.path.exists(path):
            continue
        best = {}
        for line in open(path):
            r = json.loads(line)
            best[(r["arch"], r["shape"], r["mesh"])] = r
        for (arch, shape, mesh), r in sorted(best.items()):
            if r["status"] == "skipped":
                rows.append(row(f"roofline_{arch}_{shape}_{mesh}", 0,
                                "SKIPPED_BY_DESIGN"))
                continue
            if r["status"] != "ok":
                rows.append(row(f"roofline_{arch}_{shape}_{mesh}", 0,
                                "ERROR"))
                continue
            derived = (f"compute={r['compute_s']:.4f};"
                       f"memory={r['memory_s']:.4f};"
                       f"collective={r['collective_s']:.4f};"
                       f"bottleneck={r['bottleneck']}")
            if "useful_flops_ratio" in r:
                derived += (";useful_flops_ratio="
                            f"{r['useful_flops_ratio']:.3f}")
            if "variant" in r:
                derived += f";variant={r['variant']}"
            rows.append(row(
                f"roofline_{arch}_{shape}_{mesh}",
                r.get("t_compile_s", 0) * 1e6, derived))
    return rows


def _legacy_step(cfg):
    """The pre-fusion three-stage serve step (what _make_step compiled
    before kernels/solve_attach existed) — the compiled-HLO anchor the
    fused step's rows are read against."""
    import jax
    from repro.core import server
    from repro.core.local_kmeans import batched_local_kmeans

    def step(tau, keys, data, point_mask, k_valid):
        loc = batched_local_kmeans(keys, data, k_max=cfg.k_prime,
                                   k_valid=k_valid,
                                   point_mask=point_mask, **cfg.local_kw)
        ctr = jax.vmap(
            lambda c, m: server.assign_new_device(c, m, tau))(
                loc.centers, loc.center_mask)
        labels = server.induced_labels(ctr, loc.assign)
        return (labels, loc.centers, loc.center_mask,
                server.core_weights(loc.core_counts))

    return step


def _compiled_row(name, step, B, n, d, k):
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze
    from repro.launch.roofline import roofline_terms

    sds = jax.ShapeDtypeStruct
    args = (sds((k, d), jnp.float32), sds((B, 2), jnp.uint32),
            sds((B, n, d), jnp.float32), sds((B, n), jnp.bool_),
            sds((B,), jnp.int32))
    t0 = time.time()
    compiled = jax.jit(step).lower(*args).compile()
    us = (time.time() - t0) * 1e6
    hc = analyze(compiled.as_text())
    flops = float(hc["flops"]) + float(hc.get("flops_f32", 0.0))
    byt = float(hc["bytes"])
    pts = B * n
    terms = roofline_terms(flops, byt, float(hc["coll_bytes"]))
    return row(name, us,
               f"flops_per_pt={flops / pts:.1f};"
               f"bytes_per_pt={byt / pts:.1f};"
               f"ai={flops / max(byt, 1.0):.4f};"
               f"bottleneck={terms['bottleneck']}")


def _serve_step_rows(full: bool):
    from repro.fed.plane import _make_step
    from repro.fed.stream import StreamConfig

    B, n, d, k, kp, iters = _FULL if full else _SMOKE
    rows = []
    for dt in ("f32", "bf16"):
        cfg = StreamConfig(k=k, k_prime=kp, d=d, capacity=64,
                           batch_size=B, bucket_sizes=(n,),
                           serve_dtype=dt,
                           local_kw={"max_iters": iters})
        rows.append(_compiled_row(f"roofline_serve_fused_{dt}",
                                  _make_step(cfg), B, n, d, k))
        if dt == "f32":
            rows.append(_compiled_row("roofline_serve_legacy_f32",
                                      _legacy_step(cfg), B, n, d, k))
    return rows


def _analytic_rows(full: bool):
    from repro.kernels.solve_attach import (hbm_bytes, hbm_bytes_legacy,
                                            kernel_flops)

    B, n, d, k, kp, iters = _FULL if full else _SMOKE
    pts = B * n
    rows = []
    byts = {}
    for dt in ("f32", "bf16"):
        b = hbm_bytes(B, n, d, kp, k, dt)
        fl = kernel_flops(B, n, d, kp, k, iters, dt)
        byts[dt] = b
        rows.append(row(
            f"roofline_attach_kernel_{dt}", 0,
            f"bytes_per_pt={b / pts:.1f};ai={fl / b:.4f}"))
    legacy = hbm_bytes_legacy(B, n, d, kp, k, iters)
    fl = kernel_flops(B, n, d, kp, k, iters)
    rows.append(row(
        "roofline_attach_kernel_legacy", 0,
        f"bytes_per_pt={legacy / pts:.1f};ai={fl / legacy:.4f}"))
    rows.append(row(
        "roofline_serve_fusion_gain", 0,
        f"bytes_saved_frac={1.0 - byts['f32'] / legacy:.4f};"
        f"bf16_bytes_saved_frac={1.0 - byts['bf16'] / legacy:.4f};"
        f"lloyd_iter_bound={iters}"))
    return rows


def run(full: bool = False):
    rows = _serve_step_rows(full) + _analytic_rows(full)
    rows += _artifact_rows()
    return rows
