"""Table 1: k-FED accuracy on mixtures of Gaussians, k' = sqrt(k),
across (d, k, m0) settings. Paper reports 98.4-100% at c=100."""
from __future__ import annotations

import math

import jax
import numpy as np

from benchmarks.common import row, time_call
from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy

# (d, k, m0): paper's settings, with a quick-mode subset first.
SETTINGS_QUICK = [(100, 16, 5), (100, 64, 5)]
SETTINGS_FULL = [(100, 16, 5), (100, 64, 5), (300, 64, 5), (300, 100, 5),
                 (300, 16, 5)]


def run(full: bool = False, seeds: int = 3):
    settings = SETTINGS_FULL if full else SETTINGS_QUICK
    rows = []
    for (d, k, m0) in settings:
        kp = int(math.isqrt(k))
        accs = []
        us = 0.0
        for s in range(seeds):
            fm = structured_devices(jax.random.PRNGKey(s), k=k, d=d,
                                    k_prime=kp, m0=m0,
                                    n_per_comp_dev=40,
                                    sep=100.0 * 0.3)  # c~O(10) effective
            sess = Session(FederationPlan(k=k, k_prime=kp, d=d))
            fn = jax.jit(lambda data: sess.run(
                jax.random.PRNGKey(100 + s), data))
            us, out = time_call(fn, fm.data, repeats=1)
            accs.append(clustering_accuracy(np.asarray(out.labels),
                                            np.asarray(fm.labels), k))
        acc = 100 * float(np.mean(accs))
        sd = 100 * float(np.std(accs))
        rows.append(row(f"table1_d{d}_k{k}_m{m0}", us,
                        f"acc={acc:.2f}±{sd:.2f}"))
    return rows
