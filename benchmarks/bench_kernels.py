"""Kernel micro-bench: Pallas (interpret; TPU target) numerics already
validated in tests — here we time the jnp oracle paths that the CPU
actually executes, sized like the paper's workloads (distance+argmin is
the k-means hot-spot k-FED optimizes on-device)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.kernels import ref


def run(full: bool = False):
    rows = []
    shapes = [(4096, 64, 64), (16384, 128, 100)] if not full else \
        [(4096, 64, 64), (16384, 128, 100), (65536, 256, 256)]
    for (n, d, k) in shapes:
        kx, kc = jax.random.split(jax.random.PRNGKey(n))
        x = jax.random.normal(kx, (n, d))
        c = jax.random.normal(kc, (k, d))
        fn = jax.jit(lambda x, c: ref.assign_argmin(x, c))
        us, _ = time_call(fn, x, c)
        gflops = (2 * n * k * d) / (us * 1e-6) / 1e9
        rows.append(row(f"pdist_argmin_n{n}_d{d}_k{k}", us,
                        f"gflops={gflops:.1f}"))
        a = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, k)
        fn2 = jax.jit(lambda x, a: ref.kmeans_update(x, a, k))
        us2, _ = time_call(fn2, x, a)
        rows.append(row(f"kmeans_update_n{n}_d{d}_k{k}", us2,
                        f"gbps={(n * d * 4) / (us2 * 1e-6) / 1e9:.2f}"))
    return rows
