"""Table 2: personalization on rotated tasks (synthetic rotated-prototype
proxy for rotated MNIST). Compares:
  * Global   — one FedAvg model over all devices
  * IFCA     — iterative federated clustering (Ghosh et al., 2020)
  * k-FED    — one-shot cluster (device mean embeddings), then per-cluster
               FedAvg
at k' = 1 (each device one rotation) and k' = 2 (mixed devices)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._models import init_mlp, mlp_accuracy, mlp_loss
from benchmarks.common import row
from repro.data.synthetic_tasks import rotation_tasks
from repro.fed.fedavg import FedAvgConfig, fedavg_round
from repro.fed.ifca import ifca_round
from repro.fed.personalize import kfed_personalize
from repro.utils.metrics import clustering_accuracy


def _eval_per_device(models, assign, data):
    accs = []
    for z in range(data.x.shape[0]):
        params = jax.tree.map(lambda leaf: leaf[int(assign[z])], models)
        accs.append(float(mlp_accuracy(params, jnp.asarray(data.x[z]),
                                       jnp.asarray(data.y[z]))))
    return 100 * float(np.mean(accs))


def _eval_per_chunk(models, lbl, data, kp):
    """k'>1: every device chunk is served by its own cluster's model —
    the data-level personalization k-FED enables (IFCA assigns whole
    devices)."""
    accs = []
    Z, n = data.x.shape[0], data.x.shape[1]
    for z in range(Z):
        for c, idx in enumerate(np.array_split(np.arange(n), kp)):
            params = jax.tree.map(lambda leaf: leaf[int(lbl[z, c])], models)
            accs.append(float(mlp_accuracy(
                params, jnp.asarray(data.x[z][idx]),
                jnp.asarray(data.y[z][idx]))))
    return 100 * float(np.mean(accs))


def run(full: bool = False):
    rows = []
    k = 4
    hidden = 200 if full else 48
    rounds = 12 if full else 6
    Z_list = [100, 200] if full else [24]
    for Z in Z_list:
        for kp in (1, 2):
            rng = np.random.default_rng(Z + kp)
            data = rotation_tasks(rng, Z=Z, n_per_dev=64 if full else 40,
                                  d=32, k=k, k_prime=kp)
            batch = {"x": jnp.asarray(data.x), "y": jnp.asarray(data.y),
                     "mask": jnp.asarray(data.point_mask)}
            dev_data = {"x": batch["x"], "y": batch["y"],
                        "mask": batch["mask"]}
            cfg = FedAvgConfig(lr=0.1, local_epochs=3, rounds=rounds)
            init = init_mlp(jax.random.PRNGKey(0), 32, hidden, 10)

            def loss_fn(p, d):
                return mlp_loss(p, d)

            t0 = time.perf_counter()
            # --- Global FedAvg
            gp = init
            for _ in range(rounds):
                gp, _ = fedavg_round(loss_fn, gp, dev_data, cfg,
                                     point_mask=batch["mask"])
            acc_global = _eval_per_device(
                jax.tree.map(lambda leaf: leaf[None], gp),
                np.zeros(Z, int), data)

            # --- IFCA
            keys = jax.random.split(jax.random.PRNGKey(1), k)
            models = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[init_mlp(keys[j], 32, hidden, 10) for j in range(k)])
            for _ in range(rounds):
                models, choice, _ = ifca_round(loss_fn, models, dev_data,
                                               cfg,
                                               point_mask=batch["mask"])
            acc_ifca = _eval_per_device(models, np.asarray(choice), data)

            # --- k-FED + per-cluster FedAvg. Features: per-chunk
            # *per-class prototype means* (concatenated over classes) —
            # rotation moves every class prototype coherently, so these
            # separate the rotation clusters far better than a plain
            # chunk mean (which averages 10 random prototypes to ~0).
            n_cls = 10
            feats = []
            for z in range(Z):
                xs, ys_z = data.x[z], data.y[z]
                chunk_feats = []
                for ci, idx in zip(range(kp), np.array_split(
                        np.arange(xs.shape[0]), max(kp, 1))):
                    cx, cy = xs[idx], ys_z[idx]
                    proto = np.zeros((n_cls, xs.shape[1]), np.float32)
                    for c in range(n_cls):
                        sel = cy == c
                        if sel.any():
                            proto[c] = cx[sel].mean(0)
                    chunk_feats.append(proto.reshape(-1))
                feats.append(np.stack(chunk_feats))
            feats = jnp.asarray(np.stack(feats))      # (Z, kp, n_cls*d)
            models_kf, assign_kf, _ = kfed_personalize(
                jax.random.PRNGKey(2), loss_fn, init, dev_data, feats, k,
                cfg, k_prime=kp, point_mask=batch["mask"],
                per_chunk=kp > 1)
            if kp > 1:
                acc_kfed = _eval_per_chunk(models_kf,
                                           np.asarray(assign_kf), data, kp)
                clu_acc = clustering_accuracy(
                    np.asarray(assign_kf)[:, 0], data.cluster, k)
            else:
                acc_kfed = _eval_per_device(
                    models_kf, np.asarray(assign_kf), data)
                clu_acc = clustering_accuracy(np.asarray(assign_kf),
                                              data.cluster, k)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(row(
                f"table2_Z{Z}_kprime{kp}", us,
                f"global={acc_global:.1f};ifca={acc_ifca:.1f};"
                f"kfed={acc_kfed:.1f};kfed_cluster_acc={100*clu_acc:.1f}"))
    return rows
