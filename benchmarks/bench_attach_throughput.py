"""Attachment-service throughput: devices/sec and points/sec of the
streaming post-round serving path (``fed.api.Session.serve``) over a
batch-size sweep, plus the checkpoint -> restore -> serve bitwise
round-trip the crash-recovery story depends on."""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session


def _stream(means, k_prime, requests, n, seed):
    """Fixed-shape requests (one bucket) so the sweep times pure serve."""
    return [r[0] for r in late_device_stream(
        means, k_prime, requests, seed, n_range=(n, n + 1),
        kv_min=k_prime)]


def run(full: bool = False):
    k, kp, d = 16, 4, 24
    n = 128 if full else 64
    requests = 32 if full else 8
    batch_sizes = (1, 8, 32) if full else (1, 8)

    fm = structured_devices(jax.random.PRNGKey(0), k=k, d=d, k_prime=kp,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    # ONE round shared across every streaming plan in the sweep.
    rr = Session(FederationPlan(k=k, k_prime=kp, d=d)).run(
        jax.random.PRNGKey(1), fm.data).detail

    def session(B):
        plan = FederationPlan(k=k, k_prime=kp, d=d, capacity=4096,
                              batch_size=B, bucket_sizes=(n,))
        return Session.from_round(plan, rr)

    rows = []
    for B in batch_sizes:
        sess = session(B)
        sess.serve(_stream(fm.means, kp, B, n, seed=99))  # compile warmup
        reqs = _stream(fm.means, kp, requests, n, seed=7)
        t0 = time.perf_counter()
        sess.serve(reqs)
        dt = time.perf_counter() - t0
        pts = requests * n
        rows.append(row(f"attach_bs{B}_n{n}", dt / requests * 1e6,
                        f"dev_per_s={requests / dt:.1f};"
                        f"pts_per_s={pts / dt:.0f}"))

    # Crash recovery: checkpoint mid-stream, restore, serve the rest —
    # must be bitwise identical to the uninterrupted session.
    live = session(batch_sizes[-1])
    reqs = _stream(fm.means, kp, requests, n, seed=11)
    half = len(reqs) // 2
    live.serve(reqs[:half])
    path = os.path.join(tempfile.mkdtemp(), "attach_ck.npz")
    t0 = time.perf_counter()
    live.save(path)
    restored = Session.restore(path, live.plan)
    us_ck = (time.perf_counter() - t0) * 1e6
    same = all(np.array_equal(a, b)
               for a, b in zip(live.serve(reqs[half:]),
                               restored.serve(reqs[half:])))
    rows.append(row("attach_ckpt_roundtrip", us_ck, f"bitwise={same}"))
    return rows
