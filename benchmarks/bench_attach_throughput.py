"""Attachment-service throughput: devices/sec and points/sec of the
streaming post-round serving path (``fed.api.Session.serve``) over a
batch-size sweep, the checkpoint -> restore -> serve bitwise round-trip
the crash-recovery story depends on, the sharded serve plane
(DESIGN.md §11): points/sec vs shard count and sync-vs-async tau
refresh, measured in a subprocess with 8 forced host-platform devices
(the embarrassingly-parallel local solves split across shards), and the
§12 load-adaptive autoscaler: a ramp/burst/trickle load-shape sweep
(``autoscale_*`` rows) pitting the controller against both static
(shards, batch) extremes — repeat-padding rows are real compute, so a
static-large plan burns points/sec on shallow flushes while a
static-small plan fragments deep ones; the controller's steady-state
recompile count is asserted to be zero in-row. The ``autoscale_*`` and
``attach_bs*`` points/sec rows are what the CI perf gate
(``benchmarks/compare.py``) compares against the committed baseline."""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session

_PLANE_DEVICES = 8

# Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by
# the parent): single-host baseline vs the serve plane sharded over all
# devices, sync vs async refresh, same request stream throughout.
_PLANE_CHILD = r"""
import time
import jax
import numpy as np
from repro.utils.compat import make_mesh
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session

B, n, requests, passes = {B}, {n}, {requests}, {passes}
k, kp, d = 16, 4, 24
fm = structured_devices(jax.random.PRNGKey(0), k=k, d=d, k_prime=kp,
                        m0=4, n_per_comp_dev=25, sep=60.0)
rr = Session(FederationPlan(k=k, k_prime=kp, d=d)).run(
    jax.random.PRNGKey(1), fm.data).detail
mesh = make_mesh((jax.device_count(),), ("data",))

def reqs(seed):
    # Heterogeneous k^(z) in [1, k'] — the paper's workload. The spread
    # in per-request convergence is exactly what batch-axis sharding
    # exploits: a vmapped solve iterates until the slowest request in
    # the WHOLE batch converges, a shard only until its own slice does.
    s = late_device_stream(fm.means, kp, requests, seed,
                           n_range=(n, n + 1))
    return [r[0] for r in s], [r[2] for r in s]

S = jax.device_count()
sessions = []
for name, serve_axes, refresh, every in (
        ("shards1_sync", None, "sync", 0),
        ("shards%d_sync" % S, ("data",), "sync", 0),
        ("shards%d_refresh_sync" % S, ("data",), "sync", B),
        ("shards%d_refresh_async" % S, ("data",), "async", B)):
    plan = FederationPlan(k=k, k_prime=kp, d=d, capacity=1024,
                          batch_size=B, bucket_sizes=(n,),
                          refresh_every=every, refresh=refresh,
                          serve_axes=serve_axes)
    sess = Session.from_round(plan, rr, mesh=mesh if serve_axes else None)
    wd, wkv = reqs(99)
    sess.serve(wd[:B], wkv[:B])                    # compile warmup
    sessions.append([name, sess, float("inf")])
batch, kvs = reqs(7)
# Interleave timing passes across configs (best-of) so machine drift
# lands on every config equally instead of biasing whichever ran last.
for _ in range(passes):
    for rec in sessions:
        t0 = time.perf_counter()
        rec[1].serve(batch, kvs)
        # a staged async re-finalization may still be in flight; block
        # on both tau buffers so every mode pays its full cost.
        jax.block_until_ready(rec[1].service._taubuf.bufs)
        rec[2] = min(rec[2], time.perf_counter() - t0)
pts = {{}}
for name, sess, best in sessions:
    pts[name] = requests * n / best
    print("ROW plane_%s,%.3f,dev_per_s=%.1f;pts_per_s=%.0f;version=%d"
          % (name, best / requests * 1e6, requests / best, pts[name],
             sess.tau_version))
base = pts["shards1_sync"]
for name, v in pts.items():
    if name != "shards1_sync":
        print("ROW plane_speedup_%s,0,x_vs_single_shard=%.2f"
              % (name, v / base))
"""


# Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8: the
# load-shape sweep. Each flush submits `depth` requests then flushes —
# ramp (1 -> 64 doubling), burst (alternating 64/1), and trickle (all
# singletons) — against the controller and both static extremes on the
# same request pool. pts_per_s counts REAL points only, so padding
# waste shows up as lost throughput.
_AUTOSCALE_CHILD = r"""
import time
import jax
import numpy as np
from repro.utils.compat import make_mesh
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session

n, passes = {n}, {passes}
k, kp, d = 16, 4, 24
fm = structured_devices(jax.random.PRNGKey(0), k=k, d=d, k_prime=kp,
                        m0=4, n_per_comp_dev=25, sep=60.0)
rr = Session(FederationPlan(k=k, k_prime=kp, d=d)).run(
    jax.random.PRNGKey(1), fm.data).detail
mesh = make_mesh((jax.device_count(),), ("data",))

SHAPES = {{
    "ramp": [1, 2, 4, 8, 16, 32, 64],
    "burst": [64, 1, 64, 1, 64, 1],
    "trickle": [1] * 12,
}}
CONFIGS = (
    ("static_b8", dict(batch_size=8)),
    ("static_b64", dict(batch_size=64)),
    ("auto_latency", dict(batch_size=64, autoscale="latency")),
    ("auto_throughput", dict(batch_size=64, autoscale="throughput")),
)
stream = late_device_stream(fm.means, kp, 256, 7, n_range=(n, n + 1))
pool = [(r[0], r[2]) for r in stream]

def run_shape(sess, depths):
    i = 0
    t0 = time.perf_counter()
    for q in depths:
        for _ in range(q):
            data, kv = pool[i % len(pool)]
            sess.submit(data, kv)
            i += 1
        sess.flush()
    return time.perf_counter() - t0, i

pts = {{}}
for name, kw in CONFIGS:
    plan = FederationPlan(k=k, k_prime=kp, d=d, capacity=65536,
                          bucket_sizes=(n,), serve_axes=("data",), **kw)
    sess = Session.from_round(plan, rr, mesh=mesh)
    for depths in SHAPES.values():                  # compile warmup
        run_shape(sess, depths)
    warm = sess.stats()["plane_compiles"]
    for shape, depths in SHAPES.items():
        best, reqs = min((run_shape(sess, depths) for _ in range(passes)),
                         key=lambda r: r[0])
        key = (shape, name)
        pts[key] = reqs * n / best
        steady = sess.stats()["plane_compiles"] - warm
        print("ROW autoscale_%s_%s,%.3f,pts_per_s=%.0f;dev_per_s=%.1f;"
              "steady_recompiles=%d"
              % (shape, name, best / reqs * 1e6, pts[key], reqs / best,
                 steady))
        assert steady == 0, (name, shape, steady)
for shape in SHAPES:
    best_static = max(pts[(shape, "static_b8")], pts[(shape, "static_b64")])
    print("ROW autoscale_%s_margin,0,auto_latency_vs_best_static=%.2f"
          % (shape, pts[(shape, "auto_latency")] / best_static))
"""


def _forced_device_child(src: str, timeout: int):
    """Run a bench child under XLA_FLAGS forced host devices (the flag
    must precede jax backend init, hence the subprocess)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{_PLANE_DEVICES}")
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-c", src], env=env,
                          capture_output=True, text=True, timeout=timeout)


def _autoscale_rows(full: bool):
    """The §12 controller vs the static extremes, per load shape."""
    n, passes = (256, 3) if full else (128, 2)
    out = _forced_device_child(
        _AUTOSCALE_CHILD.format(n=n, passes=passes), timeout=1800)
    if out.returncode != 0:
        return [row("autoscale_sweep", 0, f"ERROR:{out.stderr[-200:]!r}")]
    return [line[4:] for line in out.stdout.splitlines()
            if line.startswith("ROW ")]


def _plane_rows(full: bool):
    """The static serve-plane sweep (shard count x refresh mode)."""
    B, n, requests, passes = ((64, 256, 256, 5) if full
                              else (64, 256, 128, 3))
    out = _forced_device_child(
        _PLANE_CHILD.format(B=B, n=n, requests=requests, passes=passes),
        timeout=1800)
    if out.returncode != 0:
        return [row("plane_sweep", 0,
                    f"ERROR:{out.stderr[-200:]!r}")]
    return [line[4:] for line in out.stdout.splitlines()
            if line.startswith("ROW ")]


def _stream(means, k_prime, requests, n, seed):
    """Fixed-shape requests (one bucket) so the sweep times pure serve."""
    return [r[0] for r in late_device_stream(
        means, k_prime, requests, seed, n_range=(n, n + 1),
        kv_min=k_prime)]


def run(full: bool = False):
    k, kp, d = 16, 4, 24
    n = 128 if full else 64
    requests = 32 if full else 8
    batch_sizes = (1, 8, 32) if full else (1, 8)

    fm = structured_devices(jax.random.PRNGKey(0), k=k, d=d, k_prime=kp,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    # ONE round shared across every streaming plan in the sweep.
    rr = Session(FederationPlan(k=k, k_prime=kp, d=d)).run(
        jax.random.PRNGKey(1), fm.data).detail

    def session(B):
        plan = FederationPlan(k=k, k_prime=kp, d=d, capacity=4096,
                              batch_size=B, bucket_sizes=(n,))
        return Session.from_round(plan, rr)

    rows = []
    for B in batch_sizes:
        sess = session(B)
        sess.serve(_stream(fm.means, kp, B, n, seed=99))  # compile warmup
        reqs = _stream(fm.means, kp, requests, n, seed=7)
        t0 = time.perf_counter()
        sess.serve(reqs)
        dt = time.perf_counter() - t0
        pts = requests * n
        rows.append(row(f"attach_bs{B}_n{n}", dt / requests * 1e6,
                        f"dev_per_s={requests / dt:.1f};"
                        f"pts_per_s={pts / dt:.0f}"))

    # Crash recovery: checkpoint mid-stream, restore, serve the rest —
    # must be bitwise identical to the uninterrupted session.
    live = session(batch_sizes[-1])
    reqs = _stream(fm.means, kp, requests, n, seed=11)
    half = len(reqs) // 2
    live.serve(reqs[:half])
    path = os.path.join(tempfile.mkdtemp(), "attach_ck.npz")
    t0 = time.perf_counter()
    live.save(path)
    restored = Session.restore(path, live.plan)
    us_ck = (time.perf_counter() - t0) * 1e6
    same = all(np.array_equal(a, b)
               for a, b in zip(live.serve(reqs[half:]),
                               restored.serve(reqs[half:])))
    rows.append(row("attach_ckpt_roundtrip", us_ck, f"bitwise={same}"))

    rows.extend(_plane_rows(full))
    rows.extend(_autoscale_rows(full))
    return rows
