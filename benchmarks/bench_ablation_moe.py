"""Ablation (beyond-paper): MoE dispatch capacity factor vs dropped-token
fraction and layer output error, on the reduced mixtral config. Fixed
routing; only the queue capacity varies. Informs the production
capacity_factor=1.25 choice (≤2% drops at balanced load, graceful under
skew)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs.base import MoEConfig
from repro.models import moe as MoE


def run(full: bool = False):
    rows = []
    T, d, dff, E, k = (4096, 64, 128, 8, 2) if full else (1024, 32, 64, 8, 2)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    p = {"router": jax.random.normal(ks[0], (d, E), jnp.float32) * 0.4,
         "w1": jax.random.normal(ks[1], (E, d, dff), jnp.float32) * 0.2,
         "w3": jax.random.normal(ks[2], (E, d, dff), jnp.float32) * 0.2,
         "w2": jax.random.normal(ks[3], (E, dff, d), jnp.float32) * 0.2}
    # skewed tokens: half the batch biased toward two experts
    x = jax.random.normal(ks[4], (T, d), jnp.float32)
    bias_dir = p["router"][:, 0] + p["router"][:, 1]
    x = x.at[: T // 2].add(0.8 * bias_dir[None, :])

    m_ref = MoEConfig(n_experts=E, top_k=k, d_expert=dff,
                      capacity_factor=64.0, impl="dense")
    y_ref, _ = MoE._local_moe(p, x, m_ref)   # effectively dropless
    y_ref = np.asarray(y_ref)

    for cf in (0.5, 0.75, 1.0, 1.25, 1.5, 2.0):
        m = MoEConfig(n_experts=E, top_k=k, d_expert=dff,
                      capacity_factor=cf, impl="dense")
        t0 = time.perf_counter()
        ids, _, _ = MoE._route(p["router"], x, m)
        C = MoE._capacity(T, m)
        _, _, _, keep = MoE._pack(x, ids, m, C)
        y, _ = MoE._local_moe(p, x, m)
        us = (time.perf_counter() - t0) * 1e6
        dropped = 1.0 - float(np.asarray(keep).mean())
        err = float(np.linalg.norm(np.asarray(y) - y_ref) /
                    max(np.linalg.norm(y_ref), 1e-9))
        rows.append(row(f"moe_cf{cf}", us,
                        f"dropped_frac={dropped:.4f};rel_err={err:.4f}"))
    return rows
