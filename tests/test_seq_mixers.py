"""Numerical equivalence of the chunked-parallel sequence mixers against
their exact step-recurrence oracles (RWKV6 + Mamba2/SSD), across shapes —
this is what makes train (chunked) and decode (scan) consistent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba as M
from repro.models import rwkv as R


@pytest.mark.parametrize("B,S,H,dh,chunk", [(2, 64, 2, 8, 16),
                                            (1, 96, 4, 16, 32),
                                            (3, 32, 1, 4, 8)])
def test_rwkv6_chunked_matches_scan(B, S, H, dh, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 6)
    r = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dh)) * 0.5)
    logw = jnp.clip(logw, -4.0, -1e-4)
    u = jax.random.normal(ks[4], (H, dh)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, dh, dh)) * 0.1

    o1, st1 = R.rwkv6_scan(r, k, v, logw, u, s0)
    o2, st2 = R.rwkv6_chunked(r, k, v, logw, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [(2, 64, 2, 8, 4, 16),
                                             (1, 96, 3, 4, 8, 32)])
def test_ssd_chunked_matches_scan(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(S + N), 6)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    Bv = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cv = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    loga = jnp.clip(-jnp.exp(jax.random.normal(ks[4], (B, S, H)) * 0.3) *
                    dt, -8.0, -1e-6)
    D = jnp.ones((H,)) * 0.5
    h0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.1

    y1, hf1 = M.ssd_scan(xh, Bv, Cv, dt, loga, D, h0)
    y2, hf2 = M.ssd_chunked(xh, Bv, Cv, dt, loga, D, h0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf2),
                               rtol=2e-3, atol=2e-3)


def test_rwkv6_decode_consistency():
    """Running the scan one token at a time == running it over the full
    sequence (the decode path invariant)."""
    B, S, H, dh = 1, 12, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (B, S, H, dh))),
                    -4.0, -1e-4)
    u = jax.random.normal(ks[4], (H, dh)) * 0.3
    s0 = jnp.zeros((B, H, dh, dh))
    full, sf = R.rwkv6_scan(r, k, v, logw, u, s0)
    s = s0
    outs = []
    for t in range(S):
        o, s = R.rwkv6_scan(r[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1],
                            logw[:, t:t + 1], u, s)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(s),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_matches_naive():
    """Chunked flash path == naive softmax attention (causal + windowed)."""
    from repro.models.attention import flash_attention
    B, S, H, KVH, D = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))

    def naive(q, k, v, window):
        g = H // KVH
        qg = q.reshape(B, S, KVH, g, D) / np.sqrt(D)
        s = jnp.einsum("bqhgd,bjhd->bqhgj", qg, k)
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        allow = j <= i
        if window:
            allow = allow & (j > i - window)
        s = jnp.where(allow[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bqhgj,bjhd->bqhgd", p, v).reshape(B, S, H, D)

    for window in (None, 24):
        got = flash_attention(q, k, v, causal=True, window=window,
                              cq=16, ck=16)
        want = naive(q, k, v, window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
