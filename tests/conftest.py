import jax
import pytest

# Tests run on the single real CPU device; the 512-device forced host
# platform is confined to launch/dryrun.py (see the system design notes).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
