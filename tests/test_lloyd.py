"""Unit tests for the masked k-means primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lloyd as L
from repro.kernels import ref


def _blobs(key, k=4, d=8, n_per=50, sep=30.0):
    km, kn = jax.random.split(key)
    means = jax.random.normal(km, (k, d)) * sep
    labels = jnp.repeat(jnp.arange(k), n_per)
    x = means[labels] + jax.random.normal(kn, (k * n_per, d))
    return x, labels, means


def test_assign_points_matches_bruteforce(rng_key):
    x = jax.random.normal(rng_key, (40, 5))
    c = jax.random.normal(jax.random.PRNGKey(1), (7, 5))
    idx, mind = L.assign_points(x, c)
    d = np.asarray(ref.pairwise_sq_dists(x, c))
    np.testing.assert_array_equal(np.asarray(idx), d.argmin(1))
    np.testing.assert_allclose(np.asarray(mind), d.min(1), rtol=1e-5,
                               atol=1e-5)


def test_assign_points_respects_center_mask(rng_key):
    x = jax.random.normal(rng_key, (20, 3))
    c = jnp.stack([x[0] + 1e-3, x[0] + 100.0, x[0]])
    cm = jnp.array([True, True, False])  # nearest center masked out
    idx, _ = L.assign_points(x[:1], c, center_mask=cm)
    assert int(idx[0]) == 0


def test_assign_points_masks_points(rng_key):
    x = jax.random.normal(rng_key, (10, 3))
    c = jax.random.normal(jax.random.PRNGKey(2), (2, 3))
    pm = jnp.arange(10) < 6
    idx, mind = L.assign_points(x, c, point_mask=pm)
    assert np.all(np.asarray(idx[6:]) == -1)
    assert np.all(np.asarray(mind[6:]) == 0.0)


def test_update_centers_empty_cluster_keeps_old(rng_key):
    x = jax.random.normal(rng_key, (12, 4))
    assign = jnp.zeros((12,), jnp.int32)  # everything to cluster 0
    old = jnp.full((3, 4), 7.0)
    new, cnt = L.update_centers(x, assign, 3, old)
    np.testing.assert_allclose(np.asarray(new[0]), np.asarray(x.mean(0)),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new[1:]), 7.0)
    assert cnt[0] == 12 and cnt[1] == 0


def test_lloyd_recovers_separated_blobs(rng_key):
    x, labels, means = _blobs(rng_key)
    init, cm = L.kmeans_pp_init(jax.random.PRNGKey(3), x, 4)
    res = L.lloyd(x, init, center_mask=cm)
    assert bool(res.converged)
    # Every recovered center is near a true mean.
    d = np.sqrt(np.asarray(ref.pairwise_sq_dists(res.centers, means)))
    assert d.min(axis=1).max() < 1.0


def test_lloyd_cost_monotone(rng_key):
    x = jax.random.normal(rng_key, (200, 6))
    init, cm = L.kmeans_pp_init(jax.random.PRNGKey(5), x, 5)
    c_prev = init
    prev_cost = float(L.kmeans_cost(x, c_prev, cm))
    for _ in range(5):
        res = L.lloyd(x, c_prev, center_mask=cm, max_iters=1)
        cost = float(L.kmeans_cost(x, res.centers, cm))
        assert cost <= prev_cost + 1e-3
        prev_cost, c_prev = cost, res.centers


def test_kmeans_pp_k_valid(rng_key):
    x = jax.random.normal(rng_key, (50, 4))
    centers, cm = L.kmeans_pp_init(rng_key, x, 8, k_valid=jnp.int32(3))
    assert np.asarray(cm).sum() == 3
    np.testing.assert_allclose(np.asarray(centers[3:]), 0.0)


def test_maxmin_seed_picks_one_per_cluster(rng_key):
    x, labels, _ = _blobs(rng_key, k=6, sep=50.0)
    # Seed with a point of cluster 0 selected.
    init_sel = jnp.zeros((x.shape[0],), bool).at[0].set(True)
    valid = jnp.ones((x.shape[0],), bool)
    chosen = L.maxmin_seed(x, valid, init_sel, 6)
    picked_clusters = np.asarray(labels)[np.asarray(chosen)]
    assert len(set(picked_clusters.tolist())) == 6


def test_maxmin_seed_respects_validity(rng_key):
    x, labels, _ = _blobs(rng_key, k=4, sep=50.0)
    valid = labels != 3  # cluster 3 points are padding
    init_sel = jnp.zeros((x.shape[0],), bool).at[0].set(True)
    chosen = L.maxmin_seed(x, valid, init_sel, 3)
    assert not np.any(np.asarray(labels)[np.asarray(chosen)] == 3)
