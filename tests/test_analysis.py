"""repro.analysis — the §15 static-analysis gate's own tests.

Three layers:

  * fabricated-jaxpr unit tests: one positive and one negative program
    per determinism rule, traced with ``jax.make_jaxpr`` so the rules
    are exercised against REAL jaxprs, not mocks;
  * seeded mutations (acceptance criteria): a fold-like function with a
    second scatter must trip the single-scatter invariant, and an
    oversized fabricated block plan must trip ``vmem-overflow``;
  * the real tree: the full gate over the repo must be clean, the fold
    artifacts must carry exactly one scatter per state leaf on the
    single-host AND (on mesh CI legs) the shard_mapped path, and the
    solve_attach footprint must match hand-computed bytes at both
    ladder extremes.

Mesh-matrix legs (2 and 8 forced devices) run the sharded audit in
process; the tier-1 leg covers it via a forced-device subprocess child
(the test_plane.py idiom).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import determinism, imports, kernels, lint, visitor
from repro.analysis.visitor import Finding

NDEV = jax.device_count()


def _audit(fn, *args, contract=None, name="t"):
    return determinism.audit_jaxpr(jax.make_jaxpr(fn)(*args), name,
                                   contract or determinism.Contract())


def _rules(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------- determinism: rules ------


class TestDeterminismRules:
    def test_float_scatter_add_flagged(self):
        def f(x, idx):
            return jnp.zeros((8,), jnp.float32).at[idx].add(x)
        fs = _audit(f, jnp.ones((4,), jnp.float32),
                    jnp.zeros((4,), jnp.int32))
        assert "float-scatter-add" in _rules(fs)

    def test_int_scatter_add_clean(self):
        def f(x, idx):
            return jnp.zeros((8,), jnp.int32).at[idx].add(x)
        fs = _audit(f, jnp.ones((4,), jnp.int32),
                    jnp.zeros((4,), jnp.int32))
        assert "float-scatter-add" not in _rules(fs)

    def test_iota_indexed_scatter_add_clean(self):
        # statically-unique indices: a pure iota never collides
        def f(x):
            idx = jax.lax.iota(jnp.int32, 4)
            return jnp.zeros((8,), jnp.float32).at[idx].add(x)
        assert _audit(f, jnp.ones((4,), jnp.float32)) == []

    def test_overwrite_scatter_clean(self):
        def f(x, idx):
            return jnp.zeros((8,), jnp.float32).at[idx].set(x)
        fs = _audit(f, jnp.ones((4,), jnp.float32),
                    jnp.zeros((4,), jnp.int32))
        assert "float-scatter-add" not in _rules(fs)

    def test_implicit_rng_flagged(self):
        def f(x):
            return x + jax.lax.rng_uniform(0.0, 1.0, (4,))
        assert "implicit-rng" in _rules(_audit(f, jnp.ones((4,))))

    def test_unthreaded_key_flagged(self):
        # PRNGKey(0) inside the trace: the seed reaches no invar
        def f(x):
            return x + jax.random.uniform(jax.random.PRNGKey(0), (4,))
        assert "rng-unthreaded-key" in _rules(_audit(f, jnp.ones((4,))))

    def test_threaded_key_clean(self):
        def f(key, x):
            return x + jax.random.uniform(key, (4,))
        fs = _audit(f, jax.random.PRNGKey(0), jnp.ones((4,)))
        assert "rng-unthreaded-key" not in _rules(fs)
        assert "implicit-rng" not in _rules(fs)

    @pytest.mark.skipif(NDEV < 2, reason="needs >1 device")
    def test_float_psum_flagged_and_allowlisted(self):
        from repro.utils.compat import make_mesh, shard_map
        mesh = make_mesh((NDEV,), ("data",))
        fn = shard_map(
            lambda x: jax.lax.psum(x, "data"), mesh=mesh,
            in_specs=jax.sharding.PartitionSpec("data"),
            out_specs=jax.sharding.PartitionSpec())
        x = jnp.ones((NDEV, 4), jnp.float32)
        fs = _audit(fn, x)
        assert "unordered-collective" in _rules(fs)
        assert "contract-collective" in _rules(fs)
        # Allowlisting clears the contract rule and demotes the
        # FP-order finding to suppressed (visible, non-gating).
        ok = _audit(fn, x, contract=determinism.Contract(
            allow_collectives=frozenset({"psum"})))
        assert "contract-collective" not in _rules(ok)
        assert all(f.suppressed for f in ok
                   if f.rule == "unordered-collective")


# --------------------------------- determinism: fold invariant -------


def _fold_like(extra_scatter):
    """A miniature fold: FILL_OR_DROP overwrite scatters into 2 state
    leaves, indexed by the same data-derived slot vector."""
    def f(centers, mass, slots, new_c, new_m):
        centers = centers.at[slots].set(new_c, mode="drop")
        mass = mass.at[slots].set(new_m, mode="drop")
        if extra_scatter:
            mass = mass.at[slots].set(new_m * 2.0, mode="drop")
        return centers, mass
    return f


def _fold_args():
    return (jnp.zeros((8, 4), jnp.float32), jnp.zeros((8,), jnp.float32),
            jnp.zeros((3,), jnp.int32), jnp.ones((3, 4), jnp.float32),
            jnp.ones((3,), jnp.float32))


class TestFoldInvariant:
    def test_conforming_fold_clean(self):
        fs = _audit(_fold_like(False), *_fold_args(),
                    contract=determinism.Contract(fold_leaves=2))
        assert fs == []

    def test_seeded_second_scatter_caught(self):
        # acceptance criterion: a mutated fold with one extra scatter
        # must violate the structural count
        fs = _audit(_fold_like(True), *_fold_args(),
                    contract=determinism.Contract(fold_leaves=2))
        assert "fold-single-scatter" in _rules(fs)

    def test_accumulating_fold_caught(self):
        def f(mass, slots, w):
            return mass.at[slots].add(w, mode="drop")
        fs = _audit(f, jnp.zeros((8,), jnp.float32),
                    jnp.zeros((3,), jnp.int32), jnp.ones((3,)),
                    contract=determinism.Contract(fold_leaves=1))
        assert "fold-single-scatter" in _rules(fs)


# ----------------------------------------- determinism: real tree ----


class TestRealArtifacts:
    def test_gate_clean_on_tree(self):
        findings, audited, skipped = determinism.audit_all()
        assert [f for f in findings if not f.suppressed] == [], findings
        assert {"serve_step", "fold", "finalize",
                "split_retire"} <= set(audited)
        if NDEV > 1:
            assert "fold_sharded" in audited
        else:
            assert "fold_sharded" in skipped

    def test_fold_is_exactly_one_scatter_per_leaf(self):
        """The invariant stated structurally: the single-host fold
        jaxpr carries exactly len(ServerState) overwrite scatters and
        zero accumulating ones."""
        arts = {a.name: a
                for a in determinism.trace_artifacts(include_sharded=False)[0]}
        leaves = determinism.n_fold_leaves()
        names = [s.eqn.primitive.name
                 for s in visitor.iter_eqns(arts["fold"].closed_jaxpr)]
        assert names.count("scatter") == leaves
        assert not any(n in determinism.ACCUM_SCATTERS for n in names)

    @pytest.mark.skipif(NDEV < 2, reason="mesh CI legs (2 and 8 devices)")
    def test_sharded_fold_single_scatter_and_allgather_only(self):
        """Mesh-matrix acceptance: the shard_mapped fold is all_gather
        + the same per-leaf overwrite scatters — audited at whatever
        device count the CI leg forces (2 and 8)."""
        arts = {a.name: a
                for a in determinism.trace_artifacts(include_sharded=True)[0]}
        art = arts["fold_sharded"]
        assert determinism.audit_jaxpr(
            art.closed_jaxpr, art.name, art.contract) == []
        names = [s.eqn.primitive.name
                 for s in visitor.iter_eqns(art.closed_jaxpr)]
        assert names.count("scatter") == determinism.n_fold_leaves()
        assert not any(n in determinism.ACCUM_SCATTERS for n in names)

    def test_aggregate_sharded_no_scatter_add(self):
        """Regression for the fixed real finding: the one-shot sharded
        aggregation (M0 seeding) no longer accumulates via scatter."""
        pytest.importorskip("repro.core.distributed")
        from repro.core import server
        def agg(pts, mask):
            return server.aggregate(pts, mask, k=4)
        jaxpr = jax.make_jaxpr(agg)(
            jnp.zeros((2, 3, 5), jnp.float32), jnp.ones((2, 3), bool))
        names = [s.eqn.primitive.name for s in visitor.iter_eqns(jaxpr)]
        assert "scatter-add" not in names


# ------------------------------------------------- kernels pass ------


class TestKernelChecker:
    def test_ladder_clean(self):
        findings, n_plans = kernels.audit_all()
        assert findings == []
        assert n_plans >= 20

    def test_solve_attach_footprint_ladder_extremes(self):
        """Hand-computed VMEM bytes at both ends of the rung ladder
        (B=8 grid row; padded shapes; x2 streaming double-buffer,
        tau resident x1)."""
        from repro.kernels import solve_attach
        for n, d, kp, k in ((64, 64, 4, 16), (1024, 512, 8, 128)):
            plan = solve_attach.block_plan(8, n, d, kp, k, dtype="f32")
            npad = ((n + 7) // 8) * 8
            dpad = ((d + 127) // 128) * 128
            kppad = ((kp + 127) // 128) * 128
            kpad = ((k + 127) // 128) * 128
            expect = (
                2 * (npad * dpad            # x block
                     + kppad * dpad         # theta0
                     + kppad + npad         # center_mask + point_mask
                     + npad + npad          # labels + min_dists
                     + kppad * dpad + kppad)  # centers + center_labels
                * 4
                + kpad * dpad * 4)          # tau: resident, single
            assert kernels.footprint_bytes(plan) == expect, (n, d)

    def test_seeded_oversized_plan_caught(self):
        # acceptance criterion: a fabricated plan past the budget
        plan = {"kernel": "fab", "grid": (1,), "storage": "f32",
                "accum": "f32",
                "blocks": [{"name": "x", "shape": (4096, 1024),
                            "dtype": "f32", "kind": "in",
                            "array_shape": (4096, 1024)}]}
        hw = {"vmem_bytes": 16 * 2 ** 20}
        assert _rules(kernels.check_plan(plan, hw)) == ["vmem-overflow"]

    def test_lane_and_sublane_lint(self):
        hw = {"vmem_bytes": 1 << 40}
        bad = {"kernel": "fab", "grid": (2, 2), "storage": "f32",
               "accum": "f32",
               "blocks": [{"name": "x", "shape": (4, 100), "dtype": "f32",
                           "kind": "in", "array_shape": (64, 1000)}]}
        assert _rules(kernels.check_plan(bad, hw)) == [
            "lane-misaligned", "sublane-misaligned"]
        # unpartitioned dims only pad — no findings
        ok = dict(bad, blocks=[dict(bad["blocks"][0],
                                    array_shape=(4, 100))])
        assert kernels.check_plan(ok, hw) == []
        # extent-1 sublane windows are the DMA gather granule
        granule = dict(bad, blocks=[{"name": "x", "shape": (1, 128),
                                     "dtype": "f32", "kind": "in",
                                     "array_shape": (64, 128)}])
        assert kernels.check_plan(granule, hw) == []

    def test_bf16_accum_rule(self):
        hw = {"vmem_bytes": 1 << 40}
        plan = {"kernel": "fab", "grid": (1,), "storage": "bf16",
                "accum": "bf16", "blocks": []}
        assert _rules(kernels.check_plan(plan, hw)) == ["bf16-accum"]
        plan["accum"] = "f32"
        assert kernels.check_plan(plan, hw) == []


# ---------------------------------------------------- lint pass ------


class TestLint:
    def test_tracer_branch_pos_neg(self):
        pos = ("import jax.numpy as jnp\n"
               "def f(x):\n"
               "    y = jnp.sum(x)\n"
               "    if y > 0:\n"
               "        return 1\n")
        assert _rules(lint.scan_source(pos, "t.py")) == ["tracer-branch"]
        neg = ("import jax.numpy as jnp\n"
               "def f(x, flag):\n"
               "    y = jnp.sum(x)\n"
               "    if x is not None and flag:\n"
               "        return int(x.shape[0])\n")
        assert lint.scan_source(neg, "t.py") == []

    def test_tracer_coercion_and_materializer(self):
        pos = ("import jax.numpy as jnp\n"
               "def f(x):\n"
               "    return float(jnp.mean(x))\n")
        assert _rules(lint.scan_source(pos, "t.py")) == ["tracer-coercion"]
        neg = ("import numpy as np\nimport jax.numpy as jnp\n"
               "def f(x):\n"
               "    return float(np.asarray(jnp.mean(x)))\n")
        assert lint.scan_source(neg, "t.py") == []

    def test_suppression_comment(self):
        src = ("import jax.numpy as jnp\n"
               "def f(x):\n"
               "    y = jnp.sum(x)\n"
               "    if y > 0:  # repro: allow(tracer-branch)\n"
               "        return 1\n")
        (f,) = lint.scan_source(src, "t.py")
        assert f.suppressed
        # a different rule name does NOT suppress
        src2 = src.replace("allow(tracer-branch)", "allow(tracer-coercion)")
        (f2,) = lint.scan_source(src2, "t.py")
        assert not f2.suppressed

    def test_static_unhashable(self):
        src = ("import jax\n"
               "@jax.jit(static_argnames=('opts',))\n"
               "def f(x, opts=[1, 2]):\n"
               "    return x\n")
        assert _rules(lint.scan_source(src, "t.py")) == ["static-unhashable"]

    def test_checkpoint_bypass(self):
        src = ("import numpy as np\n"
               "def f(x):\n"
               "    np.savez('out.npz', x=x)\n")
        assert _rules(lint.scan_source(src, "t.py")) == ["checkpoint-bypass"]
        assert lint.scan_source(src, "repro/checkpoint/store.py") == []

    def test_tree_clean(self):
        findings, n = lint.audit_all()
        assert n > 50
        assert [f for f in findings if not f.suppressed] == []


# ------------------------------------------------- imports pass ------


class TestImports:
    def test_report_shape(self):
        rep = imports.report()
        assert rep["modules"] > 50
        # the live serve scaffold stays reachable...
        assert "repro.models.model" in rep["reachable"]
        # ...and every unreachable candidate is zoo-only, never core
        assert all(m.startswith(("repro.models.", "repro.configs."))
                   for m in rep["unreachable"])
        assert imports.render(rep)


# ------------------------------------------------------- the CLI -----


def _run_cli(*argv, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.update(env_extra or {})
    return subprocess.run([sys.executable, "-m", "repro.analysis", *argv],
                          env=env, capture_output=True, text=True,
                          timeout=900)


@pytest.mark.slow
def test_cli_unknown_pass_exits_2():
    out = _run_cli("--only", "nosuchpass")
    assert out.returncode == 2
    assert "valid passes:" in out.stderr
    assert "determinism" in out.stderr


@pytest.mark.slow
def test_cli_json_gate_clean(tmp_path):
    """The CI invocation: --all --json must exit 0 on this tree with a
    parseable report, including sharded artifacts when forced devices
    are available (the tier-1 leg's mesh coverage)."""
    out = _run_cli("--all", "--json", env_extra={
        "XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert out.returncode == 0, out.stderr[-4000:]
    payload = json.loads(out.stdout)
    assert payload["ok"] is True
    passes = payload["passes"]
    assert passes["determinism"]["gated"] is True
    assert "fold_sharded" in passes["determinism"]["audited"]
    assert passes["imports"]["gated"] is False
    assert passes["kernels"]["plans"] >= 20


def test_finding_serialization():
    f = Finding("lint", "tracer-branch", "x.py:3", "msg", suppressed=True)
    d = f.to_dict()
    assert d == {"pass": "lint", "rule": "tracer-branch", "where": "x.py:3",
                 "message": "msg", "suppressed": True}
    assert "tracer-branch" in str(f) and "(suppressed)" in str(f)
