"""Per-architecture smoke tests: REDUCED variant of every assigned config
(<=2 layers, d_model<=512, <=4 experts) runs one forward/train step and one
prefill+decode step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import InputShape
from repro.configs.shapes import dummy_inputs
from repro.models import DistCtx, build_model
from repro.utils.tree import check_finite, param_count

ARCHS = list_archs()
SMOKE_TRAIN = InputShape("smoke_train", 128, 2, "train")
SMOKE_DECODE = InputShape("smoke_decode", 64, 2, "decode")
CTX = DistCtx.local()


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_is_reduced(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    full = get_config(arch)
    assert full.family == cfg.family and full.cite


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss(arch, built):
    cfg, model, params = built[arch]
    assert param_count(params) > 0
    batch = dummy_inputs(jax.random.PRNGKey(1), cfg, SMOKE_TRAIN)
    loss, metrics = jax.jit(
        lambda p, b: model.loss(p, b, CTX))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, built):
    cfg, model, params = built[arch]
    batch = dummy_inputs(jax.random.PRNGKey(2), cfg, SMOKE_TRAIN)

    @jax.jit
    def step(p, b):
        g = jax.grad(lambda p: model.loss(p, b, CTX)[0])(p)
        return jax.tree.map(lambda w, gw: w - 1e-3 * gw.astype(w.dtype),
                            p, g)

    new_params = step(params, batch)
    assert bool(check_finite(new_params)), arch
    # Something actually moved.
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode(arch, built):
    cfg, model, params = built[arch]
    B, S = SMOKE_DECODE.global_batch, SMOKE_DECODE.seq_len
    pre_shape = InputShape("p", S, B, "prefill")
    batch = dummy_inputs(jax.random.PRNGKey(3), cfg, pre_shape,
                         with_labels=False)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, CTX))(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: model.serve_step(p, c, t, CTX))(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch
    assert int(cache2["len"][0]) == int(cache["len"][0]) + 1


# --------------------------------------------------------------------------
# §17 ingestion-encoder path: every zoo config must resolve (reduced
# mode) and forward raw token sequences to finite (…, d) f32 embeddings
# at both encode dtypes. d=48 divides every reduced config's n_heads
# ({4, 6, 8} across the zoo).
# --------------------------------------------------------------------------

ENC_D = 48


@pytest.mark.parametrize("arch", ARCHS)
def test_encoder_spec_resolves_every_arch(arch):
    from repro.models.encoder import resolve_encoder_spec
    cfg = get_config(arch, reduced=True)
    spec = resolve_encoder_spec(arch, ENC_D)
    assert spec.d == ENC_D and spec.d_ff >= ENC_D
    assert 1 <= spec.n_layers <= 2
    assert ENC_D % spec.n_heads == 0
    assert spec.activation == cfg.activation


@pytest.mark.parametrize("arch", ARCHS)
def test_encoder_forward_every_arch(arch):
    from repro.models.encoder import (apply_encoder, init_encoder,
                                      resolve_encoder_spec)
    spec = resolve_encoder_spec(arch, ENC_D)
    params = init_encoder(jax.random.PRNGKey(5), spec)
    B, n, S = 3, 5, 12
    x = jax.random.normal(jax.random.PRNGKey(6), (B, n, S, ENC_D))
    tmask = np.zeros((B, n, S), bool)
    tmask[:, :, :7] = True
    tmask[0, 4] = False           # one item with zero valid tokens
    for dt in ("f32", "bf16"):
        y = apply_encoder(params, x, jnp.asarray(tmask), spec,
                          encode_dtype=dt)
        assert y.shape == (B, n, ENC_D), (arch, dt)
        assert y.dtype == jnp.float32, (arch, dt)
        assert np.all(np.isfinite(np.asarray(y))), (arch, dt)
        # the all-masked item embeds to exactly zero
        assert float(np.abs(np.asarray(y)[0, 4]).max()) == 0.0


def test_encoder_rejects_indivisible_heads():
    from repro.models.encoder import (EncoderConfigError,
                                      resolve_encoder_spec)
    # nemotron's reduced n_heads=6 does not divide d=32
    with pytest.raises(EncoderConfigError, match="n_heads"):
        resolve_encoder_spec("nemotron-4-15b", 32)
    with pytest.raises(EncoderConfigError, match="accepted values"):
        resolve_encoder_spec("not-a-config", 32)


@pytest.mark.parametrize("arch", ARCHS)
def test_init_cache_matches_prefill_cache_structure(arch, built):
    cfg, model, params = built[arch]
    B, S = 2, 32
    # For enc-dec the decoder consumes S - n_ctx tokens at prefill.
    S_cache = S - cfg.encoder.n_ctx if cfg.family == "encdec" else S
    fresh = model.init_cache(B, S_cache)
    batch = dummy_inputs(jax.random.PRNGKey(4), cfg,
                         InputShape("p", S, B, "prefill"), with_labels=False)
    # decode_room defaults to 1 → prefill cache has room S+1, same as
    # init_cache(B, S).
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, CTX))(params, batch)
    fs = jax.tree.structure(fresh)
    cs = jax.tree.structure(cache)
    assert fs == cs, (arch, fs, cs)
    for a, b in zip(jax.tree.leaves(fresh), jax.tree.leaves(cache)):
        assert a.shape == b.shape, (arch, a.shape, b.shape)
