"""MoE dispatch correctness: the sort/gather-based fixed-capacity pack
must reproduce the naive per-token top-k reference exactly when no token
drops (capacity_factor large), and degrade only by dropping overflow
tokens when capacity binds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe as MoE


def _naive_moe(p, x2d, m):
    """Every token through its top-k experts, no capacity limit."""
    ids, gates, _ = MoE._route(p["router"], x2d, m)
    T, d = x2d.shape
    y = np.zeros((T, d), np.float32)
    w1, w3, w2 = (np.asarray(p[k], np.float32) for k in ("w1", "w3", "w2"))
    xf = np.asarray(x2d, np.float32)
    ids = np.asarray(ids)
    gates = np.asarray(gates)
    for t in range(T):
        for j in range(m.top_k):
            e = ids[t, j]
            # match _expert_ffn compute dtype (bf16 weights in prod; f32
            # here since the test builds f32 params)
            h = (np.maximum(xf[t] @ w1[e], 0) /
                 (1 + np.exp(-np.clip(xf[t] @ w1[e], -30, 30))))
            h = (xf[t] @ w1[e]) * (1 / (1 + np.exp(-np.clip(
                xf[t] @ w1[e], -30, 30)))) * (xf[t] @ w3[e])
            y[t] += gates[t, j] * (h @ w2[e])
    return y


def _mk(key, T=48, d=16, E=4, k=2, dff=24, cf=8.0):
    m = MoEConfig(n_experts=E, top_k=k, d_expert=dff,
                  capacity_factor=cf, impl="dense")
    ks = jax.random.split(key, 5)
    p = {"router": jax.random.normal(ks[0], (d, E), jnp.float32) * 0.5,
         "w1": jax.random.normal(ks[1], (E, d, dff), jnp.float32) * 0.2,
         "w3": jax.random.normal(ks[2], (E, d, dff), jnp.float32) * 0.2,
         "w2": jax.random.normal(ks[3], (E, dff, d), jnp.float32) * 0.2}
    x = jax.random.normal(ks[4], (T, d), jnp.float32)
    return p, x, m


def test_local_moe_matches_naive_no_drop():
    p, x, m = _mk(jax.random.PRNGKey(0))
    y, _ = MoE._local_moe(p, x, m)
    ref = _naive_moe(p, x, m)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_pack_places_every_kept_entry_once():
    p, x, m = _mk(jax.random.PRNGKey(1), T=64, E=4, k=2, cf=8.0)
    ids, gates, _ = MoE._route(p["router"], x, m)
    C = MoE._capacity(x.shape[0], m)
    buf, flat_e, pos_c, keep = MoE._pack(x, ids, m, C)
    assert bool(keep.all())      # cf=8 => nothing drops
    # every (token, choice) entry is present at its (expert, pos) slot
    for n in range(flat_e.shape[0]):
        t = n // m.top_k
        np.testing.assert_allclose(np.asarray(buf[flat_e[n], pos_c[n]]),
                                   np.asarray(x[t]), rtol=0, atol=0)


def test_capacity_drops_only_overflow():
    p, x, m = _mk(jax.random.PRNGKey(2), T=64, E=4, k=2, cf=0.5)
    ids, _, _ = MoE._route(p["router"], x, m)
    C = MoE._capacity(x.shape[0], m)
    buf, flat_e, pos_c, keep = MoE._pack(x, ids, m, C)
    kept = np.asarray(keep)
    fe = np.asarray(flat_e)
    for e in range(m.n_experts):
        assert kept[fe == e].sum() == min((fe == e).sum(), C)


def test_unpack_is_gate_weighted_identity():
    """With the identity 'expert', unpack returns sum_j gates_j * x = x
    (gates renormalize to 1)."""
    p, x, m = _mk(jax.random.PRNGKey(3), cf=8.0)
    ids, gates, _ = MoE._route(p["router"], x, m)
    C = MoE._capacity(x.shape[0], m)
    buf, flat_e, pos_c, keep = MoE._pack(x, ids, m, C)
    y = MoE._unpack(buf, flat_e, pos_c, keep, gates, x.shape[0], m.top_k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("E,k", [(4, 1), (4, 2), (8, 3)])
def test_grad_flows_and_finite(E, k):
    p, x, m = _mk(jax.random.PRNGKey(4), E=E, k=k, dff=16)
    def loss(p):
        y, aux = MoE._local_moe(p, x, m)
        return (y * y).mean() + aux
    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
