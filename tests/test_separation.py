"""Tests for the Section 3 analysis quantities."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import separation as S
from repro.data.gaussian import structured_devices


def test_spectral_norm_matches_svd(rng_key):
    M = jax.random.normal(rng_key, (40, 25))
    got = float(S.spectral_norm(M, iters=200))
    want = float(np.linalg.svd(np.asarray(M), compute_uv=False)[0])
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_a_minus_c_norm_zero_for_degenerate_clusters():
    A = jnp.concatenate([jnp.ones((10, 3)), -jnp.ones((10, 3))])
    lb = jnp.concatenate([jnp.zeros(10, jnp.int32), jnp.ones(10, jnp.int32)])
    assert float(S.a_minus_c_norm(A, lb, 2)) < 1e-4


def test_active_pairs():
    presence = jnp.array([[True, True, False],
                          [False, True, True]])
    act = np.asarray(S.active_pairs(presence))
    assert act[0, 1] and act[1, 2]
    assert not act[0, 2]
    assert not act.diagonal().any()


def test_separation_report_on_well_separated_mixture():
    fm = structured_devices(jax.random.PRNGKey(0), k=16, d=32, k_prime=4,
                            m0=3, n_per_comp_dev=40, sep=2000.0)
    A = fm.data.reshape(-1, 32)
    lb = fm.labels.reshape(-1)
    n_min = fm.data.shape[1]
    rep = S.separation_report(A, lb, 16, fm.presence, n_min,
                              k_prime=4, m0=3.0, c=2.0)
    # With sep=2000 everything is comfortably separated.
    assert float(rep.active_satisfied) == 1.0
    assert float(rep.inactive_satisfied) == 1.0
    # Inactive pairs exist in the G_i construction.
    act = np.asarray(rep.active)
    off = ~np.eye(16, dtype=bool)
    assert (~act & off).sum() > 0


def test_proximity_all_satisfied_when_far():
    fm = structured_devices(jax.random.PRNGKey(1), k=4, d=16, k_prime=2,
                            m0=2, n_per_comp_dev=50, sep=500.0)
    A = fm.data.reshape(-1, 16)
    lb = fm.labels.reshape(-1)
    ok = S.proximity_satisfied(A, lb, 4)
    assert bool(jnp.all(ok))


def test_proximity_violated_when_overlapping():
    key = jax.random.PRNGKey(2)
    A = jax.random.normal(key, (200, 8))  # one blob, split arbitrarily
    lb = (jnp.arange(200) % 2).astype(jnp.int32)
    ok = S.proximity_satisfied(A, lb, 2)
    assert float(jnp.mean(ok)) < 0.9
