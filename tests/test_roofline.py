"""Roofline analysis layer (launch/roofline + launch/hlo_analysis) as
LOAD-BEARING code — exercised against the actually-compiled serve step,
not canned fixtures only (ISSUE 6 satellite; this is what the CI
perf-gate's analytic rows are built from):

- parse_module / analyze on the compiled fused serve step: positive
  dot FLOPs, positive bytes, zero collectives at 1 device.
- while-loop single-count semantics: a lax.fori_loop'd dot must be
  charged trip_count times, not once (the XLA cost_analysis bug this
  module exists to fix).
- hardware profiles: named lookup, env-var resolution, KeyError on
  unknown, roofline_terms accepting name / dict / None.
- parse_collectives on canned partitioned-HLO text + collective bytes
  of a genuinely compiled shard_map program when >= 2 devices are
  forced (the CI mesh job runs this file at 2 and 8).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed.plane import _make_step
from repro.fed.stream import StreamConfig
from repro.launch.hlo_analysis import analyze, parse_module
from repro.launch.roofline import (DEFAULT_HW_PROFILE, HW, HW_PROFILES,
                                   hw_profile, parse_collectives,
                                   roofline_terms)

# ----------------------------------------------- compiled serve step ---

_SHAPE = dict(B=4, n=64, d=16, k=8, kp=3, iters=6)


def _compiled_serve_hlo():
    s = _SHAPE
    cfg = StreamConfig(k=s["k"], k_prime=s["kp"], d=s["d"], capacity=16,
                       batch_size=s["B"], bucket_sizes=(s["n"],),
                       local_kw={"max_iters": s["iters"]})
    sds = jax.ShapeDtypeStruct
    args = (sds((s["k"], s["d"]), jnp.float32),
            sds((s["B"], 2), jnp.uint32),
            sds((s["B"], s["n"], s["d"]), jnp.float32),
            sds((s["B"], s["n"]), jnp.bool_),
            sds((s["B"],), jnp.int32))
    return jax.jit(_make_step(cfg)).lower(*args).compile().as_text()


@pytest.fixture(scope="module")
def serve_hlo():
    return _compiled_serve_hlo()


def test_parse_module_on_compiled_serve_step(serve_hlo):
    comps, entry = parse_module(serve_hlo)
    assert entry is not None and entry in comps
    assert len(comps) > 1                   # fusions/loops parsed too
    ent = comps[entry]
    assert ent.root in ent.instrs           # ROOT detected
    opcodes = {i.opcode for c in comps.values() for i in c.instrs.values()}
    assert "while" in opcodes               # the Lloyd loop survived


def test_analyze_compiled_serve_step(serve_hlo):
    s = _SHAPE
    hc = analyze(serve_hlo)
    flops = hc["flops"] + hc.get("flops_f32", 0.0)
    # The Lloyd assignment alone is 2*B*n*d*k' per iteration — the
    # analyzer must see at least one iteration's dots...
    assert flops >= 2 * s["B"] * s["n"] * s["d"] * s["kp"]
    # ...and bytes at least one read of the request batch.
    assert hc["bytes"] >= s["B"] * s["n"] * s["d"] * 4
    assert hc["coll_bytes"] == 0.0          # single-host program
    assert hc["n_computations"] == len(parse_module(serve_hlo)[0])


def test_while_loop_counts_every_trip():
    """XLA's cost_analysis counts a while body once; analyze() must
    multiply by the extracted trip count — the FLOPs of a fori_loop'd
    dot scale with T."""
    w = jnp.ones((32, 32), jnp.float32)

    def prog(trips):
        def fn(x):
            return jax.lax.fori_loop(
                0, trips, lambda _, c: jnp.dot(c, w), x)
        return jax.jit(fn).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ).compile().as_text()

    f5 = analyze(prog(5))
    f10 = analyze(prog(10))
    body = 2 * 32 * 32 * 32
    tot5 = f5["flops"] + f5["flops_f32"]
    tot10 = f10["flops"] + f10["flops_f32"]
    assert tot5 >= 5 * body, "while body under-counted (single-count bug)"
    # doubling the trip count roughly doubles the charged FLOPs
    assert 1.5 < tot10 / tot5 < 2.5


# ------------------------------------------------- hardware profiles ---

def test_hw_profile_lookup():
    assert hw_profile("tpu_v5p")["peak_flops"] == 459e12
    assert hw_profile(None) is HW_PROFILES[DEFAULT_HW_PROFILE]
    assert hw_profile() is HW               # back-compat alias holds
    for prof in HW_PROFILES.values():
        assert set(prof) == {"peak_flops", "hbm_bw", "link_bw",
                             "vmem_bytes"}
        assert all(v > 0 for v in prof.values())


def test_hw_profile_env_var(monkeypatch):
    monkeypatch.setenv("REPRO_HW_PROFILE", "cpu_ci")
    assert hw_profile() is HW_PROFILES["cpu_ci"]
    assert hw_profile("tpu_v4") is HW_PROFILES["tpu_v4"]  # arg wins


def test_hw_profile_unknown_raises():
    with pytest.raises(KeyError, match="tpu_v6z"):
        hw_profile("tpu_v6z")


def test_roofline_terms_accepts_name_dict_none():
    by_name = roofline_terms(1e12, 1e9, 0.0, hw="tpu_v5e")
    by_dict = roofline_terms(1e12, 1e9, 0.0, hw=HW_PROFILES["tpu_v5e"])
    by_none = roofline_terms(1e12, 1e9, 0.0)
    assert by_name == by_dict == by_none
    assert by_name["bottleneck"] == "compute"
    # a slower-HBM profile can flip the bottleneck for the same program
    slow = roofline_terms(1e12, 1e9, 0.0,
                          hw={"peak_flops": 1e15, "hbm_bw": 1e9,
                              "link_bw": 1e9})
    assert slow["bottleneck"] == "memory"
    assert slow["total_s"] == slow["memory_s"]


# ----------------------------------------------------- collectives -----

_CANNED_SPMD = """
HloModule canned, entry_computation_layout={()->f32[]}

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[128,128]{1,0} all-gather(f32[64,128]{1,0} %p0), dimensions={0}
  %ar = f32[64,128]{1,0} all-reduce(f32[64,128]{1,0} %p0), to_apply=%add
  %cp = f32[64,128]{1,0} collective-permute(f32[64,128]{1,0} %ar)
  ROOT %out = f32[64,128]{1,0} add(f32[64,128]{1,0} %cp, f32[64,128]{1,0} %p0)
}
"""


def test_parse_collectives_canned():
    stats = parse_collectives(_CANNED_SPMD)
    op_bytes = 64 * 128 * 4
    assert stats["all-gather"] == {"count": 1, "bytes": op_bytes}
    assert stats["all-reduce"] == {"count": 1, "bytes": op_bytes}
    assert stats["collective-permute"] == {"count": 1, "bytes": op_bytes}
    assert "reduce-scatter" not in stats


def test_analyze_collective_bytes_canned():
    hc = analyze(_CANNED_SPMD)
    assert hc["coll_bytes"] == 3 * 64 * 128 * 4
    assert hc["coll"]["all-gather"]["count"] == 1


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (CI mesh job forces them)")
def test_collective_bytes_on_compiled_shard_map():
    """A real psum over a 2+-device mesh must surface as all-reduce
    bytes in BOTH parsers (parse_collectives and analyze agree)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    ndev = jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("data",))

    def fn(x):
        return jax.lax.psum(x, "data")

    shmapped = shard_map(fn, mesh=mesh, in_specs=P("data"),
                         out_specs=P())
    hlo = jax.jit(shmapped).lower(
        jax.ShapeDtypeStruct((ndev * 8, 32), jnp.float32)
    ).compile().as_text()
    stats = parse_collectives(hlo)
    assert "all-reduce" in stats and stats["all-reduce"]["bytes"] > 0
    hc = analyze(hlo)
    assert hc["coll_bytes"] >= stats["all-reduce"]["bytes"]
    assert hc["coll"]["all-reduce"]["count"] >= 1
