"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.kernels import ref
from repro.kernels.kmeans_update import kmeans_update as pk_update
from repro.kernels.pdist_argmin import pairwise_argmin as pk_argmin
from repro.kernels.swa_decode import swa_decode_attention as pk_swa

SHAPES = [(16, 8, 3), (100, 33, 7), (256, 128, 130), (70, 260, 5),
          (130, 513, 129)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_argmin_matches_ref(n, d, k, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(n + d + k))
    x = (jax.random.normal(kx, (n, d)) * 3).astype(dtype)
    c = (jax.random.normal(kc, (k, d)) * 3).astype(dtype)
    idx, val = pk_argmin(x, c, bn=32, bd=128, interpret=True)
    ridx, rval = ref.assign_argmin(x, c)
    # Argmin ties can differ legally; compare distances at chosen indices.
    rd = np.asarray(ref.pairwise_sq_dists(x, c))
    np.testing.assert_allclose(rd[np.arange(n), np.asarray(idx)],
                               rd[np.arange(n), np.asarray(ridx)],
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                               rtol=2e-2, atol=2e-2)


def test_pairwise_argmin_center_mask():
    x = jnp.zeros((4, 6))
    c = jnp.stack([jnp.zeros(6), jnp.ones(6) * 0.1, jnp.ones(6)])
    cm = jnp.array([False, True, True])
    idx, _ = pk_argmin(x, c, cm, bn=32, bd=128, interpret=True)
    assert np.all(np.asarray(idx) == 1)


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_kmeans_update_matches_ref(n, d, k):
    key = jax.random.PRNGKey(n * 7 + k)
    x = jax.random.normal(key, (n, d))
    assign = jax.random.randint(jax.random.PRNGKey(1), (n,), -1, k)
    sums, cnt = pk_update(x, assign.astype(jnp.int32), k, bn=64,
                          interpret=True)
    rsums, rcnt = ref.kmeans_update(x, assign, k)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(rcnt))


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_kmeans_update_weighted_matches_ref(n, d, k):
    """The weighted center update (server Lloyd round with core-set
    weights) through the Pallas kernel vs the oracle."""
    key = jax.random.PRNGKey(n * 11 + k)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (n, d))
    assign = jax.random.randint(jax.random.PRNGKey(2), (n,), -1, k)
    w = jax.random.uniform(kw, (n,), jnp.float32, 0.0, 5.0)
    sums, cnt = pk_update(x, assign.astype(jnp.int32), k, w, bn=64,
                          interpret=True)
    rsums, rcnt = ref.kmeans_update(x, assign, k, w)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(rsums),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(rcnt),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b,h,kvh,dh,W", [(2, 8, 2, 64, 128),
                                          (1, 4, 4, 32, 200),
                                          (3, 8, 1, 128, 384)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_swa_decode_matches_ref(b, h, kvh, dh, W, dtype):
    keys = jax.random.split(jax.random.PRNGKey(b + W), 4)
    q = (jax.random.normal(keys[0], (b, h, dh)) * 0.5).astype(dtype)
    kw = (jax.random.normal(keys[1], (b, W, kvh, dh)) * 0.5).astype(dtype)
    vw = (jax.random.normal(keys[2], (b, W, kvh, dh)) * 0.5).astype(dtype)
    # Ragged validity: device i has valid window min(W, 17*i+30).
    lens = np.minimum(W, 17 * np.arange(b) + 30)
    bias = np.zeros((b, W), np.float32)
    for i, L in enumerate(lens):
        bias[i, L:] = -1e30
    bias = jnp.asarray(bias)
    scale = 1.0 / np.sqrt(dh)
    out = pk_swa(q, kw, vw, bias, scale, bw=64, interpret=True)
    want = ref.swa_decode_attention(q, kw, vw, bias, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# -------- pairwise_argmin edge shapes: ragged n/d, k-tiling, masks ------

from repro.kernels import ops

EDGE_SHAPES = [
    (37, 5, 7),      # n % bn != 0, d far below bd
    (64, 130, 7),    # d above bd, non-multiple
    (50, 33, 129),   # k > 128: two k-blocks at bk=128
    (100, 70, 300),  # k > 256: three k-blocks
]


@pytest.mark.parametrize("n,d,k", EDGE_SHAPES)
def test_pairwise_argmin_edge_shapes_match_ref(n, d, k):
    kx, kc, km = jax.random.split(jax.random.PRNGKey(n * 3 + k), 3)
    x = jax.random.normal(kx, (n, d)) * 3
    c = jax.random.normal(kc, (k, d)) * 3
    cm = jax.random.bernoulli(km, 0.8, (k,)).at[0].set(True)
    idx, val = pk_argmin(x, c, cm, bn=32, bd=64, bk=128, interpret=True)
    ridx, rval = ref.assign_argmin(x, c, cm)
    rd = np.asarray(jnp.where(cm[None, :], ref.pairwise_sq_dists(x, c),
                              ref.MASKED_DIST))
    np.testing.assert_allclose(rd[np.arange(n), np.asarray(idx)],
                               rd[np.arange(n), np.asarray(ridx)],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                               rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(cm)[np.asarray(idx)])  # never a masked center


def test_pairwise_argmin_single_valid_center_k_tiled():
    """One valid center living in the SECOND k-block: every point must
    find it across the block-merge."""
    n, d, k, only = 40, 9, 200, 137
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    c = jax.random.normal(jax.random.PRNGKey(1), (k, d))
    cm = jnp.zeros((k,), bool).at[only].set(True)
    idx, val = pk_argmin(x, c, cm, bn=32, bd=64, bk=128, interpret=True)
    assert np.all(np.asarray(idx) == only)
    want = np.asarray(ref.pairwise_sq_dists(x, c))[:, only]
    np.testing.assert_allclose(np.asarray(val), want, rtol=1e-4, atol=1e-4)


def test_pairwise_argmin_interpret_autodetect():
    """The interpret default routes through ops' platform auto-detect
    (compiled on TPU, interpret elsewhere) instead of hardcoding True."""
    assert ops.resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert ops.resolve_interpret(True) is True
    assert ops.resolve_interpret(False) is False
    x = jax.random.normal(jax.random.PRNGKey(0), (17, 6))
    c = jax.random.normal(jax.random.PRNGKey(1), (5, 6))
    idx, _ = pk_argmin(x, c)  # no interpret kwarg: auto-detected path
    ridx, _ = ref.assign_argmin(x, c)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_assign_argmin_chunked_matches_monolithic(impl):
    """The streaming driver (fixed-size row tiles) is exact vs the
    one-call path, on both backends, ragged final chunk included."""
    x = jax.random.normal(jax.random.PRNGKey(2), (777, 10))
    c = jax.random.normal(jax.random.PRNGKey(3), (9, 10))
    cm = jnp.arange(9) != 4
    prev_impl, prev_interp = ops.get_backend(), ops._STATE["interpret"]
    try:
        ops.set_backend(impl)
        ci, cv = ops.assign_argmin_chunked(x, c, cm, chunk=100)
        mi, mv = ops.assign_argmin(x, c, cm)
    finally:
        ops.set_backend(prev_impl, prev_interp)
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(mi))
    np.testing.assert_allclose(np.asarray(cv), np.asarray(mv),
                               rtol=1e-5, atol=1e-5)


# ---------------- hypothesis property tests ----------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 80), d=st.integers(1, 40), k=st.integers(1, 20),
       seed=st.integers(0, 2 ** 16))
def test_property_argmin_is_true_min(n, d, k, seed):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n, d))
    c = jax.random.normal(kc, (k, d))
    idx, val = pk_argmin(x, c, bn=32, bd=64, interpret=True)
    d2 = np.asarray(ref.pairwise_sq_dists(x, c))
    np.testing.assert_allclose(np.asarray(val), d2.min(1), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 100), k=st.integers(1, 10),
       seed=st.integers(0, 2 ** 16))
def test_property_update_conserves_mass(n, k, seed):
    """sum of per-cluster sums == sum of valid points (mass conservation)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 5))
    assign = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), -1, k)
    sums, cnt = pk_update(x, assign.astype(jnp.int32), k, bn=32,
                          interpret=True)
    valid = np.asarray(assign) >= 0
    np.testing.assert_allclose(np.asarray(sums).sum(0),
                               np.asarray(x)[valid].sum(0), rtol=1e-4,
                               atol=1e-4)
    assert np.asarray(cnt).sum() == valid.sum()


# ---------------------------------------------------------------- moe --
from repro.kernels.moe_dispatch import moe_combine as pk_combine
from repro.kernels.moe_dispatch import moe_dispatch as pk_dispatch

MOE_SHAPES = [(32, 8, 24), (100, 130, 48), (64, 256, 16)]  # (T, d, S)


@pytest.mark.parametrize("T,d,S", MOE_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_dispatch_matches_ref(T, d, S, dtype):
    key = jax.random.PRNGKey(T + d + S)
    kx, ks, kv = jax.random.split(key, 3)
    x = (jax.random.normal(kx, (T, d)) * 2).astype(dtype)
    src = jax.random.randint(ks, (S,), 0, T)
    valid = jax.random.bernoulli(kv, 0.8, (S,))
    out = pk_dispatch(x, src, valid, bd=128, interpret=True)
    rout = ref.moe_dispatch(x, src, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(rout, np.float32),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("T,d,S", MOE_SHAPES)
@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_moe_combine_matches_ref(T, d, S, top_k):
    key = jax.random.PRNGKey(T * top_k)
    ky, ks, kg = jax.random.split(key, 3)
    ybuf = (jax.random.normal(ky, (S, d)) * 2).astype(jnp.bfloat16)
    slot = jax.random.randint(ks, (T * top_k,), 0, S)
    gates = jax.random.uniform(kg, (T * top_k,), jnp.float32)
    out = pk_combine(ybuf, slot, gates, top_k=top_k, bd=128, interpret=True)
    rout = ref.moe_combine(ybuf, slot, gates, top_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                               rtol=2e-2, atol=2e-2)


@given(T=st.integers(4, 40), d=st.integers(1, 70),
       frac=st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_moe_dispatch_property_zero_invalid(T, d, frac):
    """Invalid slots are exactly zero; valid slots bit-equal their row."""
    S = 2 * T
    key = jax.random.PRNGKey(T * d + 1)
    kx, ks, kv = jax.random.split(key, 3)
    x = jax.random.normal(kx, (T, d), jnp.float32)
    src = jax.random.randint(ks, (S,), 0, T)
    valid = jax.random.bernoulli(kv, frac, (S,))
    out = np.asarray(pk_dispatch(x, src, valid, bd=128, interpret=True))
    xv = np.asarray(x)
    for s in range(S):
        if bool(valid[s]):
            np.testing.assert_array_equal(out[s], xv[int(src[s])])
        else:
            assert (out[s] == 0).all()
