"""The sharded streaming serve plane + double-buffered versioned tau
(fed/plane.py over fed/stream.py, DESIGN.md §11).

Covers the refresh-vs-serve consistency window: every served label maps
to exactly one tau version, pre-swap requests read the old buffer and
post-swap the new, and a checkpoint restored mid-window replays the
same version assignments bitwise. The mesh tests build over whatever
devices exist — run them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI mesh
leg) for real sharding; on one device the sharded plane degenerates to
the single-host plane and the parity assertions still pin it.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import server as S
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, PlanError, Session
from repro.fed.plane import TauBuffer
from repro.fed.policy import make_policy
from repro.utils.compat import make_mesh

K, KP, D = 16, 4, 24
NDEV = jax.device_count()


@pytest.fixture(scope="module")
def fixture_round():
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    return fm, rr


def _mesh():
    return make_mesh((NDEV,), ("data",))


def _plan(**kw):
    base = dict(k=K, k_prime=KP, d=D, capacity=256,
                batch_size=2 * NDEV, bucket_sizes=(32, 64, 128))
    base.update(kw)
    return FederationPlan(**base)


def _requests(fm, count, seed, n_hi=120):
    stream = late_device_stream(fm.means, KP, count, seed,
                                n_range=(10, n_hi))
    return ([r[0] for r in stream], [r[1] for r in stream],
            [r[2] for r in stream])


# ----------------------------------------------------- sharded plane --


def test_sharded_serve_bitwise_matches_single_host(fixture_round):
    """Fixed tau version: per-request labels AND the folded server
    state of the sharded plane are bitwise identical to the single-host
    plane (acceptance criterion)."""
    fm, rr = fixture_round
    reqs, _, kvs = _requests(fm, 3 * NDEV + 1, seed=3)
    single = Session.from_round(_plan(), rr)
    shard = Session.from_round(_plan(serve_axes=("data",)), rr,
                               mesh=_mesh())
    out_a = single.serve_versioned(reqs, kvs)
    out_b = shard.serve_versioned(reqs, kvs)
    for (la, va), (lb, vb) in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
        assert va == vb == 0
    for x, y in zip(jax.tree.leaves(single.service.state),
                    jax.tree.leaves(shard.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert shard.service.stats()["serve_shards"] == NDEV


@pytest.mark.parametrize("policy", ["lru", "weighted_reservoir"])
def test_sharded_fold_policies_match_single_host(fixture_round, policy):
    """Admission is shard-deterministic: under lru/weighted_reservoir
    the sharded plane folds exactly the same slots as the single-host
    plane (policy state AND server state bitwise)."""
    fm, rr = fixture_round
    kw = dict(capacity=8, fold_policy=policy)
    reqs, _, kvs = _requests(fm, 2 * NDEV + 3, seed=7)
    single = Session.from_round(_plan(**kw), rr)
    shard = Session.from_round(_plan(**kw, serve_axes=("data",)), rr,
                               mesh=_mesh())
    for sess in (single, shard):
        sess.serve(reqs, kvs)
    pa = single.service.policy.state_arrays()
    pb = shard.service.policy.state_arrays()
    assert sorted(pa) == sorted(pb)
    for name in pa:
        np.testing.assert_array_equal(pa[name], pb[name])
    for x, y in zip(jax.tree.leaves(single.service.state),
                    jax.tree.leaves(shard.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_session_topology_parity_direct(fixture_round):
    """Replicated/sharded shard_map rounds agree bitwise with the vmap
    simulation, directly on this process's devices (the CI mesh leg
    runs this at 8 forced host devices; tier-1 subprocess children
    cover it too)."""
    fm, _ = fixture_round
    Z = fm.data.shape[0]
    if Z % NDEV:
        pytest.skip(f"{Z} devices not divisible over {NDEV} shards")
    sim = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data)
    mesh = _mesh()
    for topology in ("replicated", "sharded"):
        out = Session(FederationPlan(k=K, k_prime=KP, d=D,
                                     topology=topology), mesh=mesh).run(
            jax.random.PRNGKey(1), fm.data)
        np.testing.assert_array_equal(np.asarray(out.labels),
                                      np.asarray(sim.labels))


def test_aggregate_incremental_sharded_matches_sequential():
    """The collective fold path == the sequential fold primitive,
    bitwise, for a batch sharded over this process's devices."""
    from jax.sharding import PartitionSpec as P
    from repro.utils.compat import shard_map
    kp, d = 3, 5
    B = 4 * NDEV
    cap = B  # distinct ids, some past capacity (exercises the drop)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.permutation(B + 4)[:B], jnp.int32)
    centers = jnp.asarray(rng.normal(size=(B, kp, d)), jnp.float32)
    mask = jnp.asarray(rng.random((B, kp)) < 0.8)
    w = jnp.asarray(rng.random((B, kp)), jnp.float32)
    st0 = S.init_state(cap, kp, d)
    seq = S.aggregate_incremental(st0, ids, centers, mask, weights=w)
    mesh = _mesh()
    spec = P(("data",))
    fn = shard_map(
        lambda st, i, c, m, wt: S.aggregate_incremental_sharded(
            st, i, c, m, ("data",), weights=wt),
        mesh=mesh, in_specs=(P(), spec, spec, spec, spec),
        out_specs=P())
    got = fn(st0, ids, centers, mask, w)
    for a, b in zip(jax.tree.leaves(seq), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_axes_validation():
    with pytest.raises(PlanError, match="serve_axes"):
        FederationPlan(k=K, k_prime=KP, d=D, serve_axes=())
    with pytest.raises(PlanError, match="mesh"):
        Session(FederationPlan(k=K, k_prime=KP, d=D,
                               serve_axes=("data",)))
    with pytest.raises(PlanError, match="not in the mesh"):
        Session(FederationPlan(k=K, k_prime=KP, d=D,
                               serve_axes=("model",)), mesh=_mesh())
    if NDEV > 1:
        with pytest.raises(PlanError, match="divisible"):
            Session(FederationPlan(k=K, k_prime=KP, d=D, batch_size=1,
                                   serve_axes=("data",)), mesh=_mesh())


# ------------------------------------------- versioned tau / refresh --


def test_every_label_maps_to_exactly_one_version(fixture_round):
    """Sync refresh: versions are recorded per request, bump exactly
    once per swap, and pre-swap requests used the old buffer while
    post-swap requests use the new (satellite acceptance)."""
    fm, rr = fixture_round
    sess = Session.from_round(_plan(batch_size=2, refresh_every=2,
                                    bucket_sizes=(128,)), rr)
    reqs, _, kvs = _requests(fm, 6, seed=5)
    tau0 = np.asarray(sess.tau_centers)
    out = sess.serve_versioned(reqs, kvs)
    versions = [v for _, v in out]
    # batch 1 (2 folds) served at v0, then swap; batch 2 at v1; etc.
    assert versions == [0, 0, 1, 1, 2, 2]
    assert sess.tau_version == 3
    assert not np.array_equal(tau0, np.asarray(sess.tau_centers))


def test_async_refresh_defers_swap_to_flush_boundary(fixture_round):
    """Async refresh: the cadence mid-flush stages the standby buffer
    without touching in-flight serving (old version throughout), and
    the next flush commits ONE atomic version bump."""
    fm, rr = fixture_round
    sess = Session.from_round(_plan(batch_size=2, refresh_every=2,
                                    refresh="async",
                                    bucket_sizes=(128,)), rr)
    reqs, _, kvs = _requests(fm, 6, seed=9)
    out1 = sess.serve_versioned(reqs, kvs)
    assert [v for _, v in out1] == [0] * 6  # swap never lands mid-flush
    st = sess.stats()
    assert st["refresh_pending"] and st["tau_version"] == 0
    out2 = sess.serve_versioned(reqs[:2], kvs[:2])
    assert [v for _, v in out2] == [1, 1]   # committed at the boundary
    assert sess.tau_version == 1


def test_async_swap_serves_against_standby_content(fixture_round):
    """The committed buffer really is the staged re-finalization: after
    the boundary swap, serving tau equals finalize() over the fold
    state at staging time."""
    fm, rr = fixture_round
    sess = Session.from_round(_plan(batch_size=2, refresh_every=64,
                                    refresh="async",
                                    bucket_sizes=(128,)), rr)
    reqs, _, kvs = _requests(fm, 2, seed=11)
    sess.serve(reqs, kvs)
    svc = sess.service
    svc._stage_refresh()
    want = S.finalize(svc.state, K).tau_centers
    np.testing.assert_array_equal(
        np.asarray(svc._taubuf.standby), np.asarray(want))
    old = np.asarray(sess.tau_centers)
    assert not np.array_equal(old, np.asarray(want))
    sess.serve(reqs, kvs)  # boundary: commit
    np.testing.assert_array_equal(np.asarray(sess.tau_centers),
                                  np.asarray(want))
    assert sess.tau_version == 1


def test_checkpoint_restore_mid_window_replays_versions_bitwise(
        fixture_round, tmp_path):
    """Crash recovery inside a refresh window: the staged standby
    buffer, the pending flag, and the version counter all ride the
    checkpoint, so the replica replays the SAME labels and the SAME
    version assignments (satellite acceptance)."""
    fm, rr = fixture_round
    plan = _plan(batch_size=2, refresh_every=2, refresh="async",
                 bucket_sizes=(128,))
    live = Session.from_round(plan, rr)
    reqs, _, kvs = _requests(fm, 8, seed=13)
    live.serve(reqs[:4], kvs[:4])           # cadence fired: mid-window
    assert live.stats()["refresh_pending"]
    path = str(tmp_path / "midwindow.npz")
    live.save(path)
    replica = Session.restore(path, plan)
    assert replica.stats()["refresh_pending"]
    out_live = live.serve_versioned(reqs[4:], kvs[4:])
    out_rep = replica.serve_versioned(reqs[4:], kvs[4:])
    for (la, va), (lb, vb) in zip(out_live, out_rep):
        np.testing.assert_array_equal(la, lb)
        assert va == vb
    np.testing.assert_array_equal(
        np.asarray(live.service._taubuf.bufs),
        np.asarray(replica.service._taubuf.bufs))
    assert (live.service._taubuf.version
            == replica.service._taubuf.version)


def test_legacy_v1_checkpoint_still_restores(fixture_round, tmp_path):
    """A pre-plane checkpoint (single ``tau`` key) restores as version
    0 with both buffers equal — old checkpoints keep replaying."""
    from repro.checkpoint.store import save_pytree
    from repro.fed.policy import POLICY_IDS
    fm, rr = fixture_round
    sess = Session.from_round(_plan(), rr)
    reqs, _, kvs = _requests(fm, 2, seed=17)
    sess.serve(reqs, kvs)
    svc = sess.service
    path = str(tmp_path / "v1.npz")
    save_pytree(path, {"tau": svc.tau, "server": svc.state,
                       "counters": svc._counters(),
                       "policy_id": np.asarray(POLICY_IDS["drop"],
                                               np.int64),
                       "policy": {}})
    replica = Session.restore(path, sess.plan)
    np.testing.assert_array_equal(np.asarray(replica.tau_centers),
                                  np.asarray(sess.tau_centers))
    assert replica.tau_version == 0
    more, _, mkv = _requests(fm, 3, seed=19)
    for a, b in zip(sess.serve(more, mkv), replica.serve(more, mkv)):
        np.testing.assert_array_equal(a, b)


def test_tau_buffer_transitions():
    buf = TauBuffer.fresh(np.zeros((2, 3), np.float32))
    assert (buf.active, buf.version, buf.pending) == (0, 0, False)
    staged = buf.stage(np.ones((2, 3), np.float32))
    assert staged.pending and staged.version == 0
    np.testing.assert_array_equal(np.asarray(staged.tau),
                                  np.zeros((2, 3)))  # serving untouched
    np.testing.assert_array_equal(np.asarray(staged.standby),
                                  np.ones((2, 3)))
    done = staged.commit()
    assert (done.active, done.version, done.pending) == (1, 1, False)
    np.testing.assert_array_equal(np.asarray(done.tau), np.ones((2, 3)))
    rt = TauBuffer.from_arrays(np.asarray(done.bufs), done.meta_array())
    assert (rt.active, rt.version, rt.pending) == (1, 1, False)


# ------------------------------------------------- bucket ladder -----


def test_oversized_bucket_geometric_ladder_and_warn_per_rung(
        fixture_round):
    """Requests above the largest bucket pad to a geometric (doubling)
    ladder — O(log) distinct jit shapes instead of one per rounded-up
    n — and warn once per (active ladder, rung) under the NAMED perf
    category (``ReproPerfWarning``) so filterwarnings can target it.
    Each new oversized pad shape is visible exactly once and repeats
    are silent — the old once-per-service latch hid every rung after
    the first (bugfix, see also tests/test_autoscale.py for the
    post-coalesce ladder half of the key)."""
    from repro.fed.stream import ReproPerfWarning
    fm, rr = fixture_round
    sess = Session.from_round(_plan(bucket_sizes=(32, 64)), rr)
    svc = sess.service
    assert svc._bucket(10) == 32 and svc._bucket(64) == 64
    with pytest.warns(ReproPerfWarning, match="largest configured bucket"):
        assert svc._bucket(65) == 128
    with pytest.warns(ReproPerfWarning, match="largest configured bucket"):
        assert svc._bucket(129) == 256
    # distinct oversized n values share pads -> shared jit signatures,
    # and an already-warned (ladder, rung) key stays silent
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error", ReproPerfWarning)
        assert svc._bucket(66) == 128
        assert svc._bucket(200) == svc._bucket(256) == 256
    with pytest.warns(ReproPerfWarning, match="largest configured bucket"):
        assert svc._bucket(3000) == 4096


# --------------------------------------------- tier-1 mesh child -----


PLANE_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.utils.compat import make_mesh
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session

mesh = make_mesh((8,), ("data",))
fm = structured_devices(jax.random.PRNGKey(0), k=16, d=24, k_prime=4,
                        m0=4, n_per_comp_dev=25, sep=60.0)
rr = Session(FederationPlan(k=16, k_prime=4, d=24)).run(
    jax.random.PRNGKey(1), fm.data).detail
base = dict(k=16, k_prime=4, d=24, capacity=256, batch_size=8,
            bucket_sizes=(32, 64, 128), refresh_every=5, refresh="async")
stream = late_device_stream(fm.means, 4, 13, 5, n_range=(10, 120))
reqs, kvs = [r[0] for r in stream], [r[2] for r in stream]
single = Session.from_round(FederationPlan(**base), rr)
shard = Session.from_round(FederationPlan(**base, serve_axes=("data",)),
                           rr, mesh=mesh)
for sess in (single, shard):
    out1 = sess.serve_versioned(reqs, kvs)
    out2 = sess.serve_versioned(reqs[:4], kvs[:4])
    sess.result = out1 + out2
for (la, va), (lb, vb) in zip(single.result, shard.result):
    np.testing.assert_array_equal(la, lb)
    assert va == vb, (va, vb)
for x, y in zip(jax.tree.leaves(single.service.state),
                jax.tree.leaves(shard.service.state)):
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
assert shard.service.stats()["serve_shards"] == 8
assert shard.tau_version == single.tau_version >= 1
print("OK sharded plane parity")
"""


@pytest.mark.slow
def test_sharded_plane_parity_subprocess():
    """8-shard serve plane == single-host, bitwise (labels, versions,
    fold state), across an async refresh window — with REAL sharding
    (8 forced host devices, hence the subprocess; acceptance
    criterion)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", PLANE_CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK sharded plane parity" in out.stdout


# ------------------------------------------------- admission batch ---


def test_admit_batch_equals_sequential_admits():
    """FoldPolicy.admit_batch == the sequential admit loop with
    within-batch evictions suppressed (last write wins), for every
    policy — the contract that makes one batched scatter equal
    sequential folding."""
    rng = np.random.default_rng(0)
    for policy in ("drop", "lru", "weighted_reservoir"):
        for trial in range(5):
            cap = int(rng.integers(1, 8))
            rids = rng.integers(0, 3 * cap, size=int(rng.integers(1, 20)))
            w = rng.uniform(0.1, 5.0, size=len(rids))
            a = make_policy(policy, cap, seed=3)
            b = make_policy(policy, cap, seed=3)
            got, granted = a.admit_batch(rids, w)
            slot_of, want_granted = {}, 0
            for i, rid in enumerate(rids):
                s = b.admit(int(rid), float(w[i]))
                if s is not None:
                    slot_of[s] = i
                    want_granted += 1
            want = np.full((len(rids),), -1, np.int64)
            for s, i in slot_of.items():
                want[i] = s
            np.testing.assert_array_equal(got, want)
            assert granted == want_granted  # cadence counts grants


# --------------------------------- encoder=off bitwise parity (§17) ---


@pytest.mark.parametrize("seed", [23, 29, 31])
def test_encoder_off_plan_is_bitwise_inert(fixture_round, seed):
    """§17 acceptance: ``encoder="off"`` plans replay the existing
    serve/fold path bitwise — the encode fields are inert (even
    non-default ``encode_dtype``/``encode_seq_len``), no encode planes
    are compiled, and labels, versions, tau buffers, and every fold
    state leaf match the pre-§17 default plan exactly."""
    fm, rr = fixture_round
    kw = dict(batch_size=2, refresh_every=3, refresh="async",
              bucket_sizes=(32, 64, 128))
    base = Session.from_round(_plan(**kw), rr)
    off = Session.from_round(_plan(**kw, encoder="off",
                                   encode_dtype="bf16",
                                   encode_seq_len=999), rr)
    reqs, _, kvs = _requests(fm, 7, seed=seed)
    out_a = base.serve_versioned(reqs, kvs)
    out_b = off.serve_versioned(reqs, kvs)
    for (la, va), (lb, vb) in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
        assert va == vb
    for x, y in zip(jax.tree.leaves(base.service.state),
                    jax.tree.leaves(off.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(
        np.asarray(base.service._taubuf.bufs),
        np.asarray(off.service._taubuf.bufs))
    assert off.service.plane._encode == {}
    assert off.service.plane._enc_routed == {}
    assert off.service.encoder is None
    assert off.service.stats()["encoder"]["mode"] == "off"


def test_encoder_off_checkpoint_roundtrips_with_default_plan(
        fixture_round, tmp_path):
    """A checkpoint written by an explicit ``encoder="off"`` plan
    restores under the default plan (and vice versa) — the off mode
    adds no schema surface."""
    fm, rr = fixture_round
    sess = Session.from_round(_plan(encoder="off"), rr)
    reqs, _, kvs = _requests(fm, 3, seed=37)
    sess.serve(reqs, kvs)
    path = str(tmp_path / "off.npz")
    sess.save(path)
    replica = Session.restore(path, _plan())
    np.testing.assert_array_equal(np.asarray(replica.tau_centers),
                                  np.asarray(sess.tau_centers))
    more, _, mkv = _requests(fm, 2, seed=41)
    for a, b in zip(sess.serve(more, mkv), replica.serve(more, mkv)):
        np.testing.assert_array_equal(a, b)


# --------------------------------------- fused step under shard_map ---


def test_fused_step_matches_staged_step_sharded():
    """DESIGN.md §13 acceptance: the fused solve+attach serve step is
    bitwise identical to the pre-fusion three-stage composition UNDER
    THE PLANE'S OWN SHARDING — shard_mapped over the full mesh exactly
    as ServePlane._plane_for wires it (the CI mesh job runs this at 2
    and 8 forced devices; at 1 device both reduce to the jitted step)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.local_kmeans import batched_local_kmeans
    from repro.fed.plane import _make_step
    from repro.utils.compat import shard_map as _shard_map

    B, n = 2 * NDEV, 48
    cfg = _plan(batch_size=B, bucket_sizes=(n,),
                local_kw={"approx_iters": 2, "max_iters": 7},
                serve_axes=("data",) if NDEV > 1 else None).stream_config()

    def legacy(tau, keys, data, point_mask, k_valid):
        loc = batched_local_kmeans(keys, data, k_max=cfg.k_prime,
                                   k_valid=k_valid, point_mask=point_mask,
                                   **cfg.local_kw)
        ctr = jax.vmap(lambda c, m: S.assign_new_device(c, m, tau))(
            loc.centers, loc.center_mask)
        labels = S.induced_labels(ctr, loc.assign)
        return (labels, loc.centers, loc.center_mask,
                S.core_weights(loc.core_counts))

    fused = _make_step(cfg)
    if NDEV > 1:
        spec = P(("data",))
        specs = dict(in_specs=(P(), spec, spec, spec, spec),
                     out_specs=(spec, spec, spec, spec))
        mesh = _mesh()
        fused = _shard_map(fused, mesh=mesh, **specs)
        legacy = _shard_map(legacy, mesh=mesh, **specs)

    rng = np.random.default_rng(NDEV)
    tau = jnp.asarray(rng.normal(size=(K, D)) * 4, jnp.float32)
    data = jnp.asarray(rng.normal(size=(B, n, D)) * 3, jnp.float32)
    pm = jnp.asarray(rng.random((B, n)) < 0.9)
    kv = jnp.asarray(rng.integers(1, KP + 1, size=(B,)), jnp.int32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(5), jnp.arange(B))

    got = jax.jit(fused)(tau, keys, data, pm, kv)
    want = jax.jit(legacy)(tau, keys, data, pm, kv)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
