"""Checkpoint round-trip + data partitioner tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.data.gaussian import iid_devices, structured_devices
from repro.data.partition import partition_iid, partition_structured


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "seg": ({"w": jnp.ones((4,), jnp.bfloat16)},
                    {"w": jnp.zeros((2, 2))})}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree, step=7)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = load_pytree(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    from repro.checkpoint.store import checkpoint_step
    assert checkpoint_step(path) == 7


def test_structured_partition_respects_k_prime():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(400, 8)).astype(np.float32)
    y = rng.integers(0, 10, 400)
    part = partition_structured(rng, X, y, k=10, Z=12, k_prime=3)
    assert part.k_valid.max() <= 3
    # every cluster owned somewhere
    assert part.presence.any(axis=0).all()
    # masked data only
    assert (part.labels[~part.point_mask] == -1).all()


def test_iid_partition_covers_everything():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(100, 4)).astype(np.float32)
    y = rng.integers(0, 5, 100)
    part = partition_iid(rng, X, y, k=5, Z=7)
    assert int(part.point_mask.sum()) == 100


def test_structured_devices_presence():
    fm = structured_devices(jax.random.PRNGKey(0), k=8, d=6, k_prime=2,
                            m0=3, n_per_comp_dev=5, sep=10.0)
    assert fm.data.shape == (12, 10, 6)
    # each device sees exactly k'=2 clusters
    assert (np.asarray(fm.presence).sum(1) == 2).all()
    # devices in the same group see the same clusters; different groups
    # see disjoint clusters (active/inactive structure of Section 4.1)
    pres = np.asarray(fm.presence)
    g = np.asarray(fm.group_of_device)
    for z1 in range(12):
        for z2 in range(12):
            inter = (pres[z1] & pres[z2]).sum()
            if g[z1] == g[z2]:
                assert inter == 2
            else:
                assert inter == 0


def test_iid_devices_spread():
    fm = iid_devices(jax.random.PRNGKey(0), k=8, d=6, Z=4, n_per_dev=200,
                     sep=10.0)
    assert (np.asarray(fm.presence).sum(1) > 4).all()
