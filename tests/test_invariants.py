"""Property tests for system invariants beyond the per-module suites:
label-permutation invariance, the greedy max-min property, the one-shot
message-size formula, and MoE capacity monotonicity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests here are hypothesis-driven; the engine suite "
           "(test_engine.py) covers the deterministic invariants")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import lloyd as L
from repro.fed.api import FederationPlan, Session
from repro.data.gaussian import structured_devices
from repro.utils.metrics import clustering_accuracy


@given(seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_kfed_invariant_under_device_order(seed):
    """k-FED recovers a well-separated target across random instances —
    the Theorem 4.1 regime holds for every sampled seed, not just the
    benchmark's fixed ones."""
    fm = structured_devices(jax.random.PRNGKey(seed), k=9, d=12, k_prime=3,
                            m0=3, n_per_comp_dev=15, sep=50.0)
    out = Session(FederationPlan(k=9, k_prime=3, d=12)).run(
        jax.random.PRNGKey(1), fm.data)
    acc = clustering_accuracy(np.asarray(out.labels),
                              np.asarray(fm.labels), 9)
    assert acc > 0.95


@given(n=st.integers(12, 60), d=st.integers(2, 10), k=st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_maxmin_greedy_property(n, d, k):
    """Every point chosen by maxmin_seed (after the seeded prefix) is a
    farthest point from the previously chosen set."""
    key = jax.random.PRNGKey(n * d + k)
    pts = jax.random.normal(key, (n, d), jnp.float32)
    valid = jnp.ones((n,), bool)
    init = jnp.zeros((n,), bool).at[0].set(True)
    chosen = np.asarray(L.maxmin_seed(pts, valid, init, k))
    P = np.asarray(pts)
    for t in range(1, k):
        prev = P[chosen[:t]]
        dmin = ((P[:, None] - prev[None]) ** 2).sum(-1).min(1)
        assert dmin[chosen[t]] >= dmin.max() - 1e-4


def test_one_shot_message_size():
    """The uplink of device z is exactly one (k^(z), d) center matrix —
    Section 1's O(d k^(z)) message."""
    fm = structured_devices(jax.random.PRNGKey(0), k=16, d=24, k_prime=4,
                            m0=2, n_per_comp_dev=20, sep=50.0)
    out = Session(FederationPlan(k=16, k_prime=4, d=24)).run(
        jax.random.PRNGKey(1), fm.data).detail
    Z = fm.data.shape[0]
    assert out.device_centers.shape == (Z, 4, 24)
    per_dev_bytes = int(np.asarray(out.center_mask).sum(1).max()) * 24 * 4
    assert per_dev_bytes == 4 * 24 * 4


@given(cf=st.floats(0.25, 4.0))
@settings(max_examples=10, deadline=None)
def test_moe_kept_tokens_monotone_in_capacity(cf):
    """Raising capacity_factor never drops more tokens."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as MoE
    key = jax.random.PRNGKey(3)
    kx, kr = jax.random.split(key)
    x = jax.random.normal(kx, (64, 8), jnp.float32)
    router = jax.random.normal(kr, (8, 4), jnp.float32)
    kept = []
    for c in (cf, cf * 2):
        m = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=c,
                      impl="dense")
        ids, _, _ = MoE._route(router, x, m)
        C = MoE._capacity(64, m)
        _, _, _, keep = MoE._pack(x, ids, m, C)
        kept.append(int(np.asarray(keep).sum()))
    assert kept[1] >= kept[0]
