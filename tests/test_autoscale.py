"""The load-adaptive serve plane (fed/autoscale.py over fed/plane.py +
fed/stream.py, DESIGN.md §12).

Covers the three controller promises:

  * decisions are PURE functions of (policy, queue snapshot, persisted
    controller state) — unit-tested directly on ``decide`` — and never
    change per-request labels (scaling is result-neutral; only
    refresh/version boundaries track the batch shape);
  * every (shards, batch, bucket) triple compiles exactly once —
    steady-state traffic over an already-seen load shape never
    recompiles (``ServePlane.compile_count`` flat, the acceptance
    criterion);
  * the decision state rides the schema-v3 checkpoint, so a restore
    mid-stream replays labels, tau versions, fold state AND the
    decision sequence bitwise (property test), while v1/v2 checkpoints
    still restore.

The mesh tests build over whatever devices exist — the CI mesh leg
runs this file under ``--xla_force_host_platform_device_count={2,8}``
so shard-count switching is exercised on both a cramped and a roomy
grant; on one device the controller degenerates to batch/ladder
scaling only and every assertion still pins it.
"""
import warnings

import numpy as np
import pytest

import jax

from _hyp import given, settings, st

from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed import autoscale as A
from repro.fed.api import FederationPlan, PlanError, Session
from repro.fed.plane import ServePlaneError
from repro.fed.stream import ReproPerfWarning, StreamConfigError
from repro.utils.compat import make_mesh

K, KP, D = 16, 4, 24
NDEV = jax.device_count()


@pytest.fixture(scope="module")
def fixture_round():
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    return fm, rr


def _plan(**kw):
    base = dict(k=K, k_prime=KP, d=D, capacity=256, batch_size=8,
                bucket_sizes=(32, 64, 128))
    base.update(kw)
    return FederationPlan(**base)


def _requests(fm, count, seed, n_range=(10, 120)):
    stream = late_device_stream(fm.means, KP, count, seed,
                                n_range=n_range)
    return [r[0] for r in stream], [r[2] for r in stream]


def _serve_depths(sess, reqs, kvs, depths):
    """Submit `depth` requests per flush (the queue shapes the bench
    and the controller see), returning [(labels, version)] in request
    order."""
    out, i = [], 0
    for q in depths:
        rids = [sess.submit(reqs[(i + j) % len(reqs)],
                            kvs[(i + j) % len(kvs)]) for j in range(q)]
        i += q
        got = sess.flush_versioned()
        out.extend(got[r] for r in rids)
    return out


# ------------------------------------------------------ decision rule --


def test_decide_is_pure_and_tracks_queue_depth():
    """latency: the batch rung is the next power of two of the queue
    depth (capped at the plan ceiling), shards follow the batch within
    the grant — and the same inputs always produce the same decision."""
    base = (64, 256)
    prev = A.AutoscaleDecision(8, 64, base, 0)
    kw = dict(max_batch=64, granted=8, n_axes=1, base_ladder=base,
              prev=prev, streak=0)
    snap = A.QueueSnapshot(3, ((64, 3),))
    d1, s1 = A.decide("latency", snap, **kw)
    assert (d1.batch_size, d1.shards, d1.seq) == (4, 4, 1)
    assert A.decide("latency", snap, **kw) == (d1, s1)  # pure
    deep = A.QueueSnapshot(500, ((64, 500),))
    d2, _ = A.decide("latency", deep, **kw)
    assert (d2.batch_size, d2.shards) == (64, 8)  # ceiling + full grant
    d3, _ = A.decide("latency", A.QueueSnapshot(1, ((64, 1),)), **kw)
    assert (d3.batch_size, d3.shards) == (1, 1)


def test_off_controller_is_inert():
    """``off`` never reaches the decision rule: observe() returns the
    static plan decision untouched, seq stays 0, whatever the queue
    looks like."""
    ctl = A.AutoscaleController("off", max_batch=16, granted=4,
                                n_axes=1, base_ladder=(64,))
    static = ctl.decision
    for snap in (A.QueueSnapshot(1, ((64, 1),)),
                 A.QueueSnapshot(500, ((64, 500),))):
        assert ctl.observe(snap) == static
    assert ctl.decision.seq == 0 and ctl.streak == 0
    assert (static.shards, static.batch_size, static.ladder) == (
        4, 16, (64,))


def test_throughput_shrinks_only_after_streak():
    """throughput holds the full batch through a single shallow flush
    (a dip inside a burst) and only shrinks after SHRINK_STREAK
    consecutive ones; growth is instant."""
    base = (64,)
    kw = dict(max_batch=64, granted=8, n_axes=1, base_ladder=base)
    prev = A.AutoscaleDecision(8, 64, base, 0)
    shallow = A.QueueSnapshot(1, ((64, 1),))
    d1, s1 = A.decide("throughput", shallow, prev=prev, streak=0, **kw)
    assert d1.batch_size == 64 and s1 == 1          # held through dip 1
    d2, s2 = A.decide("throughput", shallow, prev=d1, streak=s1, **kw)
    assert d2.batch_size == 1 and s2 == 0           # shrunk on dip 2
    deep = A.QueueSnapshot(64, ((64, 64),))
    d3, s3 = A.decide("throughput", deep, prev=d2, streak=s2, **kw)
    assert d3.batch_size == 64 and s3 == 0          # instant growth


def test_shards_divide_batch_within_grant():
    assert A.shards_for(64, 8, 1) == 8
    assert A.shards_for(4, 8, 1) == 4
    assert A.shards_for(8, 6, 1) == 4    # non-pow2 grant: pow2 floor
    assert A.shards_for(12, 6, 1) == 6   # full grant when it divides
    assert A.shards_for(8, 6, 2) == 1    # multi-axis: 1 or full only
    assert A.shards_for(12, 6, 2) == 6


def test_ladder_rebuckets_oversized_backlog():
    """Oversized queue entries fragment across geometric rungs under
    latency (tight pads) but coalesce into ONE rung under throughput —
    or under latency once the oversized backlog alone fills a batch."""
    base = (32,)
    hist = ((32, 2), (64, 1), (128, 1), (256, 1))
    snap = A.QueueSnapshot(5, hist)
    assert A._ladder_for("latency", snap, 8, base) == (32, 64, 128, 256)
    assert A._ladder_for("throughput", snap, 8, base) == (32, 256)
    assert A._ladder_for("latency", snap, 2, base) == (32, 256)
    none = A.QueueSnapshot(2, ((32, 2),))
    assert A._ladder_for("throughput", none, 8, base) == base


def test_snapshot_queue_histogram():
    snap = A.snapshot_queue([5, 30, 33, 70, 300], (32, 64))
    assert snap.pending == 5
    assert snap.hist == ((32, 2), (64, 1), (128, 1), (512, 1))
    assert A.bucket_of(65, (32, 64)) == 128 and A.bucket_of(64, (64,)) == 64


def test_validation_named_errors():
    with pytest.raises(PlanError, match="autoscale"):
        _plan(autoscale="bogus")
    with pytest.raises(PlanError,
                       match="batch_size.*power of two"):
        _plan(autoscale="latency", batch_size=12)
    with pytest.raises(A.AutoscaleError, match="autoscale"):
        A.AutoscaleController("nope", max_batch=8, granted=1, n_axes=1,
                              base_ladder=(64,))
    _plan(autoscale="latency")  # valid knob constructs


# ------------------------------------------------- end-to-end serving --


def test_labels_invariant_under_autoscale(fixture_round):
    """Scaling is result-neutral: per-request labels and the folded
    state match the static plan bitwise for the same stream (versions
    too, with no refresh cadence)."""
    fm, rr = fixture_round
    reqs, kvs = _requests(fm, 17, seed=3)
    depths = [1, 2, 8, 5, 1]
    static = Session.from_round(_plan(), rr)
    auto = Session.from_round(_plan(autoscale="latency"), rr)
    out_a = _serve_depths(static, reqs, kvs, depths)
    out_b = _serve_depths(auto, reqs, kvs, depths)
    for (la, va), (lb, vb) in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
        assert va == vb == 0
    for x, y in zip(jax.tree.leaves(static.service.state),
                    jax.tree.leaves(auto.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    st_ = auto.stats()["autoscale"]
    assert st_["decisions"] == len(depths)
    assert st_["batch_size"] == 1  # last flush had depth 1
    assert static.stats()["autoscale"]["decisions"] == 0  # off: static


def test_steady_state_never_recompiles(fixture_round):
    """Acceptance criterion: after one warm-up pass over a ramp load
    shape, repeating the ramp (any number of times) adds ZERO compiled
    signatures — the (shards, batch, bucket) step cache absorbs every
    scaling decision."""
    fm, rr = fixture_round
    reqs, kvs = _requests(fm, 32, seed=5, n_range=(100, 128))
    ramp = [1, 2, 4, 8]
    sess = Session.from_round(_plan(autoscale="latency",
                                    refresh_every=4), rr)
    _serve_depths(sess, reqs, kvs, ramp)          # warm-up
    warm = sess.stats()["plane_compiles"]
    for _ in range(3):
        _serve_depths(sess, reqs, kvs, ramp)      # steady state
    assert sess.stats()["plane_compiles"] == warm
    assert sess.stats()["autoscale"]["decisions"] == 4 * len(ramp)
    assert sess.tau_version > 0                   # refreshes really ran


def test_sharded_autoscale_matches_single_host(fixture_round):
    """Shard-count switching is result-neutral AND decision-neutral:
    the sharded-grant session makes the same (batch, ladder) decisions
    and serves bitwise-identical labels/versions as the single-host
    session (the CI mesh leg runs this at 2 and 8 devices)."""
    fm, rr = fixture_round
    reqs, kvs = _requests(fm, 2 * NDEV + 9, seed=7)
    depths = [1, NDEV, 2 * NDEV + 3, 2, 3]
    kw = dict(batch_size=16, refresh_every=3, refresh="async",
              autoscale="latency")
    single = Session.from_round(_plan(**kw), rr)
    shard = Session.from_round(_plan(**kw, serve_axes=("data",)), rr,
                               mesh=make_mesh((NDEV,), ("data",)))
    out_a = _serve_depths(single, reqs, kvs, depths)
    out_b = _serve_depths(shard, reqs, kvs, depths)
    for (la, va), (lb, vb) in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
        assert va == vb
    for x, y in zip(jax.tree.leaves(single.service.state),
                    jax.tree.leaves(shard.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    sa = single.stats()["autoscale"]
    sb = shard.stats()["autoscale"]
    assert sa["batch_size"] == sb["batch_size"]
    assert sa["ladder"] == sb["ladder"]
    assert sb["granted_shards"] == NDEV
    assert sb["shards"] <= NDEV


def test_oversized_coalesce_end_to_end(fixture_round):
    """Under throughput, a flush with multi-rung oversized backlog
    re-buckets into ONE coalesced rung (one jit shape) and still serves
    the exact labels of the static geometric ladder."""
    fm, rr = fixture_round
    stream = late_device_stream(fm.means, KP, 6, 9, n_range=(40, 290))
    reqs, kvs = [r[0] for r in stream], [r[2] for r in stream]
    static = Session.from_round(_plan(bucket_sizes=(32,)), rr)
    auto = Session.from_round(
        _plan(bucket_sizes=(32,), autoscale="throughput"), rr)
    with pytest.warns(ReproPerfWarning, match="largest configured"):
        out_a = static.serve(reqs, kvs)
    with pytest.warns(ReproPerfWarning, match="largest configured"):
        out_b = auto.serve(reqs, kvs)
    for la, lb in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
    ladder = auto.stats()["autoscale"]["ladder"]
    assert len(ladder) == 2 and ladder[0] == 32   # base + ONE rung
    assert ladder[1] >= max(r.shape[0] for r in reqs)


def test_oversized_warning_latches_per_ladder_rung(fixture_round):
    """The oversized-pad warning latches on the (active ladder, rung)
    pair, not a session-wide bool (bugfix): repeats of an already-
    warned shape are silent, a different rung warns once, and when the
    autoscaler COALESCES the ladder the re-bucketed shape warns once
    more under its new key — the old latch stayed silent forever after
    the first oversized request, hiding every later re-bucket."""
    fm, rr = fixture_round
    reqs, kvs = _requests(fm, 4, seed=21, n_range=(60, 61))
    big, bkv = _requests(fm, 2, seed=23, n_range=(130, 131))
    # static ladder: keyed per RUNG
    sess = Session.from_round(_plan(bucket_sizes=(32,)), rr)
    with pytest.warns(ReproPerfWarning, match="largest configured"):
        sess.serve(reqs[:1], kvs[:1])           # rung 64: warns once
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReproPerfWarning)
        sess.serve(reqs[1:2], kvs[1:2])         # same key: latched
    with pytest.warns(ReproPerfWarning, match="largest configured"):
        sess.serve(big[:1], bkv[:1])            # rung 256: new key
    # autoscale coalesce: keyed per LADDER too
    auto = Session.from_round(
        _plan(bucket_sizes=(32,), autoscale="throughput"), rr)
    with pytest.warns(ReproPerfWarning, match="largest configured"):
        auto.serve(reqs[:1], kvs[:1])           # ladder (32, 64)
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReproPerfWarning)
        auto.serve(reqs[1:2], kvs[1:2])         # same key: latched
    with pytest.warns(ReproPerfWarning, match="largest configured"):
        # multi-rung backlog coalesces the ladder; n=60 re-buckets to
        # the coalesced rung -> a NEW (ladder, rung) key warns again
        auto.serve([reqs[2], big[0]], [kvs[2], bkv[0]])
    assert len(auto.stats()["autoscale"]["ladder"]) == 2
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReproPerfWarning)
        auto.serve([reqs[3], big[1]], [kvs[3], bkv[1]])  # latched anew


def test_plane_rejects_out_of_grant_shards(fixture_round):
    fm, rr = fixture_round
    sess = Session.from_round(_plan(), rr)
    plane = sess.service.plane
    with pytest.raises(ServePlaneError, match="shards"):
        plane._plane_for(plane.n_shards + 1)


def test_mixed_rung_flush_right_sizes_each_group(fixture_round):
    """A flush spread across several pad rungs must not pad every
    bucket group up to the WHOLE queue's depth: each group's batch
    right-sizes to its own power-of-two rung under the decision's
    ceiling (repeat-padding rows are real compute)."""
    fm, rr = fixture_round
    sess = Session.from_round(_plan(autoscale="latency",
                                    batch_size=64), rr)
    for rung_lo in (10, 40, 100):       # 4 requests in each base rung
        stream = late_device_stream(fm.means, KP, 4, rung_lo,
                                    n_range=(rung_lo, rung_lo + 1))
        for data, _, kv in stream:
            sess.submit(data, kv)
    got = sess.flush()                  # depth 12 -> decision rung 16
    assert len(got) == 12
    assert sess.stats()["autoscale"]["batch_size"] == 16  # the ceiling
    steps = {sig[2][0] for sig in sess.service.plane._signatures
             if sig[0] == "step"}
    assert steps == {4}                 # every group executed at 4


def test_multi_axis_grant_right_sizes_to_one_shard(fixture_round):
    """A multi-axis serve grant has no canonical sub-grant: a
    right-sized bucket group must drop to ONE shard (the shard rule),
    never to an intermediate count the plane rejects mid-flush — and
    labels still match the single-host session bitwise."""
    fm, rr = fixture_round
    shape = (2, NDEV // 2) if NDEV % 2 == 0 else (1, NDEV)
    mesh = make_mesh(shape, ("a", "b"))
    kw = dict(autoscale="latency", batch_size=8)
    shard = Session.from_round(_plan(**kw, serve_axes=("a", "b")), rr,
                               mesh=mesh)
    single = Session.from_round(_plan(**kw), rr)
    reqs, kvs = [], []
    for rung_lo, count in ((10, 6), (40, 2)):   # mixed rungs: the
        stream = late_device_stream(fm.means, KP, count, rung_lo,
                                    n_range=(rung_lo, rung_lo + 1))
        reqs += [r[0] for r in stream]
        kvs += [r[2] for r in stream]
    out_a = single.serve(reqs, kvs)             # 2-request group right-
    out_b = shard.serve(reqs, kvs)              # sizes below the grant
    for la, lb in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
    used = {sig[1] for sig in shard.service.plane._signatures
            if sig[0] == "step"}
    assert used <= {1, NDEV}                    # never an intermediate


def test_restore_reconciles_decision_with_restoring_plan(fixture_round,
                                                         tmp_path):
    """A v3 checkpoint written under one plan restores under another:
    ``off`` serves at the RESTORING plan's static shape (never the
    writer's), and an adaptive controller clamps the batch rung to the
    new ceiling and recomputes shards from the new grant — no stale
    out-of-grant decision can crash the first flush."""
    fm, rr = fixture_round
    writer = Session.from_round(_plan(batch_size=64), rr)   # off, B=64
    reqs, kvs = _requests(fm, 6, seed=21)
    writer.serve(reqs, kvs)
    path = str(tmp_path / "wide.npz")
    writer.save(path)
    narrow = Session.restore(path, _plan(batch_size=8))
    for a, b in zip(writer.serve(reqs, kvs), narrow.serve(reqs, kvs)):
        np.testing.assert_array_equal(a, b)
    st = narrow.stats()["autoscale"]
    assert st["batch_size"] == 8 and st["max_batch"] == 8
    ctl = A.AutoscaleController("latency", max_batch=8, granted=2,
                                n_axes=1, base_ladder=(64,))
    ctl.load_state(np.asarray([8, 64, 5, 1]), np.asarray([64]))
    assert ctl.decision.batch_size == 8     # clamped to the ceiling
    assert ctl.decision.shards == 2         # recomputed from the grant
    assert ctl.decision.seq == 5 and ctl.streak == 1


# --------------------------------------------- checkpoint replay (v3) --


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 4), cut=st.integers(1, 3))
def test_decision_sequence_replays_bitwise_from_checkpoint(seed, cut):
    """Property (satellite acceptance): interrupt an autoscaled stream
    at ANY flush boundary, checkpoint, restore — the replica replays
    the remaining stream with bitwise-identical labels, tau versions,
    fold state, and the SAME decision sequence as the uninterrupted
    run."""
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    plan = _plan(autoscale="latency", refresh_every=3, refresh="async",
                 fold_policy="lru", capacity=24,
                 bucket_sizes=(32, 64))
    stream = late_device_stream(fm.means, KP, 20, 100 + seed,
                                n_range=(10, 150))
    reqs, kvs = [r[0] for r in stream], [r[2] for r in stream]
    depths = [1, 5, 2, 7, 1, 4]

    live = Session.from_round(plan, rr)
    ref = Session.from_round(plan, rr)
    out_ref = _serve_depths(ref, reqs, kvs, depths)   # uninterrupted

    out_live = _serve_depths(live, reqs, kvs, depths[:cut])
    import tempfile
    import os
    path = os.path.join(tempfile.mkdtemp(), "autoscale_v3.npz")
    live.save(path)
    replica = Session.restore(path, plan)
    served = sum(depths[:cut])
    # clients re-submit the remaining stream to both
    rest = [reqs[i % len(reqs)] for i in range(served, sum(depths))]
    rkvs = [kvs[i % len(kvs)] for i in range(served, sum(depths))]
    out_live += _serve_depths(live, rest, rkvs, depths[cut:])
    out_rep = _serve_depths(replica, rest, rkvs, depths[cut:])
    assert len(out_live) == len(out_ref)
    for (la, va), (lb, vb) in zip(out_live[served:], out_rep):
        np.testing.assert_array_equal(la, lb)
        assert va == vb
    for (la, va), (lb, vb) in zip(out_ref, out_live):
        np.testing.assert_array_equal(la, lb)
        assert va == vb
    for a, b in ((live, replica), (live, ref)):
        assert (a.service.autoscaler.decision
                == b.service.autoscaler.decision)
        assert a.service.autoscaler.streak == b.service.autoscaler.streak
        for x, y in zip(jax.tree.leaves(a.service.state),
                        jax.tree.leaves(b.service.state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_v3_checkpoint_schema_and_mismatch_error(fixture_round,
                                                 tmp_path):
    from repro.checkpoint.store import npz_keys
    fm, rr = fixture_round
    plan = _plan(autoscale="latency")
    sess = Session.from_round(plan, rr)
    reqs, kvs = _requests(fm, 5, seed=11)
    _serve_depths(sess, reqs, kvs, [2, 3])
    path = str(tmp_path / "v3.npz")
    sess.save(path)
    keys = npz_keys(path)
    assert {"autoscale_id", "autoscale_state",
            "autoscale_ladder"} <= keys
    assert "tau_meta" in keys                      # rides NEXT to v2 tau
    replica = Session.restore(path, plan)
    assert (replica.service.autoscaler.decision
            == sess.service.autoscaler.decision)
    with pytest.raises(StreamConfigError, match="autoscale"):
        Session.restore(path, _plan(autoscale="throughput"))


def test_v3_autoscale_checkpoint_restores_under_drift(fixture_round,
                                                      tmp_path):
    """A true v3 archive (autoscale decision state, 4-field pre-drift
    server, no epoch stamps) restores into an autoscaled AND
    drift-enabled v4 plan: the decision state replays bitwise while
    the drift layer starts from defaults, and serving continues with
    the labels the source session produces."""
    from repro.checkpoint.store import save_pytree
    from repro.fed.policy import POLICY_IDS
    from repro.fed.stream import AUTOSCALE_IDS, _ServerStateV3
    fm, rr = fixture_round
    src = Session.from_round(_plan(autoscale="latency"), rr)
    reqs, kvs = _requests(fm, 5, seed=29)
    _serve_depths(src, reqs, kvs, [2, 3])
    svc = src.service
    path = str(tmp_path / "v3_drift.npz")
    save_pytree(path, {
        "tau_bufs": svc._taubuf.bufs,
        "tau_meta": svc._taubuf.meta_array(),
        "server": _ServerStateV3(svc.state.centers, svc.state.mask,
                                 svc.state.weights, svc.state.received),
        "counters": svc._counters(),
        "policy_id": np.asarray(POLICY_IDS["drop"], np.int64),
        "policy": {},
        "autoscale_id": np.asarray(AUTOSCALE_IDS["latency"], np.int64),
        **svc.autoscaler.state_arrays()})
    rep = Session.restore(path, _plan(autoscale="latency", drift="decay",
                                      drift_half_life=64))
    assert rep.service.autoscaler.decision == svc.autoscaler.decision
    dstats = rep.stats()["drift"]
    assert dstats["mode"] == "decay" and dstats["events"] == 0
    more, mkv = _requests(fm, 4, seed=31)
    for a, b in zip(src.serve(more, mkv), rep.serve(more, mkv)):
        np.testing.assert_array_equal(a, b)


def test_v1_and_v2_checkpoints_restore_under_autoscale(fixture_round,
                                                       tmp_path):
    """Pre-v3 checkpoints (no autoscale arrays) restore into an
    autoscaled plan with a fresh static decision — and pre-v2 (single
    tau) still restore too."""
    from repro.checkpoint.store import save_pytree
    from repro.fed.policy import POLICY_IDS
    fm, rr = fixture_round
    sess = Session.from_round(_plan(), rr)
    reqs, kvs = _requests(fm, 4, seed=13)
    _serve_depths(sess, reqs, kvs, [4])
    svc = sess.service
    plan = _plan(autoscale="latency")
    common = {"server": svc.state, "counters": svc._counters(),
              "policy_id": np.asarray(POLICY_IDS["drop"], np.int64),
              "policy": {}}
    v2 = str(tmp_path / "v2.npz")
    save_pytree(v2, {"tau_bufs": svc._taubuf.bufs,
                     "tau_meta": svc._taubuf.meta_array(), **common})
    v1 = str(tmp_path / "v1.npz")
    save_pytree(v1, {"tau": svc.tau, **common})
    more, mkv = _requests(fm, 6, seed=17)
    want = sess.serve(more, mkv)
    for path in (v2, v1):
        replica = Session.restore(path, plan)
        assert replica.service.autoscaler.decision.seq == 0
        np.testing.assert_array_equal(np.asarray(replica.tau_centers),
                                      np.asarray(sess.tau_centers))
        for a, b in zip(want, replica.serve(more, mkv)):
            np.testing.assert_array_equal(a, b)
