"""Optional-hypothesis shim.

``hypothesis`` is not installed in every container this repo runs in.
Property-test modules import ``given/settings/st`` from here: with
hypothesis present they get the real thing; without it, ``@given`` runs
the test ONCE with each strategy's minimum value — a deterministic smoke
example — instead of failing collection for the whole module. A failing
example still FAILS the test; a passing one reports as SKIPPED (with
reason) rather than passed, so the lost strategy-space coverage stays
visible in the summary.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _MinExample:
        def __init__(self, example):
            self.example = example

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value=None):
            return _MinExample(min_value)

        @staticmethod
        def floats(min_value, max_value=None):
            return _MinExample(min_value)

    def settings(**_kw):
        return lambda f: f

    def given(**strategies):
        def deco(f):
            def run_min_example():
                import pytest
                f(**{k: s.example for k, s in strategies.items()})
                pytest.skip("hypothesis not installed: only the single "
                            "min-value example ran (and passed)")
            run_min_example.__name__ = f.__name__
            run_min_example.__doc__ = f.__doc__
            return run_min_example
        return deco
