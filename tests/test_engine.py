"""Federated engine scenarios (DESIGN.md §4): the shared server core,
partial participation with Theorem 3.2 re-attachment, asynchronous
staged arrival, and core-count-weighted aggregation — exercised through
the declarative ``fed.api.Session`` surface."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from _hyp import given, settings, st

from repro.core import kfed as K
from repro.core import server as S
from repro.core.local_kmeans import batched_local_kmeans
from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy


def _setup(key=0, k=16, d=24, k_prime=4, m0=4, n=20, sep=60.0):
    return structured_devices(jax.random.PRNGKey(key), k=k, d=d,
                              k_prime=k_prime, m0=m0, n_per_comp_dev=n,
                              sep=sep)


PLAN = FederationPlan(k=16, k_prime=4, d=24)


def run_round(key, data, plan, **kw):
    """One synchronous round through the Session surface, returning the
    engine-detail RoundResult the assertions inspect."""
    return Session(plan).run(key, data, **kw).detail


def run_round_async(key, data, plan, cohorts):
    sess = Session(plan).begin(key, data)
    for ids in cohorts:
        sess.fold(ids)
    return sess.finalize().detail


def test_engine_is_the_kfed_path():
    """The legacy kfed() shim is a thin configuration of the Session
    path; both equal the hand-composed stage pipeline through the
    shared server core."""
    fm = _setup()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        out = K.kfed(jax.random.PRNGKey(1), fm.data, k=16, k_prime=4)
    # re-arm the warn-once registry for the suite's legacy-leak guard
    from repro.utils.deprecation import reset_legacy_warnings
    reset_legacy_warnings()
    r = run_round(jax.random.PRNGKey(1), fm.data, PLAN)
    np.testing.assert_array_equal(np.asarray(r.labels),
                                  np.asarray(out.labels))

    keys = jax.random.split(jax.random.PRNGKey(1), fm.data.shape[0])
    loc = batched_local_kmeans(keys, fm.data, k_max=4)
    agg = S.aggregate(loc.centers, loc.center_mask, 16)
    labels = S.induced_labels(agg.center_labels, loc.assign)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(r.labels))
    assert clustering_accuracy(np.asarray(r.labels),
                               np.asarray(fm.labels), 16) > 0.98


def test_partial_participation_matches_theorem32_attachment():
    """Dropping a device from the round and re-attaching it post-hoc is
    EXACTLY the Theorem 3.2 nearest-center rule of assign_new_device."""
    fm = _setup()
    Z = fm.data.shape[0]
    drop = 5
    part = jnp.asarray(np.arange(Z) != drop)
    r = run_round(jax.random.PRNGKey(1), fm.data, PLAN, participation=part)

    # Manual attachment from the same local solve + retained tau centers.
    manual_ctr = S.assign_new_device(r.device_centers[drop],
                                     r.center_mask[drop],
                                     r.agg.tau_centers)
    manual_pts = S.induced_labels(manual_ctr[None],
                                  r.local_assign[drop][None])[0]
    np.testing.assert_array_equal(np.asarray(r.labels[drop]),
                                  np.asarray(manual_pts))
    # The aggregate itself never saw the dropped device.
    assert not bool(np.asarray(r.participated)[drop])
    assert np.all(np.asarray(r.agg.center_labels)[drop] == -1)
    # Everyone — including the re-attached device — lands correctly.
    assert clustering_accuracy(np.asarray(r.labels),
                               np.asarray(fm.labels), 16) > 0.97


def test_async_staged_arrival_bitwise_equals_oneshot():
    """Cohorts reporting across multiple aggregate_incremental folds, in
    any order, finalize to bitwise-identical labels."""
    fm = _setup()
    full = run_round(jax.random.PRNGKey(1), fm.data, PLAN)
    orders = [
        [[0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12, 13, 14, 15]],
        [[15, 3, 9], [0, 1, 2, 4, 5, 6, 7, 8], [10, 11, 12, 13, 14]],
        [[i] for i in reversed(range(16))],          # fully serialized
    ]
    for cohorts in orders:
        ra = run_round_async(jax.random.PRNGKey(1), fm.data, PLAN, cohorts)
        np.testing.assert_array_equal(np.asarray(ra.labels),
                                      np.asarray(full.labels))
        assert bool(np.all(np.asarray(ra.participated)))


def test_async_with_stragglers_matches_participation_mask():
    """Devices missing from every cohort == the same participation mask
    on the synchronous path, bitwise."""
    fm = _setup()
    missing = [3, 12]
    part = jnp.asarray(~np.isin(np.arange(16), missing))
    sync = run_round(jax.random.PRNGKey(1), fm.data, PLAN,
                     participation=part)
    cohorts = [[i for i in range(16) if i not in missing and i % 3 == j]
               for j in range(3)]
    ra = run_round_async(jax.random.PRNGKey(1), fm.data, PLAN, cohorts)
    np.testing.assert_array_equal(np.asarray(ra.labels),
                                  np.asarray(sync.labels))
    np.testing.assert_array_equal(np.asarray(ra.participated),
                                  np.asarray(sync.participated))


def test_incremental_redelivery_idempotent():
    """Re-delivering a cohort's report (retry after a network failure)
    cannot change the finalized clustering."""
    fm = _setup()
    full = run_round(jax.random.PRNGKey(1), fm.data, PLAN)
    cohorts = [[0, 1, 2, 3, 4, 5, 6, 7], [4, 5, 6, 7],  # retry overlap
               [8, 9, 10, 11, 12, 13, 14, 15], [0, 1, 2, 3]]
    ra = run_round_async(jax.random.PRNGKey(1), fm.data, PLAN, cohorts)
    np.testing.assert_array_equal(np.asarray(ra.labels),
                                  np.asarray(full.labels))


def test_weighted_aggregation_recovers_and_weights_the_update():
    """Core-count weighting keeps the paper's recovery guarantee on
    well-separated data, and lloyd_round really computes the weighted
    mean."""
    fm = _setup()
    plan = PLAN.with_options(weight_by_core_counts=True)
    r = run_round(jax.random.PRNGKey(1), fm.data, plan)
    assert clustering_accuracy(np.asarray(r.labels),
                               np.asarray(fm.labels), 16) > 0.98

    # Exact weighted-mean semantics on a tiny hand case: two points in
    # one cluster, weights 3 and 1 -> tau at the 3:1 interpolation.
    x = jnp.asarray([[0.0, 0.0], [4.0, 0.0]])
    fm_mask = jnp.ones((2,), bool)
    M = jnp.asarray([[1.0, 0.0]])
    w = jnp.asarray([3.0, 1.0])
    tau, labels = S.lloyd_round(x, fm_mask, M, 1, weights=w)
    np.testing.assert_array_equal(np.asarray(labels), [0, 0])
    np.testing.assert_allclose(np.asarray(tau), [[1.0, 0.0]])


def test_sharded_replicated_aggregate_share_one_core():
    """The duplicated-protocol regression guard: the replicated
    aggregate and the sharded execution route through the same greedy
    loop (lloyd.maxmin_grow) and the same Lloyd round
    (server.lloyd_round) — verified structurally, not by parallel
    reimplementations drifting into agreement."""
    import inspect
    from repro.core import lloyd as L
    rep_src = inspect.getsource(S.aggregate)
    sh_src = inspect.getsource(S.aggregate_sharded)
    assert "maxmin_seed" in rep_src and "lloyd_round" in rep_src
    assert "maxmin_grow" in sh_src and "lloyd_round" in sh_src
    assert "maxmin_grow" in inspect.getsource(L.maxmin_seed)
    # kfed.aggregate and the engine delegate to the same function.
    assert inspect.getsource(K.aggregate).count("S.aggregate") == 1


def test_server_state_fold_matches_oneshot_aggregate():
    """finalize(fold(cohorts)) == aggregate(all) when every device
    reports — the fold state is the one-shot sufficient statistic."""
    fm = _setup(m0=2)
    Z = fm.data.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(1), Z)
    loc = batched_local_kmeans(keys, fm.data, k_max=4)
    one = S.aggregate(loc.centers, loc.center_mask, 16)

    st = S.init_state(Z, 4, fm.data.shape[-1], loc.centers.dtype)
    for ids in (list(range(Z - 1, -1, -2)), list(range(0, Z, 2))):
        ids = jnp.asarray(ids, jnp.int32)
        st = S.aggregate_incremental(st, ids, loc.centers[ids],
                                     loc.center_mask[ids])
    inc = S.finalize(st, 16)
    np.testing.assert_array_equal(np.asarray(inc.center_labels),
                                  np.asarray(one.center_labels))
    np.testing.assert_array_equal(np.asarray(inc.seeds_idx),
                                  np.asarray(one.seeds_idx))
    np.testing.assert_allclose(np.asarray(inc.tau_centers),
                               np.asarray(one.tau_centers))


# ----------- property-based fold conformance (generated shapes) -----------
#
# The hand-picked cohort cases above pin two schedules; these generate the
# whole space: random (Z, k', d, k), random participation, a random
# permutation of the participants split at random chunk boundaries, plus a
# re-delivered chunk — every schedule must finalize bitwise identical to
# the synchronous aggregate with the same participation set.


def _aggs_equal(a: S.KFedAggregate, b: S.KFedAggregate) -> None:
    np.testing.assert_array_equal(np.asarray(a.seeds_idx),
                                  np.asarray(b.seeds_idx))
    np.testing.assert_array_equal(np.asarray(a.center_labels),
                                  np.asarray(b.center_labels))
    np.testing.assert_array_equal(np.asarray(a.tau_centers),
                                  np.asarray(b.tau_centers))  # bitwise
    np.testing.assert_array_equal(np.asarray(a.z0), np.asarray(b.z0))


def _fold_schedule(rng, st0, ids, centers, mask, weights):
    """Deliver ``ids`` permuted, in random chunks, with one random chunk
    re-delivered at a random later point (retry)."""
    perm = rng.permutation(ids)
    nchunks = int(rng.integers(1, len(perm) + 1))
    bounds = np.sort(rng.choice(np.arange(1, len(perm)),
                                size=min(nchunks - 1, len(perm) - 1),
                                replace=False)) if len(perm) > 1 else []
    cohorts = [c for c in np.split(perm, bounds) if len(c)]
    if cohorts:  # idempotent re-delivery of a random cohort
        cohorts.insert(int(rng.integers(0, len(cohorts) + 1)),
                       cohorts[int(rng.integers(0, len(cohorts)))])
    state = st0
    for ids_c in cohorts:
        ids_c = jnp.asarray(ids_c, jnp.int32)
        w = None if weights is None else weights[ids_c]
        state = S.aggregate_incremental(state, ids_c, centers[ids_c],
                                        mask[ids_c], weights=w)
    return state


@settings(max_examples=8, deadline=None)
@given(Z=st.integers(2, 20), kp=st.integers(1, 5), d=st.integers(1, 12),
       seed=st.integers(0, 2 ** 16))
def test_property_fold_conformance_bitwise(Z, kp, d, seed):
    rng = np.random.default_rng((Z, kp, d, seed))
    centers = jnp.asarray(rng.normal(size=(Z, kp, d)) * 3, jnp.float32)
    mask = rng.random((Z, kp)) < 0.7
    mask[:, 0] = True                       # >= 1 valid center per device
    mask = jnp.asarray(mask)
    part = rng.random(Z) < 0.8
    part[int(rng.integers(Z))] = True       # >= 1 participant
    weighted = bool(seed & 1)
    weights = (jnp.asarray(rng.uniform(0.5, 5.0, (Z, kp)), jnp.float32)
               if weighted else None)

    eff_mask = mask & jnp.asarray(part)[:, None]
    k = int(rng.integers(1, int(np.asarray(eff_mask).sum()) + 1))
    sync = S.aggregate(centers, eff_mask, k, weights=weights)

    st0 = S.init_state(Z, kp, d, centers.dtype)
    ids = np.nonzero(part)[0].astype(np.int32)
    folded = _fold_schedule(rng, st0, ids, centers, mask, weights)
    inc = S.finalize(folded, k, weighted=weighted)
    _aggs_equal(sync, inc)

    # A second independent schedule folds to the same state bitwise —
    # order/chunking invariance without reference to the sync path.
    folded2 = _fold_schedule(rng, st0, ids, centers, mask, weights)
    for la, lb in zip(jax.tree.leaves(folded), jax.tree.leaves(folded2)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
