"""benchmarks/compare.py — the CI perf-gate comparator (unit tests:
the gate's pass/fail logic must be testable without running a bench)."""
import json

import pytest

from benchmarks.compare import (compare_records, main, metric_rows,
                                parse_derived)


def _rec(rows):
    return {"rows": [{"bench": "attach", "name": n, "us_per_call": 0.0,
                      "derived": d} for n, d in rows]}


def test_parse_derived_extracts_floats_only():
    got = parse_derived("pts_per_s=1500;dev_per_s=12.5;bitwise=True")
    assert got == {"pts_per_s": 1500.0, "dev_per_s": 12.5}
    assert parse_derived("") == {}
    assert parse_derived("ERROR:'boom'") == {}


def test_metric_rows_filters_to_rows_carrying_the_metric():
    rec = _rec([("a", "pts_per_s=100"), ("b", "bitwise=True"),
                ("c", "x=1;pts_per_s=7")])
    assert metric_rows(rec, "pts_per_s") == {"a": 100.0, "c": 7.0}


def test_compare_within_tolerance_passes():
    base = _rec([("a", "pts_per_s=1000"), ("b", "pts_per_s=500")])
    cur = _rec([("a", "pts_per_s=700"), ("b", "pts_per_s=800")])
    comps, missing = compare_records(cur, base, tolerance=0.40)
    assert missing == []
    assert [c.regressed for c in comps] == [False, False]
    assert comps[0].ratio == pytest.approx(0.7)


def test_compare_flags_regression_beyond_tolerance():
    base = _rec([("a", "pts_per_s=1000")])
    cur = _rec([("a", "pts_per_s=599")])
    comps, _ = compare_records(cur, base, tolerance=0.40)
    assert comps[0].regressed
    comps, _ = compare_records(_rec([("a", "pts_per_s=601")]), base,
                               tolerance=0.40)
    assert not comps[0].regressed


def test_compare_reports_missing_baseline_rows():
    base = _rec([("a", "pts_per_s=10"), ("gone", "pts_per_s=10")])
    cur = _rec([("a", "pts_per_s=10"), ("new", "pts_per_s=10")])
    comps, missing = compare_records(cur, base)
    assert [c.name for c in comps] == ["a"]  # new rows aren't gated
    assert missing == ["gone"]


def _write(tmp_path, name, rec):
    p = tmp_path / name
    p.write_text(json.dumps(rec))
    return str(p)


def test_main_exit_codes_and_require(tmp_path, capsys):
    base = _write(tmp_path, "base.json",
                  _rec([("attach_bs8", "pts_per_s=1000")]))
    good = _write(tmp_path, "good.json",
                  _rec([("attach_bs8", "pts_per_s=900")]))
    bad = _write(tmp_path, "bad.json",
                 _rec([("attach_bs8", "pts_per_s=100")]))
    empty = _write(tmp_path, "empty.json",
                   _rec([("attach_bs8", "ERROR:'boom'")]))
    assert main([good, base]) == 0
    assert "perf gate OK" in capsys.readouterr().out
    assert main([bad, base]) == 1
    assert "REGRESSED" in capsys.readouterr().out
    # a bench that errored into zero metric rows: missing baseline row
    # AND an unmet --require both fail the gate
    assert main([empty, base, "--require", "attach_bs"]) == 1
    err = capsys.readouterr().err
    assert "missing" in err and "attach_bs" in err
    # tolerance is a knob: the same drop passes at 95%
    assert main([bad, base, "--tolerance", "0.95"]) == 0


def test_main_multi_metric(tmp_path, capsys):
    """--metric takes a comma list (the analytic roofline gate runs
    ai,bytes_saved_frac): each metric gates independently over the rows
    that carry it, a regression in ANY fails, and --require prefixes
    match against the union of compared rows."""
    base = _write(tmp_path, "mm_base.json", _rec([
        ("roofline_serve_fused_f32", "ai=0.80;bytes_per_pt=100"),
        ("roofline_serve_fusion_gain", "bytes_saved_frac=0.94")]))
    good = _write(tmp_path, "mm_good.json", _rec([
        ("roofline_serve_fused_f32", "ai=0.79;bytes_per_pt=101"),
        ("roofline_serve_fusion_gain", "bytes_saved_frac=0.93")]))
    bad_ai = _write(tmp_path, "mm_bad_ai.json", _rec([
        ("roofline_serve_fused_f32", "ai=0.40;bytes_per_pt=100"),
        ("roofline_serve_fusion_gain", "bytes_saved_frac=0.94")]))
    bad_frac = _write(tmp_path, "mm_bad_frac.json", _rec([
        ("roofline_serve_fused_f32", "ai=0.80;bytes_per_pt=100"),
        ("roofline_serve_fusion_gain", "bytes_saved_frac=0.10")]))

    args = ["--metric", "ai,bytes_saved_frac", "--tolerance", "0.10"]
    assert main([good, base] + args) == 0
    out = capsys.readouterr().out
    assert "[ai]" in out and "[bytes_saved_frac]" in out  # per-metric tables
    # a regression in EITHER metric fails the gate
    assert main([bad_ai, base] + args) == 1
    assert "ai 0.4" in capsys.readouterr().err
    assert main([bad_frac, base] + args) == 1
    assert "bytes_saved_frac" in capsys.readouterr().err
    # --require matches the union across metrics: the gain row carries
    # no ai, but the require prefix is still satisfied via its metric
    assert main([good, base] + args
                + ["--require",
                   "roofline_serve_fused,roofline_serve_fusion_gain"]) == 0
    assert main([good, base] + args + ["--require", "nonexistent_"]) == 1
    assert "--require" in capsys.readouterr().err
