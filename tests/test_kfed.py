"""End-to-end tests of Algorithm 1 + Algorithm 2 on the paper's synthetic
construction (Section 4.1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kfed as K
from repro.core.local_kmeans import local_kmeans
from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy


def _setup(key=0, k=16, d=32, k_prime=4, m0=3, n=25, sep=60.0):
    fm = structured_devices(jax.random.PRNGKey(key), k=k, d=d,
                            k_prime=k_prime, m0=m0, n_per_comp_dev=n,
                            sep=sep)
    return fm


def _kfed(key, data, k, k_prime, **kw):
    """End-to-end k-FED through the Session surface; returns the
    detailed RoundResult (a superset of the legacy KFedResult)."""
    plan = FederationPlan(k=k, k_prime=k_prime, d=int(data.shape[-1]))
    return Session(plan).run(key, data, **kw).detail


def test_local_kmeans_recovers_device_clusters():
    fm = _setup()
    res = local_kmeans(jax.random.PRNGKey(1), fm.data[0], k_max=4)
    acc = clustering_accuracy(np.asarray(res.assign),
                              np.asarray(fm.labels[0]) % 4, 4)
    assert acc > 0.99


def test_kfed_recovers_target_clustering():
    fm = _setup()
    out = _kfed(jax.random.PRNGKey(2), fm.data, 16, 4)
    acc = clustering_accuracy(np.asarray(out.labels),
                              np.asarray(fm.labels), 16)
    assert acc > 0.98


def test_kfed_seeds_one_center_per_target_cluster():
    """Lemma 6: max-min seeding picks exactly one device center per target
    cluster under the separation assumptions."""
    fm = _setup(sep=100.0)
    out = _kfed(jax.random.PRNGKey(3), fm.data, 16, 4)
    # Identify each seed's true cluster by nearest target mean.
    seeds = np.asarray(out.agg.seed_centers)
    means = np.asarray(fm.means)
    d = ((seeds[:, None] - means[None]) ** 2).sum(-1)
    assert len(set(d.argmin(1).tolist())) == 16


def test_kfed_heterogeneous_k_valid():
    """Devices with different k^(z) (some clusters missing)."""
    fm = _setup()
    # Drop one component from device 0 by masking its points.
    pm = np.ones(fm.labels.shape, bool)
    pm[0] = np.asarray(fm.labels[0] % 4) != 2
    kv = np.asarray(fm.k_valid).copy()
    kv[0] = 3
    out = _kfed(jax.random.PRNGKey(4), fm.data, 16, 4,
                k_valid=jnp.asarray(kv), point_mask=jnp.asarray(pm))
    acc = clustering_accuracy(np.asarray(out.labels)[pm],
                              np.asarray(fm.labels)[pm], 16)
    assert acc > 0.97


def test_induced_labels_definition():
    center_labels = jnp.array([[2, 0, -1], [1, 1, 3]])
    local_assign = jnp.array([[0, 1, -1], [2, 0, 1]])
    lbl = K.induced_labels(center_labels, local_assign)
    np.testing.assert_array_equal(np.asarray(lbl),
                                  [[2, 0, -1], [3, 1, 1]])


def test_assign_new_device_matches_existing_clustering():
    """Theorem 3.2: a straggler joining later is assigned correctly with
    no network-wide recomputation."""
    fm = _setup(sep=80.0)
    # Hold out the last device.
    out = _kfed(jax.random.PRNGKey(5), fm.data[:-1], 16, 4)
    loc = local_kmeans(jax.random.PRNGKey(6), fm.data[-1], k_max=4)
    lbl = K.assign_new_device(loc.centers, loc.center_mask,
                              out.agg.tau_centers)
    point_lbl = K.induced_labels(lbl[None], loc.assign[None])[0]
    # Consistency: new-device points land in the cluster holding the same
    # target component (compare against full-network run).
    full = _kfed(jax.random.PRNGKey(5), fm.data, 16, 4)
    # Map both labelings to target labels for comparison.
    acc_joint = clustering_accuracy(
        np.concatenate([np.asarray(out.labels).ravel(),
                        np.asarray(point_lbl).ravel()]),
        np.asarray(fm.labels).ravel(), 16)
    assert acc_joint > 0.97
    assert full is not None


def test_kmeans_cost_of_labels_matches_manual():
    x = jax.random.normal(jax.random.PRNGKey(7), (30, 4))
    lb = jnp.concatenate([jnp.zeros(15, jnp.int32), jnp.ones(15, jnp.int32)])
    cost = float(K.kmeans_cost_of_labels(x, lb, 2))
    manual = 0.0
    xn = np.asarray(x)
    for r in range(2):
        pts = xn[np.asarray(lb) == r]
        manual += ((pts - pts.mean(0)) ** 2).sum()
    np.testing.assert_allclose(cost, manual, rtol=1e-5)
