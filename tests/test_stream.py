"""Streaming attachment through the Session lifecycle (fed/api.py over
fed/stream.py, DESIGN.md §9–§10): batched Theorem 3.2 serving,
consistency with the full round, incremental folding + refresh, and
checkpointed crash recovery."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session
from repro.utils.metrics import clustering_accuracy

K, KP, D = 16, 4, 24


@pytest.fixture(scope="module")
def fixture_round():
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    return fm, rr


def _plan(**kw):
    base = dict(k=K, k_prime=KP, d=D, capacity=256, batch_size=4,
                bucket_sizes=(32, 64, 128))
    base.update(kw)
    return FederationPlan(**base)


def _session(rr, **kw) -> Session:
    """A serving session over the module fixture's finished round."""
    return Session.from_round(_plan(**kw), rr)


def _requests(fm, count, seed, n_lo=10, n_hi=120):
    """Heterogeneous (n, k') late devices from the round's mixture."""
    stream = late_device_stream(fm.means, KP, count, seed,
                                n_range=(n_lo, n_hi))
    return ([r[0] for r in stream], [r[1] for r in stream],
            [r[2] for r in stream])


def test_service_serves_heterogeneous_requests(fixture_round):
    """Mixed (n, k') requests land in the right clusters; reports fold."""
    fm, rr = fixture_round
    sess = _session(rr)
    reqs, truths, kvs = _requests(fm, 9, seed=3)
    labels = sess.serve(reqs, kvs)
    for lbl, truth, req in zip(labels, truths, reqs):
        assert lbl.shape == (req.shape[0],)
        assert clustering_accuracy(lbl, truth, K) > 0.97
    st = sess.stats()
    Z = fm.data.shape[0]
    assert st["served_devices"] == 9
    assert st["served_points"] == sum(r.shape[0] for r in reqs)
    assert st["folded"] == Z + 9  # round reports + streamed reports


def test_participating_device_attach_matches_round(fixture_round):
    """Theorem 3.2 consistency: a device that DID participate gets the
    same point labels from the serving attach path as the full round's
    induced labeling gave it."""
    fm, rr = fixture_round
    Z = fm.data.shape[0]
    # The round's per-device local-solve keys (fed.engine.local_stage).
    keys = jax.random.split(jax.random.PRNGKey(1), Z)
    attach = Session.from_tau(_plan(), rr.agg.tau_centers).attach_fn()
    for z in [0, 5, Z - 1]:
        pts = attach(keys[z], fm.data[z])
        np.testing.assert_array_equal(np.asarray(pts),
                                      np.asarray(rr.labels[z]))


def test_batched_service_matches_round_labels(fixture_round):
    """The batched service path agrees with the round's induced labels
    when fed participating devices' own data (fresh local solves —
    label agreement, the Theorem 3.2 guarantee on separated data)."""
    fm, rr = fixture_round
    sess = _session(rr, bucket_sizes=(128,))
    zs = [1, 4, 7, 10]
    labels = sess.serve([np.asarray(fm.data[z]) for z in zs])
    for lbl, z in zip(labels, zs):
        np.testing.assert_array_equal(lbl, np.asarray(rr.labels[z]))


def test_batched_vs_one_at_a_time_bitwise(fixture_round):
    """Serving a batch of B requests is bitwise identical to serving
    them one at a time: request PRNG streams are keyed by request id,
    never by batch composition."""
    fm, rr = fixture_round
    reqs, _, kvs = _requests(fm, 7, seed=5)
    batched = _session(rr, batch_size=4)
    single = _session(rr, batch_size=1)
    out_b = batched.serve(reqs, kvs)
    out_s = single.serve(reqs, kvs)
    for a, b in zip(out_b, out_s):
        np.testing.assert_array_equal(a, b)
    # The folded server states agree bitwise too.
    for la, lb in zip(jax.tree.leaves(batched.service.state),
                      jax.tree.leaves(single.service.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_checkpoint_restore_serve_bitwise(fixture_round, tmp_path):
    """Crash recovery: checkpoint mid-stream, restore, serve the rest —
    bitwise identical labels AND fold state vs the uninterrupted
    session (acceptance criterion)."""
    fm, rr = fixture_round
    live = _session(rr, refresh_every=6)  # cross a refresh mid-stream
    reqs, _, kvs = _requests(fm, 10, seed=9)
    live.serve(reqs[:5], kvs[:5])
    path = str(tmp_path / "attach_ck.npz")
    live.save(path)
    restored = Session.restore(path, live.plan)
    np.testing.assert_array_equal(np.asarray(live.tau_centers),
                                  np.asarray(restored.tau_centers))
    out_live = live.serve(reqs[5:], kvs[5:])
    out_rest = restored.serve(reqs[5:], kvs[5:])
    for a, b in zip(out_live, out_rest):
        np.testing.assert_array_equal(a, b)
    for la, lb in zip(jax.tree.leaves(live.service.state),
                      jax.tree.leaves(restored.service.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert restored.stats()["served_devices"] == 10  # 5 restored + 5 new


def test_pre_v4_checkpoints_restore_into_drift_enabled_plan(
        fixture_round, tmp_path):
    """Schema-migration matrix (DESIGN.md §14): v1 (single tau), v2
    (double-buffered tau) and v3 (+autoscale) archives — all carrying
    the 4-field pre-drift server state, no epoch stamps — restore into
    a drift-enabled v4 plan with drift state default-initialized (zero
    epochs, zero split/retire counters, zero mass) and serve bitwise
    what a drift=off restore of the same archive serves (drift is
    strictly additive). A v4 archive refuses a drift-mode mismatch
    with a named config error."""
    from repro.checkpoint.store import npz_keys, save_pytree
    from repro.fed.policy import POLICY_IDS
    from repro.fed.stream import (AUTOSCALE_IDS, StreamConfigError,
                                  _ServerStateV3)
    fm, rr = fixture_round
    base = _session(rr)
    reqs, _, kvs = _requests(fm, 10, seed=19)
    base.serve(reqs[:4], kvs[:4])
    svc = base.service
    old_srv = _ServerStateV3(svc.state.centers, svc.state.mask,
                             svc.state.weights, svc.state.received)
    common = {"server": old_srv, "counters": svc._counters(),
              "policy_id": np.asarray(POLICY_IDS["drop"], np.int64),
              "policy": {}}
    bufs = {"tau_bufs": svc._taubuf.bufs,
            "tau_meta": svc._taubuf.meta_array()}
    v1 = str(tmp_path / "v1.npz")
    save_pytree(v1, {"tau": svc.tau, **common})
    v2 = str(tmp_path / "v2.npz")
    save_pytree(v2, {**bufs, **common})
    v3 = str(tmp_path / "v3.npz")
    save_pytree(v3, {**bufs, **common,
                     "autoscale_id": np.asarray(AUTOSCALE_IDS["off"],
                                                np.int64),
                     **svc.autoscaler.state_arrays()})
    drift_kw = dict(drift="split_merge", drift_half_life=512,
                    drift_retire_frac=0.2)
    restored = None
    for path in (v1, v2, v3):
        assert "server/.epoch" not in npz_keys(path)   # truly pre-v4
        restored = Session.restore(path, _plan(**drift_kw))
        plain = Session.restore(path, _plan())
        d = restored.service
        assert (d._drift_events, d._drift_moves, d._drift_last) \
            == (0, 0, 0)
        np.testing.assert_array_equal(d._drift_mass,
                                      np.zeros((K,), np.float32))
        np.testing.assert_array_equal(np.asarray(d.state.epoch),
                                      np.zeros((256,), np.int32))
        np.testing.assert_array_equal(np.asarray(restored.tau_centers),
                                      np.asarray(base.tau_centers))
        out_d = restored.serve(reqs[4:], kvs[4:])
        out_p = plain.serve(reqs[4:], kvs[4:])
        for a, b in zip(out_d, out_p):
            np.testing.assert_array_equal(a, b)
        assert restored.stats()["drift"]["mode"] == "split_merge"
    v4 = str(tmp_path / "v4.npz")
    restored.save(v4)
    assert {"drift_id", "drift_state", "drift_mass",
            "server/.epoch"} <= npz_keys(v4)
    with pytest.raises(StreamConfigError, match="drift"):
        Session.restore(v4, _plan())


def test_refresh_refolds_round_plus_stream(fixture_round):
    """The refresh cadence re-finalizes Algorithm 2 over round + stream
    reports; serving quality holds across the tau swap."""
    fm, rr = fixture_round
    sess = _session(rr, refresh_every=3)
    reqs, truths, kvs = _requests(fm, 8, seed=13)
    labels = sess.serve(reqs, kvs)
    for lbl, truth in zip(labels, truths):
        assert clustering_accuracy(lbl, truth, K) > 0.97
    st = sess.stats()
    assert st["since_refresh"] < 3  # cadence fired
    assert np.all(np.isfinite(np.asarray(sess.tau_centers)))
    # An explicit refresh equals finalize over the current fold state.
    from repro.core import server as S
    agg = S.finalize(sess.service.state, K)
    sess.refresh()
    np.testing.assert_array_equal(np.asarray(sess.tau_centers),
                                  np.asarray(agg.tau_centers))


def test_capacity_overflow_serves_without_folding(fixture_round):
    """Requests past the fold capacity are still served (Theorem 3.2
    needs no state), just not folded (the drop admission policy)."""
    fm, rr = fixture_round
    Z = fm.data.shape[0]
    sess = _session(rr, capacity=Z + 2)
    reqs, truths, kvs = _requests(fm, 5, seed=17)
    labels = sess.serve(reqs, kvs)
    for lbl, truth in zip(labels, truths):
        assert clustering_accuracy(lbl, truth, K) > 0.97
    assert sess.stats()["folded"] == Z + 2


def test_submit_interleaved_with_serve_not_lost(fixture_round):
    """serve() must not swallow the results of requests that were
    already pending from submit(): they stay queued for the next
    flush()."""
    fm, rr = fixture_round
    sess = _session(rr)
    reqs, truths, kvs = _requests(fm, 2, seed=21)
    rid0 = sess.submit(reqs[0], kvs[0])
    sess.serve([reqs[1]], [kvs[1]])  # flushes rid0 too, must not drop it
    assert sess.stats()["undelivered"] == 1
    got = sess.flush()
    assert set(got) == {rid0}
    assert clustering_accuracy(got[rid0], truths[0], K) > 0.97


def test_flush_failure_requeues_and_keeps_results(fixture_round,
                                                  monkeypatch):
    """A batch failure mid-flush must not lose work: computed results
    stay in the undelivered buffer, unserved requests requeue."""
    fm, rr = fixture_round
    sess = _session(rr, batch_size=1)
    reqs, truths, kvs = _requests(fm, 2, seed=23, n_lo=10, n_hi=20)
    for r, kv in zip(reqs, kvs):
        sess.submit(r, kv)
    svc = sess.service
    orig, calls = svc._serve_batch, []

    def boom(batch, n_pad, out, decision):
        if calls:
            raise RuntimeError("boom")
        calls.append(1)
        orig(batch, n_pad, out, decision)

    monkeypatch.setattr(svc, "_serve_batch", boom)
    with pytest.raises(RuntimeError):
        sess.flush()
    st = sess.stats()
    assert st["pending"] == 1 and st["undelivered"] == 1
    monkeypatch.setattr(svc, "_serve_batch", orig)
    got = sess.flush()  # retry serves the requeued request, delivers both
    assert len(got) == 2
    for lbl, truth in zip(got.values(), truths):
        assert lbl.shape[0] == truth.shape[0]


def test_fold_drops_out_of_range_ids():
    """aggregate_incremental must DROP an over-capacity device id, not
    clip it onto (and corrupt) the last slot."""
    from repro.core import server as S
    st = S.init_state(4, 2, 3)
    good = jnp.ones((1, 2, 3))
    st = S.aggregate_incremental(st, jnp.asarray([3]), good,
                                 jnp.ones((1, 2), bool))
    bad = jnp.full((1, 2, 3), 7.0)
    st = S.aggregate_incremental(st, jnp.asarray([4]), bad,
                                 jnp.ones((1, 2), bool))
    np.testing.assert_array_equal(np.asarray(st.centers[3]),
                                  np.ones((2, 3)))
    np.testing.assert_array_equal(np.asarray(st.received),
                                  [False, False, False, True])
