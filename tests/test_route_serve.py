"""Cluster-routed personalization serving (fed/plane.py routed step +
fed/stream.py heads plumbing, DESIGN.md §16).

Covers the §16 contract end to end: the routed step's label body is
bitwise the heads=off plane (labels, fold state, tau versions never
move when heads turn on); online routing and offline
``cluster_devices`` personalization agree through the SAME majority
vote; kept requests match the IFCA-shaped all-k baseline's
predictions; overflow is labels-only with a zero prediction; head
params ride checkpoint schema v5 (v1–v4 archives restore with fresh
deterministic heads); tau split/retire re-maps head assignment through
the same atomic version bump; and the steady state never recompiles.
The CI mesh matrix ({2,8} forced host devices) runs this file too —
the sharded-parity test pins the shard_mapped routed plane against the
single-host plane bitwise.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session
from repro.fed import plane as plane_mod
from repro.fed.personalize import majority_vote
from repro.fed.stream import StreamConfig, StreamConfigError
from repro.models import heads as heads_mod
from repro.utils.compat import make_mesh

K, KP, D = 16, 4, 24
NDEV = jax.device_count()
HEADS = "qwen1.5-0.5b"


@pytest.fixture(scope="module")
def fixture_round():
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    return fm, rr


def _plan(**kw):
    base = dict(k=K, k_prime=KP, d=D, capacity=256, batch_size=4,
                bucket_sizes=(32, 64, 128))
    base.update(kw)
    return FederationPlan(**base)


def _requests(fm, count, seed, n_lo=10, n_hi=120):
    stream = late_device_stream(fm.means, KP, count, seed,
                                n_range=(n_lo, n_hi))
    return ([r[0] for r in stream], [r[1] for r in stream],
            [r[2] for r in stream])


def _step_cfg(**kw):
    base = dict(k=8, k_prime=2, d=16, capacity=64, batch_size=8,
                bucket_sizes=(32,), heads=HEADS, head_arch="ffn")
    base.update(kw)
    return StreamConfig(**base)


def _step_inputs(cfg, n=32, spread=True):
    """(tau, heads, keys, data, pmask, kv) for a direct step call."""
    k, d, B = cfg.k, cfg.d, cfg.batch_size
    kt, kd, kh, kk = jax.random.split(jax.random.PRNGKey(42), 4)
    tau = jax.random.normal(kt, (k, d), jnp.float32) * 20.0
    owner = (jnp.arange(B, dtype=jnp.int32) % k if spread
             else jnp.zeros((B,), jnp.int32))
    data = (jax.random.normal(kd, (B, n, d), jnp.float32)
            + tau[owner][:, None, :])
    pmask = jnp.ones((B, n), jnp.bool_)
    keys = jax.random.split(kk, B).astype(jnp.uint32).reshape(B, 2)
    kv = jnp.full((B,), k, jnp.int32)
    heads = heads_mod.init_heads(kh, k, cfg.head_spec())
    return tau, heads, keys, data, pmask, kv


# ------------------------------------------------ routed step (plane) --


def test_routed_step_labels_bitwise_match_plain_step():
    """The routed step shares the label body: labels, centers, masks
    and weights are bitwise the heads=off serve step's."""
    cfg = _step_cfg()
    tau, heads, keys, data, pmask, kv = _step_inputs(cfg)
    plain = jax.jit(plane_mod._make_step(cfg))(tau, keys, data, pmask,
                                               kv)
    routed = jax.jit(plane_mod._make_routed_step(cfg))(
        tau, heads, keys, data, pmask, kv)
    for a, b in zip(plain, routed[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_routed_matches_allk_baseline_on_kept_requests():
    """Kept requests get bitwise the prediction the IFCA-shaped run-
    all-k-heads baseline computes; cluster votes agree everywhere."""
    cfg = _step_cfg()
    args = _step_inputs(cfg)
    r = jax.jit(plane_mod._make_routed_step(cfg))(*args)
    a = jax.jit(plane_mod._make_allk_step(cfg))(*args)
    np.testing.assert_array_equal(np.asarray(r[5]), np.asarray(a[5]))
    kept = np.asarray(r[6])
    assert kept.any()
    np.testing.assert_allclose(np.asarray(r[4])[kept],
                               np.asarray(a[4])[kept],
                               rtol=1e-6, atol=1e-6)


def test_overflow_is_labels_only_with_zero_prediction():
    """All requests voting one cluster: C = ceil(B/k) slots keep the
    first arrivals, the rest overflow — kept=False, prediction exactly
    zero, labels still served."""
    cfg = _step_cfg(k=4, head_capacity=1.0)
    tau, heads, keys, data, pmask, kv = _step_inputs(cfg, spread=False)
    out = jax.jit(plane_mod._make_routed_step(cfg))(
        tau, heads, keys, data, pmask, kv)
    labels, preds, cluster, kept = (np.asarray(out[0]),
                                    np.asarray(out[4]),
                                    np.asarray(out[5]),
                                    np.asarray(out[6]))
    B = cfg.batch_size
    C = plane_mod.route_capacity(B, cfg.k, cfg.head_capacity)
    np.testing.assert_array_equal(cluster, np.zeros((B,), np.int32))
    np.testing.assert_array_equal(kept, np.arange(B) < C)
    assert (preds[~kept] == 0.0).all()
    assert np.abs(preds[kept]).sum() > 0
    assert (labels[kept.argmin():] == labels[0]).all()  # still labeled


def test_bf16_head_forward_tracks_f32_oracle():
    """serve_dtype="bf16" head forwards stay within bf16 tolerance of
    the f32 oracle (f32 accumulation contract: errors are rounding,
    not accumulation drift)."""
    cfg = _step_cfg()
    spec = cfg.head_spec()
    kh, kd = jax.random.split(jax.random.PRNGKey(5))
    heads = heads_mod.init_heads(kh, cfg.k, spec)
    C, n = 2, 32
    qdata = jax.random.normal(kd, (cfg.k, C, n, cfg.d), jnp.float32)
    qmask = jnp.ones((cfg.k, C, n), jnp.bool_)
    y32 = heads_mod.apply_heads(heads, qdata, qmask, spec,
                                serve_dtype="f32")
    ybf = heads_mod.apply_heads(heads, qdata, qmask, spec,
                                serve_dtype="bf16")
    assert y32.dtype == ybf.dtype == jnp.float32
    scale = np.abs(np.asarray(y32)).max()
    np.testing.assert_allclose(np.asarray(ybf), np.asarray(y32),
                               atol=0.05 * max(scale, 1.0))


# ------------------------------------------- service + session layer --


def test_predict_labels_bitwise_vs_heads_off_session(fixture_round):
    """Turning heads on never moves the attachment tier: labels, tau
    versions AND the folded server state are bitwise the heads=off
    session's (acceptance criterion)."""
    fm, rr = fixture_round
    plain = Session.from_round(_plan(), rr)
    routed = Session.from_round(_plan(heads="linear"), rr)
    reqs, _, kvs = _requests(fm, 9, seed=3)
    out_p = plain.serve_versioned(reqs, kvs)
    out_r = routed.serve_predict(reqs, kvs)
    for (lbl, ver), pred in zip(out_p, out_r):
        np.testing.assert_array_equal(lbl, pred.labels)
        assert ver == pred.tau_version
        assert pred.prediction.shape == (D,)
        assert pred.routed
    for x, y in zip(jax.tree.leaves(plain.service.state),
                    jax.tree.leaves(routed.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    st = routed.stats()["heads"]
    assert st["mode"] == "linear" and st["routed_served"] == 9
    assert plain.stats()["heads"] == {"mode": "off"}


def test_online_routing_matches_offline_cluster_devices(fixture_round):
    """§4.2.2 parity: the cluster a request routes to online is the
    SAME majority vote offline ``cluster_devices`` personalization
    assigns on identical labels — participating devices' own data
    reproduces the round's labels, so the served cluster equals the
    offline assignment computed from ``rr.labels``."""
    fm, rr = fixture_round
    offline = np.asarray(majority_vote(jnp.asarray(rr.labels), K))
    sess = Session.from_round(_plan(bucket_sizes=(128,), heads="linear"),
                              rr)
    zs = [1, 4, 7, 10]
    out = sess.serve_predict([np.asarray(fm.data[z]) for z in zs])
    for pred, z in zip(out, zs):
        np.testing.assert_array_equal(pred.labels,
                                      np.asarray(rr.labels[z]))
        assert pred.cluster == offline[z]


def test_session_overflow_flags_requests(fixture_round):
    """head_capacity below the skew floor: overflowed requests come
    back routed=False with a zero prediction and full labels; the
    overflow counter ticks."""
    fm, rr = fixture_round
    sess = Session.from_round(
        _plan(heads="linear", head_capacity=0.1, batch_size=8), rr)
    reqs, _, kvs = _requests(fm, 8, seed=21)
    out = sess.serve_predict(reqs, kvs)
    dropped = [p for p in out if not p.routed]
    assert dropped  # C = 1 slot per cluster cannot hold the batch skew
    for p in dropped:
        assert (p.prediction == 0.0).all()
        assert p.labels.shape[0] > 0
    assert sess.stats()["heads"]["overflowed"] == len(dropped)


def test_serve_predict_requires_heads(fixture_round):
    fm, rr = fixture_round
    sess = Session.from_round(_plan(), rr)
    reqs, _, kvs = _requests(fm, 2, seed=1)
    with pytest.raises(StreamConfigError, match="heads"):
        sess.serve_predict(reqs, kvs)


def test_zero_steady_state_recompiles(fixture_round):
    """After the first wave warms each bucket, further routed waves
    never recompile (acceptance criterion)."""
    fm, rr = fixture_round
    sess = Session.from_round(_plan(heads="linear",
                                    bucket_sizes=(128,)), rr)
    reqs, _, kvs = _requests(fm, 12, seed=17, n_hi=100)
    sess.serve_predict(reqs[:4], kvs[:4])
    warm = sess.stats()["plane_compiles"]
    for lo in range(4, 12, 4):
        sess.serve_predict(reqs[lo:lo + 4], kvs[lo:lo + 4])
    assert sess.stats()["plane_compiles"] == warm


def test_split_retire_remaps_heads_through_version_bump(fixture_round):
    """Drift split/retire under heads: the donor's head follows the
    re-seeded center through the SAME atomic tau bump (no staged remap
    left pending at the end), labels stay bitwise the heads=off drift
    twin's, and the whole routed stream replays deterministically."""
    fm, rr = fixture_round
    rng = np.random.default_rng(3)
    new_means = rng.normal(size=(K, D)).astype(np.float32) * 40.0
    kw = dict(refresh_every=4, drift="split_merge", drift_half_life=24,
              drift_retire_frac=0.2, capacity=512)
    stream = late_device_stream(new_means, KP, 24, 19, n_range=(15, 50))
    reqs = [r[0] for r in stream]
    kvs = [r[2] for r in stream]
    plain = Session.from_round(_plan(**kw), rr)
    routed = Session.from_round(_plan(**kw, heads="linear"), rr)
    twin = Session.from_round(_plan(**kw, heads="linear"), rr)
    for lo in range(0, 24, 6):
        out_p = plain.serve_versioned(reqs[lo:lo + 6], kvs[lo:lo + 6])
        out_r = routed.serve_predict(reqs[lo:lo + 6], kvs[lo:lo + 6])
        out_t = twin.serve_predict(reqs[lo:lo + 6], kvs[lo:lo + 6])
        for (lbl, ver), pr, pt in zip(out_p, out_r, out_t):
            np.testing.assert_array_equal(lbl, pr.labels)
            assert ver == pr.tau_version
            np.testing.assert_array_equal(pr.prediction, pt.prediction)
            assert (pr.cluster, pr.routed) == (pt.cluster, pt.routed)
    assert routed.service._drift_events > 0      # machinery exercised
    assert routed.tau_version == plain.tau_version > 0
    assert routed.stats()["heads"]["remap_pending"] is False
    for x, y in zip(jax.tree.leaves(plain.service.state),
                    jax.tree.leaves(routed.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------- checkpoint schema --


def test_checkpoint_v5_roundtrip_bitwise(fixture_round, tmp_path):
    """Schema v5: save mid-stream with heads on, restore, serve the
    rest — labels AND predictions bitwise vs the uninterrupted
    session; the archive carries the heads tag + folded params."""
    from repro.checkpoint.store import npz_keys
    fm, rr = fixture_round
    live = Session.from_round(_plan(heads=HEADS, refresh_every=6), rr)
    reqs, _, kvs = _requests(fm, 10, seed=9)
    live.serve_predict(reqs[:5], kvs[:5])
    path = str(tmp_path / "v5.npz")
    live.save(path)
    assert "heads_tag" in npz_keys(path)
    restored = Session.restore(path, live.plan)
    out_l = live.serve_predict(reqs[5:], kvs[5:])
    out_r = restored.serve_predict(reqs[5:], kvs[5:])
    for a, b in zip(out_l, out_r):
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(a.prediction, b.prediction)
        assert (a.tau_version, a.cluster, a.routed) == \
            (b.tau_version, b.cluster, b.routed)
    assert restored.stats()["heads"]["routed_served"] == 10


def test_v5_archive_refuses_mismatched_heads(fixture_round, tmp_path):
    """A v5 archive names its head config: restoring under heads=off
    or a different config fails with a named error, never a silent
    re-init."""
    fm, rr = fixture_round
    sess = Session.from_round(_plan(heads=HEADS), rr)
    path = str(tmp_path / "v5.npz")
    sess.save(path)
    with pytest.raises(StreamConfigError, match="heads"):
        Session.restore(path, _plan())
    with pytest.raises(StreamConfigError, match="heads"):
        Session.restore(path, _plan(heads="linear"))


def test_pre_v5_archives_restore_with_fresh_heads(fixture_round,
                                                  tmp_path):
    """Migration matrix: v1–v4 archives (no heads_tag) restore into a
    heads-on plan with deterministically re-initialized heads — labels
    bitwise what a heads=off restore serves, predictions identical
    across two restores of the same archive."""
    from repro.checkpoint.store import npz_keys, save_pytree
    from repro.fed.policy import POLICY_IDS
    from repro.fed.stream import AUTOSCALE_IDS, _ServerStateV3
    fm, rr = fixture_round
    base = Session.from_round(_plan(), rr)
    reqs, _, kvs = _requests(fm, 10, seed=19)
    base.serve(reqs[:4], kvs[:4])
    svc = base.service
    old_srv = _ServerStateV3(svc.state.centers, svc.state.mask,
                             svc.state.weights, svc.state.received)
    common = {"server": old_srv, "counters": svc._counters(),
              "policy_id": np.asarray(POLICY_IDS["drop"], np.int64),
              "policy": {}}
    bufs = {"tau_bufs": svc._taubuf.bufs,
            "tau_meta": svc._taubuf.meta_array()}
    v1 = str(tmp_path / "v1.npz")
    save_pytree(v1, {"tau": svc.tau, **common})
    v2 = str(tmp_path / "v2.npz")
    save_pytree(v2, {**bufs, **common})
    v3 = str(tmp_path / "v3.npz")
    save_pytree(v3, {**bufs, **common,
                     "autoscale_id": np.asarray(AUTOSCALE_IDS["off"],
                                                np.int64),
                     **svc.autoscaler.state_arrays()})
    v4 = str(tmp_path / "v4.npz")
    base.save(v4)
    for path in (v1, v2, v3, v4):
        assert "heads_tag" not in npz_keys(path)    # truly pre-v5
        plain = Session.restore(path, _plan())
        routed = Session.restore(path, _plan(heads="linear"))
        again = Session.restore(path, _plan(heads="linear"))
        out_p = plain.serve_versioned(reqs[4:], kvs[4:])
        out_r = routed.serve_predict(reqs[4:], kvs[4:])
        out_a = again.serve_predict(reqs[4:], kvs[4:])
        for (lbl, ver), pr, pa in zip(out_p, out_r, out_a):
            np.testing.assert_array_equal(lbl, pr.labels)
            assert ver == pr.tau_version
            np.testing.assert_array_equal(pr.prediction, pa.prediction)


# ------------------------------------------------- config validation --


def test_config_validation_names_the_field():
    with pytest.raises(StreamConfigError, match="heads"):
        _step_cfg(heads="no-such-config")
    with pytest.raises(StreamConfigError, match="head_capacity"):
        _step_cfg(head_capacity=0.0)
    with pytest.raises(StreamConfigError, match="head_arch"):
        _step_cfg(head_arch="cnn")
    with pytest.raises(heads_mod.HeadConfigError, match="arch"):
        heads_mod.resolve_head_spec(HEADS, "cnn", 16)
    spec = heads_mod.resolve_head_spec(HEADS, "transformer", 16)
    bad_d = spec.n_heads * 2 + 1  # never divisible by n_heads > 1
    if spec.n_heads > 1:
        with pytest.raises(StreamConfigError, match="heads"):
            _step_cfg(d=bad_d, head_arch="transformer")


def test_head_zoo_stays_reachable_in_import_report():
    """Satellite: the §16 heads make the models/configs zoo
    load-bearing — the import-graph report shows every zoo module
    reachable and the serving head modules live."""
    from repro.analysis.imports import report
    rep = report()
    assert rep["unreachable"] == []
    assert "repro.models.heads" in rep["reachable"]
    assert "repro.configs.qwen1_5_0_5b" in rep["reachable"]


# ------------------------------------------------------ sharded plane --


@pytest.mark.skipif(NDEV < 2, reason="needs >= 2 devices (CI mesh leg)")
def test_sharded_routed_parity_with_single_host(fixture_round):
    """The shard_mapped routed plane serves bitwise the single-host
    plane: labels, predictions, clusters, kept flags and the folded
    state (acceptance criterion at the CI {2,8}-device legs)."""
    fm, rr = fixture_round
    kw = dict(heads="linear", batch_size=2 * NDEV)
    single = Session.from_round(_plan(**kw), rr)
    shard = Session.from_round(_plan(**kw, serve_axes=("data",)), rr,
                               mesh=make_mesh((NDEV,), ("data",)))
    reqs, _, kvs = _requests(fm, 3 * NDEV + 1, seed=3)
    out_a = single.serve_predict(reqs, kvs)
    out_b = shard.serve_predict(reqs, kvs)
    for a, b in zip(out_a, out_b):
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(a.prediction, b.prediction)
        assert (a.tau_version, a.cluster, a.routed) == \
            (b.tau_version, b.cluster, b.routed)
    for x, y in zip(jax.tree.leaves(single.service.state),
                    jax.tree.leaves(shard.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
