"""Federated runtime tests: FedAvg, IFCA, selection, personalization."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._models import init_mlp, mlp_accuracy, mlp_loss
from repro.data.synthetic_tasks import rotation_tasks
from repro.fed.fedavg import FedAvgConfig, fedavg_round, weighted_average
from repro.fed.ifca import ifca_round
from repro.fed.personalize import kfed_personalize
from repro.fed.selection import kfed_pow_d, pow_d, random_selection


def _setup(Z=8, k=2, kp=1):
    rng = np.random.default_rng(0)
    data = rotation_tasks(rng, Z=Z, n_per_dev=24, d=16, k=k, k_prime=kp,
                          n_classes=4)
    dev = {"x": jnp.asarray(data.x), "y": jnp.asarray(data.y),
           "mask": jnp.asarray(data.point_mask)}
    return data, dev


def test_weighted_average():
    stack = {"w": jnp.stack([jnp.zeros((2,)), jnp.ones((2,)) * 4])}
    avg = weighted_average(stack, jnp.array([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(avg["w"]), 3.0)


def test_fedavg_reduces_loss():
    data, dev = _setup()
    cfg = FedAvgConfig(lr=0.2, local_epochs=2, rounds=1)
    params = init_mlp(jax.random.PRNGKey(0), 16, 16, 4)
    losses = []
    for _ in range(6):
        params, l = fedavg_round(mlp_loss, params, dev, cfg,
                                 point_mask=dev["mask"])
        losses.append(float(l))
    assert losses[-1] < losses[0]


def test_fedavg_member_mask_restricts():
    data, dev = _setup()
    cfg = FedAvgConfig(lr=0.2, local_epochs=1)
    params = init_mlp(jax.random.PRNGKey(0), 16, 16, 4)
    member = jnp.zeros((dev["x"].shape[0],)).at[0].set(1.0)
    p2, _ = fedavg_round(mlp_loss, params, dev, cfg,
                         point_mask=dev["mask"], member_mask=member)
    # equals a pure local update of device 0
    from repro.fed.client import local_sgd
    upd = local_sgd(mlp_loss, params,
                    {"x": dev["x"][0], "y": dev["y"][0],
                     "mask": dev["mask"][0]}, lr=0.2, epochs=1)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(upd.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


def test_ifca_assigns_and_improves():
    data, dev = _setup(Z=8, k=2)
    cfg = FedAvgConfig(lr=0.2, local_epochs=2)
    keys = jax.random.split(jax.random.PRNGKey(1), 2)
    models = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[init_mlp(k, 16, 16, 4) for k in keys])
    for _ in range(5):
        models, choice, loss = ifca_round(mlp_loss, models, dev, cfg,
                                          point_mask=dev["mask"])
    assert choice.shape == (8,)
    assert set(np.asarray(choice).tolist()) <= {0, 1}


def test_selection_strategies():
    rng = np.random.default_rng(0)
    losses = np.array([0.1, 0.9, 0.5, 0.8, 0.2, 0.7])
    sel = pow_d(rng, losses, m=2, d=6)
    assert losses[sel[0]] >= losses[sel[1]]
    clusters = np.array([0, 0, 1, 1, 2, 2])
    sel2 = kfed_pow_d(rng, losses, clusters, m=3, d=6)
    assert len(set(clusters[sel2])) == 3  # one per cluster
    assert len(random_selection(rng, 6, 3)) == 3


def test_kfed_personalize_end_to_end():
    data, dev = _setup(Z=12, k=2, kp=1)
    cfg = FedAvgConfig(lr=0.2, local_epochs=2, rounds=3)
    init = init_mlp(jax.random.PRNGKey(0), 16, 16, 4)
    feats = jnp.asarray(data.x.mean(axis=1, keepdims=True))
    models, assign, hist = kfed_personalize(
        jax.random.PRNGKey(1), mlp_loss, init, dev, feats, 2, cfg,
        point_mask=dev["mask"])
    # clustered models beat chance on their devices
    accs = [float(mlp_accuracy(
        jax.tree.map(lambda l: l[int(assign[z])], models),
        dev["x"][z], dev["y"][z])) for z in range(12)]
    assert np.mean(accs) > 0.3
    # device clustering should largely agree with true rotation clusters
    from repro.utils.metrics import clustering_accuracy
    assert clustering_accuracy(np.asarray(assign), data.cluster, 2) > 0.8
