"""shard_map distributed k-FED vs the single-host vmap simulation.

These run in a subprocess because the forced 8-device host platform must
be configured before JAX initializes (the main test process keeps the
single real CPU device).
"""
import os
import subprocess
import sys

import pytest

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.compat import make_mesh
from repro.core import kfed as K
from repro.core.distributed import distributed_lloyd, kfed_shard_map
from repro.data.gaussian import structured_devices
from repro.utils.metrics import clustering_accuracy

mesh = make_mesh((8,), ("data",))
fm = structured_devices(jax.random.PRNGKey(0), k=16, d=24, k_prime=4,
                        m0=4, n_per_comp_dev=20, sep=60.0)
assert fm.data.shape[0] == 16  # 16 devices over 8 shards

labels, tau = kfed_shard_map(mesh, fm.data, 16, 4,
                             key=jax.random.PRNGKey(1))
acc = clustering_accuracy(np.asarray(labels), np.asarray(fm.labels), 16)
assert acc > 0.98, f"shard_map kfed accuracy {acc}"

# Simulation path gives the same numerics (same key).
sim = K.kfed(jax.random.PRNGKey(1), fm.data, k=16, k_prime=4)
np.testing.assert_array_equal(np.asarray(labels), np.asarray(sim.labels))

# Sharded-server variant (beyond-paper, §Perf k-FED iter 2): identical
# clustering, same tau centers, no (Z, k', d) gather in its schedule.
sh_labels, sh_tau = kfed_shard_map(mesh, fm.data, 16, 4,
                                   key=jax.random.PRNGKey(1),
                                   server="sharded")
np.testing.assert_array_equal(np.asarray(sh_labels), np.asarray(labels))
np.testing.assert_allclose(np.asarray(sh_tau), np.asarray(tau),
                           rtol=1e-4, atol=1e-4)

# Partial participation: drop two devices; all THREE paths (vmap
# simulation, replicated server, sharded server) route through the one
# shared server core and must produce identical labels — the dropped
# devices re-attached post-hoc via the Theorem 3.2 rule.
part = np.ones(16, bool); part[[3, 12]] = False
part = jnp.asarray(part)
p_sim = K.kfed(jax.random.PRNGKey(1), fm.data, k=16, k_prime=4,
               participation=part)
p_rep, _ = kfed_shard_map(mesh, fm.data, 16, 4,
                          key=jax.random.PRNGKey(1), participation=part)
p_sh, _ = kfed_shard_map(mesh, fm.data, 16, 4,
                         key=jax.random.PRNGKey(1), server="sharded",
                         participation=part)
np.testing.assert_array_equal(np.asarray(p_rep), np.asarray(p_sim.labels))
np.testing.assert_array_equal(np.asarray(p_sh), np.asarray(p_rep))
p_acc = clustering_accuracy(np.asarray(p_rep), np.asarray(fm.labels), 16)
assert p_acc > 0.97, f"participation accuracy {p_acc}"

# Core-count-weighted aggregation: same three-way parity.
w_sim = K.kfed(jax.random.PRNGKey(1), fm.data, k=16, k_prime=4,
               weight_by_core_counts=True)
w_rep, _ = kfed_shard_map(mesh, fm.data, 16, 4,
                          key=jax.random.PRNGKey(1),
                          weight_by_core_counts=True)
w_sh, _ = kfed_shard_map(mesh, fm.data, 16, 4,
                         key=jax.random.PRNGKey(1), server="sharded",
                         weight_by_core_counts=True)
np.testing.assert_array_equal(np.asarray(w_rep), np.asarray(w_sim.labels))
np.testing.assert_array_equal(np.asarray(w_sh), np.asarray(w_rep))

# The collective schedule really is one-shot: exactly one all-gather
# (centers + masks fused or not), zero all-reduces in the lowered HLO.
lowered = jax.jit(lambda d: kfed_shard_map(
    mesh, d, 16, 4, key=jax.random.PRNGKey(1))).lower(fm.data)
hlo = lowered.compile().as_text()
n_ag = hlo.count("all-gather(") + hlo.count("all-gather-start(")
assert n_ag >= 1, "expected an all-gather in the one-shot schedule"
assert "all-to-all" not in hlo

# Baseline: multi-round distributed Lloyd also clusters reasonably (its
# k-means++ restart-free init can lose a center — exactly the gap to
# one-shot k-FED the paper highlights) but needs per-iteration
# all-reduces.
bl_labels, bl_centers = distributed_lloyd(mesh, fm.data, 16,
                                          key=jax.random.PRNGKey(2))
bl_acc = clustering_accuracy(np.asarray(bl_labels), np.asarray(fm.labels), 16)
assert bl_acc > 0.75, f"baseline accuracy {bl_acc}"
print("OK", acc, bl_acc)
"""


@pytest.mark.slow
def test_distributed_kfed_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout


MOE_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.compat import make_mesh
from repro.configs.base import MoEConfig
from repro.models import moe as MoE
from repro.models.common import DistCtx

mesh = make_mesh((2, 4), ("data", "model"))
ctx = DistCtx(mesh=mesh, dp=("data",), tp="model")
B, S, d, dff, E, k = 4, 16, 8, 12, 8, 2
ks = jax.random.split(jax.random.PRNGKey(0), 6)
p = {"router": jax.random.normal(ks[0], (d, E), jnp.float32) * .5,
     "w1": jax.random.normal(ks[1], (E, d, dff), jnp.float32) * .2,
     "w3": jax.random.normal(ks[2], (E, d, dff), jnp.float32) * .2,
     "w2": jax.random.normal(ks[3], (E, dff, d), jnp.float32) * .2}
x = jax.random.normal(ks[4], (B, S, d), jnp.float32)

# dropless reference: every token through its experts, no mesh
m_ref = MoEConfig(n_experts=E, top_k=k, d_expert=dff, capacity_factor=64.0,
                  impl="dense")
y_ref, _ = MoE._local_moe(p, x.reshape(-1, d), m_ref)
y_ref = np.asarray(y_ref).reshape(B, S, d)

for ep in ("tp", "2d"):
    m = MoEConfig(n_experts=E, top_k=k, d_expert=dff, capacity_factor=64.0,
                  impl="alltoall", ep=ep)
    cfg = type("C", (), {"moe": m})()
    with mesh:
        y, aux = jax.jit(lambda p, x: MoE.apply_moe(p, x, cfg, ctx))(p, x)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    print("ep", ep, "matches dropless reference")

# expert tensor-parallel path (impl=dense + mesh)
m = MoEConfig(n_experts=E, top_k=k, d_expert=dff, capacity_factor=64.0,
              impl="dense")
cfg = type("C", (), {"moe": m})()
with mesh:
    y, aux = jax.jit(lambda p, x: MoE.apply_moe(p, x, cfg, ctx))(p, x)
np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
print("OK etp matches dropless reference")
"""


@pytest.mark.slow
def test_distributed_moe_paths_subprocess():
    """Numeric parity of the a2a (tp-EP and 2-D EP with hierarchical
    all_to_all) and expert-TP MoE paths against the dropless local
    reference, on a real 2-axis (data, model) mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", MOE_CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK etp" in out.stdout
