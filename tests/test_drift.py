"""The online drift layer (core/server.py decay + split/retire over
fed/stream.py, DESIGN.md §14).

Covers the drift subsystem's four promises:

  * the decay is LAZY — the hot-path fold stays one scatter; the
    exponential age factor (and the zero-mass mask-out that keeps a
    fully-decayed or never-filled slot from dividing NaN into tau) is
    applied only at finalize, as a pure function of the persisted
    (epoch, next request id) pair;
  * split/retire decisions are deterministic functions of the decayed
    per-center mass histogram (stable sorts, first-occurrence argmax,
    no RNG), committed through the TauBuffer as one atomic versioned
    bump — so they replay bitwise from a mid-stream checkpoint
    (property test, the acceptance criterion);
  * ``drift="off"`` (the default) is strictly additive: the decay
    branch is never entered and every pre-drift code path is bitwise
    untouched (the rest of the tier-1 suite pins this);
  * under a piecewise-stationary stream the adapted tau tracks the new
    phase where a frozen tau keeps serving the stale snapshot.

The mesh matrix (ci.yml, {2,8} forced host devices) runs this file too:
the sharded-parity test pins that a drift-enabled sharded serve plane
folds epoch stamps bitwise-identically to the single-host plane.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st

from repro.core import server as S
from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, Session
from repro.fed.autoscale import QueueSnapshot, snapshot_queue
from repro.fed.stream import StreamConfigError
from repro.utils.compat import make_mesh
from repro.utils.metrics import clustering_accuracy

K, KP, D = 16, 4, 24
NDEV = jax.device_count()


@pytest.fixture(scope="module")
def fixture_round():
    fm = structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                            m0=4, n_per_comp_dev=25, sep=60.0)
    rr = Session(FederationPlan(k=K, k_prime=KP, d=D)).run(
        jax.random.PRNGKey(1), fm.data).detail
    return fm, rr


def _plan(**kw):
    base = dict(k=K, k_prime=KP, d=D, capacity=256, batch_size=4,
                bucket_sizes=(32, 64, 128))
    base.update(kw)
    return FederationPlan(**base)


def _requests(fm, count, seed, n_range=(10, 120)):
    stream = late_device_stream(fm.means, KP, count, seed,
                                n_range=n_range)
    return ([r[0] for r in stream], [r[1] for r in stream],
            [r[2] for r in stream])


# ----------------------------------------------------- decay primitives --


def test_decay_factors_halve_per_half_life():
    ep = jnp.asarray([100, 90, 80, 100], jnp.int32)
    fac = np.asarray(S.decay_factors(ep, 100, 10))
    np.testing.assert_allclose(fac, [1.0, 0.5, 0.25, 1.0], rtol=1e-6)


def test_lloyd_round_fractional_weights_average_exactly():
    """Satellite bugfix: the Lloyd division uses the ACTUAL mass. A
    fractional total weight in (0, 1) — decayed fold weights — must
    produce the weighted MEAN, not a sum silently shrunk toward the
    origin by the historical max(cnt, 1) clamp; and a center with zero
    attached mass keeps its seed coordinates instead of dividing 0/0
    into NaN."""
    x = jnp.asarray([[2.0, 0.0], [4.0, 0.0]], jnp.float32)
    fm = jnp.asarray([True, True])
    M = jnp.asarray([[3.0, 0.0], [100.0, 100.0]], jnp.float32)
    w = jnp.asarray([0.125, 0.125], jnp.float32)   # total mass 0.25 < 1
    tau, labels = S.lloyd_round(x, fm, M, 2, weights=w)
    tau = np.asarray(tau)
    assert np.all(np.isfinite(tau))
    np.testing.assert_allclose(tau[0], [3.0, 0.0], rtol=1e-6)  # the mean
    np.testing.assert_allclose(tau[1], [100.0, 100.0])  # zero mass: seed
    np.testing.assert_array_equal(np.asarray(labels), [0, 0])


def test_finalize_decay_masks_fully_decayed_garbage_slot():
    """A slot whose decayed weight underflows to exactly 0 is evidence
    no more: its (garbage) centers must not seed, anchor, or NaN-poison
    the re-finalized tau, and its center labels come out -1."""
    st = S.init_state(3, 1, 2)
    nan_row = jnp.asarray([[[np.nan, np.nan]]], jnp.float32)
    st = S.aggregate_incremental(st, [0], nan_row, jnp.ones((1, 1), bool),
                                 epochs=[0])
    good = jnp.asarray([[[1.0, 2.0]], [[5.0, 6.0]]], jnp.float32)
    st = S.aggregate_incremental(st, [1, 2], good, jnp.ones((2, 1), bool),
                                 epochs=[100_000, 100_000])
    # age 100k at half-life 10: 2^-10000 underflows to exactly 0.0
    agg = S.finalize(st, 2, decay=(100_000, 10))
    assert np.all(np.isfinite(np.asarray(agg.tau_centers)))
    lbl = np.asarray(agg.center_labels).reshape(-1)
    assert lbl[0] == -1 and set(lbl[1:]) == {0, 1}
    mask, w = S.decayed_evidence(st, 100_000, 10)
    assert not bool(np.asarray(mask)[0, 0])
    np.testing.assert_array_equal(np.asarray(w[0]), [0.0])


def test_center_mass_sums_decayed_weights_per_center():
    st = S.init_state(4, 1, 2)
    c = jnp.asarray([[[0.0, 0.0]], [[0.1, 0.0]],
                     [[10.0, 10.0]], [[10.1, 10.0]]], jnp.float32)
    st = S.aggregate_incremental(st, [0, 1, 2, 3], c,
                                 jnp.ones((4, 1), bool),
                                 epochs=[10, 10, 10, 0])
    agg = S.finalize(st, 2, decay=(10, 10))
    mask, w = S.decayed_evidence(st, 10, 10)
    mass = np.asarray(S.center_mass(agg, mask, w))
    assert mass.shape == (2,)
    # slots 0+1 fresh (1.0 each) on one center; slot 2 fresh + slot 3
    # one half-life old (0.5) on the other.
    np.testing.assert_allclose(sorted(mass), [1.5, 2.0], rtol=1e-6)


def test_split_retire_reseeds_starved_center_from_donor_residual():
    """One fat two-lobe cluster + one starved center: the starved
    center re-seeds at the donor's farthest attached report (the
    max-min rule restricted to the donor cluster), and after the one
    Lloyd round each lobe anchors its own center."""
    pts = np.asarray([[0.0, 0.0], [0.2, 0.0], [0.1, 0.0],
                      [8.0, 0.0], [8.2, 0.0], [8.1, 0.0],
                      [100.0, 100.0]], np.float32)
    # the far center's one report is nearly fully decayed (starved)
    w_slot = jnp.asarray([[1.0]] * 6 + [[0.001]], jnp.float32)
    st = S.init_state(8, 1, 2)
    st = S.aggregate_incremental(st, np.arange(7), pts[:, None, :],
                                 jnp.ones((7, 1), bool),
                                 weights=w_slot)
    agg = S.finalize(st, 2, weighted=True)
    mask = jnp.asarray(st.mask & st.received[:, None])
    mass = S.center_mass(agg, mask, st.weights)
    # 6 units of mass on the two-lobe center, ~0 on the far one
    np.testing.assert_allclose(sorted(np.asarray(mass)), [0.001, 6.0],
                               rtol=1e-5)
    # Make the 1-report center starved: retire it, re-seed from the fat
    # cluster's residual (the off-lobe), then one Lloyd round.
    flat = st.centers.reshape(-1, 2).astype(jnp.float32)
    fm = (st.mask & st.received[:, None]).reshape(-1)
    tau, moved, donors, n_mv = S.split_retire(
        flat, fm, agg, mass, 2, split_factor=1.5, retire_frac=0.5,
        max_moves=1, weights=st.weights.reshape(-1))
    assert int(np.asarray(n_mv)) == 1
    assert int(np.sum(np.asarray(moved))) == 1
    tau = np.asarray(tau)
    got = sorted(round(float(t[0]), 1) for t in tau)
    np.testing.assert_allclose(got, [0.1, 8.1], atol=0.05)
    # With loose thresholds (nothing starved), tau is returned verbatim.
    tau0, _, _, n0 = S.split_retire(
        flat, fm, agg, mass, 2, split_factor=100.0, retire_frac=0.0,
        max_moves=1, weights=st.weights.reshape(-1))
    assert int(np.asarray(n0)) == 0
    np.testing.assert_array_equal(np.asarray(tau0),
                                  np.asarray(agg.tau_centers))


def test_queue_snapshot_mass_defaults_empty():
    """Drift-off snapshots are bitwise-identical to pre-drift ones: the
    mass field defaults empty on both construction paths."""
    assert QueueSnapshot(pending=3, hist=((32, 3),)).mass == ()
    snap = snapshot_queue([4, 10, 40], (32, 64))
    assert snap.mass == ()
    assert snap == QueueSnapshot(3, ((32, 2), (64, 1)))
    withm = snapshot_queue([4], (32,), mass=np.asarray([1.5, 0.5]))
    assert withm.mass == (1.5, 0.5)


def test_drift_config_validation():
    from repro.fed.api import PlanError
    from repro.fed.stream import StreamConfig
    with pytest.raises(PlanError, match="drift="):
        _plan(drift="sideways")
    with pytest.raises(StreamConfigError, match="drift="):
        StreamConfig(k=K, k_prime=KP, d=D, capacity=8, drift="sideways")
    for bad in ({"drift": "decay"},                       # no half-life
                {"drift": "decay", "drift_half_life": 0},
                {"drift": "split_merge", "drift_half_life": 8,
                 "drift_split_factor": 1.0},
                {"drift": "split_merge", "drift_half_life": 8,
                 "drift_retire_frac": 1.0},
                {"drift": "split_merge", "drift_half_life": 8,
                 "drift_max_moves": 0}):
        with pytest.raises(Exception, match="drift"):
            _plan(**bad)
    # drift knobs are inert (still validated) while drift="off"
    assert _plan().stream_config().drift == "off"


# --------------------------------------------------------- end to end --


def test_decayed_refresh_tracks_recent_distribution(fixture_round):
    """Piecewise-stationary stream: after the mixture shifts, a
    drift="decay" session's refreshed tau serves the NEW phase
    accurately while the frozen-tau session keeps labeling against the
    stale snapshot (lower accuracy under Hungarian matching)."""
    fm, rr = fixture_round
    rng = np.random.default_rng(7)
    # Phase 2: a freshly resampled mixture (same k, new means).
    new_means = rng.normal(size=(K, D)).astype(np.float32) * 40.0
    frozen = Session.from_round(_plan(refresh_every=0), rr)
    drift = Session.from_round(
        _plan(refresh_every=8, drift="decay", drift_half_life=32,
              capacity=512), rr)
    stream = late_device_stream(new_means, KP, 48, 11,
                                n_range=(20, 60))
    reqs = [r[0] for r in stream]
    truths = [r[1] for r in stream]
    kvs = [r[2] for r in stream]
    accs = {}
    for name, sess in (("frozen", frozen), ("drift", drift)):
        acc = []
        for lo in range(0, len(reqs), 8):
            for lbl, tr in zip(
                    sess.serve(reqs[lo:lo + 8], kvs[lo:lo + 8]),
                    truths[lo:lo + 8]):
                acc.append(clustering_accuracy(lbl, tr, K))
        # judge on the stream's tail, after refreshes had evidence
        accs[name] = float(np.mean(acc[24:]))
    assert accs["drift"] > 0.95
    assert accs["drift"] > accs["frozen"] + 0.03
    assert drift.tau_version > 0
    assert sum(drift.stats()["drift"]["mass"]) > 0


def test_split_merge_replays_bitwise_from_checkpoint(fixture_round):
    """Acceptance criterion: interrupt a drift="split_merge" stream at
    a flush boundary, checkpoint, restore — labels, tau versions, fold
    state (including epoch stamps), the per-center mass histogram AND
    the split/retire counters replay bitwise vs the uninterrupted
    session."""
    import os
    import tempfile
    fm, rr = fixture_round
    rng = np.random.default_rng(3)
    new_means = rng.normal(size=(K, D)).astype(np.float32) * 40.0
    plan = _plan(refresh_every=4, drift="split_merge",
                 drift_half_life=24, drift_retire_frac=0.2,
                 capacity=512)
    stream = late_device_stream(new_means, KP, 24, 19, n_range=(15, 50))
    reqs = [r[0] for r in stream]
    kvs = [r[2] for r in stream]

    live = Session.from_round(plan, rr)
    ref = Session.from_round(plan, rr)
    out_ref = [ref.serve_versioned(reqs[lo:lo + 6], kvs[lo:lo + 6])
               for lo in range(0, 24, 6)]
    out_live = [live.serve_versioned(reqs[:6], kvs[:6]),
                live.serve_versioned(reqs[6:12], kvs[6:12])]
    path = os.path.join(tempfile.mkdtemp(), "drift_v4.npz")
    live.save(path)
    replica = Session.restore(path, plan)
    for sess in (live, replica):
        out = [sess.serve_versioned(reqs[12:18], kvs[12:18]),
               sess.serve_versioned(reqs[18:24], kvs[18:24])]
        if sess is live:
            out_live += out
        else:
            out_rep = out
    for batch_a, batch_b in zip(out_live[2:], out_rep):
        for (la, va), (lb, vb) in zip(batch_a, batch_b):
            np.testing.assert_array_equal(la, lb)
            assert va == vb
    for batch_a, batch_b in zip(out_ref, out_live):
        for (la, va), (lb, vb) in zip(batch_a, batch_b):
            np.testing.assert_array_equal(la, lb)
            assert va == vb
    for a, b in ((live.service, replica.service),
                 (live.service, ref.service)):
        for x, y in zip(jax.tree.leaves(a.state),
                        jax.tree.leaves(b.state)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert a._drift_events == b._drift_events
        assert a._drift_moves == b._drift_moves
        assert a._drift_last == b._drift_last
        np.testing.assert_array_equal(a._drift_mass, b._drift_mass)
    # the stream actually exercised the split/retire machinery
    assert live.service._drift_events > 0


def test_v4_schema_keys_and_drift_mismatch_error(fixture_round,
                                                 tmp_path):
    from repro.checkpoint.store import npz_keys
    fm, rr = fixture_round
    plan = _plan(drift="decay", drift_half_life=16, refresh_every=4)
    sess = Session.from_round(plan, rr)
    reqs, _, kvs = _requests(fm, 5, seed=23)
    sess.serve(reqs, kvs)
    path = str(tmp_path / "v4.npz")
    sess.save(path)
    keys = npz_keys(path)
    assert {"drift_id", "drift_state", "drift_mass",
            "server/.epoch"} <= keys
    with pytest.raises(StreamConfigError, match="drift"):
        Session.restore(path, _plan())                    # off != decay
    with pytest.raises(StreamConfigError, match="drift"):
        Session.restore(path, plan.with_options(drift="split_merge"))
    replica = Session.restore(path, plan)
    np.testing.assert_array_equal(replica.service._drift_mass,
                                  sess.service._drift_mass)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 6))
def test_reservoir_decayed_key_prefers_recent_requests(seed):
    """Under drift, the A-ES admission key uses the DECAYED weight: for
    equal report masses, recent request ids systematically crowd out
    old ones (while half_life=0 reproduces the undecayed key exactly)."""
    from repro.fed.policy import WeightedReservoirPolicy
    plain = WeightedReservoirPolicy(4, seed=seed)
    decayed = WeightedReservoirPolicy(4, seed=seed, half_life=4)
    assert plain.key_of(7, 2.0) == WeightedReservoirPolicy(
        4, seed=seed, half_life=0).key_of(7, 2.0)
    for rid in range(64):
        plain.admit(rid, 1.0)
        decayed.admit(rid, 1.0)
    held = sorted(int(r) for r in decayed._slot_rid if r >= 0)
    # every survivor under decay is from the recent half of the stream
    assert min(held) >= 32, held
    # keys decay monotonically for a fixed draw: an older twin of the
    # same (seed, weight) never outranks a newer id's own key ordering
    k_old = decayed.key_of(0, 1.0)
    k_new = decayed.key_of(0, 1.0)  # deterministic
    assert k_old == k_new


def test_sharded_drift_parity_with_single_host(fixture_round):
    """The sharded serve plane gathers epoch stamps with the batch: a
    drift-enabled sharded session folds, refreshes and splits bitwise
    identically to the single-host plane (meaningful under the CI mesh
    matrix's forced {2,8} devices)."""
    fm, rr = fixture_round
    if NDEV < 2:
        pytest.skip("needs >= 2 devices (CI mesh matrix)")
    rng = np.random.default_rng(5)
    new_means = rng.normal(size=(K, D)).astype(np.float32) * 40.0
    kw = dict(refresh_every=4, drift="split_merge", drift_half_life=24,
              capacity=512, batch_size=NDEV)
    mesh = make_mesh((NDEV,), ("data",))
    single = Session.from_round(_plan(**kw), rr)
    shard = Session.from_round(_plan(**kw, serve_axes=("data",)), rr,
                               mesh=mesh)
    stream = late_device_stream(new_means, KP, 16, 29, n_range=(15, 40))
    reqs = [r[0] for r in stream]
    kvs = [r[2] for r in stream]
    out_a = single.serve(reqs, kvs)
    out_b = shard.serve(reqs, kvs)
    for la, lb in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
    for x, y in zip(jax.tree.leaves(single.service.state),
                    jax.tree.leaves(shard.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(single.service._drift_mass,
                                  shard.service._drift_mass)
    assert single.service._drift_moves == shard.service._drift_moves
