"""Latent-space ingestion front-end (DESIGN.md §17): the encoder stage
ahead of the local solve.

With ``FederationPlan.encoder=<config>`` devices submit raw (n, seq, d)
token/patch sequences; the serve plane encodes them (bf16-storage or
f32, f32-accumulate, masked-mean pooled to d) and runs the UNCHANGED
fused solve+attach — fold, drift, autoscale, and routed heads all
operate on the embeddings. Covers: end-to-end determinism, (n, seq)
bucketing and compile-count bounds, checkpoint schema v6 (round-trip,
tag-mismatch refusal, pre-v6 restore with a fresh deterministic
encoder), submit/plan validation, the encoder+heads combination, and
single-host vs sharded bitwise parity (the CI mesh leg runs this file
at 2 and 8 forced host devices).
"""
import numpy as np
import pytest

import jax

from repro.fed.api import FederationPlan, PlanError, Session
from repro.fed.stream import StreamConfigError
from repro.utils.compat import make_mesh

K, KP, D = 8, 3, 16
ENC = "qwen1.5-0.5b"
SEQ = 16
NDEV = jax.device_count()


def _plan(**kw):
    base = dict(k=K, k_prime=KP, d=D, capacity=128, batch_size=2,
                bucket_sizes=(16, 32), encoder=ENC, encode_seq_len=SEQ)
    base.update(kw)
    return FederationPlan(**base)


def _tau(seed=0):
    rng = np.random.default_rng(seed)
    return np.asarray(rng.normal(size=(K, D)) * 4, np.float32)


def _token_requests(count, seed, n_range=(4, 14), s_range=(2, SEQ)):
    """``count`` raw-sequence requests with varied (n, seq) shapes."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(count):
        n = int(rng.integers(*n_range))
        s = int(rng.integers(s_range[0], s_range[1] + 1))
        reqs.append(np.asarray(rng.normal(size=(n, s, D)), np.float32))
    return reqs


# ------------------------------------------------- end-to-end serve --


def test_encoded_serve_end_to_end_deterministic():
    """Two identical sessions over the same raw-sequence stream agree
    bitwise on every label, version, and fold-state leaf; the encoder
    counters advance."""
    reqs = _token_requests(7, seed=1)
    outs, states = [], []
    for _ in range(2):
        sess = Session.from_tau(_plan(), _tau())
        outs.append(sess.serve_versioned(reqs))
        states.append(sess.service.state)
        st = sess.stats()["encoder"]
        assert st["mode"] == ENC and st["seq_len"] == SEQ
        assert st["encoded_points"] == sum(r.shape[0] for r in reqs)
    for (la, va), (lb, vb) in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(la, lb)
        assert va == vb
        assert la.dtype == np.int32
    for x, y in zip(jax.tree.leaves(states[0]), jax.tree.leaves(states[1])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for r, (lbl, _) in zip(reqs, outs[0]):
        assert lbl.shape == (r.shape[0],)
        assert set(np.unique(lbl)) <= set(range(K))


def test_bucketing_over_n_and_seq_bounds_compiles():
    """Requests group by (n_pad, seq_rung): same-rung shapes share one
    compiled encode signature, a new seq rung adds exactly one, and
    replaying the same shapes adds none."""
    sess = Session.from_tau(_plan(), _tau())
    svc = sess.service
    rng = np.random.default_rng(3)

    def req(n, s):
        return np.asarray(rng.normal(size=(n, s, D)), np.float32)

    assert svc._bucket_key(req(5, 5)) == (16, 8)
    assert svc._bucket_key(req(7, 8)) == (16, 8)
    assert svc._bucket_key(req(5, 9)) == (16, 16)  # next pow2 rung
    assert svc._bucket_key(req(20, 3)) == (32, 8)

    sess.serve([req(5, 5), req(7, 8)])        # one (16, 8) group
    c1 = svc.plane.compile_count
    sess.serve([req(6, 6), req(4, 7)])        # same rung: no new sig
    assert svc.plane.compile_count == c1
    sess.serve([req(5, 12)])                  # new seq rung
    assert svc.plane.compile_count == c1 + 1


def test_submit_rejects_overlong_and_empty_sequences():
    sess = Session.from_tau(_plan(), _tau())
    rng = np.random.default_rng(5)
    with pytest.raises(StreamConfigError, match="encode_seq_len"):
        sess.submit(np.asarray(rng.normal(size=(4, SEQ + 1, D)),
                               np.float32))
    with pytest.raises(StreamConfigError, match="encode_seq_len"):
        sess.submit(np.asarray(rng.normal(size=(4, 0, D)), np.float32))


def test_plan_validation_named_errors():
    with pytest.raises(PlanError, match="FederationPlan.encoder"):
        _plan(encoder="not-a-config")
    with pytest.raises(PlanError, match="FederationPlan.encode_dtype"):
        _plan(encode_dtype="f16")
    with pytest.raises(PlanError, match="FederationPlan.encode_seq_len"):
        _plan(encode_seq_len=0)


# ------------------------------------------------ checkpoint schema --


def test_v6_checkpoint_roundtrip_bitwise(tmp_path):
    """Encoder params and counters ride the v6 checkpoint: restore +
    serve is bitwise identical to the uninterrupted session."""
    plan = _plan(encode_dtype="bf16")
    live = Session.from_tau(plan, _tau())
    reqs = _token_requests(5, seed=7)
    live.serve(reqs[:3])
    path = str(tmp_path / "v6.npz")
    live.save(path)
    replica = Session.restore(path, plan)
    for a, b in zip(jax.tree.leaves(live.service.encoder),
                    jax.tree.leaves(replica.service.encoder)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (replica.stats()["encoder"]["encoded_points"]
            == live.stats()["encoder"]["encoded_points"])
    out_a = live.serve_versioned(reqs[3:])
    out_b = replica.serve_versioned(reqs[3:])
    for (la, va), (lb, vb) in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
        assert va == vb


def test_v6_checkpoint_tag_mismatch_refuses(tmp_path):
    """A checkpoint written under one encoder config refuses to load
    under another, naming both tags."""
    live = Session.from_tau(_plan(), _tau())
    live.serve(_token_requests(2, seed=9))
    path = str(tmp_path / "tag.npz")
    live.save(path)
    with pytest.raises(StreamConfigError, match="encoder"):
        Session.restore(path, _plan(encode_seq_len=32))
    with pytest.raises(StreamConfigError, match="encoder"):
        Session.restore(path, _plan(encode_dtype="bf16"))


def test_pre_v6_checkpoint_restores_fresh_deterministic_encoder(tmp_path):
    """A checkpoint written before the encode stage existed (encoder
    off) restores into an encoder-on plan: tau and fold state load,
    the encoder comes up fresh and DETERMINISTIC — two replicas of the
    same old checkpoint serve bitwise-identically."""
    old = Session.from_tau(FederationPlan(k=K, k_prime=KP, d=D,
                                          capacity=128), _tau())
    rng = np.random.default_rng(11)
    old.serve([np.asarray(rng.normal(size=(6, D)), np.float32)])
    path = str(tmp_path / "pre_v6.npz")
    old.save(path)
    ra = Session.restore(path, _plan())
    rb = Session.restore(path, _plan())
    np.testing.assert_array_equal(np.asarray(ra.tau_centers),
                                  np.asarray(old.tau_centers))
    assert ra.stats()["encoder"]["encoded_points"] == 0
    for a, b in zip(jax.tree.leaves(ra.service.encoder),
                    jax.tree.leaves(rb.service.encoder)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    reqs = _token_requests(3, seed=13)
    for a, b in zip(ra.serve(reqs), rb.serve(reqs)):
        np.testing.assert_array_equal(a, b)


# --------------------------------------------------- encoder + heads --


def test_encoder_with_routed_heads():
    """The routed personalization step runs on the embeddings: every
    request gets a prediction in latent space (d-wide), the labels
    match the un-routed encode path bitwise, and the majority-vote
    cluster is a real tau index."""
    reqs = _token_requests(5, seed=15)
    plain = Session.from_tau(_plan(), _tau())
    routed = Session.from_tau(_plan(heads="linear"), _tau())
    base = plain.serve(reqs)
    preds = routed.serve_predict(reqs)
    assert len(preds) == len(reqs)
    for r, lbl, p in zip(reqs, base, preds):
        np.testing.assert_array_equal(p.labels, lbl)
        assert 0 <= int(p.cluster) < K
        assert p.prediction.shape == (D,)
        assert np.all(np.isfinite(p.prediction))


# ------------------------------------------------------ sharded plane --


@pytest.mark.skipif(NDEV < 2, reason="needs >= 2 devices (CI mesh leg)")
def test_sharded_encoded_serve_bitwise_matches_single_host():
    """§17 acceptance: the shard_mapped encode+serve plane is bitwise
    identical to the single-host plane — labels, fold state, and the
    encoder-counter stats all match, with encoder params riding
    replicated like tau."""
    plan_kw = dict(batch_size=2 * NDEV)
    reqs = _token_requests(3 * NDEV + 1, seed=17)
    single = Session.from_tau(_plan(**plan_kw), _tau())
    shard = Session.from_tau(_plan(**plan_kw, serve_axes=("data",)),
                             _tau(), mesh=make_mesh((NDEV,), ("data",)))
    out_a = single.serve_versioned(reqs)
    out_b = shard.serve_versioned(reqs)
    for (la, va), (lb, vb) in zip(out_a, out_b):
        np.testing.assert_array_equal(la, lb)
        assert va == vb
    for x, y in zip(jax.tree.leaves(single.service.state),
                    jax.tree.leaves(shard.service.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert shard.service.stats()["serve_shards"] == NDEV
    assert (shard.stats()["encoder"]["encoded_points"]
            == single.stats()["encoder"]["encoded_points"])
