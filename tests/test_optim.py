"""Optimizer unit tests (including factored Adafactor state shapes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adamw, build_optimizer,
                         clip_by_global_norm, sgd, warmup_cosine)


def _quadratic_params():
    return {"a": jnp.array([3.0, -2.0]),
            "nested": {"b": jnp.full((2, 3), 1.5)}}


def _loss(p):
    return (jnp.sum(p["a"] ** 2) + jnp.sum(p["nested"]["b"] ** 2))


@pytest.mark.parametrize("name,kw", [("sgd", {}),
                                     ("sgd", {"momentum": 0.9}),
                                     ("adamw", {}),
                                     ("adafactor", {})])
def test_optimizers_descend_quadratic(name, kw):
    opt = build_optimizer(name, 0.1, **kw)
    params = _quadratic_params()
    state = opt.init(params)
    loss0 = float(_loss(params))
    for i in range(50):
        g = jax.grad(_loss)(params)
        params, state = opt.update(g, state, params, jnp.int32(i))
    assert float(_loss(params)) < 0.2 * loss0


def test_adafactor_state_is_factored():
    opt = adafactor(1e-2)
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((16,))}
    st = opt.init(params)
    assert st["f"]["w"]["r"].shape == (64,)
    assert st["f"]["w"]["c"].shape == (32,)
    assert st["f"]["b"]["v"].shape == (16,)


def test_clip_by_global_norm():
    g = {"x": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 20.0, rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["x"])), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(sched(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.int32(10))), 1.0, atol=1e-5)
    assert float(sched(jnp.int32(109))) < 0.01


def test_tuple_containing_param_trees():
    """Segments are tuples — optimizers must handle non-dict containers."""
    opt = adamw(1e-2)
    params = {"segments": ({"w": jnp.ones((3, 3))}, {"w": jnp.ones((3,))})}
    st = opt.init(params)
    g = jax.tree.map(jnp.ones_like, params)
    new, st = opt.update(g, st, params, jnp.int32(0))
    assert new["segments"][0]["w"].shape == (3, 3)
