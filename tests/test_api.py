"""The declarative federation API (fed/api.py, DESIGN.md §10):
FederationPlan validation, Session-vs-legacy bitwise parity on all
three topologies, FoldPolicy admission properties (drop pinned to the
historical behavior, lru / weighted_reservoir capacity invariants),
and the warn-once deprecation contract of the legacy shims."""
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hyp import given, settings, st

from repro.data.gaussian import late_device_stream, structured_devices
from repro.fed.api import FederationPlan, PlanError, Session, SessionError
from repro.fed.policy import make_policy
from repro.fed.stream import StreamConfig, StreamConfigError
from repro.utils.deprecation import reset_legacy_warnings

K, KP, D = 16, 4, 24
PLAN = FederationPlan(k=K, k_prime=KP, d=D)


@pytest.fixture(scope="module")
def fixture_data():
    return structured_devices(jax.random.PRNGKey(0), k=K, d=D, k_prime=KP,
                              m0=4, n_per_comp_dev=20, sep=60.0)


def _legacy(fn, *args, **kw):
    """Call a deprecated entry point with its warning suppressed (the
    shims are exactly what these tests compare Session against). The
    warn-once registry is re-armed afterwards so a stray legacy call
    elsewhere in the suite still trips the pytest.ini error rule."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        out = fn(*args, **kw)
    reset_legacy_warnings()
    return out


# ------------------------------------------------------- validation --


def test_plan_validation_names_field_and_accepted_values():
    cases = [
        (dict(k=0, k_prime=1, d=2), "FederationPlan.k="),
        (dict(k=4, k_prime=9, d=2), "k_prime"),
        (dict(k=4, k_prime=0, d=2), "k_prime"),
        (dict(k=4, k_prime=2, d=0), "FederationPlan.d="),
        (dict(k=4, k_prime=2, d=2, topology="ring"), "topology"),
        (dict(k=4, k_prime=2, d=2, mesh_axes=()), "mesh_axes"),
        (dict(k=4, k_prime=2, d=2, fold_capacity=0), "fold_capacity"),
        (dict(k=4, k_prime=2, d=2, capacity=0), "capacity"),
        (dict(k=4, k_prime=2, d=2, batch_size=0), "batch_size"),
        (dict(k=4, k_prime=2, d=2, refresh_every=-1), "refresh_every"),
        (dict(k=4, k_prime=2, d=2, bucket_sizes=(64, 32)),
         "bucket_sizes"),
        (dict(k=4, k_prime=2, d=2, bucket_sizes=()), "bucket_sizes"),
        (dict(k=4, k_prime=2, d=2, fold_policy="fifo"), "fold_policy"),
    ]
    for kw, frag in cases:
        with pytest.raises(PlanError) as ei:
            FederationPlan(**kw)
        assert frag in str(ei.value), (kw, str(ei.value))
    # the topology error enumerates the accepted values
    with pytest.raises(PlanError, match="simulated"):
        FederationPlan(k=4, k_prime=2, d=2, topology="ring")
    with pytest.raises(PlanError, match="weighted_reservoir"):
        FederationPlan(k=4, k_prime=2, d=2, fold_policy="fifo")


def test_stream_config_validation_names_field():
    good = dict(k=4, k_prime=2, d=3, capacity=8)
    StreamConfig(**good)
    for kw, frag in [(dict(good, bucket_sizes=(64, 64)), "bucket_sizes"),
                     (dict(good, k_prime=5), "k_prime"),
                     (dict(good, capacity=0), "capacity"),
                     (dict(good, batch_size=0), "batch_size"),
                     (dict(good, fold_policy="fifo"), "fold_policy")]:
        with pytest.raises(StreamConfigError) as ei:
            StreamConfig(**kw)
        assert frag in str(ei.value), str(ei.value)


def test_session_lifecycle_errors():
    with pytest.raises(PlanError, match="mesh"):
        Session(FederationPlan(k=4, k_prime=2, d=2,
                               topology="replicated"))
    sess = Session(PLAN)
    with pytest.raises(SessionError, match="finalized round"):
        sess.serve([np.zeros((4, D), np.float32)])
    with pytest.raises(SessionError, match="fold"):
        sess.finalize()
    with pytest.raises(SessionError, match="key"):
        sess.fold([0, 1])
    with pytest.raises(PlanError, match="feature dim"):
        sess.run(jax.random.PRNGKey(0), jnp.zeros((2, 4, D + 1)))


# -------------------------------------- Session-vs-legacy parity -----


def test_session_run_bitwise_equals_kfed(fixture_data):
    """Simulated topology: Session.run == the legacy core.kfed.kfed
    shim, bitwise, incl. participation masks and core-count weighting
    (acceptance criterion)."""
    from repro.core.kfed import kfed
    fm = fixture_data
    Z = fm.data.shape[0]
    part = jnp.asarray(~np.isin(np.arange(Z), [3, 12]))
    variants = [
        (PLAN, {}),
        (PLAN, dict(participation=part)),
        (PLAN.with_options(weight_by_core_counts=True), {}),
        (PLAN.with_options(weight_by_core_counts=True),
         dict(participation=part)),
    ]
    for plan, kw in variants:
        mine = Session(plan).run(jax.random.PRNGKey(1), fm.data, **kw)
        old = _legacy(kfed, jax.random.PRNGKey(1), fm.data, k=K,
                      k_prime=KP,
                      weight_by_core_counts=plan.weight_by_core_counts,
                      **kw)
        np.testing.assert_array_equal(np.asarray(mine.labels),
                                      np.asarray(old.labels))
        np.testing.assert_array_equal(np.asarray(mine.tau_centers),
                                      np.asarray(old.agg.tau_centers))
        np.testing.assert_array_equal(
            np.asarray(mine.detail.agg.center_labels),
            np.asarray(old.agg.center_labels))


def test_session_fold_finalize_bitwise_equals_async(fixture_data):
    """Session.fold/finalize == the legacy run_round_async shim ==
    Session.run with participation = union(cohorts), bitwise."""
    from repro.fed.engine import EngineConfig, run_round_async
    fm = fixture_data
    cohorts = [[15, 3, 9], [0, 1, 2, 4, 5, 6, 7, 8], [3, 9],  # retry
               [10, 11, 12, 13]]
    sess = Session(PLAN).begin(jax.random.PRNGKey(1), fm.data)
    for c in cohorts:
        sess.fold(c)
    mine = sess.finalize()
    old = _legacy(run_round_async, jax.random.PRNGKey(1), fm.data,
                  EngineConfig(k=K, k_prime=KP), cohorts)
    np.testing.assert_array_equal(np.asarray(mine.labels),
                                  np.asarray(old.labels))
    part = jnp.zeros((fm.data.shape[0],), bool)
    for c in cohorts:
        part = part.at[jnp.asarray(c)].set(True)
    sync = Session(PLAN).run(jax.random.PRNGKey(1), fm.data,
                             participation=part)
    np.testing.assert_array_equal(np.asarray(mine.labels),
                                  np.asarray(sync.labels))
    np.testing.assert_array_equal(np.asarray(mine.tau_centers),
                                  np.asarray(sync.tau_centers))


def test_session_attach_fn_bitwise_equals_make_kfed_attach(fixture_data):
    from repro.launch.serve import make_kfed_attach
    fm = fixture_data
    sess = Session(PLAN)
    rr = sess.run(jax.random.PRNGKey(1), fm.data)
    legacy_fn = _legacy(make_kfed_attach, rr.tau_centers, KP)
    mine_fn = sess.attach_fn()
    for z in [0, 7]:
        key = jax.random.PRNGKey(100 + z)
        np.testing.assert_array_equal(
            np.asarray(mine_fn(key, fm.data[z])),
            np.asarray(legacy_fn(key, fm.data[z])))


def test_session_serve_bitwise_equals_attach_service(fixture_data):
    """Session streaming == legacy AttachService.from_round/serve/
    save/restore, bitwise (labels AND fold state)."""
    from repro.fed.stream import AttachService
    fm = fixture_data
    plan = PLAN.with_options(capacity=256, batch_size=4,
                             bucket_sizes=(32, 64, 128))
    sess = Session(plan)
    rr = sess.run(jax.random.PRNGKey(1), fm.data).detail
    svc = _legacy(AttachService.from_round, rr, plan.stream_config())
    stream = late_device_stream(fm.means, KP, 7, 5)
    reqs, kvs = [r[0] for r in stream], [r[2] for r in stream]
    a = sess.serve(reqs, kvs)
    b = svc.serve(reqs, kvs)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    for la, lb in zip(jax.tree.leaves(sess.service.state),
                      jax.tree.leaves(svc.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


CHILD = r"""
import os, warnings
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.compat import make_mesh
from repro.core.distributed import kfed_shard_map
from repro.data.gaussian import structured_devices
from repro.fed.api import FederationPlan, Session

mesh = make_mesh((8,), ("data",))
fm = structured_devices(jax.random.PRNGKey(0), k=16, d=24, k_prime=4,
                        m0=4, n_per_comp_dev=20, sep=60.0)
part = np.ones(16, bool); part[[3, 12]] = False
part = jnp.asarray(part)

for topology in ("replicated", "sharded"):
    for kw in ({}, {"participation": part}):
        for weighted in (False, True):
            plan = FederationPlan(k=16, k_prime=4, d=24,
                                  topology=topology,
                                  weight_by_core_counts=weighted)
            mine = Session(plan, mesh=mesh).run(
                jax.random.PRNGKey(1), fm.data, **kw)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                lbl, tau = kfed_shard_map(
                    mesh, fm.data, 16, 4, key=jax.random.PRNGKey(1),
                    server=topology, weight_by_core_counts=weighted,
                    **kw)
            np.testing.assert_array_equal(np.asarray(mine.labels),
                                          np.asarray(lbl))
            np.testing.assert_array_equal(np.asarray(mine.tau_centers),
                                          np.asarray(tau))

# simulated-vs-replicated cross-topology agreement (same key)
sim = Session(FederationPlan(k=16, k_prime=4, d=24)).run(
    jax.random.PRNGKey(1), fm.data)
rep = Session(FederationPlan(k=16, k_prime=4, d=24,
                             topology="replicated"),
              mesh=mesh).run(jax.random.PRNGKey(1), fm.data)
np.testing.assert_array_equal(np.asarray(sim.labels),
                              np.asarray(rep.labels))
print("OK session topology parity")
"""


@pytest.mark.slow
def test_session_topology_parity_subprocess():
    """Session-vs-legacy bitwise parity on the replicated and sharded
    shard_map topologies, incl. participation + weighting (acceptance
    criterion; 8 forced host devices, so subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK session topology parity" in out.stdout


# ------------------------------------------------ fold policies ------


@settings(max_examples=15, deadline=None)
@given(cap=st.integers(1, 8), n=st.integers(1, 50),
       seed=st.integers(0, 2 ** 16))
def test_property_drop_policy_pins_historical_behavior(cap, n, seed):
    """drop admits slot==rid for rid < capacity and nothing else —
    exactly the pre-policy over-capacity rule, for any id sequence."""
    rng = np.random.default_rng((cap, n, seed))
    rids = rng.integers(0, 3 * cap, size=n)
    pol = make_policy("drop", cap)
    got = [pol.admit(int(r)) for r in rids]
    want = [int(r) if r < cap else None for r in rids]
    assert got == want


@settings(max_examples=15, deadline=None)
@given(cap=st.integers(1, 8), n=st.integers(1, 60),
       seed=st.integers(0, 2 ** 16))
def test_property_lru_policy_keeps_most_recent(cap, n, seed):
    """lru always admits, never exceeds capacity, and retains exactly
    the last `cap` distinct ids by most-recent admission."""
    rng = np.random.default_rng((cap, n, seed, 1))
    rids = rng.integers(0, 2 * cap + 4, size=n)
    pol = make_policy("lru", cap)
    for r in rids:
        assert pol.admit(int(r)) is not None  # lru never drops
    last_seen = {}
    for i, r in enumerate(rids):
        last_seen[int(r)] = i
    want = set(sorted(last_seen, key=last_seen.get)[-cap:])
    held = {int(r) for r in pol._slot_rid if r >= 0}
    assert held == want
    assert len(held) <= cap


@settings(max_examples=15, deadline=None)
@given(cap=st.integers(1, 6), n=st.integers(1, 40),
       seed=st.integers(0, 2 ** 16))
def test_property_weighted_reservoir_exact_topk(cap, n, seed):
    """A-ES invariant: the held set equals the exact top-capacity of
    all distinct ids by (key, id) — independent of arrival order —
    and re-delivery is slot-stable."""
    rng = np.random.default_rng((cap, n, seed, 2))
    rids = rng.integers(0, 2 * cap + 6, size=n)
    w_of = {int(r): float(rng.uniform(0.1, 10.0))
            for r in np.unique(rids)}
    pol = make_policy("weighted_reservoir", cap, seed=seed)
    for r in rids:
        pol.admit(int(r), w_of[int(r)])
    keys = {r: (pol.key_of(r, w), r) for r, w in w_of.items()}
    want = set(sorted(keys, key=keys.get)[-min(cap, len(keys)):])
    held = {int(r) for r in pol._slot_rid if r >= 0}
    assert held == want
    # arrival-order invariance
    pol2 = make_policy("weighted_reservoir", cap, seed=seed)
    for r in rng.permutation(np.unique(rids)):
        pol2.admit(int(r), w_of[int(r)])
    assert {int(r) for r in pol2._slot_rid if r >= 0} == want
    # re-delivery of a held id keeps its slot
    if held:
        r0 = next(iter(held))
        s0 = pol._index[r0]
        assert pol.admit(r0, w_of[r0]) == s0


@settings(max_examples=15, deadline=None)
@given(cap=st.integers(1, 6), n=st.integers(1, 24),
       seed=st.integers(0, 2 ** 16), pol_i=st.integers(0, 2))
def test_property_admit_padded_sentinel_never_aliases(cap, n, seed,
                                                      pol_i):
    """Degenerate-batch sentinel contract (bugfix): for ANY batch —
    including one that is entirely duplicates of a single hot request
    id, or fully declined — the padded slot vector contains each live
    slot at most ONCE, every declined/padding row is exactly the
    out-of-capacity sentinel, and the slots granted match a sequential
    admit-then-fold oracle's final occupancy."""
    name = ["drop", "lru", "weighted_reservoir"][pol_i]
    rng = np.random.default_rng((cap, n, seed, 3))
    batches = [rng.integers(0, 2 * cap + 4, size=n),       # generic
               np.full((n,), int(rng.integers(0, 2 * cap)))]  # all-hot
    for rids in batches:
        w = rng.uniform(0.1, 10.0, size=n)
        pol = make_policy(name, cap, seed=seed)
        oracle = make_policy(name, cap, seed=seed)
        total = n + int(rng.integers(0, 4))
        full, granted = pol.admit_padded(rids, w, total=total)
        # oracle: sequential admits into a dict fold state
        fold = {}
        o_granted = 0
        for r, wi in zip(rids, w):
            s = oracle.admit(int(r), float(wi))
            if s is not None:
                o_granted += 1
                fold[s] = int(r)
        assert granted == o_granted
        assert full.shape == (total,)
        live = full[full < cap]
        assert len(set(live.tolist())) == len(live)   # no aliasing
        assert np.all(full[(full >= cap)] == cap)     # sentinel exact
        assert np.all(full[n:] == cap)                # padding rows
        # executing the vector as one scatter lands the oracle's state
        got = {int(full[i]): int(rids[i]) for i in range(n)
               if full[i] < cap}
        assert got == fold


@pytest.mark.parametrize("policy", ["lru", "weighted_reservoir"])
def test_policy_service_respects_capacity_and_checkpoints(
        fixture_data, tmp_path, policy):
    """End-to-end: an over-capacity stream folds at most `capacity`
    reports under lru/weighted_reservoir (vs drop's served-not-folded),
    and checkpoint -> restore replays serving AND admission bitwise."""
    fm = fixture_data
    plan = PLAN.with_options(capacity=8, batch_size=4,
                             bucket_sizes=(32, 64, 128),
                             fold_policy=policy)
    sess = Session(plan)
    sess.run(jax.random.PRNGKey(1), fm.data)
    stream = late_device_stream(fm.means, KP, 9, 5)
    sess.serve([r[0] for r in stream], [r[2] for r in stream])
    st = sess.stats()
    assert st["folded"] <= 8
    assert st["served_devices"] == 9          # over-capacity still served
    assert st["fold_policy"] == policy

    path = str(tmp_path / f"{policy}.npz")
    sess.save(path)
    replica = Session.restore(path, plan)
    more = late_device_stream(fm.means, KP, 4, 11)
    a = sess.serve([r[0] for r in more], [r[2] for r in more])
    b = replica.serve([r[0] for r in more], [r[2] for r in more])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    for la, lb in zip(jax.tree.leaves(sess.service.state),
                      jax.tree.leaves(replica.service.state)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    pa = sess.service.policy.state_arrays()
    pb = replica.service.policy.state_arrays()
    assert sorted(pa) == sorted(pb)
    for name in pa:
        np.testing.assert_array_equal(pa[name], pb[name])


def test_second_run_reseeds_serving_layer(fixture_data):
    """A new finalized round invalidates the session's serving layer:
    attach/serve always answer against the LATEST tau centers."""
    fm = fixture_data
    sess = Session(PLAN)
    sess.run(jax.random.PRNGKey(1), fm.data)
    sess.attach(np.asarray(fm.data[0]))  # builds the round-1 service
    out2 = sess.run(jax.random.PRNGKey(2), fm.data)
    np.testing.assert_array_equal(np.asarray(sess.tau_centers),
                                  np.asarray(out2.tau_centers))
    lbl = sess.attach(np.asarray(fm.data[2]))
    np.testing.assert_array_equal(lbl, np.asarray(out2.labels[2]))


def test_restore_refuses_policy_mismatch(fixture_data, tmp_path):
    """A checkpoint records its admission policy; restoring under a
    different fold_policy is a named error, never silent slot-state
    corruption."""
    fm = fixture_data
    lru = PLAN.with_options(capacity=8, fold_policy="lru")
    sess = Session(lru)
    sess.run(jax.random.PRNGKey(1), fm.data)
    sess.attach(np.asarray(fm.data[1]))
    path = str(tmp_path / "lru.npz")
    sess.save(path)
    with pytest.raises(StreamConfigError, match="fold_policy"):
        Session.restore(path, lru.with_options(fold_policy="drop"))


def test_drop_service_over_capacity_served_not_folded(fixture_data):
    """The drop policy end-to-end: ids past capacity are served but the
    fold state holds exactly the first-come ids (historical rule)."""
    fm = fixture_data
    Z = fm.data.shape[0]
    plan = PLAN.with_options(capacity=Z + 2, batch_size=4,
                             bucket_sizes=(32, 64, 128))
    sess = Session(plan)
    sess.run(jax.random.PRNGKey(1), fm.data)
    stream = late_device_stream(fm.means, KP, 5, 17)
    out = sess.serve([r[0] for r in stream], [r[2] for r in stream])
    assert len(out) == 5
    received = np.asarray(sess.service.state.received)
    assert received.sum() == Z + 2
    assert received[:Z + 2].all()             # slots == request ids


# ------------------------------------------------- deprecation -------


def test_legacy_shims_warn_once_naming_session(fixture_data):
    """Each legacy entry point emits exactly ONE DeprecationWarning per
    process, naming its Session replacement; repeat calls are silent
    (the tier-1 suites otherwise run warning-clean — enforced globally
    by the pytest.ini filterwarnings error rule)."""
    from repro.core.kfed import kfed
    fm = fixture_data
    reset_legacy_warnings()
    with pytest.warns(DeprecationWarning, match="Session.run"):
        kfed(jax.random.PRNGKey(1), fm.data, k=K, k_prime=KP)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        kfed(jax.random.PRNGKey(1), fm.data, k=K, k_prime=KP)
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)
                and "repro legacy" in str(w.message)]
    reset_legacy_warnings()


def test_new_surface_is_warning_clean(fixture_data):
    """The Session lifecycle never routes through a deprecation shim."""
    fm = fixture_data
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        sess = Session(PLAN.with_options(capacity=64, batch_size=2,
                                         bucket_sizes=(32, 64, 128)))
        sess.run(jax.random.PRNGKey(1), fm.data)
        sess.attach(np.asarray(fm.data[0]))
        s2 = Session(PLAN).begin(jax.random.PRNGKey(1), fm.data)
        s2.fold(list(range(fm.data.shape[0])))
        s2.finalize()
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)
                and "repro legacy" in str(w.message)], (
        [str(w.message) for w in rec])


# ---------------------------------------------------- bench CLI ------


def test_bench_cli_unknown_key_and_list():
    """`benchmarks.run --only <typo>` names the bad key + valid keys and
    exits non-zero; `--list` prints the keys (ROADMAP open item)."""
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    bad = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "tabel1"],
        env=env, cwd=root, capture_output=True, text=True, timeout=120)
    assert bad.returncode != 0
    assert "tabel1" in bad.stderr and "table1" in bad.stderr
    lst = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--list"],
        env=env, cwd=root, capture_output=True, text=True, timeout=120)
    assert lst.returncode == 0
    assert "table1" in lst.stdout and "attach" in lst.stdout
