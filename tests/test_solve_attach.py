"""Fused solve+attach serve step (kernels/solve_attach, DESIGN.md §13):

- ref oracle vs the pre-fusion staged composition: BITWISE in f32 over
  shape/mask sweeps (the §9/§11 replay contract).
- the full serve-step body (fed.plane._make_step) vs the legacy
  three-stage body: bitwise on all four outputs.
- Pallas kernel (interpret mode) vs the oracle: labels / centers /
  center-labels exact, min-dists to reduction-order tolerance.
- bf16 storage mode: tolerance-bounded against the f32 oracle.
- serve_dtype config plumbing + the analytic HBM traffic model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import server
from repro.core.local_kmeans import (batched_local_kmeans, local_kmeans,
                                     local_prepare, split_local_kw)
from repro.core.lloyd import assign_points, lloyd, lloyd_attach
from repro.fed.plane import _make_step
from repro.fed.stream import StreamConfig, StreamConfigError
from repro.kernels import ref
from repro.kernels.solve_attach import (hbm_bytes, hbm_bytes_legacy,
                                        kernel_flops, solve_attach_fused)


def _request_batch(seed, B, n, d, kp, k):
    rng = np.random.default_rng(seed)
    tau = jnp.asarray(rng.normal(size=(k, d)) * 4, jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, n, d)) * 3, jnp.float32)
    c0 = jnp.asarray(rng.normal(size=(B, kp, d)) * 3, jnp.float32)
    cm = jnp.asarray(rng.random((B, kp)) < 0.8).at[:, 0].set(True)
    pm = jnp.asarray(rng.random((B, n)) < 0.9)
    return tau, x, c0, cm, pm


def _staged_solve_attach(x, c0, tau, cm, pm, max_iters):
    """The pre-fusion composition the oracle must replicate bitwise:
    core.lloyd.lloyd -> server.assign_new_device ->
    server.induced_labels (plus the final assignment's min-dists)."""
    def one(x1, c1, cm1, pm1):
        res = lloyd(x1, c1, center_mask=cm1, point_mask=pm1,
                    max_iters=max_iters)
        _, mind = assign_points(x1, res.centers, cm1, pm1)
        return res.centers, res.assign, mind

    centers, assign, mind = jax.vmap(one)(x, c0, cm, pm)
    ctr = jax.vmap(lambda c, m: server.assign_new_device(c, m, tau))(
        centers, cm)
    labels = server.induced_labels(ctr, assign)
    return labels, mind, centers, ctr


# ------------------------------------------------------ f32 bitwise ----

@pytest.mark.parametrize("B,n,d,kp,k,iters", [
    (1, 16, 3, 2, 4, 100),    # single request, tiny dims
    (4, 33, 7, 3, 7, 9),      # ragged n, tight iteration bound
    (3, 40, 37, 5, 9, 7),     # d not lane-aligned
    (2, 64, 24, 4, 16, 1),    # single Lloyd step
])
def test_oracle_matches_staged_bitwise(B, n, d, kp, k, iters):
    tau, x, c0, cm, pm = _request_batch(B * 7 + n, B, n, d, kp, k)
    got = ref.solve_attach(x, c0, tau, cm, pm, max_iters=iters)
    want = _staged_solve_attach(x, c0, tau, cm, pm, iters)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_oracle_default_masks_bitwise():
    tau, x, c0, _, _ = _request_batch(11, 2, 24, 5, 3, 6)
    B, n = x.shape[:2]
    full_cm = jnp.ones((B, 3), bool)
    full_pm = jnp.ones((B, n), bool)
    got = ref.solve_attach(x, c0, tau, max_iters=5)
    want = ref.solve_attach(x, c0, tau, full_cm, full_pm, max_iters=5)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("B,n,kp", [(1, 64, 4), (8, 64, 4), (5, 33, 3)])
def test_serve_step_matches_legacy_staged_step_bitwise(B, n, kp):
    """THE acceptance property: the plane's fused step body reproduces
    the pre-fusion three-stage body bitwise — labels, centers, center
    mask, and core weights — on heterogeneous k^(z) request batches.
    (The mesh CI job re-runs the sharded equivalent in test_plane.py at
    2 and 8 forced devices.)"""
    k, d = 9, 11
    cfg = StreamConfig(k=k, k_prime=kp, d=d, capacity=64, batch_size=B,
                       bucket_sizes=(n,),
                       local_kw={"approx_iters": 2, "max_iters": 9})

    def legacy(tau, keys, data, point_mask, k_valid):
        loc = batched_local_kmeans(keys, data, k_max=cfg.k_prime,
                                   k_valid=k_valid, point_mask=point_mask,
                                   **cfg.local_kw)
        ctr = jax.vmap(lambda c, m: server.assign_new_device(c, m, tau))(
            loc.centers, loc.center_mask)
        labels = server.induced_labels(ctr, loc.assign)
        return (labels, loc.centers, loc.center_mask,
                server.core_weights(loc.core_counts))

    rng = np.random.default_rng(B * 31 + n)
    tau = jnp.asarray(rng.normal(size=(k, d)) * 4, jnp.float32)
    data = jnp.asarray(rng.normal(size=(B, n, d)) * 3, jnp.float32)
    pm = jnp.asarray(rng.random((B, n)) < 0.9)
    kv = jnp.asarray(rng.integers(1, kp + 1, size=(B,)), jnp.int32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(3), jnp.arange(B))

    got = jax.jit(_make_step(cfg))(tau, keys, data, pm, kv)
    want = jax.jit(legacy)(tau, keys, data, pm, kv)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_local_kmeans_split_is_bitwise():
    """local_kmeans == local_prepare + step-4 lloyd, factored not
    changed: same centers/assign/core_counts bitwise."""
    key = jax.random.PRNGKey(5)
    A = jax.random.normal(jax.random.PRNGKey(1), (50, 6)) * 3
    pm = jnp.arange(50) < 44
    whole = local_kmeans(key, A, k_max=4, k_valid=3, point_mask=pm,
                         approx_iters=3, max_iters=20)
    prep = local_prepare(key, A, k_max=4, k_valid=3, point_mask=pm,
                         approx_iters=3)
    res = lloyd(A.astype(jnp.float32), prep.theta,
                center_mask=prep.center_mask, point_mask=pm, max_iters=20)
    np.testing.assert_array_equal(np.asarray(whole.centers),
                                  np.asarray(res.centers))
    np.testing.assert_array_equal(np.asarray(whole.assign),
                                  np.asarray(res.assign))
    np.testing.assert_array_equal(np.asarray(whole.core_counts),
                                  np.asarray(prep.core_counts))
    np.testing.assert_array_equal(np.asarray(whole.center_mask),
                                  np.asarray(prep.center_mask))


def test_split_local_kw():
    prep_kw, iters = split_local_kw({"approx_iters": 3, "max_iters": 17})
    assert prep_kw == {"approx_iters": 3} and iters == 17
    prep_kw, iters = split_local_kw({})
    assert prep_kw == {} and iters == 100  # the local_kmeans default


# ----------------------------------------------- Pallas kernel parity --

KERNEL_SHAPES = [
    (1, 16, 8, 2, 4),     # minimal
    (3, 40, 37, 5, 9),    # ragged everything
    (2, 64, 128, 4, 16),  # lane-aligned d (no x copy in the dispatcher)
    (4, 24, 7, 3, 140),   # k above one lane tile
]


@pytest.mark.parametrize("B,n,d,kp,k", KERNEL_SHAPES)
@pytest.mark.parametrize("dtype", ["f32", "bf16"])
def test_kernel_matches_oracle(B, n, d, kp, k, dtype):
    """Interpret-mode kernel vs oracle: integer outputs and centers
    exact (fixed seeds), min-dists to the reduction-order tolerance of
    the zero-padded lane axis."""
    tau, x, c0, cm, pm = _request_batch(n * 13 + k, B, n, d, kp, k)
    ref_out = ref.solve_attach(x, c0, tau, cm, pm, max_iters=7,
                               dtype=dtype)
    pal_out = solve_attach_fused(x, c0, tau, cm, pm, max_iters=7,
                                 dtype=dtype, interpret=True)
    np.testing.assert_array_equal(np.asarray(pal_out[0]),
                                  np.asarray(ref_out[0]))       # labels
    np.testing.assert_allclose(np.asarray(pal_out[1]),
                               np.asarray(ref_out[1]),
                               rtol=1e-4, atol=1e-4)            # min-dist
    np.testing.assert_allclose(np.asarray(pal_out[2]),
                               np.asarray(ref_out[2]),
                               rtol=1e-4, atol=1e-4)            # centers
    np.testing.assert_array_equal(np.asarray(pal_out[3]),
                                  np.asarray(ref_out[3]))       # ctr lbls


def test_kernel_default_masks():
    tau, x, c0, _, _ = _request_batch(2, 2, 16, 5, 3, 6)
    got = solve_attach_fused(x, c0, tau, max_iters=5, interpret=True)
    want = ref.solve_attach(x, c0, tau, max_iters=5)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(want[3]))


def test_ops_dispatch_solve_attach(monkeypatch):
    """ops.solve_attach routes ref | pallas like every other kernel."""
    from repro.kernels import ops
    tau, x, c0, cm, pm = _request_batch(3, 2, 16, 3, 2, 5)
    want = ref.solve_attach(x, c0, tau, cm, pm, max_iters=4)
    for impl in ("ref", "pallas"):
        monkeypatch.setitem(ops._STATE, "impl", impl)
        got = ops.solve_attach(x, c0, tau, cm, pm, max_iters=4)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))


# ------------------------------------------------------- bf16 bounds ---

def test_bf16_within_tolerance_of_f32_oracle():
    """On separated clusters (the regime the paper's guarantees cover),
    bf16 storage must not move a single induced label, and centers stay
    within bf16 rounding of the f32 oracle."""
    rng = np.random.default_rng(0)
    k, kp, d, B, n = 8, 4, 16, 4, 64
    means = jnp.asarray(rng.normal(size=(k, d)) * 20, jnp.float32)
    comp = rng.integers(0, k, size=(B, n))
    x = means[comp] + jnp.asarray(rng.normal(size=(B, n, d)),
                                  jnp.float32)
    c0 = means[rng.integers(0, k, size=(B, kp))] + 0.5
    f32 = ref.solve_attach(x, c0, means, max_iters=20, dtype="f32")
    b16 = ref.solve_attach(x, c0, means, max_iters=20, dtype="bf16")
    np.testing.assert_array_equal(np.asarray(b16[0]), np.asarray(f32[0]))
    np.testing.assert_array_equal(np.asarray(b16[3]), np.asarray(f32[3]))
    np.testing.assert_allclose(np.asarray(b16[2]), np.asarray(f32[2]),
                               rtol=2e-2, atol=2e-1)
    assert b16[2].dtype == jnp.float32  # outputs stay f32 (fold schema)


def test_serve_dtype_bf16_step_runs():
    cfg = StreamConfig(k=6, k_prime=3, d=5, capacity=8, batch_size=2,
                       bucket_sizes=(32,), serve_dtype="bf16",
                       local_kw={"approx_iters": 2, "max_iters": 5})
    rng = np.random.default_rng(7)
    tau = jnp.asarray(rng.normal(size=(6, 5)) * 4, jnp.float32)
    data = jnp.asarray(rng.normal(size=(2, 32, 5)), jnp.float32)
    pm = jnp.ones((2, 32), bool)
    kv = jnp.full((2,), 3, jnp.int32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(0), jnp.arange(2))
    labels, centers, cmask, w = jax.jit(_make_step(cfg))(
        tau, keys, data, pm, kv)
    assert labels.shape == (2, 32) and labels.dtype == jnp.int32
    assert centers.dtype == jnp.float32
    assert np.all((np.asarray(labels) >= 0) & (np.asarray(labels) < 6))


# ------------------------------------------------- config validation ---

def test_serve_dtype_validation():
    with pytest.raises(StreamConfigError, match="serve_dtype"):
        StreamConfig(k=4, k_prime=2, d=3, capacity=8, serve_dtype="f16")
    from repro.fed.api import FederationPlan, PlanError
    with pytest.raises(PlanError, match="FederationPlan.serve_dtype"):
        FederationPlan(k=4, k_prime=2, d=3, serve_dtype="fp8")
    assert FederationPlan(k=4, k_prime=2, d=3,
                          serve_dtype="bf16").stream_config().serve_dtype \
        == "bf16"


# -------------------------------------------- analytic traffic model ---

def test_traffic_model_fusion_gain():
    """The model the roofline gate pins: the fused kernel's HBM bytes
    are iteration-free and >= 25% below the legacy loop's on every
    serve bucket (already at a single Lloyd iteration)."""
    for n in (64, 256, 1024):
        fused = hbm_bytes(8, n, 64, 4, 16)
        assert fused == hbm_bytes(8, n, 64, 4, 16)  # deterministic
        for iters in (1, 8, 100):
            legacy = hbm_bytes_legacy(8, n, 64, 4, 16, iters)
            assert 1.0 - fused / legacy >= 0.25, (n, iters)
    # fused traffic does not depend on the iteration bound; legacy grows.
    assert (hbm_bytes_legacy(8, 256, 64, 4, 16, 100)
            > hbm_bytes_legacy(8, 256, 64, 4, 16, 1))
    # bf16 storage strictly shrinks the fused footprint.
    assert hbm_bytes(8, 256, 64, 4, 16, "bf16") < hbm_bytes(8, 256, 64, 4, 16)
    assert kernel_flops(8, 256, 64, 4, 16, 8) > 0
