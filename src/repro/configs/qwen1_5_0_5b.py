"""qwen1.5-0.5b [dense]: 24L, d_model=1024, 16H (kv=16), d_ff=2816,
vocab=151936, QKV bias, tied embeddings. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="qwen1.5-0.5b", family="dense", cite="hf:Qwen/Qwen1.5-0.5B",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab_size=151936, qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    microbatch=1, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, attn_chunk=64, remat=False)

register(FULL, REDUCED)
