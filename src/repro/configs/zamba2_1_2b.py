"""zamba2-1.2b [hybrid]: 38 Mamba2 blocks (ssm_state=64) + a shared
(weight-tied) attention+MLP block applied every 6 blocks, d_model=2048,
32H (kv=32), d_ff=8192, vocab=32000. [arXiv:2411.15242]

O(1) SSM state + short shared-attn caches => long_500k runs natively.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid", cite="arXiv:2411.15242",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2),
    hybrid_attn_every=6, ssm_chunk=32, rope_theta=1e4,
    microbatch=2, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512,
    ssm=SSMConfig(kind="mamba2", state_dim=16, head_dim=32, expand=2),
    hybrid_attn_every=2, ssm_chunk=16, microbatch=1, attn_chunk=64,
    remat=False)

register(FULL, REDUCED)
