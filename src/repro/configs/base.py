"""Model / run configuration dataclasses and the architecture registry.

Every assigned architecture has one file in this package defining its
exact full-size config (cited) plus a REDUCED smoke variant (<= 2 layers,
d_model <= 512, <= 4 experts) used by the CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    impl: str = "alltoall"        # "alltoall" | "dense" (small-E einsum)
    ep: str = "tp"                # expert-parallel axes: "tp" (model axis
                                  # only — baseline) | "2d" (data x model:
                                  # experts chip-resident, expert grads
                                  # never cross devices; §Perf iter 3)
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"          # "mamba2" | "rwkv6"
    state_dim: int = 64           # N (mamba) / head_dim (rwkv state is dh x dh)
    head_dim: int = 64
    expand: int = 2               # mamba inner expansion
    conv_width: int = 4
    decay_lora: int = 64          # rwkv6 data-dependent decay LoRA rank


@dataclass(frozen=True)
class EncoderConfig:
    """Stub-frontend encoder (audio frames / ViT patches arrive as
    precomputed embeddings — the one allowed stub)."""
    kind: str = "audio"           # "audio" (whisper self-attn stack) | "vit"
    n_layers: int = 0             # 0 => embeddings consumed directly
    n_ctx: int = 1500             # encoder memory length at decode
    n_prefix: int = 256           # vlm: patch tokens prepended


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    cite: str = ""
    head_dim: Optional[int] = None
    attn: str = "gqa"             # gqa | mla | none
    activation: str = "swiglu"    # swiglu | gelu | relu2
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 1e6
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0       # leading non-MoE layers (deepseek: 3)
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0    # zamba2: shared attn block every N blocks
    encoder: Optional[EncoderConfig] = None
    mtp: bool = False             # deepseek multi-token-prediction head
    # runtime / distribution knobs
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024
    ssm_chunk: int = 64
    fsdp: bool = False
    seq_shard: bool = False       # Megatron-style sequence parallelism:
                                  # residual stream sharded (dp, model, -)
                                  # between blocks (§Perf mixtral iter 2)
    microbatch: int = 1           # grad-accumulation factor
    optimizer: str = "adamw"      # adamw | adafactor | sgd

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_sliding_window(self, window: int = 4096) -> "ModelConfig":
        return self.replace(sliding_window=window)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


_REGISTRY: dict = {}


def register(cfg: ModelConfig, reduced: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = (cfg, reduced)
    return cfg


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    full, red = _REGISTRY[name]
    return red if reduced else full


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    import importlib
    for mod in ("whisper_base", "mistral_nemo_12b", "granite_3_2b",
                "deepseek_v3_671b", "mixtral_8x7b", "qwen1_5_0_5b",
                "nemotron_4_15b", "internvl2_26b", "rwkv6_7b",
                "zamba2_1_2b"):
        importlib.import_module(f"repro.configs.{mod}")
