"""deepseek-v3-671b [moe]: 61L, d_model=7168, 128H, MLA (latent kv),
MoE 1 shared + 256 routed top-8 experts (d_expert=2048), first 3 layers
dense (d_ff=18432), vocab=129280, MTP head. [arXiv:2412.19437]

Distribution: MLA absorbed-form decode caches 576 B/token; experts are
EP-sharded over (data x model) jointly — every expert chip-resident, its
gradient never crossing a device boundary — with hierarchical per-axis
all_to_all dispatch (§Perf deepseek iterations 3-4; ep="tp" is the
recorded baseline). Adafactor (factored 2nd moment) + FSDP over
(pod, data) for the non-expert parameters is what fits 671B on
16 GB/chip (DESIGN.md §6). long_500k runs with the MLA compressed cache.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, register

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe", cite="arXiv:2412.19437",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280, attn="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  capacity_factor=1.25, impl="alltoall", ep="2d"),
    n_dense_layers=3, mtp=True, rope_theta=1e4,
    fsdp=True, microbatch=8, optimizer="adafactor")

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=16,
                  qk_rope_dim=8, v_dim=16),
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, n_shared=1,
                  capacity_factor=1.5, impl="dense"),
    n_dense_layers=1, mtp=True, fsdp=False, microbatch=1, attn_chunk=64,
    remat=False)

register(FULL, REDUCED)
