"""rwkv6-7b [ssm, attention-free]: RWKV-6 "Finch", 32L, d_model=4096
(64 heads x 64), d_ff=14336 channel-mix, vocab=65536, data-dependent
per-channel decay. [arXiv:2404.05892]

O(1) decode state => long_500k runs natively. §Arch-applicability: k-FED
never looks inside the model, so the paper's technique applies unchanged
(it clusters this arch's client embedding/update vectors like any other).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

FULL = ModelConfig(
    name="rwkv6-7b", family="ssm", cite="arXiv:2404.05892",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab_size=65536, attn="none",
    ssm=SSMConfig(kind="rwkv6", head_dim=64, decay_lora=64),
    ssm_chunk=32, fsdp=True, microbatch=2, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, ssm=SSMConfig(kind="rwkv6", head_dim=32, decay_lora=16),
    ssm_chunk=16, fsdp=False, microbatch=1, remat=False)

register(FULL, REDUCED)
