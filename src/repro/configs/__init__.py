from repro.configs.base import (SHAPES, InputShape, ModelConfig,  # noqa
                                get_config, list_archs)
from repro.configs.shapes import cache_specs, dummy_inputs, input_specs  # noqa
