from repro.configs.base import (SHAPES, InputShape, ModelConfig,  # noqa
                                get_config, list_archs)
from repro.configs.shapes import cache_specs, dummy_inputs, input_specs  # noqa

# Static imports of every registered architecture module. base._ensure_loaded
# importlib-loads these lazily, but the serving tier's head registry
# (models/heads.py resolve_head_spec) makes them load-bearing — static
# imports keep them visible to the AST reachability report
# (analysis/imports.py) and fail fast if a config module breaks.
from repro.configs import (deepseek_v3_671b, granite_3_2b,  # noqa
                           internvl2_26b, mistral_nemo_12b, mixtral_8x7b,
                           nemotron_4_15b, qwen1_5_0_5b, rwkv6_7b,
                           whisper_base, zamba2_1_2b)
