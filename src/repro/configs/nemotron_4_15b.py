"""nemotron-4-15b [dense]: 32L, d_model=6144, 48H (GQA kv=8), d_ff=24576,
vocab=256000, squared-ReLU MLP, LayerNorm. [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="nemotron-4-15b", family="dense", cite="arXiv:2402.16819",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=24576,
    vocab_size=256000, activation="relu2", norm="layernorm",
    rope_theta=1e4, fsdp=True, microbatch=4, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=384, n_heads=6, n_kv_heads=2, d_ff=768,
    vocab_size=512, fsdp=False, microbatch=1, attn_chunk=64, remat=False)

register(FULL, REDUCED)
