"""internvl2-26b [vlm]: InternViT (stub) + InternLM2-20B backbone: 48L,
d_model=6144, 48H (GQA kv=8), d_ff=16384, vocab=92553. [arXiv:2404.16821]

The ViT/projector frontend is the allowed stub: input_specs provides 256
projected patch embeddings per image, prepended to the text tokens.
long_500k runs the sliding-window variant.
"""
from repro.configs.base import EncoderConfig, ModelConfig, register

FULL = ModelConfig(
    name="internvl2-26b", family="vlm", cite="arXiv:2404.16821",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553, rope_theta=1e6,
    encoder=EncoderConfig(kind="vit", n_prefix=256),
    fsdp=True, microbatch=4, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512, encoder=EncoderConfig(kind="vit", n_prefix=16),
    fsdp=False, microbatch=1, attn_chunk=64, remat=False)

register(FULL, REDUCED)
