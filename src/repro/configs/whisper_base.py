"""whisper-base [audio]: enc-dec, conv/mel frontend stubbed to frame
embeddings. 6L decoder (+6L encoder), d_model=512, 8H (kv=8), d_ff=2048,
vocab=51865. [arXiv:2212.04356]

Adaptation notes: rotary positions replace Whisper's learned/sinusoidal
absolute embeddings (DESIGN.md §8); GeLU MLPs and pre-LayerNorm match the
original. long_500k is SKIPPED for this arch (enc-dec, 448-token decoder
context by design — no faithful sub-quadratic decoder variant).
"""
from repro.configs.base import EncoderConfig, ModelConfig, register

FULL = ModelConfig(
    name="whisper-base", family="encdec", cite="arXiv:2212.04356",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865, activation="gelu", norm="layernorm",
    tie_embeddings=True, rope_theta=1e4,
    encoder=EncoderConfig(kind="audio", n_layers=6, n_ctx=1500),
    attn_chunk=512, microbatch=1, optimizer="adamw")

REDUCED = FULL.replace(
    name="whisper-base", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    encoder=EncoderConfig(kind="audio", n_layers=2, n_ctx=8),
    attn_chunk=64, remat=False)

register(FULL, REDUCED)
