"""The four assigned input shapes and per-family ShapeDtypeStruct input
specs (the weak-type-correct, shardable, no-allocation stand-ins the
dry-run lowers against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, InputShape, ModelConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape, *, with_labels=None):
    """Model inputs for one (arch, shape) pair.

    train/prefill: token (and stub-frontend embedding) batches.
    decode: ONE new token; the KV cache spec comes from
    ``cache_specs`` (it is an explicit input to serve_step).
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    want_labels = shape.mode == "train" if with_labels is None else with_labels

    if shape.mode == "decode":
        return {"tokens": _sds((B,), jnp.int32)}

    if cfg.family == "encdec":
        # Stub conv/mel frontend: precomputed frame embeddings. The encoder
        # window is the architecture's fixed n_ctx (1500 frames for
        # whisper); the remaining seq budget is decoder tokens (DESIGN §5).
        Se = cfg.encoder.n_ctx
        Sd = max(S - Se, 1)
        spec = {"enc_embeds": _sds((B, Se, cfg.d_model), dt),
                "tokens": _sds((B, Sd), jnp.int32)}
        if want_labels:
            spec["labels"] = _sds((B, Sd), jnp.int32)
        return spec
    if cfg.family == "vlm":
        # Stub ViT/projector frontend: precomputed patch embeddings.
        P = cfg.encoder.n_prefix
        spec = {"patch_embeds": _sds((B, P, cfg.d_model), dt),
                "tokens": _sds((B, S - P), jnp.int32)}
        if want_labels:
            spec["labels"] = _sds((B, S - P), jnp.int32)
        return spec
    spec = {"tokens": _sds((B, S), jnp.int32)}
    if want_labels:
        spec["labels"] = _sds((B, S), jnp.int32)
    return spec


def cache_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct pytree of the decode cache (seq_len of context)."""
    from repro.models.model import build_model
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def dummy_inputs(key, cfg: ModelConfig, shape: InputShape, **kw):
    """Concrete random inputs matching input_specs (smoke tests)."""
    specs = input_specs(cfg, shape, **kw)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0,
                                           max(2, cfg.vocab_size - 1),
                                           s.dtype)
        else:
            out[name] = (jax.random.normal(k, s.shape) * 0.02).astype(s.dtype)
    return out
