"""mixtral-8x7b [moe]: 32L, d_model=4096, 32H (GQA kv=8), 8 experts top-2
(d_expert=14336), native sliding-window attention (W=4096), vocab=32000.
[arXiv:2401.04088]

With E=8 < tp=16 the EP all_to_all path is degenerate, so Mixtral uses
expert tensor parallelism: per-data-shard local dispatch with each
expert's FFN hidden dim sharded over ``model`` like a dense FFN, one bf16
activation psum per layer (§Perf mixtral iteration 1), plus Megatron-style
sequence parallelism on the residual stream (iteration 2).
"""
from repro.configs.base import MoEConfig, ModelConfig, register

FULL = ModelConfig(
    name="mixtral-8x7b", family="moe", cite="arXiv:2401.04088",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, sliding_window=4096, rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336,
                  capacity_factor=1.25, impl="dense"),
    fsdp=True, seq_shard=True, microbatch=4, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512, sliding_window=64,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128,
                  capacity_factor=1.5, impl="dense"),
    fsdp=False, microbatch=1, attn_chunk=32, remat=False)

register(FULL, REDUCED)
