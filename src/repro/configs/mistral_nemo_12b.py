"""mistral-nemo-12b [dense]: 40L, d_model=5120, 32H (GQA kv=8),
head_dim=128, d_ff=14336, vocab=131072, 128k context (rope theta 1e6).
[hf:mistralai/Mistral-Nemo-Base-2407]

long_500k runs the sliding-window variant (cfg.with_sliding_window(4096))
— see DESIGN.md §5.
"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    cite="hf:mistralai/Mistral-Nemo-Base-2407",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1e6,
    fsdp=True, microbatch=4, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, fsdp=False, microbatch=1, attn_chunk=64,
    remat=False)

register(FULL, REDUCED)
