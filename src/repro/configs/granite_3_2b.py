"""granite-3-2b [dense]: 40L, d_model=2048, 32H (GQA kv=8), d_ff=8192,
vocab=49155, tied embeddings. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ModelConfig, register

FULL = ModelConfig(
    name="granite-3-2b", family="dense",
    cite="hf:ibm-granite/granite-3.0-2b-base",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=49155, tie_embeddings=True, rope_theta=1e4,
    microbatch=2, optimizer="adamw")

REDUCED = FULL.replace(
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=512, microbatch=1, attn_chunk=64, remat=False)

register(FULL, REDUCED)
