from repro.optim.optimizers import (Optimizer, adafactor, adamw,  # noqa
                                    build_optimizer, clip_by_global_norm,
                                    sgd)
from repro.optim.schedules import (constant, cosine_decay,  # noqa
                                   warmup_cosine)
