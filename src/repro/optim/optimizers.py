"""Optimizers (functional, optax-style, built from scratch — optax is not
vendored here).

Adafactor (factored second moment) is what makes the 671B config fit
16 GB/chip: full-matrix Adam moments would add 8 bytes/param (5.4 TB for
DeepSeek-V3); the factored row/col statistics add O(rows+cols).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params, step) -> (new_params, state)


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2)
              for g in jax.tree.leaves(grads)]
    gn = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        lrt = _lr_at(lr, step)
        if momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: p - (lrt * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new, state
        m = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                         state["m"], grads)
        new = jax.tree.map(
            lambda p, mm: p - (lrt * mm.astype(jnp.float32)).astype(p.dtype),
            params, m)
        return new, {"m": m}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lrt = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lrt * u).astype(p.dtype), m, v

        lp, treedef = jax.tree.flatten(params)
        lg = treedef.flatten_up_to(grads)
        lm = treedef.flatten_up_to(state["m"])
        lv = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(lp, lg, lm, lv)]
        new = treedef.unflatten([o[0] for o in out])
        m = treedef.unflatten([o[1] for o in out])
        v = treedef.unflatten([o[2] for o in out])
        return new, {"m": m, "v": v}

    return Optimizer(init, update)


def adafactor(lr: Schedule, eps: float = 1e-30,
              decay: float = 0.8, clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Shazeer & Stern (2018) factored second moment, no first moment."""
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                r = jnp.zeros(p.shape[:-1], jnp.float32)       # row stats
                c = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"r": r, "c": c}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(per, params)}

    def update(grads, state, params, step):
        lrt = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(p, g, s):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                r = beta * s["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * s["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rc = r / jnp.maximum(
                    jnp.mean(r, axis=-1, keepdims=True), eps)
                vhat = rc[..., None] * c[..., None, :]
                u = gf * jax.lax.rsqrt(jnp.maximum(vhat, eps))
                ns = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                ns = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lrt * u).astype(p.dtype), ns

        lp, treedef = jax.tree.flatten(params)
        lg = treedef.flatten_up_to(grads)
        ls = treedef.flatten_up_to(state["f"])   # per-param state dicts
        out = [upd(p, g, s) for p, g, s in zip(lp, lg, ls)]
        new = treedef.unflatten([o[0] for o in out])
        ns = treedef.unflatten([o[1] for o in out])
        return new, {"f": ns}

    return Optimizer(init, update)


def build_optimizer(name: str, lr: Schedule = 1e-4, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    if name == "sgd":
        return sgd(lr, **kw)
    raise ValueError(name)
