from repro.models.common import DistCtx  # noqa: F401
from repro.models.model import Model, build_model  # noqa: F401
