"""Attention: chunked (flash-style) jnp softmax attention for train/prefill,
direct cache attention for decode, GQA and MLA variants, full and
sliding-window (ring-buffer) KV caches.

The chunked path never materializes an (S, S) score matrix: it tiles
queries in a static Python loop (bounding causal waste — later q-tiles see
more kv-tiles) and scans kv-tiles with an online softmax, so peak memory is
O(S * chunk) per head. This is what lets the 32k prefill and 4k train
shapes fit the dry-run memory analysis; the Pallas ``swa_decode`` kernel is
the TPU serving fast path validated separately.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import DistCtx, apply_rope, dense_init


# --------------------------------------------------------------------------
# chunked flash attention (train / prefill)
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    cq: int = 1024, ck: int = 1024,
                    scale: Optional[float] = None):
    """q: (B, S, H, Dk); k: (B, S, KVH, Dk); v: (B, S, KVH, Dv).

    Self-attention over a fresh sequence (q and kv positions coincide).
    Returns (B, S, H, Dv).
    """
    B, S, H, Dk = q.shape
    KVH, Dv = k.shape[2], v.shape[-1]
    g = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)

    cq = min(cq, S)
    ck = min(ck, S)
    pad_s = (-S) % cq
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Sp = q.shape[1]
    pad_k = (-S) % ck
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Skp = k.shape[1]

    qg = (q.reshape(B, Sp, KVH, g, Dk).astype(jnp.float32) * scale)
    outs = []
    for qi in range(Sp // cq):
        qb = qg[:, qi * cq:(qi + 1) * cq]              # (B,cq,KVH,g,Dk)
        q_pos = qi * cq + jnp.arange(cq)
        # kv range this q-tile can see (static bounds).
        hi = min(Skp, ((qi + 1) * cq + ck - 1) // ck * ck) if causal else Skp
        lo = 0
        if window is not None:
            lo = max(0, (qi * cq - window) // ck * ck)
        nk = (hi - lo) // ck
        kb = k[:, lo:hi].reshape(B, nk, ck, KVH, Dk).transpose(1, 0, 2, 3, 4)
        vb = v[:, lo:hi].reshape(B, nk, ck, KVH, Dv).transpose(1, 0, 2, 3, 4)
        kv_base = lo + jnp.arange(nk) * ck

        def step(carry, xs):
            m, l, acc = carry
            kc, vc, base = xs
            s = jnp.einsum("bqhgd,bjhd->bqhgj", qb, kc.astype(jnp.float32))
            j_pos = base + jnp.arange(ck)
            allow = j_pos[None, :] < S                      # kv padding
            if causal:
                allow = allow & (j_pos[None, :] <= q_pos[:, None])
            if window is not None:
                allow = allow & (j_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(allow[None, :, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhgj,bjhd->bqhgd", p, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((B, cq, KVH, g), -1e30, jnp.float32),
                jnp.zeros((B, cq, KVH, g), jnp.float32),
                jnp.zeros((B, cq, KVH, g, Dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, kv_base))
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])

    out = jnp.concatenate(outs, axis=1)[:, :S]
    return out.reshape(B, S, H, Dv).astype(q.dtype)


def plain_attention(q, k, v, *, scale: Optional[float] = None,
                    kv_mask: Optional[jax.Array] = None):
    """Unmasked (cross-)attention; kv is short (encoder memory)."""
    B, S, H, Dk = q.shape
    KVH = k.shape[2]
    g = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)
    qg = q.reshape(B, S, KVH, g, Dk).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bjhd->bqhgj", qg, k.astype(jnp.float32))
    if kv_mask is not None:
        s = jnp.where(kv_mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgj,bjhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, v.shape[-1]).astype(q.dtype)


def decode_attention(q1, K, V, *, kv_valid, scale: Optional[float] = None):
    """One-token decode against a cache. q1: (B, H, Dk); K/V: (B, S, KVH, D*);
    kv_valid: (B, S) bool. Returns (B, H, Dv)."""
    B, H, Dk = q1.shape
    KVH = K.shape[2]
    g = H // KVH
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)
    qg = q1.reshape(B, KVH, g, Dk).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qg, K.astype(jnp.float32))
    s = jnp.where(kv_valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, V.astype(jnp.float32))
    return o.reshape(B, H, V.shape[-1]).astype(q1.dtype)


# --------------------------------------------------------------------------
# KV caches (pytrees of arrays; static shapes)
# --------------------------------------------------------------------------

def init_full_cache(B, S, KVH, hd, dtype, layers: int):
    return {"k": jnp.zeros((layers, B, S, KVH, hd), dtype),
            "v": jnp.zeros((layers, B, S, KVH, hd), dtype),
            "len": jnp.zeros((B,), jnp.int32)}


def init_ring_cache(B, W, KVH, hd, dtype, layers: int):
    return {"k": jnp.zeros((layers, B, W, KVH, hd), dtype),
            "v": jnp.zeros((layers, B, W, KVH, hd), dtype),
            "pos": jnp.full((layers, B, W), -1, jnp.int32),
            "len": jnp.zeros((B,), jnp.int32)}


def init_mla_cache(B, S, lora, rope, dtype, layers: int):
    return {"latent": jnp.zeros((layers, B, S, lora), dtype),
            "rope": jnp.zeros((layers, B, S, rope), dtype),
            "len": jnp.zeros((B,), jnp.int32)}


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------

def init_gqa(key, cfg, dtype):
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, H * hd), dtype),
         "wk": dense_init(ks[1], (d, KVH * hd), dtype),
         "wv": dense_init(ks[2], (d, KVH * hd), dtype),
         "wo": dense_init(ks[3], (H * hd, d), dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KVH * hd,), dtype)
        p["bv"] = jnp.zeros((KVH * hd,), dtype)
    return p


def _qkv(p, x, cfg):
    B, S, _ = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KVH, hd),
            v.reshape(B, S, KVH, hd))


def gqa_self(p, x, cfg, ctx: DistCtx, *, positions=None,
             window=None, causal=True):
    """Train/prefill self-attention. x: (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.arange(S) if positions is None else positions
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q = ctx.constrain(q, ctx.dp, None, ctx.tp, None)
    k = ctx.constrain(k, ctx.dp, None, ctx.tp, None)
    v = ctx.constrain(v, ctx.dp, None, ctx.tp, None)
    w = window if window is not None else cfg.sliding_window
    o = flash_attention(q, k, v, causal=causal, window=w,
                        cq=cfg.attn_chunk, ck=cfg.attn_chunk)
    return o.reshape(B, S, -1) @ p["wo"]


def gqa_decode(p, x1, cache_layer, cfg, ctx: DistCtx, *, lengths):
    """One-token decode. x1: (B, d); cache_layer holds this layer's k/v
    (B, S, KVH, hd) (full) or ring buffers (B, W, ...). Returns
    (out (B, d), updated cache_layer)."""
    B, d = x1.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = _qkv(p, x1[:, None, :], cfg)
    pos = lengths  # (B,) absolute position of the new token
    q = apply_rope(q, pos[:, None], cfg.rope_theta)[:, 0]      # (B,H,hd)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)[:, 0]      # (B,KVH,hd)
    v = v[:, 0]
    bidx = jnp.arange(B)
    if "pos" in cache_layer:  # ring (sliding-window) cache
        W = cache_layer["k"].shape[1]
        slot = pos % W
        K = cache_layer["k"].at[bidx, slot].set(k)
        V = cache_layer["v"].at[bidx, slot].set(v)
        PS = cache_layer["pos"].at[bidx, slot].set(pos)
        valid = PS >= 0
        o = decode_attention(q, K, V, kv_valid=valid)
        new_cache = {"k": K, "v": V, "pos": PS}
    else:
        K = cache_layer["k"].at[bidx, pos].set(k)
        V = cache_layer["v"].at[bidx, pos].set(v)
        S = K.shape[1]
        valid = jnp.arange(S)[None, :] <= pos[:, None]
        o = decode_attention(q, K, V, kv_valid=valid)
        new_cache = {"k": K, "v": V}
    return o.reshape(B, -1) @ p["wo"], new_cache


# --------------------------------------------------------------------------
# MLA attention block (DeepSeek-V3)
# --------------------------------------------------------------------------

def init_mla(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qk), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_dim), dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, H * m.v_dim), dtype),
        "wo": dense_init(ks[5], (H * m.v_dim, d), dtype),
    }


def _mla_q(p, x, cfg):
    from repro.models.common import rms_norm
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.n_heads
    q = rms_norm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    return jnp.split(q, [m.qk_nope_dim], axis=-1)  # (qn, qr)


def _mla_latent(p, x, cfg):
    from repro.models.common import rms_norm
    m = cfg.mla
    kv = x @ p["wkv_a"]
    latent, krope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    return rms_norm(latent, p["kv_norm"]), krope


def mla_self(p, x, cfg, ctx: DistCtx, *, positions=None):
    """Train/prefill MLA: up-project latents to per-head K/V and run the
    chunked flash path (naive form; the absorbed form is decode-only)."""
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.n_heads
    qn, qr = _mla_q(p, x, cfg)
    latent, krope = _mla_latent(p, x, cfg)
    pos = jnp.arange(S) if positions is None else positions
    qr = apply_rope(qr, pos, cfg.rope_theta)
    krope = apply_rope(krope[:, :, None, :], pos, cfg.rope_theta)
    kn = (latent @ p["wk_b"]).reshape(B, S, H, m.qk_nope_dim)
    v = (latent @ p["wv_b"]).reshape(B, S, H, m.v_dim)
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(
        krope, (B, S, H, m.qk_rope_dim))], axis=-1)
    q = ctx.constrain(q, ctx.dp, None, ctx.tp, None)
    k = ctx.constrain(k, ctx.dp, None, ctx.tp, None)
    v = ctx.constrain(v, ctx.dp, None, ctx.tp, None)
    o = flash_attention(q, k, v, causal=True,
                        cq=cfg.attn_chunk, ck=cfg.attn_chunk)
    return o.reshape(B, S, -1) @ p["wo"]


def mla_decode(p, x1, cache_layer, cfg, ctx: DistCtx, *, lengths):
    """Absorbed-form MLA decode: scores/context live in the compressed
    latent space; the per-token cache is kv_lora + rope dims (576 for V3).
    cache_layer: {"latent": (B, S, lora), "rope": (B, S, rope)}."""
    B, _ = x1.shape
    m, H = cfg.mla, cfg.n_heads
    qn, qr = _mla_q(p, x1[:, None, :], cfg)
    latent1, krope1 = _mla_latent(p, x1[:, None, :], cfg)
    pos = lengths
    qr = apply_rope(qr, pos[:, None], cfg.rope_theta)[:, 0]     # (B,H,rope)
    krope1 = apply_rope(krope1[:, :, None, :], pos[:, None],
                        cfg.rope_theta)[:, 0, 0]                # (B,rope)
    qn = qn[:, 0]                                               # (B,H,nope)

    bidx = jnp.arange(B)
    LC = cache_layer["latent"].at[bidx, pos].set(latent1[:, 0])
    RC = cache_layer["rope"].at[bidx, pos].set(krope1)
    S = LC.shape[1]
    valid = jnp.arange(S)[None, :] <= pos[:, None]

    wk_b = p["wk_b"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    wv_b = p["wv_b"].reshape(m.kv_lora_rank, H, m.v_dim)
    q_abs = jnp.einsum("bhn,lhn->bhl", qn.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = (jnp.einsum("bhl,bsl->bhs", q_abs, LC.astype(jnp.float32)) +
         jnp.einsum("bhr,bsr->bhs", qr.astype(jnp.float32),
                    RC.astype(jnp.float32))) * scale
    s = jnp.where(valid[:, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    ctx_l = jnp.einsum("bhs,bsl->bhl", pr, LC.astype(jnp.float32))
    o = jnp.einsum("bhl,lhv->bhv", ctx_l, wv_b.astype(jnp.float32))
    o = o.reshape(B, -1).astype(x1.dtype)
    return o @ p["wo"], {"latent": LC, "rope": RC}
