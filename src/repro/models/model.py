"""Public model API: build_model(cfg) -> Model with init / loss / prefill /
serve_step, uniform across all ten assigned architectures."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.common import (DistCtx, apply_norm, cross_entropy,
                                 dense_init, init_norm)
from repro.models.transformer import (SegmentSpec, block_decode, block_seq,
                                      init_layer, init_segment,
                                      plan_segments, run_segment,
                                      run_segment_decode)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.segments = plan_segments(cfg)
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------- init --
    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg, dtype = self.cfg, self.dtype
        ks = jax.random.split(key, 8 + len(self.segments))
        p: Dict[str, Any] = {
            "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
            "segments": tuple(
                init_segment(ks[2 + i], cfg, spec, dtype)
                for i, spec in enumerate(self.segments)),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                      dtype)
        if cfg.family == "hybrid":
            p["shared_block"] = init_layer(ks[-1], cfg,
                                           SegmentSpec("attn_ffn", 1), dtype)
        if cfg.family == "encdec":
            enc_spec = SegmentSpec("attn_ffn", cfg.encoder.n_layers,
                                   causal=False)
            p["enc_segments"] = (init_segment(ks[-2], cfg, enc_spec, dtype),)
            p["enc_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if cfg.family == "vlm":
            p["vis_proj"] = dense_init(ks[-3], (cfg.d_model, cfg.d_model),
                                       dtype)
        if cfg.mtp:
            p["mtp_proj"] = dense_init(ks[-4], (2 * cfg.d_model, cfg.d_model),
                                       dtype)
            p["mtp_block"] = init_layer(ks[-5], cfg,
                                        SegmentSpec("attn_ffn", 1), dtype)
            p["mtp_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
        return p

    # ------------------------------------------------------- common bits --
    def _unembed(self, p, x, ctx: DistCtx):
        w = p["embed"].T if self.cfg.tie_embeddings else p["unembed"]
        logits = x @ w
        spec = (ctx.dp,) + (None,) * (logits.ndim - 2) + (ctx.tp,)
        return ctx.constrain(logits, *spec)

    def _encode(self, p, enc_embeds, ctx):
        cfg = self.cfg
        spec = SegmentSpec("attn_ffn", cfg.encoder.n_layers, causal=False)
        x, _, _, _ = run_segment(p["enc_segments"][0], enc_embeds, cfg, ctx,
                                 spec)
        return apply_norm(cfg.norm, p["enc_norm"], x)

    def _backbone(self, p, x, ctx, *, states=None, enc_out=None,
                  want_cache=False):
        """Runs all segments (+ hybrid shared blocks). Returns
        (x, aux, new_states, caches, shared_caches)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if states is None and any(s.kind in ("rwkv", "mamba")
                                  for s in self.segments):
            states = self._fresh_states(x.shape[0])
        new_states, caches, shared_caches = [], [], []
        for i, spec in enumerate(self.segments):
            st = states[i] if states is not None else None
            x, a, ns, cache = run_segment(p["segments"][i], x, cfg, ctx,
                                          spec, state=st, enc_out=enc_out,
                                          want_cache=want_cache)
            aux = aux + a
            new_states.append(ns)
            caches.append(cache)
            if cfg.family == "hybrid":
                sspec = SegmentSpec("attn_ffn", 1)
                x, a2, _, scache = block_seq(p["shared_block"], x, cfg, ctx,
                                             sspec, want_cache=want_cache)
                aux = aux + a2
                shared_caches.append(scache)
        x = apply_norm(cfg.norm, p["final_norm"], x)
        return x, aux, new_states, caches, shared_caches

    def _embed_inputs(self, p, batch, ctx):
        """Family-specific input embedding. Returns (x, label_offset)."""
        cfg = self.cfg
        tok = p["embed"][batch["tokens"]]
        if cfg.family == "vlm":
            vis = batch["patch_embeds"].astype(self.dtype) @ p["vis_proj"]
            return jnp.concatenate([vis, tok], axis=1), vis.shape[1]
        return tok, 0

    # -------------------------------------------------------------- loss --
    def loss(self, p, batch, ctx: DistCtx):
        """Next-token CE (+ MoE aux, + MTP aux). batch carries "tokens",
        "labels" (-1 = masked) and family extras ("enc_embeds",
        "patch_embeds")."""
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(p, batch, ctx)
        x = ctx.constrain(x, ctx.dp, None, None)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(p, batch["enc_embeds"].astype(self.dtype),
                                   ctx)
        h, aux, _, _, _ = self._backbone(p, x, ctx, enc_out=enc_out)
        h_text = h[:, n_prefix:]
        logits = self._unembed(p, h_text, ctx)
        labels = batch["labels"]
        mask = labels >= 0
        ce = cross_entropy(logits, jnp.maximum(labels, 0), mask)
        metrics = {"ce": ce, "aux": aux}
        total = ce + aux
        if cfg.mtp:
            mtp_ce = self._mtp_loss(p, h_text, batch, ctx)
            metrics["mtp_ce"] = mtp_ce
            total = total + 0.3 * mtp_ce
        return total, metrics

    def _mtp_loss(self, p, h, batch, ctx):
        """DeepSeek-V3 multi-token prediction: one extra block predicting
        token t+2 from [h_t ; embed(token_{t+1})]."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        nxt = p["embed"][jnp.roll(tokens, -1, axis=1)]
        z = jnp.concatenate([h, nxt], axis=-1) @ p["mtp_proj"]
        spec = SegmentSpec("attn_ffn", 1)
        z, _, _, _ = block_seq(p["mtp_block"], z, cfg, ctx, spec)
        z = apply_norm(cfg.norm, p["mtp_norm"], z)
        logits = self._unembed(p, z, ctx)
        lbl2 = jnp.roll(labels, -1, axis=1)
        mask = (lbl2 >= 0) & (jnp.arange(lbl2.shape[1]) <
                              lbl2.shape[1] - 1)[None, :]
        return cross_entropy(logits, jnp.maximum(lbl2, 0), mask)

    # ----------------------------------------------------------- prefill --
    def prefill(self, p, batch, ctx: DistCtx):
        """Full forward building decode caches. Returns (last-token logits,
        cache)."""
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(p, batch, ctx)
        x = ctx.constrain(x, ctx.dp, None, None)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(p, batch["enc_embeds"].astype(self.dtype),
                                   ctx)
        h, _, new_states, caches, shared_caches = self._backbone(
            p, x, ctx, enc_out=enc_out,
            states=self._fresh_states(x.shape[0]), want_cache=True)
        logits = self._unembed(p, h[:, -1, :], ctx)
        cache = self._pack_cache(p, caches, new_states, shared_caches,
                                 enc_out, x.shape[0], x.shape[1])
        return logits, cache

    def _fresh_states(self, B):
        cfg = self.cfg
        states = []
        for spec in self.segments:
            if spec.kind == "rwkv":
                s = R.init_rwkv_state(B, cfg, self.dtype, spec.n_layers)
            elif spec.kind == "mamba":
                s = M.init_mamba_state(B, cfg, self.dtype, spec.n_layers)
            else:
                s = None
            states.append(s)
        return states

    def _pack_cache(self, p, caches, new_states, shared_caches, enc_out,
                    B, S):
        """Convert prefill outputs into the decode cache layout (ring
        conversion for sliding-window archs happens here)."""
        cfg = self.cfg
        out = {"len": jnp.full((B,), S, jnp.int32), "segments": []}
        room = S + getattr(self, "decode_room", 1)
        for spec, cache, st in zip(self.segments, caches, new_states):
            if spec.kind in ("rwkv", "mamba"):
                out["segments"].append(st)
                continue
            if cfg.attn == "mla":
                lat, rp = cache["latent"], cache["rope"]
                pad = room - S
                out["segments"].append({
                    "latent": jnp.pad(lat, ((0, 0), (0, 0), (0, pad), (0, 0))),
                    "rope": jnp.pad(rp, ((0, 0), (0, 0), (0, pad), (0, 0)))})
            elif cfg.sliding_window and room > cfg.sliding_window:
                W = cfg.sliding_window
                k, v = cache["k"][:, :, -W:], cache["v"][:, :, -W:]
                pos = jnp.arange(S - W, S)
                entry = {"k": k, "v": v,
                         "pos": jnp.broadcast_to(
                             pos[None, None, :],
                             (k.shape[0], B, W)).astype(jnp.int32)}
                out["segments"].append(entry)
            else:
                pad = room - S
                entry = {"k": jnp.pad(cache["k"],
                                      ((0, 0), (0, 0), (0, pad), (0, 0),
                                       (0, 0))),
                         "v": jnp.pad(cache["v"],
                                      ((0, 0), (0, 0), (0, pad), (0, 0),
                                       (0, 0)))}
                if spec.cross:
                    entry.update(self._cross_cache(p, enc_out, spec))
                out["segments"].append(entry)
        if cfg.family == "hybrid":
            pad = room - S
            out["shared"] = [{
                "k": jnp.pad(c["k"], ((0, 0), (0, pad), (0, 0), (0, 0)))[None],
                "v": jnp.pad(c["v"], ((0, 0), (0, pad), (0, 0), (0, 0)))[None]}
                for c in shared_caches]
        return out

    def _cross_cache(self, p, enc_out, spec):
        cfg = self.cfg
        seg = p["segments"][self.segments.index(spec)]

        def per_layer(lp):
            B, Se, _ = enc_out.shape
            ck = (enc_out @ lp["xattn"]["wk"]).reshape(B, Se, cfg.n_kv_heads,
                                                       cfg.hd)
            cv = (enc_out @ lp["xattn"]["wv"]).reshape(B, Se, cfg.n_kv_heads,
                                                       cfg.hd)
            return ck, cv

        ck, cv = jax.vmap(per_layer)(seg)
        B, Se = enc_out.shape[0], enc_out.shape[1]
        return {"ck": ck, "cv": cv,
                "cvalid": jnp.ones((ck.shape[0], B, Se), bool)}

    # -------------------------------------------------------- init_cache --
    def init_cache(self, B: int, S: int):
        """Zeroed decode cache with room for S (+1) tokens — this is what
        the decode dry-run shapes lower against."""
        cfg, dtype = self.cfg, self.dtype
        room = S + 1
        out = {"len": jnp.zeros((B,), jnp.int32), "segments": []}
        for spec in self.segments:
            L = spec.n_layers
            if spec.kind == "rwkv":
                out["segments"].append(R.init_rwkv_state(B, cfg, dtype, L))
            elif spec.kind == "mamba":
                out["segments"].append(M.init_mamba_state(B, cfg, dtype, L))
            elif cfg.attn == "mla":
                out["segments"].append(A.init_mla_cache(
                    B, room, cfg.mla.kv_lora_rank, cfg.mla.qk_rope_dim,
                    dtype, L))
                out["segments"][-1].pop("len")
            elif cfg.sliding_window and room > cfg.sliding_window:
                c = A.init_ring_cache(B, cfg.sliding_window, cfg.n_kv_heads,
                                      cfg.hd, dtype, L)
                c.pop("len")
                out["segments"].append(c)
            else:
                c = A.init_full_cache(B, room, cfg.n_kv_heads, cfg.hd,
                                      dtype, L)
                c.pop("len")
                if spec.cross:
                    Se = cfg.encoder.n_ctx
                    c["ck"] = jnp.zeros((L, B, Se, cfg.n_kv_heads, cfg.hd),
                                        dtype)
                    c["cv"] = jnp.zeros((L, B, Se, cfg.n_kv_heads, cfg.hd),
                                        dtype)
                    c["cvalid"] = jnp.ones((L, B, Se), bool)
                out["segments"].append(c)
        if cfg.family == "hybrid":
            n_groups = len(self.segments)
            out["shared"] = [
                {"k": jnp.zeros((1, B, room, cfg.n_kv_heads, cfg.hd), dtype),
                 "v": jnp.zeros((1, B, room, cfg.n_kv_heads, cfg.hd), dtype)}
                for _ in range(n_groups)]
        return out

    # --------------------------------------------------------- serve_step --
    def serve_step(self, p, cache, tokens, ctx: DistCtx):
        """One decode step. tokens: (B,). Returns (logits (B, V), cache)."""
        cfg = self.cfg
        lengths = cache["len"]
        x1 = p["embed"][tokens]
        new_segments = []
        new_shared = list(cache.get("shared", []))
        for i, spec in enumerate(self.segments):
            cs = cache["segments"][i]
            if spec.kind in ("rwkv", "mamba"):
                x1, ns = run_segment_decode(p["segments"][i], x1, cfg, ctx,
                                            spec, state=cs, lengths=lengths)
            else:
                x1, ns = run_segment_decode(p["segments"][i], x1, cfg, ctx,
                                            spec, cache=cs, lengths=lengths)
            new_segments.append(ns)
            if cfg.family == "hybrid":
                sc = cache["shared"][i]
                x1, nsc = block_decode(p["shared_block"], x1, cfg, ctx,
                                       SegmentSpec("attn_ffn", 1),
                                       cache={k: v[0] for k, v in sc.items()},
                                       lengths=lengths)
                new_shared[i] = {k: v[None] for k, v in nsc.items()}
        x1 = apply_norm(cfg.norm, p["final_norm"], x1)
        logits = self._unembed(p, x1, ctx)
        new_cache = {"len": lengths + 1, "segments": new_segments}
        if cfg.family == "hybrid":
            new_cache["shared"] = new_shared
        return logits, new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
