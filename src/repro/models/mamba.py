"""Mamba2 (SSD) blocks for the Zamba2 hybrid (arXiv:2411.15242 uses Mamba2
backbone blocks + shared attention; SSD per arXiv:2405.21060).

Recurrence per head (scalar decay a_t = exp(A * dt_t), state (P, N)):

    h_t = a_t * h_{t-1} + dt_t * x_t (outer) B_t
    y_t = C_t . h_t + D * x_t

Paths:
  * ``ssd_scan``    — exact step recurrence (decode + oracle).
  * ``ssd_chunked`` — chunkwise parallel: intra-chunk decay matrix
                      L[t,i] = exp(cum_t - cum_i) is a scalar per head, so
                      it is computed directly (numerically safe) and the
                      intra part is two batched matmuls.

State per layer: {"h": (B, H, P, N), "conv": (B, conv_width-1, conv_dim)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import DistCtx, dense_init


def _dims(cfg):
    d = cfg.d_model
    d_inner = cfg.ssm.expand * d
    P = cfg.ssm.head_dim
    H = d_inner // P
    N = cfg.ssm.state_dim
    return d, d_inner, H, P, N


def init_mamba2(key, cfg, dtype):
    d, d_inner, H, P, N = _dims(cfg)
    # xBC projection: x (d_inner) + B (N) + C (N); B/C shared across heads
    # (mamba2 default n_groups=1).
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner + 2 * N + H), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm.conv_width, conv_dim), dtype,
                             scale=0.1),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -1.0, jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype),
    }


def _split_in(p, x, cfg):
    d, d_inner, H, P, N = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N],
                               axis=-1)
    return z, xbc, dt_raw  # dt_raw: (..., H)


def _causal_conv(xbc, conv_state, w, b):
    """Depthwise causal conv over time. xbc: (B, S, C); conv_state:
    (B, K-1, C) trailing context from the previous segment."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(K))
    new_state = full[:, -(K - 1):] if K > 1 else conv_state
    return jax.nn.silu(out + b), new_state


def _gates(p, dt_raw):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.clip(dt, 1e-4, 10.0)
    A = -jnp.exp(jnp.clip(p["A_log"], -8.0, 4.0))
    loga = jnp.clip(A * dt, -8.0, -1e-6)   # per-step log decay (B,S,H)
    return dt, loga


def ssd_scan(xh, Bv, Cv, dt, loga, D, h0):
    """Exact recurrence. xh: (B,S,H,P); Bv/Cv: (B,S,N); dt/loga: (B,S,H);
    h0: (B,H,P,N). Returns (y (B,S,H,P), h_final)."""
    def step(h, xs):
        xt, bt, ct, dtt, lat = xs
        a = jnp.exp(lat)[..., None, None]                  # (B,H,1,1)
        upd = (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        h = a * h + upd                                    # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in
               (xh.astype(jnp.float32), Bv.astype(jnp.float32),
                Cv.astype(jnp.float32), dt, loga))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1)
    return y + D[None, None, :, None] * xh.astype(jnp.float32), h


def ssd_chunked(xh, Bv, Cv, dt, loga, D, h0, chunk: int):
    """Chunkwise-parallel SSD; same contract as ssd_scan."""
    B, S, H, P = xh.shape
    N = Bv.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    xf = (dt[..., None] * xh.astype(jnp.float32)).reshape(B, nc, chunk, H, P)
    bf = Bv.astype(jnp.float32).reshape(B, nc, chunk, N)
    cf = Cv.astype(jnp.float32).reshape(B, nc, chunk, N)
    la = loga.reshape(B, nc, chunk, H)

    def chunk_step(h, xs):
        xc, bc, cc, lac = xs                    # (B,chunk,...)
        cum = jnp.cumsum(lac, axis=1)           # inclusive (B,chunk,H)
        ctot = cum[:, -1]                       # (B,H)
        # Inter-chunk: y_t += e^{cum_t} C_t . h0
        inter = jnp.einsum("bth,bthp->bthp", jnp.exp(cum),
                           jnp.einsum("btn,bhpn->bthp", cc, h))
        # Intra-chunk: L[t,i] = exp(cum_t - cum_i), i <= t (inclusive of i=t
        # because the scan updates h before the output).
        Lm = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,i,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        scores = jnp.einsum("btn,bin->bti", cc, bc)            # (B,t,i)
        w = jnp.where(tri[None, :, :, None], Lm, 0.0) * scores[..., None]
        intra = jnp.einsum("btih,bihp->bthp", w, xc)
        y = inter + intra
        # State update: h' = e^{ctot} h + sum_i e^{ctot - cum_i} x_i B_i^T
        dec = jnp.exp(ctot[:, None] - cum)                     # (B,chunk,H)
        upd = jnp.einsum("bih,bihp,bin->bhpn", dec, xc, bc)
        h = jnp.exp(ctot)[..., None, None] * h + upd
        return h, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xf, bf, cf, la))
    h, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y + D[None, None, :, None] * xh.astype(jnp.float32), h


def mamba2_block(p, x, state, cfg, ctx: DistCtx, *, use_chunked=True):
    """x: (B, S, d); state {"h": (B,H,P,N), "conv": (B,K-1,convdim)}."""
    from repro.models.common import rms_norm
    B, S, d = x.shape
    _, d_inner, H, P, N = _dims(cfg)
    z, xbc, dt_raw = _split_in(p, x, cfg)
    xbc, conv_state = _causal_conv(xbc, state["conv"], p["conv_w"],
                                   p["conv_b"])
    xin, Bv, Cv = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xh = xin.reshape(B, S, H, P)
    xh = ctx.constrain(xh, ctx.dp, None, ctx.tp, None)
    dt, loga = _gates(p, dt_raw)
    if use_chunked and S % cfg.ssm_chunk == 0 and S > 1:
        y, h = ssd_chunked(xh, Bv, Cv, dt, loga, p["D"], state["h"],
                           cfg.ssm_chunk)
    else:
        y, h = ssd_scan(xh, Bv, Cv, dt, loga, p["D"], state["h"])
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"], {"h": h, "conv": conv_state}


def init_mamba_state(B, cfg, dtype, layers: int):
    d, d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    return {"h": jnp.zeros((layers, B, H, P, N), jnp.float32),
            "conv": jnp.zeros((layers, B, cfg.ssm.conv_width - 1, conv_dim),
                              dtype)}
