"""Latent-space ingestion encoder for the serve plane (DESIGN.md §17).

The paper's separation analysis (Theorem 3.2, Definition 3.3) is
agnostic to WHERE the geometry lives; raw pixel/token space rarely
satisfies center separation, so related federated-clustering work
clusters clients on learned embeddings instead. This module is the
ingestion-side bridge from the model zoo (``models/`` blocks +
``configs/`` architecture registry) to the serve plane — the sibling of
``models/heads.py`` (the serving-output side), sharing its block/init/
apply conventions:

  * ``resolve_encoder_spec`` maps a plan's ``encoder`` name to an
    :class:`EncoderSpec`: any registered zoo config name
    (``configs.list_archs()``) contributes its REDUCED variant's
    activation, FFN expansion ratio, head counts and layer count,
    re-dimensioned to the plan's feature width ``d`` — the encoder
    operates at the clustering feature width, not the config's
    ``d_model`` (the ``heads.py`` re-dimensioning rule).
  * ``init_encoder`` builds one parameter set (layers stacked on a
    leading axis) through the zoo initializers (``models.ffn.init_ffn``,
    ``models.attention.init_gqa``, ``models.common.init_norm``) from one
    deterministic key.
  * ``apply_encoder`` runs every item's raw token/patch sequence
    through ``n_layers`` pre-norm blocks (non-causal masked
    self-attention over the sequence + the FFN block — a token sequence
    is ordered, but positions arrive as part of the stub-frontend
    embeddings, matching the repo's precomputed-embedding convention)
    and masked-mean pools over the VALID tokens to one ``(d,)``
    embedding per item. ``encode_dtype="bf16"`` casts storage to
    bfloat16 while every matmul accumulates in f32
    (``preferred_element_type``), mirroring the fused solve+attach
    precision contract (§13).

Inputs follow the stub-frontend rule (``configs.base.EncoderConfig``):
raw images/audio/text arrive as precomputed token/patch embeddings of
width ``d`` — each submitted point is a ``(seq, d)`` sequence, the
encoder maps it to latent space, and the unchanged solve+attach
machinery clusters the embeddings.

``block_plan`` publishes the §15 kernel-checker metadata of the encoder
forward: the VMEM feasibility certificate of a fused per-item encoder
block kernel (items on the grid's major axis, the FFN hidden dimension
tiled on the minor axis so wide ``d_ff`` never exceeds the per-core
budget), evaluated by ``analysis/kernels.py`` across the registered
ladder exactly like the Pallas kernels' plans.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.attention import plain_attention, init_gqa
from repro.models.common import init_norm, rms_norm
from repro.models.ffn import init_ffn
from repro.models.heads import _AttnDims, _dot, _ffn_apply

__all__ = ["ENCODE_DTYPES", "EncoderConfigError", "EncoderSpec",
           "apply_encoder", "block_plan", "encoder_param_count",
           "init_encoder", "resolve_encoder_spec"]

ENCODE_DTYPES = ("f32", "bf16")


class EncoderConfigError(ValueError):
    """An encoder/encode_dtype selection failed validation (named, with
    the accepted values) — raised at plan construction, never in
    tracing."""


class EncoderSpec(NamedTuple):
    """Static shape/flavor of the ingestion encoder (all fields
    hashable so the spec can ride jit static arguments)."""
    name: str           # a registered configs.* name
    d: int              # feature width (the plan's d; also the token width)
    d_ff: int           # FFN hidden width (ratio-scaled from the config)
    activation: str     # swiglu | gelu | relu2
    n_layers: int       # stacked pre-norm blocks (the REDUCED depth)
    n_heads: int
    n_kv_heads: int


def resolve_encoder_spec(name: str, d: int) -> EncoderSpec:
    """Validate + resolve a plan's ``encoder`` selection into an
    :class:`EncoderSpec`. Raises :class:`EncoderConfigError` naming the
    accepted values (``StreamConfig`` re-raises field-named)."""
    from repro.configs import get_config, list_archs
    try:
        cfg = get_config(name, reduced=True)
    except KeyError:
        raise EncoderConfigError(
            f"encoder={name!r} is invalid: accepted values are 'off' or "
            f"a registered model config {list_archs()}") from None
    # Re-dimension the REDUCED config to the clustering feature width:
    # keep its FFN expansion ratio, activation, head counts and depth,
    # floor d_ff at d (the heads.py rule).
    d_ff = max(int(d), int(round(d * cfg.d_ff / cfg.d_model)))
    n_heads, n_kv = int(cfg.n_heads), int(cfg.n_kv_heads)
    if d % n_heads:
        raise EncoderConfigError(
            f"encoder={name!r} is invalid for d={d}: the config's "
            f"n_heads={n_heads} must divide the plan's feature "
            f"dimension (pick a different config or d)")
    n_layers = max(1, min(2, int(cfg.n_layers)))
    return EncoderSpec(str(name), int(d), d_ff, str(cfg.activation),
                       n_layers, n_heads, n_kv)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _attn_dims(spec: EncoderSpec) -> _AttnDims:
    return _AttnDims(d_model=spec.d, n_heads=spec.n_heads,
                     n_kv_heads=spec.n_kv_heads,
                     hd=spec.d // spec.n_heads, qkv_bias=False)


def _init_layer(key, spec: EncoderSpec, dtype):
    ks = jax.random.split(key, 2)
    return {"norm1": init_norm("rmsnorm", spec.d, dtype),
            "attn": init_gqa(ks[0], _attn_dims(spec), dtype),
            "norm2": init_norm("rmsnorm", spec.d, dtype),
            "ffn": init_ffn(ks[1], spec.d, spec.d_ff, spec.activation,
                            dtype)}


def init_encoder(key, spec: EncoderSpec, dtype=jnp.float32):
    """The encoder parameter tree from one key: ``n_layers`` pre-norm
    blocks stacked on a leading layer axis (leaf shapes
    ``(n_layers, ...)`` — the layout checkpoint schema v6 stores) plus
    the final norm."""
    lk, _ = jax.random.split(key)
    layers = jax.vmap(lambda kk: _init_layer(kk, spec, dtype))(
        jax.random.split(lk, spec.n_layers))
    return {"layers": layers,
            "norm_f": init_norm("rmsnorm", spec.d, dtype)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn_apply(p, x, tmask, spec: EncoderSpec):
    """Non-causal masked self-attention over each item's token
    sequence. x: (R, S, d) storage dtype; tmask: (R, S) bool. Returns
    (R, S, d) f32."""
    R, S, d = x.shape
    H, KVH, hd = spec.n_heads, spec.n_kv_heads, d // spec.n_heads
    q = _dot(x, p["wq"]).reshape(R, S, H, hd).astype(x.dtype)
    kk = _dot(x, p["wk"]).reshape(R, S, KVH, hd).astype(x.dtype)
    v = _dot(x, p["wv"]).reshape(R, S, KVH, hd).astype(x.dtype)
    o = plain_attention(q, kk, v, kv_mask=tmask)
    return _dot(o.reshape(R, S, H * hd), p["wo"])


def _block_fwd(p, h, tmask, spec: EncoderSpec, store):
    """One pre-norm block (attention + FFN, residual). h: (R, S, d)
    f32; returns (R, S, d) f32."""
    a = rms_norm(h, p["norm1"]["w"].astype(jnp.float32)).astype(store)
    h = h + _attn_apply(p["attn"], a, tmask, spec)
    f = rms_norm(h, p["norm2"]["w"].astype(jnp.float32)).astype(store)
    return h + _ffn_apply(p["ffn"], f, spec.activation)


def apply_encoder(params, x, tmask, spec: EncoderSpec,
                  encode_dtype: str = "f32"):
    """Encode raw token/patch sequences into latent points.

    ``x``: (..., S, d) float token embeddings; ``tmask``: (..., S) bool
    token validity (per-item ragged lengths, padded to the bucket's
    ``S``). Returns (..., d) f32 embeddings — the masked mean of the
    final-norm token states over each item's VALID tokens; items with
    no valid tokens (padding rows) embed to exactly zero, so the serve
    step's point mask stays the single source of validity.
    ``encode_dtype`` selects f32 or bf16 storage with f32 accumulation
    (§13 contract)."""
    store = jnp.bfloat16 if encode_dtype == "bf16" else jnp.float32
    lead = x.shape[:-2]
    S, d = x.shape[-2], x.shape[-1]
    xr = x.reshape((-1, S, d)).astype(store)
    mr = tmask.reshape((-1, S))
    ps = jax.tree.map(lambda a: a.astype(store), params)
    h = xr.astype(jnp.float32)
    for i in range(spec.n_layers):
        layer = jax.tree.map(lambda a: a[i], ps["layers"])
        h = _block_fwd(layer, h, mr, spec, store)
    h = rms_norm(h, ps["norm_f"]["w"].astype(jnp.float32))
    mf = mr.astype(jnp.float32)
    tot = jnp.maximum(jnp.sum(mf, axis=-1, keepdims=True), 1.0)
    pooled = jnp.einsum("rsd,rs->rd", h, mf) / tot
    pooled = jnp.where(mr.any(axis=-1, keepdims=True), pooled, 0.0)
    return pooled.reshape(lead + (d,))


def encoder_param_count(spec: EncoderSpec) -> int:
    """Static parameter count (stats/docs)."""
    d, ff, hd = spec.d, spec.d_ff, spec.d // spec.n_heads
    per = 2 * d                                    # norm1 + norm2
    per += (3 * d * ff if spec.activation == "swiglu"
            else 2 * d * ff + ff + d)
    per += d * spec.n_heads * hd + 2 * d * spec.n_kv_heads * hd \
        + spec.n_heads * hd * d
    return spec.n_layers * per + d                 # + final norm


# ---------------------------------------------------------------------------
# §15 kernel-checker block plan
# ---------------------------------------------------------------------------


def _ff_tile(d_ff: int) -> int:
    """FFN hidden-axis tile: whole when it fits one 512-lane window,
    else 512 (a multiple of the 128-lane tile, so a partitioned d_ff
    never relayouts)."""
    return d_ff if d_ff <= 512 else 512


def block_plan(items: int, S: int, d: int, d_ff: int, n_heads: int,
               dtype: str = "f32") -> dict:
    """Static BlockSpec/grid metadata of the fused per-item encoder
    block for the §15 kernel checker: grid major axis = items (one
    (S, d) sequence per step), minor axis tiles the FFN hidden width so
    the streamed weight tiles — not the full (d, d_ff) matrices — bound
    the VMEM footprint. Attention weights are grid-constant (resident,
    single-buffered); the token block and weight tiles stream
    (double-buffered). Mirrors ``apply_encoder``'s shapes exactly —
    the checker evaluates this plan across the registered ladder."""
    store = "f32" if dtype == "f32" else "bf16"
    ft = _ff_tile(d_ff)
    blk = [
        dict(name="x", shape=(1, S, d), dtype=store, kind="in",
             resident=False, array_shape=(items, S, d)),
        dict(name="tmask", shape=(1, S), dtype="i32", kind="in",
             resident=False, array_shape=(items, S)),
        dict(name="wq", shape=(d, d), dtype=store, kind="in",
             resident=True, array_shape=(d, d)),
        dict(name="wk", shape=(d, d), dtype=store, kind="in",
             resident=True, array_shape=(d, d)),
        dict(name="wv", shape=(d, d), dtype=store, kind="in",
             resident=True, array_shape=(d, d)),
        dict(name="wo", shape=(d, d), dtype=store, kind="in",
             resident=True, array_shape=(d, d)),
        dict(name="scores", shape=(n_heads, S, S), dtype="f32",
             kind="scratch", resident=True,
             array_shape=(n_heads, S, S)),
        dict(name="w1", shape=(d, ft), dtype=store, kind="in",
             resident=False, array_shape=(d, d_ff)),
        dict(name="w3", shape=(d, ft), dtype=store, kind="in",
             resident=False, array_shape=(d, d_ff)),
        dict(name="w2", shape=(ft, d), dtype=store, kind="in",
             resident=False, array_shape=(d_ff, d)),
        dict(name="hidden", shape=(S, ft), dtype="f32", kind="scratch",
             resident=True, array_shape=(S, d_ff)),
        dict(name="out", shape=(1, d), dtype="f32", kind="out",
             resident=False, array_shape=(items, d)),
    ]
    return dict(kernel="encoder_fwd", grid=(items, d_ff // ft),
                storage=store, accum="f32", blocks=blk)
