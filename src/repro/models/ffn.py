"""Feed-forward variants: SwiGLU (llama-family), GeLU (whisper), squared
ReLU (nemotron-4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import DistCtx, dense_init


def init_ffn(key, d: int, d_ff: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {"w1": dense_init(ks[0], (d, d_ff), dtype),
                "w3": dense_init(ks[1], (d, d_ff), dtype),
                "w2": dense_init(ks[2], (d_ff, d), dtype)}
    return {"w1": dense_init(ks[0], (d, d_ff), dtype),
            "b1": jnp.zeros((d_ff,), dtype),
            "w2": dense_init(ks[2], (d_ff, d), dtype),
            "b2": jnp.zeros((d,), dtype)}


def _constrain_hidden(ctx: DistCtx, h):
    """(B, S, ff) or (B, ff): batch on data axes, hidden on model."""
    spec = (ctx.dp,) + (None,) * (h.ndim - 2) + (ctx.tp,)
    return ctx.constrain(h, *spec)


def apply_ffn(p, x, activation: str, ctx: DistCtx):
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
        return _constrain_hidden(ctx, h) @ p["w2"]
    h = x @ p["w1"] + p["b1"]
    if activation == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h)
    return _constrain_hidden(ctx, h) @ p["w2"] + p["b2"]
