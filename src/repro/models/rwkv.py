"""RWKV6 ("Finch") blocks: time-mix with data-dependent per-channel decay
and channel-mix FFN (arXiv:2404.05892).

Two equivalent sequence paths:
  * ``rwkv6_scan``    — the exact step recurrence (lax.scan over time);
                        used for decode (O(1) state) and as the oracle.
  * ``rwkv6_chunked`` — chunkwise-parallel form for training: within a
                        chunk the decay products are applied via a masked
                        attention-like matmul in log-space-normalized f32;
                        across chunks a short scan carries the (H, dh, dh)
                        state. Validated against the scan path in tests.

State layout per layer: {"s": (B, H, dh, dh), "shift": (B, d), and for the
channel-mix "shift2": (B, d)}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import DistCtx, dense_init


def init_rwkv6(key, cfg, dtype):
    d = cfg.d_model
    dh = cfg.ssm.head_dim
    H = d // dh
    r = cfg.ssm.decay_lora
    ks = jax.random.split(key, 12)
    return {
        # time-mix interpolation vectors (token shift)
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "wg": dense_init(ks[3], (d, d), dtype),
        "wo": dense_init(ks[4], (d, d), dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -2.0, dtype),
        "wA": dense_init(ks[5], (d, r), dtype),
        "wB": dense_init(ks[6], (r, d), dtype, scale=0.01),
        "u": dense_init(ks[7], (H, dh), dtype, scale=0.1),  # bonus
        "ln_x": jnp.ones((d,), dtype),                      # group-norm-ish
    }


def init_rwkv_channel_mix(key, cfg, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {"mu": jnp.full((d,), 0.5, dtype),
            "wk": dense_init(ks[0], (d, dff), dtype),
            "wv": dense_init(ks[1], (dff, d), dtype)}


def _token_shift(x, shift_state):
    """x: (B, S, d); shift_state: (B, d) = last token of previous segment.
    Returns x shifted right by one along S."""
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _time_mix_inputs(p, x, shift_state, cfg):
    B, S, d = x.shape
    dh = cfg.ssm.head_dim
    H = d // dh
    xp = _token_shift(x, shift_state)

    def mix(mu):
        return x * mu + xp * (1.0 - mu)

    r = (mix(p["mu_r"]) @ p["wr"]).reshape(B, S, H, dh)
    k = (mix(p["mu_k"]) @ p["wk"]).reshape(B, S, H, dh)
    v = (mix(p["mu_v"]) @ p["wv"]).reshape(B, S, H, dh)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    logw = -jnp.exp(jnp.clip(
        (p["w0"] + jnp.tanh(mix(p["mu_w"]) @ p["wA"]) @ p["wB"])
        .astype(jnp.float32), -8.0, 1.5))                  # (B,S,d) in (-e^1.5,0)
    # Clip per-step log-decay to [-4, -1e-4]: keeps the chunked form's
    # exponent spread bounded (see rwkv6_chunked) and is shared with the
    # scan oracle so both paths agree exactly.
    logw = jnp.clip(logw, -4.0, -1e-4).reshape(B, S, H, dh)
    return r, k, v, g, logw, x[:, -1, :]


def rwkv6_scan(r, k, v, logw, u, s0):
    """Exact recurrence. r/k/v/logw: (B, S, H, dh); u: (H, dh);
    s0: (B, H, dh, dh). Returns (out (B,S,H,dh), s_final)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw)

    def step(s, xs):
        rt, kt, vt, wt = xs  # (B,H,dh)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,dh,dh)
        out = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, w))
    s, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1), s


def rwkv6_chunked(r, k, v, logw, u, s0, chunk: int):
    """Chunkwise-parallel RWKV6. Same contract as rwkv6_scan."""
    B, S, H, dh = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rf = r.astype(jnp.float32).reshape(B, nc, chunk, H, dh)
    kf = k.astype(jnp.float32).reshape(B, nc, chunk, H, dh)
    vf = v.astype(jnp.float32).reshape(B, nc, chunk, H, dh)
    lw = logw.reshape(B, nc, chunk, H, dh)

    def chunk_step(s, xs):
        rc, kc, vc, lwc = xs  # (B, chunk, H, dh)
        # Inclusive / exclusive cumulative log-decay within the chunk.
        cinc = jnp.cumsum(lwc, axis=1)                      # sum_{tau<=t}
        cexc = cinc - lwc                                   # sum_{tau<t}
        ctot = cinc[:, -1:]                                 # (B,1,H,dh)
        # Inter-chunk: out_t += (r_t * exp(cexc_t)) . s   (exp <= 1)
        inter = jnp.einsum("bthi,bhij->bthj", rc * jnp.exp(cexc), s)
        # Intra-chunk strict-lower part:
        #   score[t,i] = sum_d r_t[d] k_i[d] exp(cexc_t[d] - cinc_i[d]),  i<t.
        # Factor through the chunk-midpoint decay c_mid so each factor's
        # exponent is bounded by (chunk/2)*|logw|_max (f32-safe for the
        # clipped logw and chunk <= 64).
        c_mid = cinc[:, chunk // 2][:, None]                # (B,1,H,dh)
        r_t = rc * jnp.exp(cexc - c_mid)
        k_t = kc * jnp.exp(c_mid - cinc)
        att = jnp.einsum("bthd,bihd->bhti", r_t, k_t)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        intra = jnp.einsum("bhti,bihd->bthd", att, vc)
        # Bonus (diagonal) term: r_t . (u * k_t) v_t
        diag = jnp.einsum("bthd,bthd->bth", rc, u[None, None] * kc)
        out = inter + intra + diag[..., None] * vc
        # State update: s' = diag(e^{ctot}) s + sum_i e^{ctot - cinc_i} k_i v_i
        k_dec = kc * jnp.exp(ctot - cinc)                   # exp <= 1
        s = jnp.exp(ctot[:, 0])[..., None] * s + jnp.einsum(
            "bihd,bihe->bhde", k_dec, vc)
        return s, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, lw))
    s, outs = jax.lax.scan(chunk_step, s0.astype(jnp.float32), xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, dh)
    return out, s


def _group_norm(x, w, dh):
    """Per-head RMS normalization of the time-mix output."""
    B, S, d = x.shape
    xh = x.reshape(B, S, d // dh, dh).astype(jnp.float32)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + 1e-5)
    return xh.reshape(B, S, d) * w


def rwkv6_time_mix(p, x, state, cfg, ctx: DistCtx, *, use_chunked=True):
    """x: (B, S, d); state: {"s": (B,H,dh,dh), "shift": (B,d)}.
    Returns (out, new_state)."""
    B, S, d = x.shape
    dh = cfg.ssm.head_dim
    r, k, v, g, logw, last = _time_mix_inputs(p, x, state["shift"], cfg)
    r = ctx.constrain(r, ctx.dp, None, ctx.tp, None)
    k = ctx.constrain(k, ctx.dp, None, ctx.tp, None)
    v = ctx.constrain(v, ctx.dp, None, ctx.tp, None)
    fn = rwkv6_chunked if (use_chunked and S % cfg.ssm_chunk == 0 and S > 1) \
        else rwkv6_scan
    if fn is rwkv6_chunked:
        o, s = fn(r, k, v, logw, p["u"].astype(jnp.float32), state["s"],
                  cfg.ssm_chunk)
    else:
        o, s = fn(r, k, v, logw, p["u"].astype(jnp.float32), state["s"])
    o = _group_norm(o.reshape(B, S, d).astype(x.dtype), p["ln_x"], dh)
    o = (o.astype(x.dtype) * g) @ p["wo"]
    return o, {"s": s, "shift": last}


def rwkv_channel_mix(p, x, shift_state, cfg):
    xp = _token_shift(x, shift_state)
    xk = x * p["mu"] + xp * (1.0 - p["mu"])
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return h @ p["wv"], x[:, -1, :]


def init_rwkv_state(B, cfg, dtype, layers: int):
    d = cfg.d_model
    dh = cfg.ssm.head_dim
    H = d // dh
    return {"s": jnp.zeros((layers, B, H, dh, dh), jnp.float32),
            "shift": jnp.zeros((layers, B, d), dtype),
            "shift2": jnp.zeros((layers, B, d), dtype)}
