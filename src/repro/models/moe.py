"""Mixture-of-Experts layer with top-k token-choice routing.

Dispatch is sort-based and fixed-capacity in both implementations — tokens
are scatter-packed into per-expert queues of capacity C = ceil(T*k/E*cf),
processed as one batched matmul over experts, and gathered back (overflow
tokens drop with zero contribution, standard dropped-token semantics):

  "dense"     — the pack/compute/unpack happens locally under GSPMD (jit).
                Right for small expert counts (Mixtral E=8), where each
                expert's FFN hidden dim is tensor-sharded over ``model``.

  "alltoall"  — expert parallelism over the ``model`` mesh axis inside a
                nested shard_map: tokens are resharded over (data x model),
                packed, exchanged with one all_to_all so each shard holds
                only its resident E/tp experts' queues, processed, and
                returned by the reverse all_to_all (+ a final all_gather
                over ``model``). This is the DeepSeek-scale path (E=256);
                the MoE collective bytes in the roofline are exactly these.

Both paths return the switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map as _shard_map

from repro.models.common import DistCtx, dense_init


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d, E, dff = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype, scale=0.006),
        "w1": dense_init(ks[1], (E, d, dff), dtype),
        "w3": dense_init(ks[2], (E, d, dff), dtype),
        "w2": dense_init(ks[3], (E, dff, d), dtype),
    }
    if m.n_shared:
        from repro.models.ffn import init_ffn
        p["shared"] = init_ffn(ks[4], d, m.n_shared * dff, "swiglu", dtype)
    return p


def _route(router_w, x2d, m):
    """Top-k routing. x2d: (T, d). Returns (ids (T,k) int32, gates (T,k)
    f32 renormalized, aux_loss)."""
    logits = (x2d @ router_w).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)
    choice = jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1)
    fe = jnp.mean(choice, axis=0)
    aux = E * jnp.sum(me * fe)                             # switch LB loss
    return ids.astype(jnp.int32), gates, aux


def _positions_in_expert(flat_e: jax.Array, E: int):
    """Rank of each routed (token, choice) entry within its expert's queue
    (deterministic flat order). flat_e: (N,) int32 in [0, E)."""
    N = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(N) - seg_start[sorted_e]
    return jnp.zeros((N,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))


def _capacity(T: int, m) -> int:
    return max(1, int(math.ceil(T * m.top_k / m.n_experts *
                                m.capacity_factor)))


def _expert_ffn(w1, w3, w2, xe):
    """Batched per-expert SwiGLU in the weights' own dtype (bf16 on the
    production configs — MXU-rate matmuls; §Perf iteration 1 moved this
    off an explicit f32 upcast that doubled compute and made every expert
    gradient an f32 tensor)."""
    xe = xe.astype(w1.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1)) * \
        jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _pack(x2d, ids, m, C: int):
    """Gather tokens into (E, C, d) queues. Returns (buf, flat_e, pos_c,
    keep).

    Gather-based (queue slot (e, c) pulls its source token) rather than
    scatter-based (token pushes itself into its slot): a d-wide gather
    costs ~2x the queue bytes where the scatter-add read-modify-writes the
    whole buffer (§Perf mixtral iteration 3). Only the (T*k,) int32
    position map is still scattered."""
    E = m.n_experts
    flat_e = ids.reshape(-1)                               # (N = T*k,)
    N = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    seg_end = jnp.searchsorted(sorted_e, jnp.arange(E), side="right")
    pos_sorted = jnp.arange(N) - seg_start[sorted_e]
    pos = jnp.zeros((N,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))                      # cheap: int32
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)
    # slot (e, c) <- token row order[seg_start[e] + c] / top_k
    slot = seg_start[:, None] + jnp.arange(C)[None, :]     # (E, C)
    valid = slot < seg_end[:, None]
    src_entry = jnp.take(order, jnp.clip(slot, 0, N - 1).reshape(-1))
    src_tok = src_entry // m.top_k                         # (E*C,)
    from repro.kernels import ops
    buf = ops.moe_dispatch(x2d, src_tok, valid.reshape(-1)) \
        .reshape(E, C, x2d.shape[1])
    return buf, flat_e, pos_c, keep


def _unpack(ybuf, flat_e, pos_c, keep, gates, T: int, top_k: int):
    from repro.kernels import ops
    C = ybuf.shape[1]
    slot = flat_e * C + pos_c                              # (T*k,)
    w = jnp.where(keep, gates.reshape(-1), 0.0)
    return ops.moe_combine(ybuf.reshape(-1, ybuf.shape[-1]), slot, w,
                           top_k=top_k)


def _local_moe(p, x2d, m):
    """Pack/compute/unpack with all experts local (GSPMD shards the
    per-expert FFN hidden dim)."""
    T, d = x2d.shape
    C = _capacity(T, m)
    ids, gates, aux = _route(p["router"], x2d, m)
    buf, flat_e, pos_c, keep = _pack(x2d, ids, m, C)
    ye = _expert_ffn(p["w1"], p["w3"], p["w2"], buf)
    y = _unpack(ye.astype(x2d.dtype), flat_e, pos_c, keep, gates, T, m.top_k)
    return y.astype(x2d.dtype), aux


def _pad_to(E: int, nsh: int) -> int:
    return ((E + nsh - 1) // nsh) * nsh


def _ep_axes_for(E: int, ctx: DistCtx):
    """Largest minor-first mesh-axis prefix (model, then data axes inward)
    whose size product divides E. Always includes the model axis."""
    axes = [ctx.tp]
    nsh = ctx.mesh.shape[ctx.tp]
    for a in reversed(tuple(ctx.dp)):
        s = ctx.mesh.shape[a]
        if nsh * s <= E and E % (nsh * s) == 0:
            axes.append(a)
            nsh *= s
        else:
            break
    return tuple(reversed(axes))   # major -> minor, matches P(...) order


def _grid_a2a(send, ep_axes, sizes):
    """Hierarchical all-to-all over an axis grid. send: (nsh, Q, d) in
    target-major flat layout (shard s = grid index, axes major->minor).
    One tiled single-axis a2a per mesh axis (minor/within-row first — the
    TPU-torus-friendly 2-D dispatch; also avoids the degenerate loopy
    lowering XLA produces for tuple-axis all_to_all). The block exchange
    along each axis is an involution, so the return path calls this same
    function."""
    if len(ep_axes) == 1:
        return jax.lax.all_to_all(send, ep_axes[0], split_axis=0,
                                  concat_axis=0, tiled=True)
    x = send.reshape(*sizes, *send.shape[1:])
    for k in reversed(range(len(ep_axes))):
        x = jax.lax.all_to_all(x, ep_axes[k], split_axis=k, concat_axis=k,
                               tiled=True)
    return x.reshape(send.shape)


def _alltoall_local(p_local, x_my, m, sizes, ep_axes):
    """Per-shard body inside shard_map. x_my: (T_my, d); p_local holds this
    shard's E_pad/nsh resident experts. ``ep_axes``: mesh axis name(s) the
    experts are sharded over — ("model",) for the baseline tp-EP,
    (*dp, "model") for 2-D EP where every expert is chip-resident and its
    gradient never crosses a device boundary. ``sizes``: mesh extent per
    axis."""
    T, d = x_my.shape
    E = m.n_experts
    nsh = 1
    for s in sizes:
        nsh *= s
    E_pad = _pad_to(E, nsh)
    E_loc = E_pad // nsh
    C = _capacity(T, m)

    ids, gates, aux = _route(p_local["router"], x_my, m)
    buf, flat_e, pos_c, keep = _pack(x_my, ids, m, C)      # (E, C, d)
    if E_pad > E:
        buf = jnp.pad(buf, ((0, E_pad - E), (0, 0), (0, 0)))
    send = buf.reshape(nsh, E_loc * C, d)
    recv = _grid_a2a(send, ep_axes, sizes)
    xe = recv.reshape(nsh, E_loc, C, d).transpose(1, 0, 2, 3)
    xe = xe.reshape(E_loc, nsh * C, d)
    ye = _expert_ffn(p_local["w1"], p_local["w3"], p_local["w2"], xe)
    ye = ye.reshape(E_loc, nsh, C, d).transpose(1, 0, 2, 3)
    back = _grid_a2a(
        ye.reshape(nsh, E_loc * C, d).astype(x_my.dtype), ep_axes, sizes)
    ybuf = back.reshape(E_pad, C, d)[:E]
    y = _unpack(ybuf, flat_e, pos_c, keep, gates, T, m.top_k)
    return y.astype(x_my.dtype), aux


def _dense_shard_map(p, x, m, ctx: DistCtx):
    """Expert tensor parallelism for small E (Mixtral-class): every data
    shard dispatches ONLY its own tokens into a local (E, C_loc, d) queue
    (no cross-shard dispatch exists — each expert's FFN hidden dim is
    sharded over ``model`` like a dense FFN), and the single collective is
    the Megatron-style psum of the bf16 layer output. Replaces the naive
    GSPMD dense path whose global dispatch buffer all-reduced ~30 GB/layer
    (§Perf mixtral iteration 1). Capacity is per data shard."""
    B, S, d = x.shape

    def block(xb, pb):
        x2 = xb.reshape(-1, d)
        ids, gates, aux = _route(pb["router"], x2, m)
        C = _capacity(x2.shape[0], m)
        buf, flat_e, pos_c, keep = _pack(x2, ids, m, C)
        ye = _expert_ffn(pb["w1"], pb["w3"], pb["w2"], buf)  # partial (ff)
        y = _unpack(ye, flat_e, pos_c, keep, gates, x2.shape[0], m.top_k)
        y = jax.lax.psum(y.astype(xb.dtype), ctx.tp)
        aux = jax.lax.pmean(aux, tuple(ctx.dp) + (ctx.tp,))
        return y.reshape(xb.shape), aux

    in_specs = (P(ctx.dp, None, None),
                {"router": P(None, None),
                 "w1": P(None, None, ctx.tp), "w3": P(None, None, ctx.tp),
                 "w2": P(None, ctx.tp, None)})
    y, aux = _shard_map(
        block, mesh=ctx.mesh, in_specs=in_specs,
        out_specs=(P(ctx.dp, None, None), P()))(
            x, {k: p[k] for k in ("router", "w1", "w3", "w2")})
    return y, jnp.mean(aux)


def apply_moe(p, x, cfg, ctx: DistCtx):
    """x: (B, S, d) -> (y (B, S, d), weighted aux loss)."""
    m = cfg.moe
    B, S, d = x.shape
    tp = ctx.tp_size
    dp = ctx.dp_size
    T_shard = (B * S) // max(dp, 1)
    use_a2a = (ctx.mesh is not None and m.impl == "alltoall"
               and B % dp == 0 and T_shard % tp == 0 and T_shard >= tp)
    use_etp = (ctx.mesh is not None and not use_a2a and B % dp == 0
               and m.d_expert % max(tp, 1) == 0)
    if use_etp:
        y, aux = _dense_shard_map(p, x, m, ctx)
    elif not use_a2a:
        y, aux = _local_moe(p, x.reshape(-1, d), m)
        y = y.reshape(B, S, d)
    else:
        if m.ep == "tp":
            ep_axes = (ctx.tp,)
        else:
            # 2-D EP: grow the expert grid from the minor (model) axis
            # outward, keeping only axes whose product divides E — on a
            # 512-chip multi-pod mesh with E=256 this selects
            # (data, model) and leaves experts replicated over "pod"
            # (padding half the mesh with fake experts costs far more
            # than a 2-way pod grad reduce; measured in §Perf).
            ep_axes = _ep_axes_for(m.n_experts, ctx)
        sizes = tuple(ctx.mesh.shape[a] for a in ep_axes)
        nsh = 1
        for s in sizes:
            nsh *= s
        E_pad = _pad_to(m.n_experts, nsh)

        def pad_experts(w):
            if E_pad == m.n_experts:
                return w
            return jnp.pad(w, ((0, E_pad - m.n_experts),) + ((0, 0),) *
                           (w.ndim - 1))

        ep = {"router": p["router"], "w1": pad_experts(p["w1"]),
              "w3": pad_experts(p["w3"]), "w2": pad_experts(p["w2"])}

        def block(xb, pb):
            # xb: (B_loc, S, d), replicated across model shards. Slice this
            # shard's token range (token resharding dp -> dp x tp).
            Tb = xb.shape[0] * xb.shape[1]
            T_my = Tb // tp
            idx = jax.lax.axis_index(ctx.tp)
            x2 = xb.reshape(Tb, d)
            x_my = jax.lax.dynamic_slice_in_dim(x2, idx * T_my, T_my, 0)
            y_my, aux = _alltoall_local(pb, x_my, m, sizes, ep_axes)
            y_full = jax.lax.all_gather(y_my, ctx.tp, axis=0, tiled=True)
            aux = jax.lax.pmean(aux, tuple(ctx.dp) + (ctx.tp,))
            return y_full.reshape(xb.shape), aux

        espec = P(ep_axes if m.ep == "2d" else ctx.tp, None, None)
        in_specs = (P(ctx.dp, None, None),
                    {"router": P(None, None), "w1": espec,
                     "w3": espec, "w2": espec})
        y, aux = _shard_map(
            block, mesh=ctx.mesh, in_specs=in_specs,
            out_specs=(P(ctx.dp, None, None), P()))(x, ep)
        aux = jnp.mean(aux)

    if m.n_shared:
        from repro.models.ffn import apply_ffn
        y = y + apply_ffn(p["shared"], x, "swiglu", ctx)
    return y, aux * m.router_aux_weight
