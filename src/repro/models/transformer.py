"""Model assembly: layer blocks -> scanned segments -> full architectures.

A model is a sequence of homogeneous SEGMENTS; each segment's per-layer
parameters are stacked along a leading axis and driven by ``lax.scan`` so
HLO size is O(1) in depth (61-layer DeepSeek lowers as fast as 2 layers).
Heterogeneous stacking (DeepSeek's dense-then-MoE, Zamba2's shared
attention every N Mamba blocks, Whisper's encoder/decoder) is expressed as
multiple segments joined by a static Python loop.

Supported layer kinds:
  attn_ffn   (gqa|mla attention) + (dense ffn | moe)
  rwkv       RWKV6 time-mix + channel-mix
  mamba      Mamba2 SSD block

Modality frontends are STUBS per the assignment: whisper consumes
precomputed audio-frame embeddings, internvl consumes projected patch
embeddings (``input_specs`` provides them).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import ffn as F
from repro.models import mamba as M
from repro.models import moe as MoE
from repro.models import rwkv as R
from repro.models.common import (DistCtx, apply_norm, cross_entropy,
                                 dense_init, init_norm)


@dataclass(frozen=True)
class SegmentSpec:
    kind: str                 # attn_ffn | rwkv | mamba
    n_layers: int
    moe: bool = False
    causal: bool = True
    cross: bool = False       # decoder cross-attention (enc-dec)


def plan_segments(cfg: ModelConfig):
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return [SegmentSpec("rwkv", cfg.n_layers)]
    if cfg.family == "hybrid":
        # Zamba2: groups of mamba blocks, a shared (weight-tied) attn block
        # applied after each group (handled outside the segment scan).
        g = cfg.hybrid_attn_every
        segs = [SegmentSpec("mamba", g) for _ in range(cfg.n_layers // g)]
        if cfg.n_layers % g:
            segs.append(SegmentSpec("mamba", cfg.n_layers % g))
        return segs
    if cfg.family == "moe":
        segs = []
        if cfg.n_dense_layers:
            segs.append(SegmentSpec("attn_ffn", cfg.n_dense_layers))
        segs.append(SegmentSpec("attn_ffn", cfg.n_layers - cfg.n_dense_layers,
                                moe=True))
        return segs
    if cfg.family == "encdec":
        return [SegmentSpec("attn_ffn", cfg.n_layers, cross=True)]
    return [SegmentSpec("attn_ffn", cfg.n_layers)]


# --------------------------------------------------------------------------
# per-layer init
# --------------------------------------------------------------------------

def _init_attn(key, cfg, dtype):
    if cfg.attn == "mla":
        return A.init_mla(key, cfg, dtype)
    return A.init_gqa(key, cfg, dtype)


def init_layer(key, cfg: ModelConfig, spec: SegmentSpec, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    if spec.kind == "rwkv":
        return {"ln1": init_norm(cfg.norm, d, dtype),
                "tm": R.init_rwkv6(ks[0], cfg, dtype),
                "ln2": init_norm(cfg.norm, d, dtype),
                "cm": R.init_rwkv_channel_mix(ks[1], cfg, dtype)}
    if spec.kind == "mamba":
        return {"ln1": init_norm(cfg.norm, d, dtype),
                "mix": M.init_mamba2(ks[0], cfg, dtype)}
    p = {"ln1": init_norm(cfg.norm, d, dtype),
         "attn": _init_attn(ks[0], cfg, dtype),
         "ln2": init_norm(cfg.norm, d, dtype)}
    if spec.moe:
        p["moe"] = MoE.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = F.init_ffn(ks[1], d, cfg.d_ff, cfg.activation, dtype)
    if spec.cross:
        p["ln_x"] = init_norm(cfg.norm, d, dtype)
        p["xattn"] = A.init_gqa(ks[2], cfg, dtype)
    return p


def init_segment(key, cfg, spec: SegmentSpec, dtype):
    keys = jax.random.split(key, spec.n_layers)
    return jax.vmap(lambda k: init_layer(k, cfg, spec, dtype))(keys)


# --------------------------------------------------------------------------
# per-layer forward (training / prefill: full sequences)
# --------------------------------------------------------------------------

def block_seq(lp, x, cfg, ctx, spec: SegmentSpec, *, state=None,
              enc_out=None, want_cache=False):
    """One layer over a full sequence. Returns (x, aux, new_state, cache)."""
    if cfg.seq_shard and x.shape[1] % max(ctx.tp_size, 1) == 0:
        # sequence parallelism: the residual stream (and with it every
        # norm / residual-add / stash) lives sequence-sharded over the
        # model axis; SPMD inserts all-gather on entry to attention and
        # reduce-scatter after the output projections.
        x = ctx.constrain(x, ctx.dp, ctx.tp, None)
    aux = jnp.zeros((), jnp.float32)
    cache = None
    new_state = None
    if spec.kind == "rwkv":
        h = apply_norm(cfg.norm, lp["ln1"], x)
        o, s_tm = R.rwkv6_time_mix(lp["tm"], h, {"s": state["s"],
                                                 "shift": state["shift"]},
                                   cfg, ctx)
        x = x + o
        h = apply_norm(cfg.norm, lp["ln2"], x)
        o, shift2 = R.rwkv_channel_mix(lp["cm"], h, state["shift2"], cfg)
        x = x + o
        new_state = {"s": s_tm["s"], "shift": s_tm["shift"],
                     "shift2": shift2}
        return x, aux, new_state, cache
    if spec.kind == "mamba":
        h = apply_norm(cfg.norm, lp["ln1"], x)
        o, new_state = M.mamba2_block(lp["mix"], h, state, cfg, ctx)
        return x + o, aux, new_state, cache
    # attn_ffn
    h = apply_norm(cfg.norm, lp["ln1"], x)
    if cfg.attn == "mla":
        o = A.mla_self(lp["attn"], h, cfg, ctx)
        if want_cache:
            latent, krope = A._mla_latent(lp["attn"], h, cfg)
            pos = jnp.arange(h.shape[1])
            krope = A.apply_rope(krope[:, :, None, :], pos,
                                 cfg.rope_theta)[:, :, 0]
            cache = {"latent": latent, "rope": krope}
    else:
        o = A.gqa_self(lp["attn"], h, cfg, ctx, causal=spec.causal)
        if want_cache:
            q, k, v = A._qkv(lp["attn"], h, cfg)
            pos = jnp.arange(h.shape[1])
            k = A.apply_rope(k, pos, cfg.rope_theta)
            cache = {"k": k, "v": v}
    x = x + o
    if spec.cross and enc_out is not None:
        h = apply_norm(cfg.norm, lp["ln_x"], x)
        q, _, _ = A._qkv(lp["xattn"], h, cfg)
        ek = (enc_out @ lp["xattn"]["wk"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        ev = (enc_out @ lp["xattn"]["wv"]).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        o = A.plain_attention(q, ek, ev).reshape(x.shape[0], x.shape[1], -1)
        x = x + o @ lp["xattn"]["wo"]
    h = apply_norm(cfg.norm, lp["ln2"], x)
    if spec.moe:
        y, aux = MoE.apply_moe(lp["moe"], h, cfg, ctx)
    else:
        y = F.apply_ffn(lp["ffn"], h, cfg.activation, ctx)
    return x + y, aux, new_state, cache


def run_segment(seg_params, x, cfg, ctx, spec: SegmentSpec, *, state=None,
                enc_out=None, want_cache=False):
    """Scan a segment over its stacked layers."""
    def body(carry, inp):
        x, aux = carry
        lp, st = inp if state is not None else (inp, None)
        x2, a, new_state, cache = block_seq(lp, x, cfg, ctx, spec,
                                            state=st, enc_out=enc_out,
                                            want_cache=want_cache)
        ys = (new_state, cache)
        return (x2, aux + a), ys

    fn = jax.checkpoint(body) if cfg.remat else body
    xs = (seg_params, state) if state is not None else seg_params
    (x, aux), (new_states, caches) = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, new_states, caches


# --------------------------------------------------------------------------
# per-layer forward (decode: one token)
# --------------------------------------------------------------------------

def block_decode(lp, x1, cfg, ctx, spec: SegmentSpec, *, cache=None,
                 state=None, lengths=None):
    """One layer, one token. Returns (x1, new_cache_or_state). Cross-attn
    K/V ("ck"/"cv"/"cvalid", precomputed at prefill) ride along in the
    per-layer cache."""
    if spec.kind == "rwkv":
        h = apply_norm(cfg.norm, lp["ln1"], x1[:, None, :])
        o, s_tm = R.rwkv6_time_mix(lp["tm"], h, {"s": state["s"],
                                                 "shift": state["shift"]},
                                   cfg, ctx, use_chunked=False)
        x1 = x1 + o[:, 0]
        h = apply_norm(cfg.norm, lp["ln2"], x1[:, None, :])
        o, shift2 = R.rwkv_channel_mix(lp["cm"], h, state["shift2"], cfg)
        x1 = x1 + o[:, 0]
        return x1, {"s": s_tm["s"], "shift": s_tm["shift"], "shift2": shift2}
    if spec.kind == "mamba":
        h = apply_norm(cfg.norm, lp["ln1"], x1[:, None, :])
        o, ns = M.mamba2_block(lp["mix"], h, state, cfg, ctx,
                               use_chunked=False)
        return x1 + o[:, 0], ns
    h = apply_norm(cfg.norm, lp["ln1"], x1)
    self_cache = {k: v for k, v in cache.items()
                  if k not in ("ck", "cv", "cvalid")}
    if cfg.attn == "mla":
        o, nc = A.mla_decode(lp["attn"], h, self_cache, cfg, ctx,
                             lengths=lengths)
    else:
        o, nc = A.gqa_decode(lp["attn"], h, self_cache, cfg, ctx,
                             lengths=lengths)
    x1 = x1 + o
    if spec.cross and "ck" in cache:
        h = apply_norm(cfg.norm, lp["ln_x"], x1)
        q = (h @ lp["xattn"]["wq"]).reshape(x1.shape[0], cfg.n_heads, cfg.hd)
        o = A.decode_attention(q, cache["ck"], cache["cv"],
                               kv_valid=cache["cvalid"])
        x1 = x1 + o.reshape(x1.shape[0], -1) @ lp["xattn"]["wo"]
        nc = {**nc, "ck": cache["ck"], "cv": cache["cv"],
              "cvalid": cache["cvalid"]}
    h = apply_norm(cfg.norm, lp["ln2"], x1)
    if spec.moe:
        y, _ = MoE.apply_moe(lp["moe"], h[:, None, :], cfg, ctx)
        y = y[:, 0]
    else:
        y = F.apply_ffn(lp["ffn"], h, cfg.activation, ctx)
    return x1 + y, nc


def run_segment_decode(seg_params, x1, cfg, ctx, spec: SegmentSpec, *,
                       cache=None, state=None, lengths=None):
    def body(x1, inp):
        lp, cs = inp
        if spec.kind in ("rwkv", "mamba"):
            x1, ns = block_decode(lp, x1, cfg, ctx, spec, state=cs,
                                  lengths=lengths)
        else:
            x1, ns = block_decode(lp, x1, cfg, ctx, spec, cache=cs,
                                  lengths=lengths)
        return x1, ns

    xs = cache if cache is not None else state
    x1, new = jax.lax.scan(body, x1, (seg_params, xs))
    return x1, new
