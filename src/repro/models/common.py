"""Shared model building blocks: norms, rotary embeddings, initializers,
and the distribution context threaded through every layer."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DistCtx:
    """Distribution context. mesh=None => single-device (smoke tests)."""
    mesh: Optional[object] = None
    dp: Tuple[str, ...] = ("data",)   # data-parallel axes (incl. "pod")
    tp: str = "model"                 # tensor/expert-parallel axis

    @staticmethod
    def local() -> "DistCtx":
        return DistCtx()

    def constrain(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    @property
    def tp_size(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.tp]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp:
            n *= self.mesh.shape[a]
        return n


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


def apply_norm(kind: str, params, x):
    if kind == "layernorm":
        return layer_norm(x, params["w"], params["b"])
    return rms_norm(x, params["w"])


def init_norm(kind: str, d: int, dtype):
    if kind == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) rotated pairwise; positions: broadcastable to
    x.shape[:-2] + (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, mask=None):
    """Mean CE over valid tokens. logits (..., V) f32-upcast."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
