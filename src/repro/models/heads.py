"""Per-cluster personalization heads for routed serving (DESIGN.md §16).

The §4.2.2 personalization story — one model per cluster, the
Theorem 3.2 label routing each request to exactly ONE of them — needs
actual per-cluster forward passes on the serve plane. This module is
the bridge from the model zoo (``models/`` blocks + ``configs/``
architecture registry) to that serving tier:

  * ``resolve_head_spec`` maps a plan's ``heads`` name to a
    :class:`HeadSpec`: ``"linear"`` is the reserved affine head; any
    registered zoo config name (``configs.list_archs()``) contributes
    its REDUCED variant's activation, FFN expansion ratio and head
    counts, re-dimensioned to the plan's feature width ``d`` — the
    head operates on the clustering features, not the config's
    ``d_model``.
  * ``init_heads`` builds ``k`` independent parameter sets (stacked on
    a leading cluster axis) through the zoo initializers
    (``models.ffn.init_ffn``, ``models.attention.init_gqa``,
    ``models.common.init_norm``) from one deterministic key.
  * ``apply_heads`` runs every cluster's queue through ITS head —
    vmapped over the stacked params — per-point forward, then a
    masked mean-pool to one (d,) prediction per request.
    ``serve_dtype="bf16"`` casts storage to bfloat16 while every
    matmul accumulates in f32 (``preferred_element_type``), mirroring
    the fused solve+attach precision contract (§13).

Architectures: ``"ffn"`` (default — pre-norm residual FFN block using
the config's activation) and ``"transformer"`` (the config-flagged
option: non-causal masked self-attention over the request's point set
+ the FFN block; a point set has no order, so no rope/causality).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.attention import init_gqa, plain_attention
from repro.models.common import dense_init, init_norm, rms_norm
from repro.models.ffn import init_ffn

__all__ = ["HEAD_ARCHS", "HeadConfigError", "HeadSpec", "apply_heads",
           "init_heads", "resolve_head_spec"]

HEAD_ARCHS = ("ffn", "transformer")

# The reserved non-zoo head: one affine map, the cheapest thing that
# still distinguishes clusters (and the bench's sanity floor).
LINEAR = "linear"


class HeadConfigError(ValueError):
    """A heads/head_arch selection failed validation (named, with the
    accepted values) — raised at plan construction, never in tracing."""


class HeadSpec(NamedTuple):
    """Static shape/flavor of one per-cluster head (all fields hashable
    so the spec can ride jit static arguments)."""
    name: str           # "linear" | a registered configs.* name
    arch: str           # "ffn" | "transformer" (ignored for linear)
    d: int              # feature width (the plan's d)
    d_ff: int           # FFN hidden width (ratio-scaled from the config)
    activation: str     # swiglu | gelu | relu2
    n_heads: int        # transformer arch only
    n_kv_heads: int     # transformer arch only


class _AttnDims(NamedTuple):
    """The duck-typed config ``models.attention.init_gqa`` reads."""
    d_model: int
    n_heads: int
    n_kv_heads: int
    hd: int
    qkv_bias: bool
    rope_theta: float = 1e4
    sliding_window: int | None = None
    attn_chunk: int = 1024


def resolve_head_spec(name: str, arch: str, d: int) -> HeadSpec:
    """Validate + resolve a plan's ``heads``/``head_arch`` selection
    into a :class:`HeadSpec`. Raises :class:`HeadConfigError` naming
    the accepted values (``StreamConfig`` re-raises field-named)."""
    if arch not in HEAD_ARCHS:
        raise HeadConfigError(
            f"head_arch={arch!r} is invalid: accepted values are "
            f"{list(HEAD_ARCHS)}")
    if name == LINEAR:
        return HeadSpec(LINEAR, arch, int(d), int(d), "gelu", 1, 1)
    from repro.configs import get_config, list_archs
    try:
        cfg = get_config(name, reduced=True)
    except KeyError:
        raise HeadConfigError(
            f"heads={name!r} is invalid: accepted values are 'off', "
            f"'{LINEAR}', or a registered model config "
            f"{list_archs()}") from None
    # Re-dimension the REDUCED config to the clustering feature width:
    # keep its FFN expansion ratio and activation, floor d_ff at d.
    d_ff = max(int(d), int(round(d * cfg.d_ff / cfg.d_model)))
    n_heads, n_kv = int(cfg.n_heads), int(cfg.n_kv_heads)
    if arch == "transformer" and d % n_heads:
        raise HeadConfigError(
            f"heads={name!r} with head_arch='transformer' is invalid "
            f"for d={d}: the config's n_heads={n_heads} must divide "
            f"the plan's feature dimension (pick a different config "
            f"or head_arch='ffn')")
    return HeadSpec(name, arch, int(d), d_ff, str(cfg.activation),
                    n_heads, n_kv)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _attn_dims(spec: HeadSpec) -> _AttnDims:
    return _AttnDims(d_model=spec.d, n_heads=spec.n_heads,
                     n_kv_heads=spec.n_kv_heads,
                     hd=spec.d // spec.n_heads, qkv_bias=False)


def _init_one(key, spec: HeadSpec, dtype):
    if spec.name == LINEAR:
        return {"w": dense_init(key, (spec.d, spec.d), dtype),
                "b": jnp.zeros((spec.d,), dtype)}
    ks = jax.random.split(key, 2)
    p = {"norm1": init_norm("rmsnorm", spec.d, dtype),
         "ffn": init_ffn(ks[0], spec.d, spec.d_ff, spec.activation,
                         dtype)}
    if spec.arch == "transformer":
        p["norm2"] = init_norm("rmsnorm", spec.d, dtype)
        p["attn"] = init_gqa(ks[1], _attn_dims(spec), dtype)
    return p


def init_heads(key, k: int, spec: HeadSpec, dtype=jnp.float32):
    """``k`` independent heads from one key, stacked on a leading
    cluster axis (leaf shapes ``(k, ...)``) — the layout the routed
    step vmaps over and checkpoint schema v5 stores."""
    return jax.vmap(lambda kk: _init_one(kk, spec, dtype))(
        jax.random.split(key, k))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _dot(a, b):
    """Matmul on the last/first axes with f32 accumulation regardless
    of the storage dtype — the §13/§15 bf16-accum contract."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _ffn_apply(p, x, activation: str):
    """init_ffn param layout, f32-accumulating apply. x: (..., d)
    storage dtype; returns (..., d) f32."""
    if activation == "swiglu":
        h = jax.nn.silu(_dot(x, p["w1"])) * _dot(x, p["w3"])
        return _dot(h.astype(x.dtype), p["w2"])
    h = _dot(x, p["w1"]) + p["b1"].astype(jnp.float32)
    h = (jnp.square(jax.nn.relu(h)) if activation == "relu2"
         else jax.nn.gelu(h))
    return _dot(h.astype(x.dtype), p["w2"]) + p["b2"].astype(jnp.float32)


def _attn_apply(p, x, pmask, spec: HeadSpec):
    """Non-causal masked self-attention over the point set. x:
    (C, n, d) storage dtype; pmask: (C, n) bool. Returns (C, n, d)
    f32."""
    C, n, d = x.shape
    H, KVH, hd = spec.n_heads, spec.n_kv_heads, d // spec.n_heads
    q = _dot(x, p["wq"]).reshape(C, n, H, hd).astype(x.dtype)
    kk = _dot(x, p["wk"]).reshape(C, n, KVH, hd).astype(x.dtype)
    v = _dot(x, p["wv"]).reshape(C, n, KVH, hd).astype(x.dtype)
    o = plain_attention(q, kk, v, kv_mask=pmask)
    return _dot(o.reshape(C, n, H * hd), p["wo"])


def _head_fwd(p, x, pmask, spec: HeadSpec):
    """One cluster's per-point forward. x: (C, n, d) storage dtype,
    pmask: (C, n); returns (C, n, d) f32 features."""
    if spec.name == LINEAR:
        return _dot(x, p["w"]) + p["b"].astype(jnp.float32)
    store = x.dtype
    h = x.astype(jnp.float32)
    if spec.arch == "transformer":
        a = rms_norm(h, p["norm2"]["w"].astype(jnp.float32)).astype(store)
        h = h + _attn_apply(p["attn"], a, pmask, spec)
    f = rms_norm(h, p["norm1"]["w"].astype(jnp.float32)).astype(store)
    return h + _ffn_apply(p["ffn"], f, spec.activation)


def apply_heads(params, qdata, qmask, spec: HeadSpec,
                serve_dtype: str = "f32"):
    """Run every cluster queue through its own head and pool.

    ``params``: pytree with leading (k,) cluster axis (``init_heads``
    layout); ``qdata``: (k, C, n, d) f32 per-cluster request queues;
    ``qmask``: (k, C, n) bool point validity (all-False rows are
    empty/overflow slots). Returns (k, C, d) f32 pooled predictions —
    zero for empty slots. ``serve_dtype`` selects f32 (bitwise) or
    bf16 storage with f32 accumulation."""
    store = jnp.bfloat16 if serve_dtype == "bf16" else jnp.float32

    def one(p, x, m):
        ps = jax.tree.map(lambda a: a.astype(store), p)
        y = _head_fwd(ps, x.astype(store), m, spec)      # (C, n, d) f32
        mf = m.astype(jnp.float32)
        tot = jnp.maximum(jnp.sum(mf, axis=-1, keepdims=True), 1.0)
        return jnp.einsum("cnd,cn->cd", y, mf) / tot

    return jax.vmap(one)(params, qdata, qmask)


def head_param_count(spec: HeadSpec) -> int:
    """Static per-head parameter count (stats/docs)."""
    d, ff = spec.d, spec.d_ff
    if spec.name == LINEAR:
        return d * d + d
    n = d  # norm1
    n += (3 * d * ff if spec.activation == "swiglu"
          else 2 * d * ff + ff + d)
    if spec.arch == "transformer":
        hd = d // spec.n_heads
        n += d + d * spec.n_heads * hd + 2 * d * spec.n_kv_heads * hd \
            + spec.n_heads * hd * d
    return n
