"""Fold-slot admission policies (ROADMAP "admission control / eviction").

The incremental server (``core.server``) buffers one report per slot;
``server.aggregate_incremental`` is the ONE fold primitive and stays
policy-free. What a deployment can choose is the *mapping from request
ids to slots* — which reports are admitted into the bounded fold state
and which occupant is evicted when it is full. That mapping is a
``FoldPolicy``:

  * ``drop`` — the slot IS the request id; ids past ``capacity`` are
    served but never folded (first-come-first-folded, the historical
    behavior, bitwise-pinned by tests);
  * ``lru`` — a full state evicts the least-recently-folded occupant's
    slot; re-delivery of a held id touches its recency. The fold state
    tracks the ``capacity`` most recently reporting devices;
  * ``weighted_reservoir`` — Efraimidis–Spirakis A-ES weighted
    reservoir sampling: each report draws a deterministic key
    u(seed, id)^(1/weight) and the state retains exactly the
    ``capacity`` largest keys seen so far, so heavy devices (large
    Algorithm 1 core sets) are proportionally more likely to stay
    folded. Deterministic: the key depends only on (seed, id, weight),
    never on arrival order or wall clock.

Eviction is just an overwrite: ``aggregate_incremental`` scatters the
new report into the victim's slot, replacing its centers/mask/weights.
Policies are host-side (they run in the service's Python loop, one
admit per served request) and checkpoint as plain integer/float arrays
so a restored service replays admission decisions bitwise.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

__all__ = ["FoldPolicy", "DropPolicy", "LruPolicy",
           "WeightedReservoirPolicy", "POLICIES", "make_policy"]


class FoldPolicy:
    """Maps request ids to fold slots; owns eviction.

    ``admit(rid, weight)`` returns the slot to scatter the report into,
    or None to serve-without-folding. Policies must be deterministic
    functions of (their persisted state, rid, weight) so that
    checkpoint -> restore -> admit replays identically.
    """

    name: str = "abstract"
    needs_weight: bool = False  # admit() wants the report's |S_r| mass

    def __init__(self, capacity: int):
        self.capacity = int(capacity)

    def admit(self, rid: int, weight: float = 1.0) -> Optional[int]:
        raise NotImplementedError

    def admit_batch(self, rids, weights=None):
        """Admission for one serve batch, IN GLOBAL REQUEST ORDER — the
        one entry point the serve planes call (DESIGN.md §11).

        Returns ``(slots, granted)``: a ``(len(rids),)`` int64 slot
        vector with -1 for declined requests, and the number of
        admissions GRANTED by the policy (what the refresh cadence
        counts — identical to running the sequential admit loop). When
        a later admission in the batch evicts a slot an earlier one was
        granted, the earlier entry is reset to -1 (its scatter is
        suppressed, though it still counted as granted), so executing
        the whole vector as ONE fold — in any per-slot order, on any
        number of shards — lands exactly the reports a sequential
        admit-then-fold loop would have kept.

        Shard-determinism contract: the result is a function of the
        persisted policy state and ``(rids, weights)`` ONLY. Policies
        never see the mesh, so a sharded plane and a single-host plane
        replaying the same request stream make identical admission
        decisions — this is what makes the sharded fold state (and a
        checkpoint written by either plane) bitwise interchangeable.
        """
        # Record only the FINAL owner of every slot and rebuild the
        # vector from that map at the end. The earlier in-place rule
        # (zap slots[prev] when slot is re-granted, then write
        # slots[i]) could leave a stale alias behind on degenerate
        # batches — e.g. every row a duplicate of one hot id bouncing
        # through the same slot — double-scattering a live slot. An
        # owner map cannot alias: each slot appears at most once by
        # construction.
        owner: Dict[int, int] = {}      # slot -> batch index holding it
        granted = 0
        for i, rid in enumerate(rids):
            w = 1.0 if weights is None else float(weights[i])
            slot = self.admit(int(rid), w)
            if slot is None:
                continue
            granted += 1
            owner[slot] = i             # within-batch eviction = rebind
        slots = np.full((len(rids),), -1, np.int64)
        for slot, i in owner.items():
            slots[i] = slot
        return slots, granted

    def admit_padded(self, rids, weights=None, *, total=None):
        """:meth:`admit_batch` plus the planes' fixed-shape scatter
        contract: returns ``((total,) int64 slot vector, granted)``
        where declined decisions AND the batch's repeat-padding rows
        (indices past ``len(rids)``) become the out-of-capacity
        sentinel the ``mode="drop"`` scatter ignores — negative ids
        would WRAP under numpy indexing, so they never leave the
        policy layer.

        ``total`` is the serve batch size of the flush that admits
        these reports. Under load-adaptive batching
        (``fed/autoscale.py``) it varies per flush decision: the
        sentinel padding, not a ladder of jit shapes, absorbs whatever
        partial batch the re-bucketed queue produced, so admission is
        one fixed-shape vector per batch no matter how the controller
        re-sized it.
        """
        slots, granted = self.admit_batch(rids, weights)
        full = np.full((total or len(rids),), self.capacity, np.int64)
        full[:len(slots)] = np.where(slots < 0, self.capacity, slots)
        return full, granted

    # -- checkpoint plumbing (npz-able arrays; {} for stateless) --------
    def state_like(self) -> Dict[str, np.ndarray]:
        """Zero-filled arrays matching :meth:`state_arrays` (restore
        template for ``checkpoint.store.load_pytree``)."""
        return {}

    def state_arrays(self) -> Dict[str, np.ndarray]:
        return {}

    def load_state(self, arrays: Dict[str, np.ndarray]) -> None:
        pass


class DropPolicy(FoldPolicy):
    """The historical admission rule: slot == request id, over-capacity
    ids dropped. Stateless (the decision is a pure function of rid)."""

    name = "drop"

    def admit(self, rid: int, weight: float = 1.0) -> Optional[int]:
        return rid if rid < self.capacity else None


class LruPolicy(FoldPolicy):
    """Least-recently-folded eviction over ``capacity`` device slots.

    Invariant (property-tested): after any admission sequence the held
    ids are exactly the ``capacity`` most recently admitted distinct
    ids, and every admit() is granted a slot (nothing is ever dropped).
    """

    name = "lru"

    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._slot_rid = np.full((self.capacity,), -1, np.int64)
        self._slot_seq = np.full((self.capacity,), -1, np.int64)
        self._seq = 0
        self._index: Dict[int, int] = {}

    def admit(self, rid: int, weight: float = 1.0) -> Optional[int]:
        slot = self._index.get(rid)
        if slot is None:
            free = np.nonzero(self._slot_rid < 0)[0]
            if free.size:
                slot = int(free[0])
            else:  # evict the least recently folded occupant
                slot = int(np.argmin(self._slot_seq))
                del self._index[int(self._slot_rid[slot])]
            self._slot_rid[slot] = rid
            self._index[rid] = slot
        self._slot_seq[slot] = self._seq
        self._seq += 1
        return slot

    def state_like(self) -> Dict[str, np.ndarray]:
        return {"slot_rid": np.zeros((self.capacity,), np.int64),
                "slot_seq": np.zeros((self.capacity,), np.int64),
                "seq": np.zeros((), np.int64)}

    def state_arrays(self) -> Dict[str, np.ndarray]:
        return {"slot_rid": self._slot_rid.copy(),
                "slot_seq": self._slot_seq.copy(),
                "seq": np.asarray(self._seq, np.int64)}

    def load_state(self, arrays: Dict[str, np.ndarray]) -> None:
        self._slot_rid = np.asarray(arrays["slot_rid"], np.int64).copy()
        self._slot_seq = np.asarray(arrays["slot_seq"], np.int64).copy()
        self._seq = int(arrays["seq"])
        self._index = {int(r): i for i, r in enumerate(self._slot_rid)
                       if r >= 0}


class WeightedReservoirPolicy(FoldPolicy):
    """A-ES weighted reservoir over the fold slots.

    Each distinct id draws key = u^(1/max(weight, eps)) with
    u = uniform(0, 1) seeded by (policy_seed, id); the state holds the
    ``capacity`` largest (key, id) pairs seen. Invariant
    (property-tested): the held set equals the exact top-``capacity``
    of all distinct ids by (key, id), independent of arrival order;
    re-delivery of a held id keeps its slot.

    With ``half_life`` > 0 (the drift layer, DESIGN.md §14) the
    effective A-ES weight is the DECAYED fold mass
    w * 2^(-rid / half_life): the key becomes u^(1/(w * 2^(-rid/h))),
    computed in the log domain as log(u) * 2^(rid/h) / w so late (large
    rid) requests never underflow. The log map is monotone, so the
    bigger-is-better ordering — and every tie rule below — is
    unchanged; ``half_life=0`` reproduces the undecayed key bitwise.
    """

    name = "weighted_reservoir"
    needs_weight = True
    _EPS = 1e-9

    def __init__(self, capacity: int, seed: int = 0, half_life: int = 0):
        super().__init__(capacity)
        self.seed = int(seed)
        self.half_life = int(half_life)
        self._slot_rid = np.full((self.capacity,), -1, np.int64)
        self._slot_key = np.full((self.capacity,), -np.inf, np.float64)
        self._index: Dict[int, int] = {}

    def key_of(self, rid: int, weight: float) -> float:
        u = np.random.default_rng((self.seed, int(rid))).random()
        if self.half_life > 0:
            # log-domain decayed key: log(u) < 0 scaled by 2^(-rid/h) —
            # recent (large rid) ids shrink toward 0 (the top of the
            # bigger-is-better order), old ones sink. Equivalent to
            # u^(1/(w * 2^(rid/h))) without its overflow at large rid.
            return float(np.log(u) * np.exp2(-float(rid) / self.half_life)
                         / max(float(weight), self._EPS))
        return float(u ** (1.0 / max(float(weight), self._EPS)))

    def admit(self, rid: int, weight: float = 1.0) -> Optional[int]:
        slot = self._index.get(rid)
        if slot is not None:
            return slot  # idempotent re-delivery, key unchanged
        key = self.key_of(rid, weight)
        free = np.nonzero(self._slot_rid < 0)[0]
        if free.size:
            slot = int(free[0])
        else:
            victim = int(np.lexsort((self._slot_rid, self._slot_key))[0])
            if (key, rid) <= (float(self._slot_key[victim]),
                              int(self._slot_rid[victim])):
                return None  # below the reservoir threshold
            del self._index[int(self._slot_rid[victim])]
            slot = victim
        self._slot_rid[slot] = rid
        self._slot_key[slot] = key
        self._index[rid] = slot
        return slot

    def state_like(self) -> Dict[str, np.ndarray]:
        return {"slot_rid": np.zeros((self.capacity,), np.int64),
                "slot_key": np.zeros((self.capacity,), np.float64)}

    def state_arrays(self) -> Dict[str, np.ndarray]:
        return {"slot_rid": self._slot_rid.copy(),
                "slot_key": self._slot_key.copy()}

    def load_state(self, arrays: Dict[str, np.ndarray]) -> None:
        self._slot_rid = np.asarray(arrays["slot_rid"], np.int64).copy()
        self._slot_key = np.asarray(arrays["slot_key"],
                                    np.float64).copy()
        self._index = {int(r): i for i, r in enumerate(self._slot_rid)
                       if r >= 0}


POLICIES = {
    "drop": DropPolicy,
    "lru": LruPolicy,
    "weighted_reservoir": WeightedReservoirPolicy,
}

# Stable numeric codes for checkpoints (npz stores no strings): a
# restored service must be configured with the SAME policy that wrote
# the state, or its admission bookkeeping would be misread.
POLICY_IDS = {"drop": 0, "lru": 1, "weighted_reservoir": 2}


def make_policy(name: str, capacity: int, *, seed: int = 0,
                half_life: int = 0) -> FoldPolicy:
    if name not in POLICIES:
        raise ValueError(
            f"fold_policy={name!r}: accepted values are "
            f"{sorted(POLICIES)}")
    if name == "weighted_reservoir":
        return WeightedReservoirPolicy(capacity, seed=seed,
                                       half_life=half_life)
    return POLICIES[name](capacity)
