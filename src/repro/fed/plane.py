"""The sharded streaming serve plane (DESIGN.md §11).

Everything the streaming layer (``fed/stream.py``, §9) executes on
device routes through this module, so ONE knob — ``serve_axes`` on the
``FederationPlan`` — decides whether the hot serving path runs on a
single host or shard_mapped over a mesh:

  * **serve step** — the jitted (batch local Algorithm 1 solve +
    Theorem 3.2 attach) over a fixed ``(batch_size, n_pad, d)`` request
    tensor. The request batch axis is embarrassingly parallel, so the
    sharded plane splits it over the ``serve_axes`` mesh axes with the
    tau centers replicated (``P()``); per-request results are bitwise
    identical to the unsharded step because every request's computation
    is a function of its own (key, data, k_valid) only.
  * **fold scatter** — the per-slot scatter of served reports into the
    replicated incremental server state. ``server.aggregate_incremental``
    stays the single fold primitive; the sharded plane runs its
    collective sibling ``server.aggregate_incremental_sharded`` (each
    shard scatters ITS slice of the batch, disjoint slots combine with
    an exact psum). Slot admission itself stays host-side in
    ``fed/policy.py`` and is shard-deterministic by contract — the plane
    only ever executes an already-decided ``(B,)`` slot vector.
  * **routed personalization step** (§16, ``heads != "off"``) — the
    serve step FUSED with cluster-routed per-request predictions:
    majority-vote one cluster per request from its Theorem 3.2 labels,
    ``moe_dispatch``-gather whole requests into per-cluster head
    queues (clusters are the experts), run each queue through ITS head
    from the ``models``/``configs`` zoo, ``moe_combine`` back to
    request order. Same cache/versioning discipline as the plain step;
    the label outputs stay bitwise-identical to the heads=off plane.
  * **encode stage** (§17, ``encoder != "off"``) — a zoo encoder
    forward fused IN FRONT of the plain or routed step: devices submit
    raw ``(n, seq, d)`` token/patch sequences, one jitted dispatch
    embeds them (masked-mean pooled to ``d``) and runs the unchanged
    solve+attach on the embeddings. Encoder params ride replicated
    like tau; ``encoder=off`` planes are bitwise-untouched.
  * **double-buffered tau** (:class:`TauBuffer`) — serving reads
    ``bufs[active]``; a refresh builds the standby buffer while serving
    continues, and the swap is an atomic version bump. Every served
    label maps to exactly one tau version; both buffers + the version
    counter ride the §9 checkpoint so a restore mid-window replays the
    same version assignments bitwise.
  * **shard-count switching** (§12) — ``serve_axes`` GRANTS up to
    ``n_shards`` devices; the load-adaptive controller
    (``fed/autoscale.py``) may execute any flush on fewer
    (``shards=`` on :meth:`step`/:meth:`fold`), down to the single-host
    plane at 1. Each active shard count gets its own compiled
    step/fold (a sub-mesh over the first ``s`` granted devices), cached
    forever alongside every (batch, bucket) shape it serves —
    ``compile_count`` tracks first-seen (kind, shards, shape)
    signatures, so steady-state scaling provably never recompiles.

The plane is deliberately free of service bookkeeping (queues, buckets,
policies, checkpoints live in ``fed/stream.py``): it owns exactly the
two device computations of the hot path and their mesh mapping.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import server
from repro.core.lloyd import lloyd_attach
from repro.core.local_kmeans import batched_local_prepare, split_local_kw
from repro.kernels import ops
from repro.utils.compat import shard_map as _shard_map

__all__ = ["ServePlane", "ServePlaneError", "TauBuffer", "route_capacity"]


class ServePlaneError(ValueError):
    """A serve-plane configuration failed validation (named, with the
    accepted values) — raised at construction, never inside tracing."""


# ---------------------------------------------------------------------------
# Double-buffered, versioned tau.
# ---------------------------------------------------------------------------


class TauBuffer(NamedTuple):
    """Double-buffered tau centers with an atomic version counter.

    ``bufs[active]`` is what the serve step reads; ``bufs[1 - active]``
    is the standby a refresh writes into. ``stage`` fills the standby
    without touching serving (the async-refresh build phase); ``commit``
    is the atomic swap: active flips and ``version`` bumps by one, so a
    request's recorded version identifies exactly which tau buffer
    produced its labels. ``swap_now`` = stage + commit (the synchronous
    refresh). Immutable — every transition returns a new TauBuffer, and
    the whole triple serializes into the service checkpoint.
    """
    bufs: jax.Array      # (2, k, d) f32
    active: int          # which buffer serves
    version: int         # monotone; bumps exactly once per commit
    pending: bool        # standby staged, swap deferred to a boundary

    @classmethod
    def fresh(cls, tau) -> "TauBuffer":
        t = jnp.asarray(tau, jnp.float32)
        return cls(jnp.stack([t, t]), 0, 0, False)

    @property
    def tau(self) -> jax.Array:
        return self.bufs[self.active]

    @property
    def standby(self) -> jax.Array:
        return self.bufs[1 - self.active]

    def stage(self, new_tau) -> "TauBuffer":
        """Write the standby buffer; serving keeps reading the active
        one until :meth:`commit`."""
        t = jnp.asarray(new_tau, jnp.float32)
        bufs = jnp.stack([self.bufs[self.active], t]
                         if self.active == 0 else [t, self.bufs[self.active]])
        return TauBuffer(bufs, self.active, self.version, True)

    def commit(self) -> "TauBuffer":
        """The atomic swap: activate the standby, bump the version."""
        return TauBuffer(self.bufs, 1 - self.active, self.version + 1,
                         False)

    def swap_now(self, new_tau) -> "TauBuffer":
        return self.stage(new_tau).commit()

    # -- checkpoint plumbing (npz-able arrays) --------------------------
    def meta_array(self):
        import numpy as np
        return np.asarray([self.active, self.version, int(self.pending)],
                          np.int64)

    @classmethod
    def from_arrays(cls, bufs, meta) -> "TauBuffer":
        import numpy as np
        m = np.asarray(meta)
        return cls(jnp.asarray(bufs, jnp.float32), int(m[0]), int(m[1]),
                   bool(m[2]))


# ---------------------------------------------------------------------------
# The plane: serve step + fold scatter, single-host or shard_mapped.
# ---------------------------------------------------------------------------


def _make_step(cfg):
    """The ONE serve-step body (shared verbatim by both planes): vmapped
    Algorithm 1 steps 1-3 over the request batch, then the FUSED
    bounded-Lloyd solve + Theorem 3.2 attach against the replicated tau
    + Definition 3.3 induced labels in a single ``lloyd_attach``
    dispatch (kernels/solve_attach, DESIGN.md §13). ``cfg.serve_dtype``
    selects f32 (bitwise vs the pre-fusion staged step) or bf16 storage
    with f32 accumulation."""
    prep_kw, max_iters = split_local_kw(cfg.local_kw)

    def step(tau, keys, data, point_mask, k_valid):
        prep = batched_local_prepare(keys, data, k_max=cfg.k_prime,
                                     k_valid=k_valid,
                                     point_mask=point_mask, **prep_kw)
        labels, _, centers, _ = lloyd_attach(
            data, prep.theta, tau, center_mask=prep.center_mask,
            point_mask=point_mask, max_iters=max_iters,
            serve_dtype=cfg.serve_dtype)
        return (labels, centers, prep.center_mask,
                server.core_weights(prep.core_counts))

    return step


def route_capacity(batch: int, k: int, factor: float) -> int:
    """Per-cluster dispatch queue depth for a ``batch``-request step:
    ``ceil(batch * factor / k)`` slots (>= 1). ``factor`` is the plan's
    ``head_capacity`` — 1.0 sizes for a perfectly uniform cluster mix;
    the default 1.25 absorbs moderate skew. Requests past a cluster's
    queue still get labels, just no prediction (DESIGN.md §16). Static
    per (batch, k, factor), so it adds no cache keys beyond the batch
    shape the plane already specializes on."""
    return max(1, int(math.ceil(batch * float(factor) / k)))


def _make_routed_step(cfg, axes=None, axis_sizes=None):
    """The fused routed personalization step (DESIGN.md §16): the SAME
    label body as :func:`_make_step` (labels/centers/fold reports stay
    bitwise-identical to the heads=off plane), then per-request majority
    vote -> ``moe_dispatch`` gather into per-cluster head queues
    (clusters are the experts; whole requests gather by scalar-prefetch
    routing indices, no (k, C, n, d) scatter materialized request-side)
    -> every queue through ITS head (``models/heads.py``, vmapped over
    the stacked params) -> ``moe_combine`` back to request order. All
    routing scatters are int/bool OVERWRITES onto unique slots, so the
    step passes the §15 determinism audit.

    ``axes``/``axis_sizes`` (set by the sharded plane): the
    keep/overflow decision must be a function of the GLOBAL batch, or
    the sharded plane would drop different requests than the
    single-host plane. Each shard all_gathers the (tiny, int32)
    cluster votes, ranks its own requests against the global
    first-come order, and keeps ``C = route_capacity(global B, ...)``
    per cluster — the one deterministic, shard-order-tiled collective
    the routed artifact's §15 contract allows (exactly the sharded
    fold's allowance). Dispatch and head forwards stay shard-local."""
    from repro.fed.personalize import majority_vote
    from repro.models import heads as heads_mod
    spec = cfg.head_spec()
    base = _make_step(cfg)
    k = cfg.k
    shards = 1
    if axes:
        for sz in axis_sizes:
            shards *= int(sz)

    def routed(tau, head_params, keys, data, point_mask, k_valid):
        labels, centers, cmask, weights = base(tau, keys, data,
                                               point_mask, k_valid)
        B, n_pad, d = data.shape
        C = route_capacity(B * shards, k, cfg.head_capacity)
        S = k * C
        # One cluster per request — the same first-max vote as the
        # offline fed/personalize.cluster_devices assignment. A padding
        # row (no valid points) votes the out-of-range class k: its
        # one-hot is all-zero, so padding never consumes a queue slot
        # and real requests route independently of batch composition.
        cluster = majority_vote(jnp.where(point_mask, labels, -1),
                                k).astype(jnp.int32)
        req = point_mask.any(axis=1)
        eff = jnp.where(req, cluster, k)
        col = jnp.minimum(eff, k - 1)  # safe gather column for padding
        if axes is None:
            gcl, off = eff, 0
        else:
            gcl = jax.lax.all_gather(eff, axes, tiled=True)
            idx = jnp.int32(0)
            for ax, sz in zip(axes, axis_sizes):
                idx = idx * sz + jax.lax.axis_index(ax)
            off = idx * B
        # Global queue position = exclusive running count of earlier
        # same-cluster requests over the WHOLE batch, in global row
        # order; this shard's rows are the [off, off + B) slice.
        goh = jax.nn.one_hot(gcl, k, dtype=jnp.int32)
        cum = jnp.cumsum(goh, axis=0) - goh
        if axes is not None:
            cum = jax.lax.dynamic_slice_in_dim(cum, off, B, axis=0)
        kept = (cum[jnp.arange(B), col] < C) & req
        # Local slot = exclusive running count among locally-KEPT
        # same-cluster rows (a subset of the <= C globally-kept ones,
        # so it always fits; slot order never changes the math — each
        # queue entry is one whole request through one head).
        ohl = (jax.nn.one_hot(eff, k, dtype=jnp.int32)
               * kept[:, None].astype(jnp.int32))
        lpos = (jnp.cumsum(ohl, axis=0) - ohl)[jnp.arange(B), col]
        slot = cluster * C + lpos
        # Invert request->slot into the dispatch kernel's slot->request
        # routing vector. Kept slots are UNIQUE, overflow goes to the
        # dropped sentinel S: int/bool overwrite scatters, never a
        # float accumulation (§15).
        slot_s = jnp.where(kept, slot, S)
        rows = jnp.arange(B, dtype=jnp.int32)
        src = jnp.zeros((S,), jnp.int32).at[slot_s].set(rows,
                                                        mode="drop")
        valid = jnp.zeros((S,), jnp.bool_).at[slot_s].set(True,
                                                          mode="drop")
        # Whole requests gather into queue order (points + validity).
        qdata = ops.moe_dispatch(data.reshape(B, n_pad * d), src,
                                 valid).reshape(k, C, n_pad, d)
        qmask = ops.moe_dispatch(point_mask.astype(jnp.float32), src,
                                 valid).reshape(k, C, n_pad) > 0.5
        ybuf = heads_mod.apply_heads(head_params, qdata, qmask, spec,
                                     serve_dtype=cfg.serve_dtype)
        # top_k=1 with the keep mask as gates: overflowed requests
        # combine to exactly zero.
        preds = ops.moe_combine(ybuf.reshape(S, d),
                                jnp.where(kept, slot, 0),
                                kept.astype(jnp.float32), top_k=1)
        return labels, centers, cmask, weights, preds, cluster, kept

    return routed


def _make_allk_step(cfg):
    """The IFCA-shaped baseline the routed step is benchmarked against:
    run EVERY cluster's head over the full batch (k forwards per
    request) and select by the vote afterwards. Same label body, same
    per-request predictions as the routed step on its kept requests —
    just k/``head_capacity``-fold more head FLOPs. Benchmark-only; the
    serving stack never calls this."""
    from repro.fed.personalize import majority_vote
    from repro.models import heads as heads_mod
    spec = cfg.head_spec()
    base = _make_step(cfg)
    k = cfg.k

    def allk(tau, head_params, keys, data, point_mask, k_valid):
        labels, centers, cmask, weights = base(tau, keys, data,
                                               point_mask, k_valid)
        B = data.shape[0]
        cluster = majority_vote(jnp.where(point_mask, labels, -1),
                                k).astype(jnp.int32)
        qdata = jnp.broadcast_to(data[None], (k,) + data.shape)
        qmask = jnp.broadcast_to(point_mask[None],
                                 (k,) + point_mask.shape)
        yb = heads_mod.apply_heads(head_params, qdata, qmask, spec,
                                   serve_dtype=cfg.serve_dtype)
        preds = yb[cluster, jnp.arange(B)]
        kept = jnp.ones((B,), jnp.bool_)
        return labels, centers, cmask, weights, preds, cluster, kept

    return allk


def _make_encode_fn(cfg):
    """The ingestion-encoder forward (DESIGN.md §17) as the plane's
    prepended stage: (B, n, S, d) raw token/patch sequences + (B, n, S)
    token masks -> (B, n, d) f32 embeddings, through the zoo encoder
    at the plan's ``encode_dtype`` (bf16 storage / f32 accumulation)."""
    from repro.models import encoder as enc_mod
    spec = cfg.encoder_spec()

    def encode(enc_params, data, token_mask):
        return enc_mod.apply_encoder(enc_params, data, token_mask, spec,
                                     encode_dtype=cfg.encode_dtype)

    return encode


def _make_encode_step(cfg):
    """Encode stage fused in front of THE serve-step body: one jitted
    dispatch encodes the raw sequences and runs the unchanged
    solve+attach on the embeddings — the (B, n, d) latent batch never
    round-trips to host between the stages."""
    base = _make_step(cfg)
    encode = _make_encode_fn(cfg)

    def step(tau, enc_params, keys, data, point_mask, token_mask,
             k_valid):
        emb = encode(enc_params, data, token_mask)
        return base(tau, keys, emb, point_mask, k_valid)

    return step


def _make_encoded_routed_step(cfg, axes=None, axis_sizes=None):
    """Encode stage fused in front of the routed personalization step:
    the routed body (labels, vote, dispatch, heads, combine) operates
    on the embeddings unchanged, so the per-cluster heads serve in the
    SAME latent space the attachment clustered."""
    routed = _make_routed_step(cfg, axes=axes, axis_sizes=axis_sizes)
    encode = _make_encode_fn(cfg)

    def step(tau, enc_params, head_params, keys, data, point_mask,
             token_mask, k_valid):
        emb = encode(enc_params, data, token_mask)
        return routed(tau, head_params, keys, emb, point_mask, k_valid)

    return step


class ServePlane:
    """Executes the streaming hot path for an ``AttachService``.

    ``serve_axes=None`` is the single-host plane: ``step`` is exactly
    the historical jitted serve step and ``fold`` is one
    ``server.aggregate_incremental`` scatter — bitwise identical to the
    pre-plane streaming layer. With ``serve_axes`` (and a mesh), both
    are shard_mapped: the request batch axis splits over the named mesh
    axes, tau and the fold state stay replicated, and the fold runs
    through ``server.aggregate_incremental_sharded``.

    The fold contract is fixed-shape: a ``(B,)`` slot vector aligned
    with the batch, where an out-of-capacity sentinel (>= capacity)
    marks declined/padding entries — the scatter drops them
    (``mode="drop"``), so the fold never recompiles as admission
    decisions vary.
    """

    @staticmethod
    def validate_mesh_axes(mesh, axes, batch_size: int) -> int:
        """THE serve-axes validation (shared by the eager Session check
        and plane construction — one rule set, never two). Returns the
        shard count. Raises :class:`ServePlaneError` naming the field
        and the accepted values."""
        if not axes or not all(isinstance(a, str) for a in axes):
            raise ServePlaneError(
                f"serve_axes={axes!r} is invalid: must be None "
                f"(single-host serving) or a non-empty tuple of mesh "
                f"axis names, e.g. ('data',)")
        if mesh is None:
            raise ServePlaneError(
                f"serve_axes={tuple(axes)!r} needs a mesh: "
                f"Session(plan, mesh=...)")
        missing = [a for a in axes if a not in mesh.shape]
        if missing:
            raise ServePlaneError(
                f"serve_axes={tuple(axes)!r}: axes {missing} not in "
                f"the mesh (available: {list(mesh.shape)})")
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if batch_size % n:
            raise ServePlaneError(
                f"batch_size={batch_size} is invalid: must be "
                f"divisible by the serve_axes shard count {n} "
                f"(axes {tuple(axes)})")
        return n

    def __init__(self, cfg, mesh=None, serve_axes=None):
        self.cfg = cfg
        axes = tuple(serve_axes) if serve_axes else None
        n = (self.validate_mesh_axes(mesh, axes, cfg.batch_size)
             if axes else 1)
        self.mesh = mesh
        self.axes = axes
        self.n_shards = n
        # The RECOMMENDED per-shard row-chunk budget (kernels/ops.py
        # hint, surfaced in stats()): callers streaming large point
        # sets next to this plane (e.g. attach_fn-scale labeling)
        # should chunk at this, not the global threshold, so the
        # aggregate footprint across concurrent shards stays bounded.
        self.chunk_rows = ops.plan_chunk_rows(self.n_shards)
        # Per-active-shard-count compiled entries (the §12 multi-spec
        # cache): s -> (step_jit, fold_jit | None, sharding | None).
        # Entries are built once and kept forever; together with jax's
        # shape-keyed jit cache, every (shards, batch, bucket) triple
        # compiles exactly once. ``compile_count`` counts first-seen
        # (kind, shards, shape) signatures — what the autoscale tests
        # and the benchmark assert stays flat in steady state.
        self._planes = {}
        self._routed = {}
        self._encode = {}
        self._enc_routed = {}
        self._signatures = set()
        self.compile_count = 0
        self._plane_for(n)
        if getattr(cfg, "heads", "off") != "off":
            self._routed_plane_for(n)
        # The §17 encode entries build eagerly too — and ONLY when the
        # encoder is on, so encoder=off planes are bitwise-untouched.
        if getattr(cfg, "encoder", "off") != "off":
            self._encode_plane_for(n)
            if getattr(cfg, "heads", "off") != "off":
                self._encoded_routed_plane_for(n)

    # ------------------------------------------------------------------
    def _submesh(self, s: int):
        """A mesh over the first ``s`` granted devices (single serve
        axis only — a multi-axis grant has no canonical sub-grant and
        the controller never asks for one)."""
        return Mesh(self.mesh.devices.flatten()[:s], self.axes)

    def _plane_for(self, s: int):
        """The compiled (step, fold, sharding) entry for an active
        shard count ``s`` — built on first use, cached forever."""
        entry = self._planes.get(s)
        if entry is not None:
            return entry
        if not (1 <= s <= self.n_shards):
            raise ServePlaneError(
                f"shards={s} is invalid: the plan's serve_axes grant "
                f"1..{self.n_shards} active shards")
        if s > 1 and s != self.n_shards and len(self.axes) > 1:
            raise ServePlaneError(
                f"shards={s} is invalid: multi-axis serve_axes "
                f"{self.axes!r} only switch between 1 and the full "
                f"grant ({self.n_shards})")
        step = _make_step(self.cfg)
        if s == 1:
            entry = (jax.jit(step), None, None, None)
        else:
            from jax.sharding import NamedSharding
            mesh = self.mesh if s == self.n_shards else self._submesh(s)
            axes = self.axes
            spec = P(axes)
            step_sharded = _shard_map(
                step, mesh=mesh,
                in_specs=(P(), spec, spec, spec, spec),
                out_specs=(spec, spec, spec, spec))

            def fold_sharded(state, slots, centers, cmask, weights,
                             epochs):
                return server.aggregate_incremental_sharded(
                    state, slots, centers, cmask, axes, weights=weights,
                    epochs=epochs)

            fold_mesh = jax.jit(_shard_map(
                fold_sharded, mesh=mesh,
                in_specs=(P(), spec, spec, spec, spec, spec),
                out_specs=P()))
            entry = (jax.jit(step_sharded), fold_mesh,
                     NamedSharding(mesh, spec),
                     NamedSharding(mesh, P()))
        self._planes[s] = entry
        return entry

    def _routed_plane_for(self, s: int):
        """The compiled routed-step entry for an active shard count —
        the §16 sibling of :meth:`_plane_for` (which it calls first, so
        shard-count validation and the label plane stay the single
        source of truth). head_params ride replicated like tau."""
        entry = self._routed.get(s)
        if entry is not None:
            return entry
        self._plane_for(s)
        if s == 1:
            entry = (jax.jit(_make_routed_step(self.cfg)), None, None)
        else:
            from jax.sharding import NamedSharding
            mesh = self.mesh if s == self.n_shards else self._submesh(s)
            sizes = tuple(int(mesh.shape[a]) for a in self.axes)
            routed = _make_routed_step(self.cfg, axes=self.axes,
                                       axis_sizes=sizes)
            spec = P(self.axes)
            routed_sharded = _shard_map(
                routed, mesh=mesh,
                in_specs=(P(), P(), spec, spec, spec, spec),
                out_specs=(spec,) * 7)
            entry = (jax.jit(routed_sharded), NamedSharding(mesh, spec),
                     NamedSharding(mesh, P()))
        self._routed[s] = entry
        return entry

    def _encode_plane_for(self, s: int):
        """The compiled encode+serve entry for an active shard count —
        the §17 sibling of :meth:`_plane_for` (which it calls first, so
        shard-count validation stays the single source of truth).
        Encoder params ride replicated like tau; the raw-sequence batch
        and its token mask shard over the batch axis with the rest."""
        entry = self._encode.get(s)
        if entry is not None:
            return entry
        self._plane_for(s)
        if s == 1:
            entry = (jax.jit(_make_encode_step(self.cfg)), None, None)
        else:
            from jax.sharding import NamedSharding
            mesh = self.mesh if s == self.n_shards else self._submesh(s)
            spec = P(self.axes)
            enc_sharded = _shard_map(
                _make_encode_step(self.cfg), mesh=mesh,
                in_specs=(P(), P(), spec, spec, spec, spec, spec),
                out_specs=(spec,) * 4)
            entry = (jax.jit(enc_sharded), NamedSharding(mesh, spec),
                     NamedSharding(mesh, P()))
        self._encode[s] = entry
        return entry

    def _encoded_routed_plane_for(self, s: int):
        """The compiled encode+routed entry (§17 x §16): encoder AND
        head params replicated, everything else sharded over the batch
        axis."""
        entry = self._enc_routed.get(s)
        if entry is not None:
            return entry
        self._plane_for(s)
        if s == 1:
            entry = (jax.jit(_make_encoded_routed_step(self.cfg)),
                     None, None)
        else:
            from jax.sharding import NamedSharding
            mesh = self.mesh if s == self.n_shards else self._submesh(s)
            sizes = tuple(int(mesh.shape[a]) for a in self.axes)
            fn = _make_encoded_routed_step(self.cfg, axes=self.axes,
                                           axis_sizes=sizes)
            spec = P(self.axes)
            fn_sharded = _shard_map(
                fn, mesh=mesh,
                in_specs=(P(), P(), P(), spec, spec, spec, spec, spec),
                out_specs=(spec,) * 7)
            entry = (jax.jit(fn_sharded), NamedSharding(mesh, spec),
                     NamedSharding(mesh, P()))
        self._enc_routed[s] = entry
        return entry

    def encode_step(self, tau, enc_params, keys, data, point_mask,
                    token_mask, k_valid, shards=None):
        """Serve one (B, n_pad, seq_pad, d) batch of raw token/patch
        sequences: encode to (B, n_pad, d) embeddings and run THE serve
        step on them in one fused dispatch (DESIGN.md §17). Returns
        exactly the :meth:`step` quadruple — the fold reports are
        computed in latent space, so fold/drift/autoscale downstream
        are unchanged."""
        s = self.n_shards if shards is None else int(shards)
        step_fn, sharding, state_sh = self._encode_plane_for(s)
        self._count("encode", s, data.shape)
        if sharding is not None:
            tau = jax.device_put(tau, state_sh)
            enc_params = jax.device_put(enc_params, state_sh)
            keys, data, point_mask, token_mask, k_valid = (
                jax.device_put(keys, sharding),
                jax.device_put(data, sharding),
                jax.device_put(point_mask, sharding),
                jax.device_put(token_mask, sharding),
                jax.device_put(k_valid, sharding))
        elif self.axes:
            dev = self.mesh.devices.flatten()[0]
            tau = jax.device_put(tau, dev)
            enc_params = jax.device_put(enc_params, dev)
        return step_fn(tau, enc_params, keys, data, point_mask,
                       token_mask, k_valid)

    def encoded_routed_step(self, tau, enc_params, head_params, keys,
                            data, point_mask, token_mask, k_valid,
                            shards=None):
        """:meth:`encode_step` through the per-cluster heads: the
        routed septuple of :meth:`routed_step`, with both the
        attachment and the head forwards operating on the encoded
        embeddings."""
        s = self.n_shards if shards is None else int(shards)
        step_fn, sharding, state_sh = self._encoded_routed_plane_for(s)
        self._count("enc_routed", s, data.shape)
        if sharding is not None:
            tau = jax.device_put(tau, state_sh)
            enc_params = jax.device_put(enc_params, state_sh)
            head_params = jax.device_put(head_params, state_sh)
            keys, data, point_mask, token_mask, k_valid = (
                jax.device_put(keys, sharding),
                jax.device_put(data, sharding),
                jax.device_put(point_mask, sharding),
                jax.device_put(token_mask, sharding),
                jax.device_put(k_valid, sharding))
        elif self.axes:
            dev = self.mesh.devices.flatten()[0]
            tau = jax.device_put(tau, dev)
            enc_params = jax.device_put(enc_params, dev)
            head_params = jax.device_put(head_params, dev)
        return step_fn(tau, enc_params, head_params, keys, data,
                       point_mask, token_mask, k_valid)

    def routed_step(self, tau, head_params, keys, data, point_mask,
                    k_valid, shards=None):
        """Serve one (B, n_pad, d) batch THROUGH the per-cluster heads
        (DESIGN.md §16). Returns the :meth:`step` quadruple plus
        (preds (B, d) f32, cluster (B,) i32, kept (B,) bool) — preds
        are zero and kept False where the request overflowed its
        cluster's dispatch queue. The label quadruple is
        bitwise-identical to :meth:`step` on the same inputs."""
        s = self.n_shards if shards is None else int(shards)
        step_fn, sharding, state_sh = self._routed_plane_for(s)
        self._count("routed", s, data.shape)
        if sharding is not None:
            tau = jax.device_put(tau, state_sh)
            head_params = jax.device_put(head_params, state_sh)
            keys, data, point_mask, k_valid = (
                jax.device_put(keys, sharding),
                jax.device_put(data, sharding),
                jax.device_put(point_mask, sharding),
                jax.device_put(k_valid, sharding))
        elif self.axes:
            dev = self.mesh.devices.flatten()[0]
            tau = jax.device_put(tau, dev)
            head_params = jax.device_put(head_params, dev)
        return step_fn(tau, head_params, keys, data, point_mask,
                       k_valid)

    def _count(self, kind: str, s: int, shape) -> None:
        sig = (kind, s, tuple(shape))
        if sig not in self._signatures:
            self._signatures.add(sig)
            self.compile_count += 1

    def step(self, tau, keys, data, point_mask, k_valid, shards=None):
        """Serve one fixed-shape (B, n_pad, d) batch. Returns
        (labels (B, n_pad), centers (B, k', d), center_mask (B, k'),
        core weights (B, k')) — sharded over the batch axis on the
        sharded plane, bitwise identical per request at ANY active
        shard count (``shards``, default: the full grant)."""
        s = self.n_shards if shards is None else int(shards)
        step_fn, _, sharding, state_sh = self._plane_for(s)
        self._count("step", s, data.shape)
        if sharding is not None:
            # Host batches land directly in their sharded placement —
            # one host->shard copy each, not a device-0 bounce plus an
            # all-to-all reshard inside the jitted step. tau rides
            # along replicated (k x d — bytes) so a buffer committed
            # elsewhere by a refresh can never clash with the batch's
            # device set when the active shard count switches.
            tau, keys, data, point_mask, k_valid = (
                jax.device_put(tau, state_sh),
                jax.device_put(keys, sharding),
                jax.device_put(data, sharding),
                jax.device_put(point_mask, sharding),
                jax.device_put(k_valid, sharding))
        elif self.axes:
            tau = jax.device_put(tau, self.mesh.devices.flatten()[0])
        return step_fn(tau, keys, data, point_mask, k_valid)

    def localize(self, x):
        """Pull a (small) array stranded on an active sub-mesh — e.g. a
        tau re-finalized from a sharded fold state — back to one
        canonical device, so the double-buffer stack and later steps at
        OTHER shard counts never mix incompatible device sets."""
        if self.axes:
            return jax.device_put(jnp.asarray(x),
                                  self.mesh.devices.flatten()[0])
        return jnp.asarray(x)

    def fold(self, state, slots, centers, cmask, weights=None,
             shards=None, epochs=None):
        """Scatter one batch of already-admitted reports into the
        replicated fold state. ``slots``: (B,) int32, entries >= the
        state capacity are dropped (declined / padding / within-batch
        evictions). ``shards`` is the flush decision's active count;
        with the default (None), only the steady plan-shaped batch
        rides the mesh — other lengths (e.g. round seeding) take the
        single-host scatter, as before the controller existed.
        ``epochs``: optional (B,) request-id epochs stamped on the
        slots for the drift layer (default: the slot ids, matching
        ``aggregate_incremental``)."""
        if weights is None:
            # The explicit form of aggregate_incremental's default —
            # same scattered values, one jit signature for both cases.
            weights = jnp.ones(jnp.shape(cmask), jnp.float32)
        if epochs is None:
            # Likewise the explicit epochs default (the slot ids).
            epochs = jnp.asarray(slots, jnp.int32)
        else:
            epochs = jnp.asarray(epochs, jnp.int32)
        B = int(slots.shape[0])
        if shards is None:
            s = self.n_shards if B == self.cfg.batch_size else 1
        else:
            s = int(shards) if B % max(int(shards), 1) == 0 else 1
        if s > 1:
            _, fold_mesh, _, state_sh = self._plane_for(s)
            self._count("fold", s, (B,) + tuple(centers.shape[1:]))
            # A shard-count switch strands the state on the PREVIOUS
            # active sub-mesh; re-place it (replicated) on the target —
            # a no-op whenever the count is unchanged, one transfer per
            # switch otherwise.
            state = jax.device_put(state, state_sh)
            return fold_mesh(state, slots, centers, cmask, weights,
                             epochs)
        self._count("fold", 1, (B,) + tuple(centers.shape[1:]))
        if self.axes:
            # Same stranding in the other direction: a sharded-plane
            # state dropping to the single-host scatter.
            state = jax.device_put(state,
                                   self.mesh.devices.flatten()[0])
        return server.aggregate_incremental(state, slots, centers, cmask,
                                            weights=weights, epochs=epochs)

    def describe(self) -> dict:
        return {"serve_axes": list(self.axes) if self.axes else None,
                "serve_shards": self.n_shards,
                "chunk_rows": self.chunk_rows,
                "plane_compiles": self.compile_count}
