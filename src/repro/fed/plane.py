"""The sharded streaming serve plane (DESIGN.md §11).

Everything the streaming layer (``fed/stream.py``, §9) executes on
device routes through this module, so ONE knob — ``serve_axes`` on the
``FederationPlan`` — decides whether the hot serving path runs on a
single host or shard_mapped over a mesh:

  * **serve step** — the jitted (batch local Algorithm 1 solve +
    Theorem 3.2 attach) over a fixed ``(batch_size, n_pad, d)`` request
    tensor. The request batch axis is embarrassingly parallel, so the
    sharded plane splits it over the ``serve_axes`` mesh axes with the
    tau centers replicated (``P()``); per-request results are bitwise
    identical to the unsharded step because every request's computation
    is a function of its own (key, data, k_valid) only.
  * **fold scatter** — the per-slot scatter of served reports into the
    replicated incremental server state. ``server.aggregate_incremental``
    stays the single fold primitive; the sharded plane runs its
    collective sibling ``server.aggregate_incremental_sharded`` (each
    shard scatters ITS slice of the batch, disjoint slots combine with
    an exact psum). Slot admission itself stays host-side in
    ``fed/policy.py`` and is shard-deterministic by contract — the plane
    only ever executes an already-decided ``(B,)`` slot vector.
  * **double-buffered tau** (:class:`TauBuffer`) — serving reads
    ``bufs[active]``; a refresh builds the standby buffer while serving
    continues, and the swap is an atomic version bump. Every served
    label maps to exactly one tau version; both buffers + the version
    counter ride the §9 checkpoint so a restore mid-window replays the
    same version assignments bitwise.
  * **shard-count switching** (§12) — ``serve_axes`` GRANTS up to
    ``n_shards`` devices; the load-adaptive controller
    (``fed/autoscale.py``) may execute any flush on fewer
    (``shards=`` on :meth:`step`/:meth:`fold`), down to the single-host
    plane at 1. Each active shard count gets its own compiled
    step/fold (a sub-mesh over the first ``s`` granted devices), cached
    forever alongside every (batch, bucket) shape it serves —
    ``compile_count`` tracks first-seen (kind, shards, shape)
    signatures, so steady-state scaling provably never recompiles.

The plane is deliberately free of service bookkeeping (queues, buckets,
policies, checkpoints live in ``fed/stream.py``): it owns exactly the
two device computations of the hot path and their mesh mapping.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import server
from repro.core.lloyd import lloyd_attach
from repro.core.local_kmeans import batched_local_prepare, split_local_kw
from repro.kernels import ops
from repro.utils.compat import shard_map as _shard_map

__all__ = ["ServePlane", "ServePlaneError", "TauBuffer"]


class ServePlaneError(ValueError):
    """A serve-plane configuration failed validation (named, with the
    accepted values) — raised at construction, never inside tracing."""


# ---------------------------------------------------------------------------
# Double-buffered, versioned tau.
# ---------------------------------------------------------------------------


class TauBuffer(NamedTuple):
    """Double-buffered tau centers with an atomic version counter.

    ``bufs[active]`` is what the serve step reads; ``bufs[1 - active]``
    is the standby a refresh writes into. ``stage`` fills the standby
    without touching serving (the async-refresh build phase); ``commit``
    is the atomic swap: active flips and ``version`` bumps by one, so a
    request's recorded version identifies exactly which tau buffer
    produced its labels. ``swap_now`` = stage + commit (the synchronous
    refresh). Immutable — every transition returns a new TauBuffer, and
    the whole triple serializes into the service checkpoint.
    """
    bufs: jax.Array      # (2, k, d) f32
    active: int          # which buffer serves
    version: int         # monotone; bumps exactly once per commit
    pending: bool        # standby staged, swap deferred to a boundary

    @classmethod
    def fresh(cls, tau) -> "TauBuffer":
        t = jnp.asarray(tau, jnp.float32)
        return cls(jnp.stack([t, t]), 0, 0, False)

    @property
    def tau(self) -> jax.Array:
        return self.bufs[self.active]

    @property
    def standby(self) -> jax.Array:
        return self.bufs[1 - self.active]

    def stage(self, new_tau) -> "TauBuffer":
        """Write the standby buffer; serving keeps reading the active
        one until :meth:`commit`."""
        t = jnp.asarray(new_tau, jnp.float32)
        bufs = jnp.stack([self.bufs[self.active], t]
                         if self.active == 0 else [t, self.bufs[self.active]])
        return TauBuffer(bufs, self.active, self.version, True)

    def commit(self) -> "TauBuffer":
        """The atomic swap: activate the standby, bump the version."""
        return TauBuffer(self.bufs, 1 - self.active, self.version + 1,
                         False)

    def swap_now(self, new_tau) -> "TauBuffer":
        return self.stage(new_tau).commit()

    # -- checkpoint plumbing (npz-able arrays) --------------------------
    def meta_array(self):
        import numpy as np
        return np.asarray([self.active, self.version, int(self.pending)],
                          np.int64)

    @classmethod
    def from_arrays(cls, bufs, meta) -> "TauBuffer":
        import numpy as np
        m = np.asarray(meta)
        return cls(jnp.asarray(bufs, jnp.float32), int(m[0]), int(m[1]),
                   bool(m[2]))


# ---------------------------------------------------------------------------
# The plane: serve step + fold scatter, single-host or shard_mapped.
# ---------------------------------------------------------------------------


def _make_step(cfg):
    """The ONE serve-step body (shared verbatim by both planes): vmapped
    Algorithm 1 steps 1-3 over the request batch, then the FUSED
    bounded-Lloyd solve + Theorem 3.2 attach against the replicated tau
    + Definition 3.3 induced labels in a single ``lloyd_attach``
    dispatch (kernels/solve_attach, DESIGN.md §13). ``cfg.serve_dtype``
    selects f32 (bitwise vs the pre-fusion staged step) or bf16 storage
    with f32 accumulation."""
    prep_kw, max_iters = split_local_kw(cfg.local_kw)

    def step(tau, keys, data, point_mask, k_valid):
        prep = batched_local_prepare(keys, data, k_max=cfg.k_prime,
                                     k_valid=k_valid,
                                     point_mask=point_mask, **prep_kw)
        labels, _, centers, _ = lloyd_attach(
            data, prep.theta, tau, center_mask=prep.center_mask,
            point_mask=point_mask, max_iters=max_iters,
            serve_dtype=cfg.serve_dtype)
        return (labels, centers, prep.center_mask,
                server.core_weights(prep.core_counts))

    return step


class ServePlane:
    """Executes the streaming hot path for an ``AttachService``.

    ``serve_axes=None`` is the single-host plane: ``step`` is exactly
    the historical jitted serve step and ``fold`` is one
    ``server.aggregate_incremental`` scatter — bitwise identical to the
    pre-plane streaming layer. With ``serve_axes`` (and a mesh), both
    are shard_mapped: the request batch axis splits over the named mesh
    axes, tau and the fold state stay replicated, and the fold runs
    through ``server.aggregate_incremental_sharded``.

    The fold contract is fixed-shape: a ``(B,)`` slot vector aligned
    with the batch, where an out-of-capacity sentinel (>= capacity)
    marks declined/padding entries — the scatter drops them
    (``mode="drop"``), so the fold never recompiles as admission
    decisions vary.
    """

    @staticmethod
    def validate_mesh_axes(mesh, axes, batch_size: int) -> int:
        """THE serve-axes validation (shared by the eager Session check
        and plane construction — one rule set, never two). Returns the
        shard count. Raises :class:`ServePlaneError` naming the field
        and the accepted values."""
        if not axes or not all(isinstance(a, str) for a in axes):
            raise ServePlaneError(
                f"serve_axes={axes!r} is invalid: must be None "
                f"(single-host serving) or a non-empty tuple of mesh "
                f"axis names, e.g. ('data',)")
        if mesh is None:
            raise ServePlaneError(
                f"serve_axes={tuple(axes)!r} needs a mesh: "
                f"Session(plan, mesh=...)")
        missing = [a for a in axes if a not in mesh.shape]
        if missing:
            raise ServePlaneError(
                f"serve_axes={tuple(axes)!r}: axes {missing} not in "
                f"the mesh (available: {list(mesh.shape)})")
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if batch_size % n:
            raise ServePlaneError(
                f"batch_size={batch_size} is invalid: must be "
                f"divisible by the serve_axes shard count {n} "
                f"(axes {tuple(axes)})")
        return n

    def __init__(self, cfg, mesh=None, serve_axes=None):
        self.cfg = cfg
        axes = tuple(serve_axes) if serve_axes else None
        n = (self.validate_mesh_axes(mesh, axes, cfg.batch_size)
             if axes else 1)
        self.mesh = mesh
        self.axes = axes
        self.n_shards = n
        # The RECOMMENDED per-shard row-chunk budget (kernels/ops.py
        # hint, surfaced in stats()): callers streaming large point
        # sets next to this plane (e.g. attach_fn-scale labeling)
        # should chunk at this, not the global threshold, so the
        # aggregate footprint across concurrent shards stays bounded.
        self.chunk_rows = ops.plan_chunk_rows(self.n_shards)
        # Per-active-shard-count compiled entries (the §12 multi-spec
        # cache): s -> (step_jit, fold_jit | None, sharding | None).
        # Entries are built once and kept forever; together with jax's
        # shape-keyed jit cache, every (shards, batch, bucket) triple
        # compiles exactly once. ``compile_count`` counts first-seen
        # (kind, shards, shape) signatures — what the autoscale tests
        # and the benchmark assert stays flat in steady state.
        self._planes = {}
        self._signatures = set()
        self.compile_count = 0
        self._plane_for(n)

    # ------------------------------------------------------------------
    def _submesh(self, s: int):
        """A mesh over the first ``s`` granted devices (single serve
        axis only — a multi-axis grant has no canonical sub-grant and
        the controller never asks for one)."""
        return Mesh(self.mesh.devices.flatten()[:s], self.axes)

    def _plane_for(self, s: int):
        """The compiled (step, fold, sharding) entry for an active
        shard count ``s`` — built on first use, cached forever."""
        entry = self._planes.get(s)
        if entry is not None:
            return entry
        if not (1 <= s <= self.n_shards):
            raise ServePlaneError(
                f"shards={s} is invalid: the plan's serve_axes grant "
                f"1..{self.n_shards} active shards")
        if s > 1 and s != self.n_shards and len(self.axes) > 1:
            raise ServePlaneError(
                f"shards={s} is invalid: multi-axis serve_axes "
                f"{self.axes!r} only switch between 1 and the full "
                f"grant ({self.n_shards})")
        step = _make_step(self.cfg)
        if s == 1:
            entry = (jax.jit(step), None, None, None)
        else:
            from jax.sharding import NamedSharding
            mesh = self.mesh if s == self.n_shards else self._submesh(s)
            axes = self.axes
            spec = P(axes)
            step_sharded = _shard_map(
                step, mesh=mesh,
                in_specs=(P(), spec, spec, spec, spec),
                out_specs=(spec, spec, spec, spec))

            def fold_sharded(state, slots, centers, cmask, weights,
                             epochs):
                return server.aggregate_incremental_sharded(
                    state, slots, centers, cmask, axes, weights=weights,
                    epochs=epochs)

            fold_mesh = jax.jit(_shard_map(
                fold_sharded, mesh=mesh,
                in_specs=(P(), spec, spec, spec, spec, spec),
                out_specs=P()))
            entry = (jax.jit(step_sharded), fold_mesh,
                     NamedSharding(mesh, spec),
                     NamedSharding(mesh, P()))
        self._planes[s] = entry
        return entry

    def _count(self, kind: str, s: int, shape) -> None:
        sig = (kind, s, tuple(shape))
        if sig not in self._signatures:
            self._signatures.add(sig)
            self.compile_count += 1

    def step(self, tau, keys, data, point_mask, k_valid, shards=None):
        """Serve one fixed-shape (B, n_pad, d) batch. Returns
        (labels (B, n_pad), centers (B, k', d), center_mask (B, k'),
        core weights (B, k')) — sharded over the batch axis on the
        sharded plane, bitwise identical per request at ANY active
        shard count (``shards``, default: the full grant)."""
        s = self.n_shards if shards is None else int(shards)
        step_fn, _, sharding, state_sh = self._plane_for(s)
        self._count("step", s, data.shape)
        if sharding is not None:
            # Host batches land directly in their sharded placement —
            # one host->shard copy each, not a device-0 bounce plus an
            # all-to-all reshard inside the jitted step. tau rides
            # along replicated (k x d — bytes) so a buffer committed
            # elsewhere by a refresh can never clash with the batch's
            # device set when the active shard count switches.
            tau, keys, data, point_mask, k_valid = (
                jax.device_put(tau, state_sh),
                jax.device_put(keys, sharding),
                jax.device_put(data, sharding),
                jax.device_put(point_mask, sharding),
                jax.device_put(k_valid, sharding))
        elif self.axes:
            tau = jax.device_put(tau, self.mesh.devices.flatten()[0])
        return step_fn(tau, keys, data, point_mask, k_valid)

    def localize(self, x):
        """Pull a (small) array stranded on an active sub-mesh — e.g. a
        tau re-finalized from a sharded fold state — back to one
        canonical device, so the double-buffer stack and later steps at
        OTHER shard counts never mix incompatible device sets."""
        if self.axes:
            return jax.device_put(jnp.asarray(x),
                                  self.mesh.devices.flatten()[0])
        return jnp.asarray(x)

    def fold(self, state, slots, centers, cmask, weights=None,
             shards=None, epochs=None):
        """Scatter one batch of already-admitted reports into the
        replicated fold state. ``slots``: (B,) int32, entries >= the
        state capacity are dropped (declined / padding / within-batch
        evictions). ``shards`` is the flush decision's active count;
        with the default (None), only the steady plan-shaped batch
        rides the mesh — other lengths (e.g. round seeding) take the
        single-host scatter, as before the controller existed.
        ``epochs``: optional (B,) request-id epochs stamped on the
        slots for the drift layer (default: the slot ids, matching
        ``aggregate_incremental``)."""
        if weights is None:
            # The explicit form of aggregate_incremental's default —
            # same scattered values, one jit signature for both cases.
            weights = jnp.ones(jnp.shape(cmask), jnp.float32)
        if epochs is None:
            # Likewise the explicit epochs default (the slot ids).
            epochs = jnp.asarray(slots, jnp.int32)
        else:
            epochs = jnp.asarray(epochs, jnp.int32)
        B = int(slots.shape[0])
        if shards is None:
            s = self.n_shards if B == self.cfg.batch_size else 1
        else:
            s = int(shards) if B % max(int(shards), 1) == 0 else 1
        if s > 1:
            _, fold_mesh, _, state_sh = self._plane_for(s)
            self._count("fold", s, (B,) + tuple(centers.shape[1:]))
            # A shard-count switch strands the state on the PREVIOUS
            # active sub-mesh; re-place it (replicated) on the target —
            # a no-op whenever the count is unchanged, one transfer per
            # switch otherwise.
            state = jax.device_put(state, state_sh)
            return fold_mesh(state, slots, centers, cmask, weights,
                             epochs)
        self._count("fold", 1, (B,) + tuple(centers.shape[1:]))
        if self.axes:
            # Same stranding in the other direction: a sharded-plane
            # state dropping to the single-host scatter.
            state = jax.device_put(state,
                                   self.mesh.devices.flatten()[0])
        return server.aggregate_incremental(state, slots, centers, cmask,
                                            weights=weights, epochs=epochs)

    def describe(self) -> dict:
        return {"serve_axes": list(self.axes) if self.axes else None,
                "serve_shards": self.n_shards,
                "chunk_rows": self.chunk_rows,
                "plane_compiles": self.compile_count}
