"""Pluggable federated execution engine for one-shot k-FED (DESIGN.md §4).

One k-FED round decomposes into four stages:

  1. local solve    — Algorithm 1 on each device (vmapped / sharded);
  2. transport      — the ONE message per device: (Theta^(z), mask,
                      optional core-set weights);
  3. server         — Algorithm 2 via the shared core (``core/server``),
                      one-shot or as an incremental fold;
  4. induced labels — Definition 3.3 back on each device.

The beyond-paper scenarios the paper's §4 promises are configurations of
these stages rather than new protocol implementations:

  * **partial participation** — a (Z,) bool mask; absent devices are
    excluded from aggregation and attached post-hoc by the Theorem 3.2
    nearest-center rule (zero extra rounds);
  * **asynchronous staged arrival** — cohorts report across multiple
    ``server.aggregate_incremental`` folds in ANY order; the finalized
    labels are bitwise identical to the one-shot run with the same
    participation set;
  * **weighted aggregation** — the server's single Lloyd round weights
    each device center by its Algorithm 1 core set size |S_r|, so large
    devices are not diluted by small ones.

The shard_map production paths (``core/distributed.kfed_shard_map``) run
the same stages over a mesh; this module is the single-host engine the
simulation path (``core.kfed.kfed``) is a thin configuration of.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import server
from repro.core.local_kmeans import LocalKMeansResult, batched_local_kmeans


@dataclass(frozen=True)
class EngineConfig:
    """Static configuration of one federated clustering round."""
    k: int                                  # global cluster count
    k_prime: int                            # per-device k^(z) cap
    weight_by_core_counts: bool = False     # weighted server Lloyd round
    local_kw: dict = field(default_factory=dict)  # Algorithm 1 options


class RoundResult(NamedTuple):
    agg: server.KFedAggregate
    device_centers: jax.Array   # (Z, k', d)
    center_mask: jax.Array      # (Z, k')
    local_assign: jax.Array     # (Z, n)
    core_counts: jax.Array      # (Z, k') |S_r| from Algorithm 1
    center_labels: jax.Array    # (Z, k') incl. post-hoc attached devices
    labels: jax.Array           # (Z, n) induced clustering, -1 padded
    participated: jax.Array     # (Z,) bool


def core_weights(loc: LocalKMeansResult) -> jax.Array:
    """Per-center weights for the server Lloyd round (shared rule:
    ``server.core_weights`` over the Algorithm 1 core set sizes)."""
    return server.core_weights(loc.core_counts)


def local_stage(key: jax.Array, device_data: jax.Array, cfg: EngineConfig,
                *, k_valid=None, point_mask=None) -> LocalKMeansResult:
    """Stage 1: vmapped Algorithm 1 over the device axis."""
    Z = device_data.shape[0]
    keys = jax.random.split(key, Z)
    return batched_local_kmeans(keys, device_data, k_max=cfg.k_prime,
                                k_valid=k_valid, point_mask=point_mask,
                                **cfg.local_kw)


def server_stage(loc: LocalKMeansResult, cfg: EngineConfig, *,
                 participation: Optional[jax.Array] = None):
    """Stages 2-3: transport masking + shared server aggregation, then
    Theorem 3.2 post-hoc attachment of any absent devices.

    Returns (agg, center_labels (Z, k'), participated (Z,) bool).
    """
    Z = loc.centers.shape[0]
    w = core_weights(loc) if cfg.weight_by_core_counts else None
    if participation is None:
        agg = server.aggregate(loc.centers, loc.center_mask, cfg.k,
                               weights=w)
        return agg, agg.center_labels, jnp.ones((Z,), bool)
    part = jnp.asarray(participation, bool)
    mask = loc.center_mask & part[:, None]
    agg = server.aggregate(loc.centers, mask, cfg.k, weights=w)
    center_labels = server.attach_absent_devices(
        agg.center_labels, loc.centers, loc.center_mask,
        agg.tau_centers, part)
    return agg, center_labels, part


def _finish(loc: LocalKMeansResult, agg, center_labels, part) -> RoundResult:
    labels = server.induced_labels(center_labels, loc.assign)
    return RoundResult(agg, loc.centers, loc.center_mask, loc.assign,
                       loc.core_counts, center_labels, labels, part)


def run_round_impl(key: jax.Array, device_data: jax.Array,
                   cfg: EngineConfig, *,
                   participation: Optional[jax.Array] = None,
                   k_valid=None, point_mask=None) -> RoundResult:
    """One synchronous k-FED round (optionally with partial
    participation). The reference execution every other path — async,
    shard_map replicated, shard_map sharded — must agree with. This is
    the engine internal; the declarative surface is
    ``fed.api.Session.run``."""
    loc = local_stage(key, device_data, cfg, k_valid=k_valid,
                      point_mask=point_mask)
    agg, center_labels, part = server_stage(loc, cfg,
                                            participation=participation)
    return _finish(loc, agg, center_labels, part)


def run_round(key: jax.Array, device_data: jax.Array, cfg: EngineConfig, *,
              participation: Optional[jax.Array] = None,
              k_valid=None, point_mask=None) -> RoundResult:
    """Deprecated: use ``fed.api.Session.run`` (this shim routes
    through it and returns the detailed RoundResult)."""
    from repro.fed import api
    from repro.utils.deprecation import warn_legacy
    warn_legacy("fed.engine.run_round", "Session.run")
    sess = api.Session(api.plan_from_engine_config(
        cfg, d=device_data.shape[-1]))
    return sess.run(key, device_data, participation=participation,
                    k_valid=k_valid, point_mask=point_mask).detail


def run_round_async(key: jax.Array, device_data: jax.Array,
                    cfg: EngineConfig, cohorts: Sequence, *,
                    k_valid=None, point_mask=None) -> RoundResult:
    """Deprecated: use ``fed.api.Session.fold`` + ``Session.finalize``
    (this shim routes the same cohorts through a Session).

    Bitwise-identical labels to the synchronous round with
    ``participation`` = union(cohorts): the fold state is keyed by
    device id, so arrival order cannot influence the finalized
    aggregate.
    """
    from repro.fed import api
    from repro.utils.deprecation import warn_legacy
    warn_legacy("fed.engine.run_round_async",
                "Session.fold/Session.finalize")
    sess = api.Session(api.plan_from_engine_config(
        cfg, d=device_data.shape[-1]))
    # begin() first so an EMPTY cohort list still finalizes (every
    # device treated as a non-participant, attached post-hoc).
    sess.begin(key, device_data, k_valid=k_valid, point_mask=point_mask)
    for ids in cohorts:
        sess.fold(ids)
    return sess.finalize().detail
