"""k-FED + FedAvg personalization (Section 4.2.2, Table 2).

One-shot clustering of client summary vectors assigns every device a
cluster id; one model per cluster is then trained with FedAvg restricted
to that cluster's members. After the initial clustering the server only
ever ships ONE model per device per round (vs IFCA's k)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.kfed import _kfed_impl
from repro.fed.fedavg import FedAvgConfig, fedavg_round


def majority_vote(labels, k: int):
    """Per-row majority cluster from per-point Theorem 3.2 labels.
    labels: (Z, n) int32 with -1 for masked points. First-max tie-break
    (argmax) — the SAME vote the routed serving step (DESIGN.md §16)
    uses, so offline `cluster_devices` assignment and online routing
    agree bitwise on identical labels. Counts are a fixed-order one-hot
    reduction, NOT a bincount: bincount with float weights lowers to a
    float scatter-add on the data-derived labels, which the §15
    determinism audit rejects on the routed serving path (and the sums
    of 1.0s are exact either way, so the vote is unchanged)."""
    oh = jax.nn.one_hot(jnp.maximum(labels, 0), k, dtype=jnp.float32)
    counts = jnp.sum(oh * (labels >= 0)[..., None].astype(jnp.float32),
                     axis=1)
    return jnp.argmax(counts, axis=1)


def cluster_devices(key, features, k: int, k_prime: int = 1):
    """Cluster devices by their summary vectors. features: (Z, n_feat, d)
    — with n_feat == 1 this is exactly device-level clustering (k' = 1 per
    the Table 2 setup); larger n_feat clusters per-device feature sets and
    majority-votes the device's cluster (the k' = 2 rows)."""
    res = _kfed_impl(key, features, k=k, k_prime=k_prime)
    return majority_vote(res.labels, k), res


def kfed_personalize(key, loss_fn: Callable, init_params, device_data,
                     features, k: int, cfg: FedAvgConfig, *,
                     k_prime: int = 1, point_mask=None,
                     per_chunk: bool = False):
    """Full pipeline: one-shot cluster -> per-cluster FedAvg.

    ``per_chunk=False``: majority-vote one cluster per device (the k'=1
    Table 2 setup). ``per_chunk=True``: the k'>1 advantage the paper
    highlights — k-FED clusters DATA, so a mixed device trains each of
    its feature chunks with that chunk's own cluster model (IFCA can only
    assign whole devices). Chunks are contiguous ``array_split`` shards
    of the device's points, matching the (Z, n_feat, ·) feature layout.

    Returns (models stacked over k, assignment, history) where
    assignment is (Z,) for per-device mode and (Z, n_feat) per-chunk.
    """
    device_cluster, res = cluster_devices(key, features, k, k_prime)
    Z = features.shape[0]
    n_feat = features.shape[1]
    n = jax.tree.leaves(device_data)[0].shape[1]
    base_pm = (jnp.ones((Z, n), bool) if point_mask is None
               else point_mask)

    if per_chunk and n_feat > 1:
        lbl = res.labels                              # (Z, n_feat)
        # chunk c covers rows [bounds[c], bounds[c+1]) (array_split)
        sizes = [(n // n_feat) + (1 if c < n % n_feat else 0)
                 for c in range(n_feat)]
        edges = [0]
        for s in sizes:
            edges.append(edges[-1] + s)
        chunk_of = jnp.concatenate([
            jnp.full((sizes[c],), c, jnp.int32) for c in range(n_feat)])
        point_lbl = lbl[:, :][jnp.arange(Z)[:, None], chunk_of[None, :]]
        assignment = lbl
    else:
        point_lbl = jnp.broadcast_to(device_cluster[:, None], (Z, n))
        assignment = device_cluster

    models = []
    history = []
    for j in range(k):
        pm_j = base_pm & (point_lbl == j)
        member = (pm_j.any(axis=1)).astype(jnp.float32)
        params = init_params
        losses = []
        for _ in range(cfg.rounds):
            params, l = fedavg_round(loss_fn, params, device_data, cfg,
                                     point_mask=pm_j,
                                     member_mask=member)
            losses.append(float(l))
        models.append(params)
        history.append(losses)
    models = jax.tree.map(lambda *xs: jnp.stack(xs), *models)
    return models, assignment, history
