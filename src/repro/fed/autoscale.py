"""Load-adaptive serve-plane autoscaling (DESIGN.md §12).

PR 4 left the serve plane statically configured: `serve_axes` grants a
shard count and ``batch_size`` fixes the step shape no matter what the
request queue looks like. This module is the deterministic controller
that closes ROADMAP's serve-plane-autoscaling item: at flush boundaries
only, it re-selects

  * the **active shard count** — within the devices the plan's
    ``serve_axes`` granted (a shallow queue runs on one device instead
    of paying the mesh dispatch for a near-empty batch);
  * the **serve batch size** — a power-of-two rung within the plan's
    ``batch_size`` ceiling (a flush with 3 queued requests pads to 4,
    not to 64 — repeat-padding rows are real compute);
  * the **active bucket ladder** — under oversized load the queued
    above-ladder requests are RE-BUCKETED into one coalesced pad rung
    instead of fragmenting across the geometric doubling ladder (fewer,
    fuller batches and fewer distinct jit shapes).

Determinism/replay contract (the property tests/test_autoscale.py
pins): a decision is a pure function of a :class:`QueueSnapshot` —
queue depth and the pending bucket histogram, both functions of the
request stream alone — plus the controller's own persisted state
(previous decision + shrink streak), which rides the schema-v3 service
checkpoint next to ``tau_meta``. Wall-clock flush telemetry
(:class:`FlushTelemetry`: the two-phase pipeline's dispatch and
materialize latency) is recorded and surfaced through
``Session.stats()`` but deliberately EXCLUDED from the decision inputs:
wall clock does not replay, and version/fold boundaries depend on batch
shape, so a latency-driven decision would break the bitwise
restore-replay guarantee the whole streaming layer is built on. Shard
count never affects results (per-request labels are
batch-composition-independent), but it follows the same rule so the
decision *sequence* itself replays bitwise.

The serve plane caches one compiled step per (shards, batch, bucket)
triple (``fed/plane.py``), so in steady state — once the load shape's
rungs have each been seen once — scaling never recompiles
(``ServePlane.compile_count`` is asserted flat in the tests and the
``autoscale_*`` benchmark rows).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np

__all__ = ["AUTOSCALE_POLICIES", "AUTOSCALE_IDS", "AutoscaleError",
           "AutoscaleController", "AutoscaleDecision", "FlushTelemetry",
           "QueueSnapshot", "bucket_of", "decide", "pow2_ceil",
           "shards_for", "snapshot_queue"]

AUTOSCALE_POLICIES = ("off", "latency", "throughput")

# Stable numeric codes for the v3 checkpoint schema (npz stores no
# strings): a restored service must run the SAME autoscale policy that
# wrote the decision state, or the replayed decision sequence — and with
# it the refresh/version boundaries — would diverge from the original.
AUTOSCALE_IDS = {"off": 0, "latency": 1, "throughput": 2}

# Shrink only after this many consecutive shallow flushes (throughput
# policy): one thin flush inside a burst must not collapse the batch.
SHRINK_STREAK = 2


class AutoscaleError(ValueError):
    """An autoscale configuration failed validation (named, with the
    accepted values) — raised at construction, never mid-flush."""


def bucket_of(n: int, ladder: Tuple[int, ...]) -> int:
    """THE pad-rung rule (shared by the service's bucketing and the
    controller's histogram so they can never disagree): the smallest
    ladder rung holding ``n`` points, geometric doubling above the top
    rung (O(log) distinct jit shapes instead of one per distinct n)."""
    for b in ladder:
        if n <= b:
            return int(b)
    b = int(ladder[-1])
    while b < n:
        b *= 2
    return b


class QueueSnapshot(NamedTuple):
    """The DETERMINISTIC flush-boundary telemetry decisions may read:
    a pure function of the queued request stream (depth + histogram
    over the base ladder's pad rungs), so an interrupted and an
    uninterrupted run observe identical snapshots.

    ``mass`` is the drift layer's per-center decayed fold-mass
    histogram (DESIGN.md §14) — empty when ``drift="off"``, otherwise
    a pure function of the folded stream, so it keeps the replay
    contract. Today's policies ignore it; it is the "state evolves at
    flush boundaries" hook the ROADMAP's predictive-scaling item
    needs (e.g. scale ahead of a mass-imbalance-triggered split)."""
    pending: int                              # queue depth at the boundary
    hist: Tuple[Tuple[int, int], ...]         # ascending (rung, count)
    mass: Tuple[float, ...] = ()              # per-center decayed fold mass


class FlushTelemetry(NamedTuple):
    """Wall-clock observability of one flush's two-phase pipeline —
    recorded, surfaced in ``stats()``, and NEVER a decision input (see
    the module docstring's replay contract)."""
    dispatch_us: int        # phase 1: every batch's step+fold dispatched
    materialize_us: int     # phase 2: labels gathered to host
    batches: int
    requests: int
    points: int


class AutoscaleDecision(NamedTuple):
    """One flush's scaling selection. ``seq`` counts decisions (one per
    non-empty flush) so checkpoint replay can be asserted against the
    uninterrupted run decision-by-decision."""
    shards: int                   # active serve shards (<= granted)
    batch_size: int               # active step batch (<= plan ceiling)
    ladder: Tuple[int, ...]       # active pad-bucket ladder
    seq: int


def snapshot_queue(pending_ns, base_ladder, mass=()) -> QueueSnapshot:
    """Histogram the queued point counts over the base ladder's rungs
    (geometric rungs above the top) — the controller's one view of the
    queue. ``mass``: the drift layer's per-center fold-mass histogram
    (empty outside drift mode)."""
    hist: Dict[int, int] = {}
    for n in pending_ns:
        b = bucket_of(int(n), tuple(base_ladder))
        hist[b] = hist.get(b, 0) + 1
    return QueueSnapshot(pending=len(pending_ns),
                         hist=tuple(sorted(hist.items())),
                         mass=tuple(float(m) for m in mass))


def pow2_ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def _pow2_floor(x: int) -> int:
    return 1 << (int(x).bit_length() - 1)


def shards_for(batch: int, granted: int, n_axes: int) -> int:
    """The most parallel ACTIVE shard count the batch divides over:
    the full grant when it fits; otherwise (single-axis serve planes
    only — a multi-axis grant has no canonical sub-grant) the largest
    power of two dividing both."""
    if batch % granted == 0:
        return granted
    if n_axes > 1:
        return 1
    return min(_pow2_floor(granted), batch)


def _ladder_for(policy: str, snap: QueueSnapshot, batch: int,
                base_ladder: Tuple[int, ...]) -> Tuple[int, ...]:
    """The active bucket ladder: base rungs, plus the queued oversized
    rungs — coalesced into the single largest occupied rung when the
    flush is load-heavy (throughput always; latency once the oversized
    backlog alone fills a batch), so oversized traffic re-buckets into
    fewer, fuller fixed shapes instead of climbing the geometric
    ladder one thin batch per rung."""
    top = base_ladder[-1]
    over = [(r, c) for r, c in snap.hist if r > top]
    if not over:
        return base_ladder
    if len(over) > 1 and (policy == "throughput"
                          or sum(c for _, c in over) >= batch):
        return base_ladder + (over[-1][0],)
    return base_ladder + tuple(r for r, _ in over)


def decide(policy: str, snap: QueueSnapshot, *, max_batch: int,
           granted: int, n_axes: int, base_ladder: Tuple[int, ...],
           prev: AutoscaleDecision,
           streak: int) -> Tuple[AutoscaleDecision, int]:
    """THE decision rule — a pure function of (policy, snapshot, prev
    decision, streak), nothing else (unit-tested directly).

    Called only for the ADAPTIVE policies — ``off`` never reaches the
    decision rule (:meth:`AutoscaleController.observe` short-circuits
    it to the static plan decision, seq untouched).

    * ``latency`` — the batch tracks the queue depth both ways
      (next power of two, capped at the plan ceiling): shallow flushes
      serve immediately in small steps instead of computing a
      near-empty padded batch.
    * ``throughput`` — grows exactly like ``latency`` but shrinks only
      after :data:`SHRINK_STREAK` consecutive shallow flushes, riding
      out single-flush dips inside a burst with full batches.

    The active shard count follows the batch (``shards_for``), and the
    ladder re-buckets oversized backlog (``_ladder_for``).
    """
    target = min(pow2_ceil(max(snap.pending, 1)), int(max_batch))
    if policy == "latency":
        batch, streak = target, 0
    elif target >= prev.batch_size:
        batch, streak = target, 0
    else:
        streak += 1
        if streak >= SHRINK_STREAK:
            batch, streak = target, 0
        else:
            batch = prev.batch_size
    return (AutoscaleDecision(
        shards=shards_for(batch, granted, n_axes),
        batch_size=batch,
        ladder=_ladder_for(policy, snap, batch, tuple(base_ladder)),
        seq=prev.seq + 1), streak)


class AutoscaleController:
    """Owns the decision state for one ``AttachService``: observe a
    queue snapshot at each flush boundary, emit the decision for that
    flush, and checkpoint/restore the state arrays that make the
    decision sequence replay bitwise (schema v3)."""

    def __init__(self, policy: str, *, max_batch: int, granted: int,
                 n_axes: int, base_ladder: Tuple[int, ...]):
        if policy not in AUTOSCALE_POLICIES:
            raise AutoscaleError(
                f"autoscale={policy!r} is invalid: accepted values are "
                f"{list(AUTOSCALE_POLICIES)}")
        self.policy = policy
        self.max_batch = int(max_batch)
        self.granted = int(granted)
        self.n_axes = int(n_axes)
        self.base_ladder = tuple(int(b) for b in base_ladder)
        # The pre-traffic decision IS the static plan configuration —
        # autoscale="off" never leaves it.
        self.decision = AutoscaleDecision(self.granted, self.max_batch,
                                          self.base_ladder, 0)
        self.streak = 0
        self.telemetry: Optional[FlushTelemetry] = None

    def observe(self, snap: QueueSnapshot) -> AutoscaleDecision:
        """One flush boundary: fold the snapshot into the controller
        state and return the decision the flush must execute."""
        if self.policy == "off":
            return self.decision
        self.decision, self.streak = decide(
            self.policy, snap, max_batch=self.max_batch,
            granted=self.granted, n_axes=self.n_axes,
            base_ladder=self.base_ladder, prev=self.decision,
            streak=self.streak)
        return self.decision

    def record(self, telemetry: FlushTelemetry) -> None:
        """Attach the flush's wall-clock telemetry (observability only;
        see the replay contract)."""
        self.telemetry = telemetry

    # -- checkpoint plumbing (the v3 schema arrays) ---------------------
    def state_arrays(self) -> Dict[str, np.ndarray]:
        d = self.decision
        return {
            "autoscale_state": np.asarray(
                [d.shards, d.batch_size, d.seq, self.streak], np.int64),
            "autoscale_ladder": np.asarray(d.ladder, np.int64),
        }

    def load_state(self, state, ladder) -> None:
        """Adopt a v3 checkpoint's decision state, RECONCILED against
        THIS controller's configuration. The checkpoint may have been
        written under a different plan or mesh (bigger batch ceiling,
        wider shard grant): the batch rung clamps to the current
        ceiling and the shard count is recomputed from the current
        grant (shard count never affects results, so this cannot
        perturb replay — under an unchanged config every
        reconciliation is the identity and the decision sequence still
        replays bitwise). ``off`` ignores the persisted shape
        entirely: off IS the restoring plan's static configuration."""
        s = np.asarray(state, np.int64)
        seq = int(s[2])
        if self.policy == "off":
            self.decision = self.decision._replace(seq=seq)
            self.streak = 0
            return
        batch = min(int(s[1]), self.max_batch)
        self.decision = AutoscaleDecision(
            shards_for(batch, self.granted, self.n_axes), batch,
            tuple(int(b) for b in np.asarray(ladder, np.int64)), seq)
        self.streak = int(s[3])

    def stats(self) -> dict:
        d, t = self.decision, self.telemetry
        return {
            "policy": self.policy,
            "shards": d.shards,
            "batch_size": d.batch_size,
            "ladder": list(d.ladder),
            "decisions": d.seq,
            "granted_shards": self.granted,
            "max_batch": self.max_batch,
            "last_dispatch_us": t.dispatch_us if t else None,
            "last_materialize_us": t.materialize_us if t else None,
            "last_batches": t.batches if t else None,
        }
