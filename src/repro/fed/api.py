"""The ONE federation API: a declarative ``FederationPlan`` + a
``Session`` lifecycle (DESIGN.md §10).

The paper's pitch is one protocol with many deployment modes — a
one-shot round, partial participation, asynchronous cohort arrival, and
post-hoc Theorem 3.2 attachment. This module is the single surface all
of them are configurations of:

  * ``FederationPlan`` — a frozen, validated spec of the problem
    (k / k' / d), the execution topology (``simulated`` vmap,
    ``replicated`` shard_map server, ``sharded`` collective server +
    mesh axes), aggregation semantics (core-count weighting), the async
    fold, and the streaming-serve layer (pad buckets, batch size,
    refresh cadence, fold-slot admission policy, checkpoint path).
    Validation errors name the offending field and the accepted values
    at construction time, never deep inside tracing.
  * ``Session`` — owns the full lifecycle against one plan:
    ``run`` (the one-shot round, dispatched to the right engine path),
    ``fold``/``finalize`` (asynchronous staged arrival),
    ``attach``/``serve``/``submit``/``flush``/``refresh`` (streaming
    Theorem 3.2 attachment with incremental folding), and
    ``save``/``restore`` (checkpointed crash recovery, bitwise replay).

Every legacy entry point (``core.kfed.kfed``, ``kfed_shard_map``,
``fed.engine.run_round``/``run_round_async``,
``fed.stream.AttachService``, ``launch.serve.make_kfed_attach``) is a
thin deprecation shim over this surface with bitwise-identical results
(tests/test_api.py pins that parity on all three topologies).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, List, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import server
from repro.fed import engine as E
from repro.fed.stream import AttachService, StreamConfig, StreamConfigError

__all__ = ["FederationPlan", "PlanError", "RunResult", "Session",
           "SessionError", "TOPOLOGIES", "plan_from_engine_config"]

TOPOLOGIES = ("simulated", "replicated", "sharded")


class PlanError(ValueError):
    """A FederationPlan field failed validation; the message names the
    field and the accepted values."""


class SessionError(RuntimeError):
    """A Session method was called out of lifecycle order (e.g. serve
    before any round finalized)."""


def _bad(fieldname: str, got: Any, accepted: str) -> None:
    raise PlanError(
        f"FederationPlan.{fieldname}={got!r} is invalid: {accepted}")


@dataclass(frozen=True)
class FederationPlan:
    """Declarative spec of a federated clustering deployment.

    Problem:   ``k`` global clusters, ``k_prime`` per-device center cap,
               ``d`` feature dimension.
    Topology:  ``simulated`` (single-host vmap), ``replicated``
               (shard_map, server replicated per chip after one
               all-gather), or ``sharded`` (the server aggregation
               itself sharded); ``mesh_axes`` names the mesh axes the
               federated-device dimension shards over.
    Semantics: ``weight_by_core_counts`` weights the server Lloyd round
               by Algorithm 1 core-set sizes; ``local_kw`` forwards
               Algorithm 1 options.
    Async:     ``fold_capacity`` bounds the staged-arrival fold state
               (default: the device count of the data).
    Streaming: ``capacity`` fold slots admitted by ``fold_policy``
               (``drop`` | ``lru`` | ``weighted_reservoir``,
               ``policy_seed`` keys the reservoir), requests padded into
               ``bucket_sizes`` point buckets and served ``batch_size``
               at a time, tau re-finalized every ``refresh_every`` folds
               (0 = never) with a ``refresh`` swap mode (``sync`` swaps
               tau immediately; ``async`` double-buffers — the standby
               builds while serving continues and the versioned swap
               commits at the next flush boundary), ``serve_axes`` the
               mesh axes the serve plane shards the request batch over
               (None = single host; dispatched by ``Session.attach`` /
               ``serve``/``flush`` exactly like ``topology`` dispatches
               ``run``), ``autoscale`` the load-adaptive serve-plane
               controller (``off`` keeps the static configuration;
               ``latency`` tracks queue depth both ways;
               ``throughput`` holds full batches across single-flush
               dips — ``batch_size`` becomes the ceiling and
               ``serve_axes`` the shard grant, DESIGN.md §12),
               ``serve_dtype`` the fused solve+attach storage precision
               (``f32`` bitwise vs the staged step; ``bf16`` bfloat16
               storage with f32 accumulation, DESIGN.md §13),
               ``checkpoint`` the default save/restore path.
    Drift:     ``drift`` turns the long-running service's online drift
               adaptation on (DESIGN.md §14): ``off`` (default — every
               path bitwise-identical to a plan without the field),
               ``decay`` (each fold slot's weight decays by
               2^(-age/``drift_half_life``), age in requests since its
               fold; fully-decayed slots drop out of refreshes), or
               ``split_merge`` (decay, plus at refresh boundaries up to
               ``drift_max_moves`` centers starved below
               ``drift_retire_frac`` x mean mass are retired and
               re-seeded from the residual reports of centers above
               ``drift_split_factor`` x mean — committed through the
               TauBuffer as one atomic versioned bump, replayed bitwise
               from checkpoints). Under ``weighted_reservoir`` the
               admission key also uses the decayed mass.
    Heads:     ``heads`` turns on cluster-routed personalization serving
               (DESIGN.md §16): each request's Theorem 3.2 label routes
               it through ONE per-cluster head on the serve plane
               (``off`` default — the plane is bitwise-identical to a
               plan without the field; ``linear`` the affine head; any
               ``configs.list_archs()`` name adopts that architecture's
               REDUCED activation/FFN ratio at width ``d``).
               ``head_arch`` picks the block (``ffn`` | ``transformer``
               — the config-flagged attention head), ``head_capacity``
               sizes the per-cluster dispatch queues as a multiple of
               ``batch_size / k`` (overflowed requests still get
               labels, just no prediction). Head params ride checkpoint
               schema v5; ``Session.serve_predict``/``flush_predict``
               return the predictions.
    Encoder:   ``encoder`` turns on the latent-space ingestion stage
               (DESIGN.md §17): devices submit raw ``(n, seq, d)``
               token/patch sequences and the serve plane encodes them
               (pre-norm zoo blocks at width ``d``, masked-mean pooled)
               ahead of the unchanged solve+attach (``off`` default —
               every path bitwise-identical to a plan without the
               field; any ``configs.list_archs()`` name adopts that
               architecture's REDUCED depth/activation/FFN ratio/head
               counts at width ``d``). ``encode_dtype`` picks f32 or
               bf16 storage (f32 accumulation either way);
               ``encode_seq_len`` caps each point's token-sequence
               length (requests bucket over (n, seq) pad rungs).
               Encoder params ride checkpoint schema v6.
    """
    k: int
    k_prime: int
    d: int
    topology: str = "simulated"
    mesh_axes: Tuple[str, ...] = ("data",)
    weight_by_core_counts: bool = False
    local_kw: Mapping[str, Any] = field(default_factory=dict)
    fold_capacity: Optional[int] = None
    capacity: int = 1024
    batch_size: int = 8
    bucket_sizes: Tuple[int, ...] = (64, 256, 1024)
    refresh_every: int = 0
    refresh: str = "sync"
    autoscale: str = "off"
    serve_axes: Optional[Tuple[str, ...]] = None
    fold_reports: bool = True
    fold_policy: str = "drop"
    policy_seed: int = 0
    serve_dtype: str = "f32"
    drift: str = "off"
    drift_half_life: int = 0
    drift_split_factor: float = 2.0
    drift_retire_frac: float = 0.1
    drift_max_moves: int = 1
    heads: str = "off"
    head_capacity: float = 1.25
    head_arch: str = "ffn"
    encoder: str = "off"
    encode_dtype: str = "f32"
    encode_seq_len: int = 64
    checkpoint: Optional[str] = None

    def __post_init__(self):
        # Plan-only fields first; the problem/streaming fields are
        # validated ONCE, by the StreamConfig this plan lowers to
        # (stream.py __post_init__) — no duplicated rule set to drift.
        if self.topology not in TOPOLOGIES:
            _bad("topology", self.topology,
                 f"accepted values are {list(TOPOLOGIES)}")
        if isinstance(self.mesh_axes, str):
            object.__setattr__(self, "mesh_axes", (self.mesh_axes,))
        if (not self.mesh_axes
                or not all(isinstance(a, str) for a in self.mesh_axes)):
            _bad("mesh_axes", self.mesh_axes,
                 "must be a non-empty tuple of mesh axis names, "
                 "e.g. ('data',) or ('data', 'model')")
        if self.fold_capacity is not None and self.fold_capacity < 1:
            _bad("fold_capacity", self.fold_capacity,
                 "must be None (infer the device count) or an int >= 1")
        if isinstance(self.serve_axes, str):
            object.__setattr__(self, "serve_axes", (self.serve_axes,))
        if self.serve_axes is not None and (
                not self.serve_axes
                or not all(isinstance(a, str) for a in self.serve_axes)):
            _bad("serve_axes", self.serve_axes,
                 "must be None (single-host serving) or a non-empty "
                 "tuple of mesh axis names, e.g. ('data',)")
        if not isinstance(self.local_kw, Mapping):
            _bad("local_kw", self.local_kw,
                 "must be a mapping of Algorithm 1 options")
        try:
            self.stream_config()
        except StreamConfigError as e:
            raise PlanError(str(e).replace("StreamConfig.",
                                           "FederationPlan.")) from None

    # ----------------------------------------------- derived configs --
    def engine_config(self) -> E.EngineConfig:
        return E.EngineConfig(
            k=self.k, k_prime=self.k_prime,
            weight_by_core_counts=self.weight_by_core_counts,
            local_kw=dict(self.local_kw))

    def stream_config(self) -> StreamConfig:
        return StreamConfig(
            k=self.k, k_prime=self.k_prime, d=self.d,
            capacity=self.capacity, batch_size=self.batch_size,
            bucket_sizes=tuple(self.bucket_sizes),
            refresh_every=self.refresh_every, refresh=self.refresh,
            autoscale=self.autoscale, fold_reports=self.fold_reports,
            weight_by_core_counts=self.weight_by_core_counts,
            fold_policy=self.fold_policy, policy_seed=self.policy_seed,
            serve_dtype=self.serve_dtype,
            drift=self.drift, drift_half_life=self.drift_half_life,
            drift_split_factor=self.drift_split_factor,
            drift_retire_frac=self.drift_retire_frac,
            drift_max_moves=self.drift_max_moves,
            heads=self.heads, head_capacity=self.head_capacity,
            head_arch=self.head_arch,
            encoder=self.encoder, encode_dtype=self.encode_dtype,
            encode_seq_len=self.encode_seq_len,
            local_kw=dict(self.local_kw))

    def with_options(self, **kw) -> "FederationPlan":
        """A copy of the plan with fields replaced (re-validated)."""
        return replace(self, **kw)


def plan_from_engine_config(cfg: E.EngineConfig, *, d: int,
                            **kw) -> FederationPlan:
    """Lift a legacy ``EngineConfig`` (which never carried ``d``) into a
    plan — the bridge the deprecation shims ride."""
    return FederationPlan(
        k=cfg.k, k_prime=cfg.k_prime, d=int(d),
        weight_by_core_counts=cfg.weight_by_core_counts,
        local_kw=dict(cfg.local_kw), **kw)


class RunResult(NamedTuple):
    """What every topology returns from ``Session.run``/``finalize``.

    ``detail`` is the full engine RoundResult (aggregate, device
    centers, masks, core counts) on the simulated topology; the
    shard_map topologies keep per-device intermediates on-device and
    return None.
    """
    labels: jax.Array          # (Z, n) induced clustering, -1 padded
    tau_centers: jax.Array     # (k, d)
    detail: Optional[E.RoundResult] = None


class Session:
    """One federation lifecycle against one ``FederationPlan``.

    ::

        plan = FederationPlan(k=16, k_prime=4, d=24)
        sess = Session(plan)
        out = sess.run(key, device_data)        # the one-shot round
        labels = sess.attach(late_device_data)  # Theorem 3.2 serving
        sess.save("ck.npz")
        replica = Session.restore("ck.npz", plan)  # bitwise replay

    Async arrival replaces ``run`` with ``fold`` per cohort +
    ``finalize``; the shard_map topologies take the mesh at
    construction. The streaming layer (an ``AttachService`` under the
    hood, reachable as ``session.service``) starts lazily on first
    ``attach``/``serve``/``submit``.
    """

    def __init__(self, plan: FederationPlan, mesh=None, *,
                 seed: int = 0):
        if not isinstance(plan, FederationPlan):
            raise PlanError(f"Session needs a FederationPlan, got "
                            f"{type(plan).__name__}")
        if plan.topology != "simulated":
            if mesh is None:
                raise PlanError(
                    f"FederationPlan.topology={plan.topology!r} needs a "
                    f"mesh: Session(plan, mesh=...)")
            missing = [a for a in plan.mesh_axes if a not in mesh.shape]
            if missing:
                _bad("mesh_axes", tuple(plan.mesh_axes),
                     f"axes {missing} not in the mesh (available: "
                     f"{list(mesh.shape)})")
        if plan.serve_axes is not None:
            # The serve plane shards the request batch axis; validate
            # its mesh mapping NOW, not at the first (lazy) serve —
            # one rule set, owned by the plane.
            from repro.fed.plane import ServePlane, ServePlaneError
            try:
                ServePlane.validate_mesh_axes(
                    mesh, tuple(plan.serve_axes), plan.batch_size)
            except ServePlaneError as e:
                raise PlanError(str(e)) from None
        self.plan = plan
        self.mesh = mesh
        self._seed = int(seed)
        self._round: Optional[E.RoundResult] = None
        self._tau = None
        self._svc: Optional[AttachService] = None
        # async-fold lifecycle
        self._loc = None
        self._fold_w = None
        self._fold_state = None
        self._fold_part = None
        self._fold_cap = None

    # ------------------------------------------------------ one-shot --
    def run(self, key: jax.Array, data: jax.Array, *,
            participation=None, k_valid=None,
            point_mask=None) -> RunResult:
        """The one communication round, dispatched by
        ``plan.topology``. Bitwise identical to the legacy entry point
        of the same topology (kfed / kfed_shard_map).

        ``run`` may be called under ``jax.jit`` (the benchmarks and
        the production dryrun lower it); in that case the session does
        NOT capture the traced round — serve from a concrete run (or
        ``from_round``/``from_tau``) instead.
        """
        self._check_data(data)
        if self.plan.topology == "simulated":
            rr = E.run_round_impl(key, data, self.plan.engine_config(),
                                  participation=participation,
                                  k_valid=k_valid, point_mask=point_mask)
            if not isinstance(rr.labels, jax.core.Tracer):
                self._set_round(rr, rr.agg.tau_centers)
            return RunResult(rr.labels, rr.agg.tau_centers, rr)
        from repro.core.distributed import kfed_shard_map_impl
        labels, tau = kfed_shard_map_impl(
            self.mesh, data, self.plan.k, self.plan.k_prime, key=key,
            axis=tuple(self.plan.mesh_axes), server=self.plan.topology,
            participation=participation,
            weight_by_core_counts=self.plan.weight_by_core_counts,
            k_valid=k_valid, point_mask=point_mask,
            **dict(self.plan.local_kw))
        if not isinstance(labels, jax.core.Tracer):
            self._set_round(None, tau)
        return RunResult(labels, tau, None)

    # ---------------------------------------------------- async fold --
    def begin(self, key: jax.Array, data: jax.Array, *,
              k_valid=None, point_mask=None) -> "Session":
        """Start an asynchronous round: run the local stage
        (Algorithm 1 on every device) and open an empty fold state
        sized ``plan.fold_capacity`` (default: the device count)."""
        if self.plan.topology != "simulated":
            raise SessionError(
                "fold/finalize staged arrival runs on the simulated "
                "topology; shard_map topologies are one-shot run()")
        self._check_data(data)
        cfg = self.plan.engine_config()
        loc = E.local_stage(key, data, cfg, k_valid=k_valid,
                            point_mask=point_mask)
        Z = data.shape[0]
        cap = self.plan.fold_capacity or Z
        self._loc = loc
        self._fold_w = (E.core_weights(loc)
                        if self.plan.weight_by_core_counts else None)
        self._fold_state = server.init_state(
            cap, self.plan.k_prime, data.shape[-1], loc.centers.dtype)
        self._fold_part = jnp.zeros((Z,), bool)
        self._fold_cap = cap
        return self

    def fold(self, cohort, *, key=None, data=None, k_valid=None,
             point_mask=None) -> "Session":
        """Fold one cohort's reports into the staged-arrival state.
        Cohorts may arrive in any order, across any number of calls,
        with idempotent re-delivery. The first call may carry
        ``key``/``data`` instead of an explicit :meth:`begin`."""
        if self._loc is None:
            if key is None or data is None:
                raise SessionError(
                    "first fold() needs key= and data= (or call "
                    "begin(key, data) first)")
            self.begin(key, data, k_valid=k_valid, point_mask=point_mask)
        ids = np.asarray(cohort, np.int64).reshape(-1)
        Z = int(self._fold_part.shape[0])
        if ids.size and (ids.min() < 0 or ids.max() >= Z):
            bad = ids[(ids < 0) | (ids >= Z)]
            raise SessionError(
                f"fold() cohort contains device ids {bad.tolist()} "
                f"outside [0, Z={Z})")
        # Ids past the (optional) fold_capacity bound are served by the
        # round but dropped from the fold state (mode='drop' parity).
        in_cap = ids[ids < self._fold_cap]
        jids = jnp.asarray(in_cap, jnp.int32)
        w = self._fold_w
        self._fold_state = server.aggregate_incremental(
            self._fold_state, jids, self._loc.centers[jids],
            self._loc.center_mask[jids],
            weights=None if w is None else w[jids])
        self._fold_part = self._fold_part.at[jids].set(True)
        return self

    def finalize(self) -> RunResult:
        """Close the staged round: Algorithm 2 over every folded
        report, Theorem 3.2 post-hoc attachment of devices that never
        reported. Bitwise identical to ``run`` with ``participation`` =
        union of the folded cohorts."""
        if self._loc is None:
            raise SessionError("finalize() before any fold()/begin()")
        agg = server.finalize(self._fold_state, self.plan.k,
                              weighted=self.plan.weight_by_core_counts)
        center_labels = server.attach_absent_devices(
            agg.center_labels, self._loc.centers,
            self._loc.center_mask, agg.tau_centers, self._fold_part)
        rr = E._finish(self._loc, agg, center_labels, self._fold_part)
        self._set_round(rr, rr.agg.tau_centers)
        return RunResult(rr.labels, rr.agg.tau_centers, rr)

    # ----------------------------------------------------- streaming --
    @property
    def service(self) -> AttachService:
        """The lazily-started streaming attachment layer (DESIGN.md
        §9). Seeding depends on what the session holds: a simulated
        round seeds tau + the participants' fold reports; a shard_map
        round or :meth:`from_tau` seeds tau ONLY (the per-device
        reports never left the mesh), so a refresh there re-finalizes
        over streamed reports alone; :meth:`restore` resumes the
        checkpointed state."""
        if self._svc is None:
            cfg = self.plan.stream_config()
            if self._round is not None:
                self._svc = AttachService._from_round(
                    self._round, cfg, seed=self._seed, mesh=self.mesh,
                    serve_axes=self.plan.serve_axes)
            elif self._tau is not None:
                if self.plan.refresh_every:
                    import warnings
                    warnings.warn(
                        "Session streaming is seeded with tau centers "
                        "only (shard_map round or from_tau) — "
                        "refresh_every will re-finalize over the "
                        "STREAMED reports alone, without the round's "
                        "device reports. Seed via a simulated round, "
                        "Session.from_round, or set refresh_every=0 "
                        "to keep tau fixed.", UserWarning, stacklevel=3)
                self._svc = AttachService(cfg, self._tau,
                                          seed=self._seed,
                                          mesh=self.mesh,
                                          serve_axes=self.plan.serve_axes)
            else:
                raise SessionError(
                    "streaming needs a finalized round: call run() or "
                    "fold()+finalize() first (or Session.from_tau / "
                    "Session.restore)")
        return self._svc

    @property
    def tau_centers(self):
        """The current retained centers (tracks streaming refreshes)."""
        if self._svc is not None:
            return self._svc.tau
        if self._tau is None:
            raise SessionError("no finalized round yet")
        return self._tau

    def attach(self, data, k_valid: Optional[int] = None) -> np.ndarray:
        """Serve ONE late-joining device (Theorem 3.2): local
        Algorithm 1 solve + O(k'k) nearest-center attachment against
        the cached tau centers. Returns its (n,) point labels."""
        return self.serve([data],
                          None if k_valid is None else [k_valid])[0]

    def serve(self, datas, k_valid=None) -> List[np.ndarray]:
        """Serve a batch of late devices (bucketed/padded, one jitted
        step on the plan's serve plane — single-host, or sharded over
        ``serve_axes``); reports fold by the plan's admission policy."""
        return self.service.serve(datas, k_valid)

    def serve_versioned(self, datas, k_valid=None):
        """Like :meth:`serve`, returning (labels, tau_version) pairs:
        the version identifies exactly which double-buffered tau swap
        each request was served under (DESIGN.md §11)."""
        return self.service.serve_versioned(datas, k_valid)

    def serve_predict(self, datas, k_valid=None):
        """Serve a batch THROUGH the plan's per-cluster heads
        (``plan.heads != "off"``, DESIGN.md §16): one
        ``stream.ServedPrediction`` per input — the
        :meth:`serve_versioned` labels/version plus the routed head's
        pooled prediction, majority-vote cluster, and whether the
        request was routed (vs overflowed its dispatch queue)."""
        return self.service.serve_predict(datas, k_valid)

    def submit(self, data, k_valid: Optional[int] = None) -> int:
        return self.service.submit(data, k_valid)

    def flush(self):
        return self.service.flush()

    def flush_versioned(self):
        """{request_id: (labels, tau_version)} for every pending
        request; a flush boundary is where a staged async refresh
        commits its atomic version bump."""
        return self.service.flush_versioned()

    def flush_predict(self):
        """{request_id: ``stream.ServedPrediction``} for every pending
        request — :meth:`flush_versioned` plus the routed per-cluster
        head predictions (``plan.heads != "off"``, DESIGN.md §16)."""
        return self.service.flush_predict()

    def refresh(self):
        """Re-finalize Algorithm 2 over all folded reports and swap in
        fresh tau centers now (one atomic version bump, regardless of
        the plan's cadence ``refresh`` mode)."""
        return self.service.refresh()

    @property
    def tau_version(self) -> int:
        """The serving layer's current tau version (bumps once per
        committed refresh swap)."""
        return self.service.tau_version

    def stats(self) -> dict:
        """Live serving counters plus the §12 load telemetry: the
        ``"autoscale"`` sub-dict carries the controller's current
        decision (policy, active shards/batch/ladder, decision count)
        and the last flush's two-phase dispatch/materialize latency,
        and ``"plane_compiles"`` the serve plane's compiled-signature
        count (flat in steady state)."""
        return self.service.stats()

    def attach_fn(self):
        """A jitted ``(key, device_data) -> point labels`` closure over
        the CURRENT tau centers — the single-device serving path the
        legacy ``launch.serve.make_kfed_attach`` is a shim of. Runs the
        same fused solve+attach as the serve plane (DESIGN.md §13), so
        ``plan.serve_dtype`` applies here too."""
        from repro.core.lloyd import lloyd_attach
        from repro.core.local_kmeans import local_prepare, split_local_kw
        tau = jnp.asarray(self.tau_centers)
        kp = self.plan.k_prime
        prep_kw, max_iters = split_local_kw(dict(self.plan.local_kw))
        serve_dtype = self.plan.serve_dtype

        def attach(key, device_data):
            prep = local_prepare(key, device_data, k_max=kp, **prep_kw)
            labels, _, _, _ = lloyd_attach(
                device_data[None], prep.theta[None], tau,
                center_mask=prep.center_mask[None],
                max_iters=max_iters, serve_dtype=serve_dtype)
            return labels[0]

        return jax.jit(attach)

    # ---------------------------------------------------- checkpoint --
    def save(self, path: Optional[str] = None) -> str:
        """Checkpoint the serving state (tau, fold state, counters,
        admission-policy state). ``path`` defaults to
        ``plan.checkpoint``."""
        path = path or self.plan.checkpoint
        if not path:
            raise SessionError(
                "save() needs a path (or set FederationPlan.checkpoint)")
        return self.service.save(path)

    @classmethod
    def restore(cls, path: str, plan: FederationPlan, mesh=None, *,
                seed: int = 0) -> "Session":
        """Rebuild a session from a checkpoint; restore + serve is
        bitwise identical to the uninterrupted session."""
        sess = cls(plan, mesh, seed=seed)
        sess._svc = AttachService._restore(path, plan.stream_config(),
                                           mesh=mesh,
                                           serve_axes=plan.serve_axes)
        sess._tau = sess._svc.tau
        return sess

    @classmethod
    def from_round(cls, plan: FederationPlan, round_result: E.RoundResult,
                   mesh=None, *, seed: int = 0) -> "Session":
        """A session whose serving layer is seeded from an
        already-finished round (tau centers + participants' fold
        reports) — e.g. to serve one round under several streaming
        plans, or a round finalized by another process."""
        sess = cls(plan, mesh, seed=seed)
        sess._round = round_result
        sess._tau = round_result.agg.tau_centers
        return sess

    @classmethod
    def from_tau(cls, plan: FederationPlan, tau_centers, mesh=None, *,
                 seed: int = 0) -> "Session":
        """A serving-only session seeded with retained tau centers from
        a round finalized elsewhere (e.g. on another host)."""
        sess = cls(plan, mesh, seed=seed)
        sess._tau = jnp.asarray(tau_centers)
        return sess

    # ------------------------------------------------------- helpers --
    def _set_round(self, rr, tau) -> None:
        """Adopt a newly finalized round: any serving layer built from
        a PREVIOUS round is invalidated so attach/serve never answer
        against stale tau centers."""
        self._round, self._tau = rr, tau
        self._svc = None

    def _check_data(self, data) -> None:
        if data.ndim != 3:
            raise PlanError(
                f"device data must be (Z, n, d), got shape "
                f"{tuple(data.shape)}")
        if int(data.shape[-1]) != self.plan.d:
            raise PlanError(
                f"device data feature dim {int(data.shape[-1])} != "
                f"FederationPlan.d={self.plan.d}")
