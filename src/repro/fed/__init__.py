from repro.fed.engine import (  # noqa: F401
    EngineConfig,
    RoundResult,
    run_round,
    run_round_async,
)
from repro.fed.api import (  # noqa: F401
    FederationPlan,
    PlanError,
    RunResult,
    Session,
    SessionError,
)
from repro.fed.autoscale import (  # noqa: F401
    AUTOSCALE_POLICIES,
    AutoscaleController,
    AutoscaleDecision,
    QueueSnapshot,
)
from repro.fed.plane import ServePlane, TauBuffer  # noqa: F401
from repro.fed.policy import (  # noqa: F401
    FoldPolicy,
    POLICIES,
    make_policy,
)
from repro.fed.fedavg import FedAvgConfig, fedavg_round, make_local_step  # noqa
from repro.fed.ifca import ifca_round  # noqa: F401
from repro.fed.personalize import kfed_personalize  # noqa: F401
from repro.fed.selection import kfed_pow_d, pow_d, random_selection  # noqa
from repro.fed.stream import AttachService, StreamConfig  # noqa: F401
