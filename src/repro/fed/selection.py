"""Client selection (Section 4.2.2, Figure 4): random sampling, pow-d
(power-of-choice, Cho et al. 2020), and k-FED-filtered pow-d, which drops
redundant same-cluster candidates before the loss-based pick."""
from __future__ import annotations

import numpy as np


def random_selection(rng: np.random.Generator, Z: int, m: int):
    return rng.choice(Z, size=min(m, Z), replace=False)


def pow_d(rng: np.random.Generator, losses: np.ndarray, m: int, d: int):
    """Sample d candidates uniformly, keep the m with largest local loss."""
    Z = len(losses)
    cand = rng.choice(Z, size=min(d, Z), replace=False)
    order = cand[np.argsort(-losses[cand])]
    return order[:m]


def kfed_pow_d(rng: np.random.Generator, losses: np.ndarray,
               clusters: np.ndarray, m: int, d: int):
    """pow-d with k-FED cluster filtering: among the d candidates, keep at
    most one device per k-FED cluster (the highest-loss one), then the
    top-m by loss; refill from remaining candidates if short."""
    Z = len(losses)
    cand = rng.choice(Z, size=min(d, Z), replace=False)
    order = cand[np.argsort(-losses[cand])]
    seen, picked = set(), []
    for z in order:
        c = int(clusters[z])
        if c not in seen:
            seen.add(c)
            picked.append(z)
        if len(picked) == m:
            return np.asarray(picked)
    for z in order:          # refill with duplicates if clusters < m
        if z not in picked:
            picked.append(z)
        if len(picked) == m:
            break
    return np.asarray(picked[:m])
