"""Streaming post-round attachment service (DESIGN.md §9).

Everything after the one communication round: a finalized k-FED round
leaves k tau centers, and from then on the paper's Theorem 3.2 promises
O(k'k) attachment of any late-joining device with zero extra rounds.
This module turns that promise into a serving layer:

  * **batching** — heterogeneous ``(n^(z), k^(z))`` attach requests are
    bucketed by padded point count, padded into fixed ``(B, n_pad, d)``
    shapes with point masks, and served by ONE jitted step that vmaps
    the Algorithm 1 local solve over the request batch and attaches via
    the Theorem 3.2 nearest-center rule;
  * **online refresh** — each served report (Theta, mask, |S_r|) can be
    folded into the incremental server state
    (``server.aggregate_incremental``), and on a configurable cadence
    the round is re-finalized so the cached tau centers track the
    population (the membership-update problem of Holzer et al. 2023 /
    Garst & Reinders 2023), still with one uplink per device ever;
  * **crash recovery** — the full service state (tau centers, fold
    state, counters, key seed) checkpoints through
    ``checkpoint/store.py``; restore + serve is bitwise identical to
    the uninterrupted service because request keys are derived from the
    persisted request-id counter, never from wall clock.

Fold-slot admission is a pluggable ``FoldPolicy`` (``fed/policy.py``):
``drop`` (slot == request id, over-capacity ids served-not-folded — the
historical behavior), ``lru`` (evict the least-recently-folded slot),
or ``weighted_reservoir`` (A-ES sampling by report mass). Eviction is a
slot overwrite, so ``server.aggregate_incremental`` stays the single
fold primitive. In-flight (submitted, unflushed) requests are NOT part
of a checkpoint — clients re-submit on failover.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import load_pytree, save_pytree
from repro.core import server
from repro.core.local_kmeans import batched_local_kmeans
from repro.fed.policy import FoldPolicy, make_policy
from repro.utils.deprecation import warn_legacy


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


class StreamConfigError(ValueError):
    """A StreamConfig field failed validation (named, with accepted
    values) — raised at construction, never deep inside tracing."""


def _bad(fieldname: str, got, accepted: str) -> None:
    raise StreamConfigError(
        f"StreamConfig.{fieldname}={got!r} is invalid: {accepted}")


@dataclass(frozen=True)
class StreamConfig:
    """Static configuration of the attachment service."""
    k: int                      # global cluster count of the round
    k_prime: int                # per-request k^(z) cap (static pad)
    d: int                      # feature dimension
    capacity: int               # fold-state slots (device ids)
    batch_size: int = 8         # requests per jitted serve step
    bucket_sizes: Tuple[int, ...] = (64, 256, 1024)  # n^(z) pad buckets
    refresh_every: int = 0      # re-finalize after this many folds; 0 = never
    fold_reports: bool = True   # fold served reports into the server state
    weight_by_core_counts: bool = False
    fold_policy: str = "drop"   # admission: drop | lru | weighted_reservoir
    policy_seed: int = 0        # weighted_reservoir key seed
    local_kw: dict = field(default_factory=dict)  # Algorithm 1 options

    def __post_init__(self):
        from repro.fed.policy import POLICIES
        if not isinstance(self.k, int) or self.k < 1:
            _bad("k", self.k, "must be an int >= 1")
        if (not isinstance(self.k_prime, int)
                or not 1 <= self.k_prime <= self.k):
            _bad("k_prime", self.k_prime,
                 f"must satisfy 1 <= k_prime <= k (k={self.k})")
        if not isinstance(self.d, int) or self.d < 1:
            _bad("d", self.d, "must be an int >= 1")
        if self.capacity < 1:
            _bad("capacity", self.capacity, "must be an int >= 1")
        if self.batch_size < 1:
            _bad("batch_size", self.batch_size, "must be an int >= 1")
        if self.refresh_every < 0:
            _bad("refresh_every", self.refresh_every,
                 "must be >= 0 (0 disables the refresh cadence)")
        if (not self.bucket_sizes
                or any(int(b) < 1 for b in self.bucket_sizes)
                or list(self.bucket_sizes)
                != sorted(set(int(b) for b in self.bucket_sizes))):
            _bad("bucket_sizes", self.bucket_sizes,
                 "must be a non-empty strictly ascending tuple of "
                 "positive point-count pads, e.g. (64, 256, 1024)")
        if self.fold_policy not in POLICIES:
            _bad("fold_policy", self.fold_policy,
                 f"accepted values are {sorted(POLICIES)}")
        if not isinstance(self.policy_seed, int) or self.policy_seed < 0:
            _bad("policy_seed", self.policy_seed,
                 "must be a non-negative int (seeds the "
                 "weighted_reservoir keys)")


class AttachService:
    """Serves batches of late-joining devices against a finalized round.

    Construct with :meth:`from_round` (seeds the fold state with the
    round's own reports) or :meth:`restore` (from a checkpoint).
    """

    def __init__(self, cfg: StreamConfig, tau_centers, *,
                 state: Optional[server.ServerState] = None,
                 policy: Optional[FoldPolicy] = None,
                 seed: int = 0, next_id: int = 0,
                 since_refresh: int = 0, served_devices: int = 0,
                 served_points: int = 0):
        self.cfg = cfg
        self.tau = jnp.asarray(tau_centers, jnp.float32)
        assert self.tau.shape == (cfg.k, cfg.d), self.tau.shape
        self.state = (server.init_state(cfg.capacity, cfg.k_prime, cfg.d)
                      if state is None
                      else jax.tree.map(jnp.asarray, state))
        self.policy = policy or make_policy(cfg.fold_policy, cfg.capacity,
                                            seed=cfg.policy_seed)
        self._base_seed = int(seed)
        self._base_key = jax.random.PRNGKey(self._base_seed)
        self._next_id = int(next_id)
        self._since_refresh = int(since_refresh)
        self._served_devices = int(served_devices)
        self._served_points = int(served_points)
        self._pending: List[Tuple[int, np.ndarray, int]] = []
        self._done: Dict[int, np.ndarray] = {}  # served, not yet delivered
        self._step = jax.jit(self._make_step())

    # ------------------------------------------------------------- build --

    @classmethod
    def from_round(cls, rr, cfg: StreamConfig, *,
                   seed: int = 0) -> "AttachService":
        """Deprecated: construct a ``fed.api.Session`` and use
        ``Session.attach``/``Session.serve`` instead."""
        warn_legacy("fed.stream.AttachService.from_round",
                    "Session.attach/Session.serve")
        return cls._from_round(rr, cfg, seed=seed)

    @classmethod
    def _from_round(cls, rr, cfg: StreamConfig, *,
                    seed: int = 0) -> "AttachService":
        """Seed the service from a finished round result: cache its tau
        centers and fold the participating devices' reports so a later
        refresh re-finalizes over round + streamed devices."""
        Z = int(rr.device_centers.shape[0])
        if cfg.fold_policy == "drop":
            assert cfg.capacity >= Z, (cfg.capacity, Z)
        svc = cls(cfg, rr.agg.tau_centers, seed=seed, next_id=Z)
        if cfg.fold_reports:
            ids = np.nonzero(np.asarray(rr.participated))[0]
            if ids.size:
                cw = server.core_weights(rr.core_counts[ids])
                dev_w = (np.asarray(jnp.sum(cw, axis=1))
                         if svc.policy.needs_weight else None)
                svc._admit_and_fold(
                    ids, dev_w, rr.device_centers[ids],
                    rr.center_mask[ids],
                    cw if cfg.weight_by_core_counts else None)
        return svc

    def _make_step(self):
        cfg = self.cfg

        def step(tau, keys, data, point_mask, k_valid):
            loc = batched_local_kmeans(keys, data, k_max=cfg.k_prime,
                                       k_valid=k_valid,
                                       point_mask=point_mask,
                                       **cfg.local_kw)
            ctr = jax.vmap(
                lambda c, m: server.assign_new_device(c, m, tau))(
                    loc.centers, loc.center_mask)
            labels = server.induced_labels(ctr, loc.assign)
            return (labels, loc.centers, loc.center_mask,
                    server.core_weights(loc.core_counts))

        return step

    # ------------------------------------------------------------- serve --

    def submit(self, data, k_valid: Optional[int] = None) -> int:
        """Enqueue one device's (n, d) data; returns its request id (the
        fold slot, and the PRNG stream of its local solve)."""
        arr = np.asarray(data, np.float32)
        assert arr.ndim == 2 and arr.shape[1] == self.cfg.d, arr.shape
        kv = self.cfg.k_prime if k_valid is None else int(k_valid)
        assert 1 <= kv <= self.cfg.k_prime, kv
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, arr, kv))
        return rid

    def _bucket(self, n: int) -> int:
        for b in self.cfg.bucket_sizes:
            if n <= b:
                return b
        return _round_up(n, self.cfg.bucket_sizes[-1])

    def flush(self) -> Dict[int, np.ndarray]:
        """Serve every pending request; returns {request_id: (n,) labels}.

        Requests are grouped by pad bucket and served in fixed
        (batch_size, n_pad, d) shapes — short batches pad by repeating
        the last real request (discarded). Served reports fold into the
        incremental server state, triggering a refresh on cadence.
        """
        pending, self._pending = self._pending, []
        buckets: Dict[int, list] = {}
        for item in pending:
            buckets.setdefault(self._bucket(item[1].shape[0]), []).append(
                item)
        out, self._done = self._done, {}  # undelivered earlier results
        try:
            for n_pad in sorted(buckets):
                group = buckets[n_pad]
                B = self.cfg.batch_size
                for lo in range(0, len(group), B):
                    self._serve_batch(group[lo:lo + B], n_pad, out)
        except BaseException:
            # A failed batch must not lose work: computed results go
            # back to the undelivered buffer, unserved requests requeue.
            self._done.update(out)
            self._pending = [it for it in pending
                             if it[0] not in out] + self._pending
            raise
        return out

    def serve(self, datas, k_valid=None) -> List[np.ndarray]:
        """Submit + flush convenience: one labels array per input.
        Results of OTHER requests already pending stay queued for the
        next :meth:`flush`."""
        kvs = ([None] * len(datas) if k_valid is None else list(k_valid))
        assert len(kvs) == len(datas), (len(kvs), len(datas))
        rids = [self.submit(d, kv) for d, kv in zip(datas, kvs)]
        got = self.flush()
        mine = [got.pop(r) for r in rids]
        self._done.update(got)
        return mine

    def _serve_batch(self, batch, n_pad: int, out: Dict[int, np.ndarray]):
        cfg = self.cfg
        B = cfg.batch_size
        data = np.zeros((B, n_pad, cfg.d), np.float32)
        pmask = np.zeros((B, n_pad), bool)
        kv = np.full((B,), cfg.k_prime, np.int32)
        rids = np.zeros((B,), np.int64)
        for i in range(B):
            rid, arr, k_valid = batch[min(i, len(batch) - 1)]  # pad=repeat
            n = arr.shape[0]
            data[i, :n] = arr
            pmask[i, :n] = True
            kv[i] = k_valid
            rids[i] = rid
        keys = jax.vmap(lambda r: jax.random.fold_in(self._base_key, r))(
            jnp.asarray(rids, jnp.uint32))
        labels, centers, cmask, weights = self._step(
            self.tau, keys, jnp.asarray(data), jnp.asarray(pmask),
            jnp.asarray(kv))
        labels = np.asarray(labels)
        for i, (rid, arr, _) in enumerate(batch):
            out[rid] = labels[i, :arr.shape[0]]
            self._served_devices += 1
            self._served_points += arr.shape[0]
        if cfg.fold_reports:
            self._fold(batch, rids, centers, cmask, weights)

    def _admit_and_fold(self, rids, dev_w, centers, cmask,
                        fold_w) -> int:
        """THE admission step shared by round seeding and streaming:
        each request id goes through the policy, the admitted reports
        scatter into their granted slots (a later admit within the
        group may evict an earlier one's slot — last write wins), and
        ``server.aggregate_incremental`` stays the single fold
        primitive. Returns the number of admitted reports."""
        admitted, slot_of = 0, {}
        for i, rid in enumerate(rids):
            slot = self.policy.admit(
                int(rid), 1.0 if dev_w is None else float(dev_w[i]))
            if slot is not None:
                admitted += 1
                slot_of[slot] = i
        if slot_of:
            items = sorted(slot_of.items(), key=lambda kv: kv[1])
            sel = jnp.asarray([i for _, i in items], jnp.int32)
            slots = jnp.asarray([s for s, _ in items], jnp.int32)
            self.state = server.aggregate_incremental(
                self.state, slots, centers[sel], cmask[sel],
                weights=None if fold_w is None else fold_w[sel])
        return admitted

    def _fold(self, batch, rids, centers, cmask, weights):
        dev_w = (np.asarray(jnp.sum(weights, axis=1))
                 if self.policy.needs_weight else None)
        admitted = self._admit_and_fold(
            rids[:len(batch)], dev_w, centers, cmask,
            weights if self.cfg.weight_by_core_counts else None)
        if not admitted:
            return
        self._since_refresh += admitted
        if self.cfg.refresh_every and (
                self._since_refresh >= self.cfg.refresh_every):
            self.refresh()

    # ----------------------------------------------------------- refresh --

    def refresh(self) -> server.KFedAggregate:
        """Re-finalize Algorithm 2 over every folded report (round
        devices + streamed attachments) and swap in the new tau centers.
        tau is a traced argument of the serve step, so no recompile."""
        agg = server.finalize(self.state, self.cfg.k,
                              weighted=self.cfg.weight_by_core_counts)
        self.tau = jnp.asarray(agg.tau_centers, jnp.float32)
        self._since_refresh = 0
        return agg

    # -------------------------------------------------------- checkpoint --

    def _counters(self) -> np.ndarray:
        return np.asarray([self._next_id, self._since_refresh,
                           self._served_devices, self._served_points,
                           self._base_seed], np.int64)

    def save(self, path: str) -> str:
        """Checkpoint tau + fold state + counters + admission-policy
        identity and state (npz via ``checkpoint.store``). Pending
        requests are not persisted."""
        from repro.fed.policy import POLICY_IDS
        return save_pytree(path, {"tau": self.tau, "server": self.state,
                                  "counters": self._counters(),
                                  "policy_id": np.asarray(
                                      POLICY_IDS[self.policy.name],
                                      np.int64),
                                  "policy": self.policy.state_arrays()})

    @classmethod
    def restore(cls, path: str, cfg: StreamConfig) -> "AttachService":
        """Deprecated: use ``fed.api.Session.restore`` instead."""
        warn_legacy("fed.stream.AttachService.restore", "Session.restore")
        return cls._restore(path, cfg)

    @classmethod
    def _restore(cls, path: str, cfg: StreamConfig) -> "AttachService":
        from repro.fed.policy import POLICY_IDS
        policy = make_policy(cfg.fold_policy, cfg.capacity,
                             seed=cfg.policy_seed)
        # Refuse a policy mismatch up front (named error, not a bare
        # KeyError / silent state corruption): the checkpoint's slot
        # bookkeeping is only meaningful under the policy that wrote
        # it. Checkpoints from before the policy layer existed could
        # only have been written under the drop rule.
        data = np.load(path if path.endswith(".npz") else path + ".npz")
        saved = (int(data["policy_id"]) if "policy_id" in data
                 else POLICY_IDS["drop"])
        if saved != POLICY_IDS[cfg.fold_policy]:
            names = {v: n for n, v in POLICY_IDS.items()}
            raise StreamConfigError(
                f"StreamConfig.fold_policy={cfg.fold_policy!r} does not "
                f"match the checkpoint at {path!r}, which was saved "
                f"under fold_policy={names.get(saved, saved)!r}")
        like = {
            "tau": jnp.zeros((cfg.k, cfg.d), jnp.float32),
            "server": server.init_state(cfg.capacity, cfg.k_prime, cfg.d),
            "counters": np.zeros((5,), np.int64),
            "policy": policy.state_like(),
        }
        if "policy_id" in data:
            like["policy_id"] = np.zeros((), np.int64)
        tree = load_pytree(path, like)
        if tree["policy"]:
            policy.load_state(tree["policy"])
        cnt = np.asarray(tree["counters"])
        return cls(cfg, tree["tau"], state=tree["server"], policy=policy,
                   seed=int(cnt[4]), next_id=int(cnt[0]),
                   since_refresh=int(cnt[1]), served_devices=int(cnt[2]),
                   served_points=int(cnt[3]))

    # ------------------------------------------------------------- stats --

    def stats(self) -> dict:
        return {
            "served_devices": self._served_devices,
            "served_points": self._served_points,
            "folded": int(np.asarray(jnp.sum(self.state.received))),
            "capacity": self.cfg.capacity,
            "fold_policy": self.policy.name,
            "pending": len(self._pending),
            "undelivered": len(self._done),
            "since_refresh": self._since_refresh,
        }
