"""Streaming post-round attachment service (DESIGN.md §9, §11).

Everything after the one communication round: a finalized k-FED round
leaves k tau centers, and from then on the paper's Theorem 3.2 promises
O(k'k) attachment of any late-joining device with zero extra rounds.
This module turns that promise into a serving layer:

  * **batching** — heterogeneous ``(n^(z), k^(z))`` attach requests are
    bucketed by padded point count, padded into fixed ``(B, n_pad, d)``
    shapes with point masks, and served by ONE jitted step that vmaps
    the Algorithm 1 local solve over the request batch and attaches via
    the Theorem 3.2 nearest-center rule. The step (and the fold
    scatter) execute on a ``fed/plane.ServePlane`` — single-host by
    default, shard_mapped over the plan's ``serve_axes`` mesh axes when
    set (the request batch axis is embarrassingly parallel; tau and the
    fold state stay replicated);
  * **online refresh** — each served report (Theta, mask, |S_r|) can be
    folded into the incremental server state
    (``server.aggregate_incremental``), and on a configurable cadence
    the round is re-finalized so the cached tau centers track the
    population (the membership-update problem of Holzer et al. 2023 /
    Garst & Reinders 2023), still with one uplink per device ever. tau
    is double-buffered and versioned (``fed/plane.TauBuffer``):
    ``refresh="sync"`` swaps immediately between batches, while
    ``refresh="async"`` builds the standby buffer without interrupting
    serving and commits the swap — one atomic version bump — at the
    next flush boundary. Every served label records the tau version
    that produced it;
  * **crash recovery** — the full service state (both tau buffers +
    version, fold state, counters, key seed) checkpoints through
    ``checkpoint/store.py``; restore + serve is bitwise identical to
    the uninterrupted service — including mid-refresh-window version
    assignments — because request keys are derived from the persisted
    request-id counter, never from wall clock.

Fold-slot admission is a pluggable ``FoldPolicy`` (``fed/policy.py``):
``drop`` (slot == request id, over-capacity ids served-not-folded — the
historical behavior), ``lru`` (evict the least-recently-folded slot),
or ``weighted_reservoir`` (A-ES sampling by report mass). Admission is
host-side and shard-deterministic (``FoldPolicy.admit_batch``);
eviction is a slot overwrite, so ``server.aggregate_incremental`` stays
the single fold primitive (the sharded plane runs its collective
sibling ``aggregate_incremental_sharded`` — bitwise the same state).
In-flight (submitted, unflushed) requests are NOT part of a checkpoint
— clients re-submit on failover.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import load_pytree, npz_keys, save_pytree
from repro.core import server
from repro.fed.plane import ServePlane, ServePlaneError, TauBuffer
from repro.fed.policy import FoldPolicy, make_policy
from repro.utils.deprecation import warn_legacy

REFRESH_MODES = ("sync", "async")


class StreamConfigError(ValueError):
    """A StreamConfig field failed validation (named, with accepted
    values) — raised at construction, never deep inside tracing."""


def _bad(fieldname: str, got, accepted: str) -> None:
    raise StreamConfigError(
        f"StreamConfig.{fieldname}={got!r} is invalid: {accepted}")


@dataclass(frozen=True)
class StreamConfig:
    """Static configuration of the attachment service."""
    k: int                      # global cluster count of the round
    k_prime: int                # per-request k^(z) cap (static pad)
    d: int                      # feature dimension
    capacity: int               # fold-state slots (device ids)
    batch_size: int = 8         # requests per jitted serve step
    bucket_sizes: Tuple[int, ...] = (64, 256, 1024)  # n^(z) pad buckets
    refresh_every: int = 0      # re-finalize after this many folds; 0 = never
    refresh: str = "sync"       # tau swap: sync (immediate) | async
    fold_reports: bool = True   # fold served reports into the server state
    weight_by_core_counts: bool = False
    fold_policy: str = "drop"   # admission: drop | lru | weighted_reservoir
    policy_seed: int = 0        # weighted_reservoir key seed
    local_kw: dict = field(default_factory=dict)  # Algorithm 1 options

    def __post_init__(self):
        from repro.fed.policy import POLICIES
        if not isinstance(self.k, int) or self.k < 1:
            _bad("k", self.k, "must be an int >= 1")
        if (not isinstance(self.k_prime, int)
                or not 1 <= self.k_prime <= self.k):
            _bad("k_prime", self.k_prime,
                 f"must satisfy 1 <= k_prime <= k (k={self.k})")
        if not isinstance(self.d, int) or self.d < 1:
            _bad("d", self.d, "must be an int >= 1")
        if self.capacity < 1:
            _bad("capacity", self.capacity, "must be an int >= 1")
        if self.batch_size < 1:
            _bad("batch_size", self.batch_size, "must be an int >= 1")
        if self.refresh_every < 0:
            _bad("refresh_every", self.refresh_every,
                 "must be >= 0 (0 disables the refresh cadence)")
        if self.refresh not in REFRESH_MODES:
            _bad("refresh", self.refresh,
                 f"accepted values are {list(REFRESH_MODES)}")
        if (not self.bucket_sizes
                or any(int(b) < 1 for b in self.bucket_sizes)
                or list(self.bucket_sizes)
                != sorted(set(int(b) for b in self.bucket_sizes))):
            _bad("bucket_sizes", self.bucket_sizes,
                 "must be a non-empty strictly ascending tuple of "
                 "positive point-count pads, e.g. (64, 256, 1024)")
        if self.fold_policy not in POLICIES:
            _bad("fold_policy", self.fold_policy,
                 f"accepted values are {sorted(POLICIES)}")
        if not isinstance(self.policy_seed, int) or self.policy_seed < 0:
            _bad("policy_seed", self.policy_seed,
                 "must be a non-negative int (seeds the "
                 "weighted_reservoir keys)")


class AttachService:
    """Serves batches of late-joining devices against a finalized round.

    Construct with :meth:`from_round` (seeds the fold state with the
    round's own reports) or :meth:`restore` (from a checkpoint). Pass
    ``mesh`` + ``serve_axes`` to run the hot path on the sharded serve
    plane (DESIGN.md §11) — per-request labels are bitwise identical to
    the single-host plane for a fixed tau version.
    """

    def __init__(self, cfg: StreamConfig, tau_centers, *,
                 state: Optional[server.ServerState] = None,
                 policy: Optional[FoldPolicy] = None,
                 seed: int = 0, next_id: int = 0,
                 since_refresh: int = 0, served_devices: int = 0,
                 served_points: int = 0, mesh=None, serve_axes=None,
                 tau_buffer: Optional[TauBuffer] = None):
        self.cfg = cfg
        try:
            self.plane = ServePlane(cfg, mesh=mesh, serve_axes=serve_axes)
        except ServePlaneError as e:
            raise StreamConfigError(str(e)) from None
        self._taubuf = (tau_buffer if tau_buffer is not None
                        else TauBuffer.fresh(tau_centers))
        assert self._taubuf.bufs.shape == (2, cfg.k, cfg.d), \
            self._taubuf.bufs.shape
        self.state = (server.init_state(cfg.capacity, cfg.k_prime, cfg.d)
                      if state is None
                      else jax.tree.map(jnp.asarray, state))
        self.policy = policy or make_policy(cfg.fold_policy, cfg.capacity,
                                            seed=cfg.policy_seed)
        self._base_seed = int(seed)
        self._base_key = jax.random.PRNGKey(self._base_seed)
        self._next_id = int(next_id)
        self._since_refresh = int(since_refresh)
        self._served_devices = int(served_devices)
        self._served_points = int(served_points)
        self._pending: List[Tuple[int, np.ndarray, int]] = []
        # served, not yet delivered: rid -> (labels, tau version)
        self._done: Dict[int, Tuple[np.ndarray, int]] = {}
        self._oversized_warned = False

    # ------------------------------------------------------------- build --

    @classmethod
    def from_round(cls, rr, cfg: StreamConfig, *,
                   seed: int = 0) -> "AttachService":
        """Deprecated: construct a ``fed.api.Session`` and use
        ``Session.attach``/``Session.serve`` instead."""
        warn_legacy("fed.stream.AttachService.from_round",
                    "Session.attach/Session.serve")
        return cls._from_round(rr, cfg, seed=seed)

    @classmethod
    def _from_round(cls, rr, cfg: StreamConfig, *, seed: int = 0,
                    mesh=None, serve_axes=None) -> "AttachService":
        """Seed the service from a finished round result: cache its tau
        centers and fold the participating devices' reports so a later
        refresh re-finalizes over round + streamed devices."""
        Z = int(rr.device_centers.shape[0])
        if cfg.fold_policy == "drop":
            assert cfg.capacity >= Z, (cfg.capacity, Z)
        svc = cls(cfg, rr.agg.tau_centers, seed=seed, next_id=Z,
                  mesh=mesh, serve_axes=serve_axes)
        if cfg.fold_reports:
            ids = np.nonzero(np.asarray(rr.participated))[0]
            if ids.size:
                cw = server.core_weights(rr.core_counts[ids])
                dev_w = (np.asarray(jnp.sum(cw, axis=1))
                         if svc.policy.needs_weight else None)
                svc._admit_and_fold(
                    ids, dev_w, rr.device_centers[ids],
                    rr.center_mask[ids],
                    cw if cfg.weight_by_core_counts else None)
        return svc

    # ------------------------------------------------------------- serve --

    @property
    def tau(self) -> jax.Array:
        """The ACTIVE tau buffer (what the serve step reads)."""
        return self._taubuf.tau

    @property
    def tau_version(self) -> int:
        return self._taubuf.version

    def submit(self, data, k_valid: Optional[int] = None) -> int:
        """Enqueue one device's (n, d) data; returns its request id (the
        fold slot, and the PRNG stream of its local solve)."""
        arr = np.asarray(data, np.float32)
        assert arr.ndim == 2 and arr.shape[1] == self.cfg.d, arr.shape
        kv = self.cfg.k_prime if k_valid is None else int(k_valid)
        assert 1 <= kv <= self.cfg.k_prime, kv
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, arr, kv))
        return rid

    def _bucket(self, n: int) -> int:
        for b in self.cfg.bucket_sizes:
            if n <= b:
                return b
        # Above the ladder: geometric (doubling) buckets bound the
        # number of distinct jitted pad shapes to O(log n_max / top)
        # instead of one recompile per distinct rounded-up n.
        b = self.cfg.bucket_sizes[-1]
        while b < n:
            b *= 2
        if not self._oversized_warned:
            self._oversized_warned = True
            warnings.warn(
                f"attach request with n={n} points exceeds the largest "
                f"configured bucket ({self.cfg.bucket_sizes[-1]}); "
                f"padding to a geometric bucket of {b}. Add larger "
                f"bucket_sizes to the plan to avoid oversized pads.",
                UserWarning, stacklevel=3)
        return b

    def flush(self) -> Dict[int, np.ndarray]:
        """Serve every pending request; returns {request_id: (n,) labels}.
        See :meth:`flush_versioned` for the tau version each request was
        served under."""
        return {rid: lbl
                for rid, (lbl, _) in self.flush_versioned().items()}

    def flush_versioned(self) -> Dict[int, Tuple[np.ndarray, int]]:
        """Serve every pending request; returns
        {request_id: ((n,) labels, tau_version)}.

        Requests are grouped by pad bucket and served in fixed
        (batch_size, n_pad, d) shapes — short batches pad by repeating
        the last real request (discarded). Served reports fold into the
        incremental server state, triggering a refresh on cadence. A
        flush boundary is where a staged async tau swap commits, so
        every request in one flush-and-refresh window maps to exactly
        one tau version.
        """
        if self._taubuf.pending:
            self._taubuf = self._taubuf.commit()
        pending, self._pending = self._pending, []
        buckets: Dict[int, list] = {}
        for item in pending:
            buckets.setdefault(self._bucket(item[1].shape[0]), []).append(
                item)
        out, self._done = self._done, {}  # undelivered earlier results
        # Two-phase pipeline: phase 1 DISPATCHES every batch (serve
        # step, fold scatter, staged refresh — all asynchronous, chained
        # by dataflow), phase 2 materializes labels on host. The host
        # never sits between consecutive device batches, which is what
        # keeps a sharded plane's shards saturated.
        staged: List[tuple] = []
        try:
            for n_pad in sorted(buckets):
                group = buckets[n_pad]
                B = self.cfg.batch_size
                for lo in range(0, len(group), B):
                    self._serve_batch(group[lo:lo + B], n_pad, staged)
            self._deliver(staged, out)
        except BaseException:
            # A failed batch must not lose work: every dispatched batch
            # that still materializes drains into the undelivered
            # buffer; everything else (unserved, or failed async)
            # requeues by request id.
            for entry in staged:
                if entry[0][0][0] in out:
                    continue  # already delivered before the failure
                try:
                    self._deliver([entry], out)
                except Exception:
                    pass  # its rids stay out of `out` -> requeued
            self._done.update(out)
            self._pending = [it for it in pending
                             if it[0] not in out] + self._pending
            raise
        return out

    def _deliver(self, staged, out) -> None:
        """Phase 2 of a flush: gather each dispatched batch's labels to
        host and hand them (with their tau version) to the caller."""
        for batch, labels_dev, version in staged:
            labels = np.asarray(labels_dev)
            for i, (rid, arr, _) in enumerate(batch):
                out[rid] = (labels[i, :arr.shape[0]], version)
                self._served_devices += 1
                self._served_points += arr.shape[0]

    def serve(self, datas, k_valid=None) -> List[np.ndarray]:
        """Submit + flush convenience: one labels array per input.
        Results of OTHER requests already pending stay queued for the
        next :meth:`flush`."""
        return [lbl for lbl, _ in self.serve_versioned(datas, k_valid)]

    def serve_versioned(self, datas,
                        k_valid=None) -> List[Tuple[np.ndarray, int]]:
        """Like :meth:`serve`, returning (labels, tau_version) pairs —
        the version identifies exactly which tau buffer produced each
        request's attachment."""
        kvs = ([None] * len(datas) if k_valid is None else list(k_valid))
        assert len(kvs) == len(datas), (len(kvs), len(datas))
        rids = [self.submit(d, kv) for d, kv in zip(datas, kvs)]
        got = self.flush_versioned()
        mine = [got.pop(r) for r in rids]
        self._done.update(got)
        return mine

    def _serve_batch(self, batch, n_pad: int, staged) -> None:
        """Phase 1 of a flush: dispatch one batch's serve step + fold
        (+ cadence refresh) and stage its device-side labels. Nothing
        here waits on the device unless the admission policy needs
        report weights (``needs_weight`` policies synchronize once per
        batch)."""
        cfg = self.cfg
        B = cfg.batch_size
        data = np.zeros((B, n_pad, cfg.d), np.float32)
        pmask = np.zeros((B, n_pad), bool)
        kv = np.full((B,), cfg.k_prime, np.int32)
        rids = np.zeros((B,), np.int64)
        for i in range(B):
            rid, arr, k_valid = batch[min(i, len(batch) - 1)]  # pad=repeat
            n = arr.shape[0]
            data[i, :n] = arr
            pmask[i, :n] = True
            kv[i] = k_valid
            rids[i] = rid
        keys = jax.vmap(lambda r: jax.random.fold_in(self._base_key, r))(
            jnp.asarray(rids, jnp.uint32))
        version = self._taubuf.version
        labels, centers, cmask, weights = self.plane.step(
            self.tau, keys, jnp.asarray(data), jnp.asarray(pmask),
            jnp.asarray(kv))
        if cfg.fold_reports:
            self._fold(batch, rids, centers, cmask, weights)
        staged.append((batch, labels, version))

    # -------------------------------------------------------------- fold --

    def _scatter_slots(self, slots: np.ndarray, total: int) -> jax.Array:
        """Admission decisions -> the plane's fixed-shape fold vector:
        declined (-1) and padding entries become the out-of-capacity
        sentinel the scatter drops (negative ids would WRAP per numpy
        indexing — never pass them to a scatter)."""
        full = np.full((total,), self.cfg.capacity, np.int64)
        full[:len(slots)] = np.where(slots < 0, self.cfg.capacity, slots)
        return jnp.asarray(full, jnp.int32)

    def _admit_and_fold(self, rids, dev_w, centers, cmask, fold_w,
                        total: Optional[int] = None) -> int:
        """THE admission step shared by round seeding and streaming:
        the batch goes through ``FoldPolicy.admit_batch`` (global
        request order, within-batch evictions suppressed), and the
        granted reports scatter into their slots through the serve
        plane — ``server.aggregate_incremental`` stays the single fold
        primitive (its collective sibling on the sharded plane).
        ``total`` pads the slot vector past ``len(rids)`` (the serve
        batch's repeat-padding rows, which never fold). Returns the
        number of GRANTED admissions (the refresh-cadence count)."""
        slots, granted = self.policy.admit_batch(rids, dev_w)
        if granted:
            self.state = self.plane.fold(
                self.state,
                self._scatter_slots(slots, total or len(rids)),
                centers, cmask, weights=fold_w)
        return granted

    def _fold(self, batch, rids, centers, cmask, weights):
        dev_w = (np.asarray(jnp.sum(weights, axis=1))[:len(batch)]
                 if self.policy.needs_weight else None)
        admitted = self._admit_and_fold(
            rids[:len(batch)], dev_w, centers, cmask,
            weights if self.cfg.weight_by_core_counts else None,
            total=len(rids))
        if not admitted:
            return
        self._since_refresh += admitted
        if self.cfg.refresh_every and (
                self._since_refresh >= self.cfg.refresh_every):
            if self.cfg.refresh == "sync":
                self.refresh()
            else:
                self._stage_refresh()

    # ----------------------------------------------------------- refresh --

    def refresh(self) -> server.KFedAggregate:
        """Re-finalize Algorithm 2 over every folded report (round
        devices + streamed attachments) and swap in the new tau centers
        NOW (one atomic version bump). tau is a traced argument of the
        serve step, so no recompile."""
        agg = server.finalize(self.state, self.cfg.k,
                              weighted=self.cfg.weight_by_core_counts)
        self._taubuf = self._taubuf.swap_now(agg.tau_centers)
        self._since_refresh = 0
        return agg

    def _stage_refresh(self) -> None:
        """The async half of the refresh: build the STANDBY tau buffer
        (jax dispatches the re-finalization asynchronously, so serving
        against the active buffer continues while it computes) and
        defer the version-bump swap to the next flush boundary."""
        agg = server.finalize(self.state, self.cfg.k,
                              weighted=self.cfg.weight_by_core_counts)
        self._taubuf = self._taubuf.stage(agg.tau_centers)
        self._since_refresh = 0

    # -------------------------------------------------------- checkpoint --

    def _counters(self) -> np.ndarray:
        return np.asarray([self._next_id, self._since_refresh,
                           self._served_devices, self._served_points,
                           self._base_seed], np.int64)

    def save(self, path: str) -> str:
        """Checkpoint both tau buffers + version, fold state, counters,
        and admission-policy identity/state (npz via
        ``checkpoint.store``). Pending requests are not persisted."""
        from repro.fed.policy import POLICY_IDS
        return save_pytree(path, {
            "tau_bufs": self._taubuf.bufs,
            "tau_meta": self._taubuf.meta_array(),
            "server": self.state,
            "counters": self._counters(),
            "policy_id": np.asarray(POLICY_IDS[self.policy.name],
                                    np.int64),
            "policy": self.policy.state_arrays()})

    @classmethod
    def restore(cls, path: str, cfg: StreamConfig) -> "AttachService":
        """Deprecated: use ``fed.api.Session.restore`` instead."""
        warn_legacy("fed.stream.AttachService.restore", "Session.restore")
        return cls._restore(path, cfg)

    @classmethod
    def _restore(cls, path: str, cfg: StreamConfig, *, mesh=None,
                 serve_axes=None) -> "AttachService":
        from repro.fed.policy import POLICY_IDS
        policy = make_policy(cfg.fold_policy, cfg.capacity,
                             seed=cfg.policy_seed)
        keys = npz_keys(path)
        # Refuse a policy mismatch up front (named error, not a bare
        # KeyError / silent state corruption): the checkpoint's slot
        # bookkeeping is only meaningful under the policy that wrote
        # it. Checkpoints from before the policy layer existed could
        # only have been written under the drop rule.
        if "policy_id" in keys:
            data = np.load(path if path.endswith(".npz")
                           else path + ".npz")
            saved = int(data["policy_id"])
        else:
            saved = POLICY_IDS["drop"]
        if saved != POLICY_IDS[cfg.fold_policy]:
            names = {v: n for n, v in POLICY_IDS.items()}
            raise StreamConfigError(
                f"StreamConfig.fold_policy={cfg.fold_policy!r} does not "
                f"match the checkpoint at {path!r}, which was saved "
                f"under fold_policy={names.get(saved, saved)!r}")
        # Schema v2 carries the double-buffered tau; v1 (pre-plane)
        # checkpoints hold one tau — restored as version 0 with both
        # buffers equal, so old checkpoints keep replaying bitwise.
        v2 = "tau_bufs" in keys
        like = {
            "server": server.init_state(cfg.capacity, cfg.k_prime, cfg.d),
            "counters": np.zeros((5,), np.int64),
            "policy": policy.state_like(),
        }
        if v2:
            like["tau_bufs"] = jnp.zeros((2, cfg.k, cfg.d), jnp.float32)
            like["tau_meta"] = np.zeros((3,), np.int64)
        else:
            like["tau"] = jnp.zeros((cfg.k, cfg.d), jnp.float32)
        if "policy_id" in keys:
            like["policy_id"] = np.zeros((), np.int64)
        tree = load_pytree(path, like)
        if tree["policy"]:
            policy.load_state(tree["policy"])
        taubuf = (TauBuffer.from_arrays(tree["tau_bufs"], tree["tau_meta"])
                  if v2 else TauBuffer.fresh(tree["tau"]))
        cnt = np.asarray(tree["counters"])
        return cls(cfg, taubuf.tau, tau_buffer=taubuf,
                   state=tree["server"], policy=policy,
                   seed=int(cnt[4]), next_id=int(cnt[0]),
                   since_refresh=int(cnt[1]), served_devices=int(cnt[2]),
                   served_points=int(cnt[3]), mesh=mesh,
                   serve_axes=serve_axes)

    # ------------------------------------------------------------- stats --

    def stats(self) -> dict:
        return {
            "served_devices": self._served_devices,
            "served_points": self._served_points,
            "folded": int(np.asarray(jnp.sum(self.state.received))),
            "capacity": self.cfg.capacity,
            "fold_policy": self.policy.name,
            "pending": len(self._pending),
            "undelivered": len(self._done),
            "since_refresh": self._since_refresh,
            "tau_version": self._taubuf.version,
            "refresh_pending": self._taubuf.pending,
            **self.plane.describe(),
        }
