"""Streaming post-round attachment service (DESIGN.md §9, §11).

Everything after the one communication round: a finalized k-FED round
leaves k tau centers, and from then on the paper's Theorem 3.2 promises
O(k'k) attachment of any late-joining device with zero extra rounds.
This module turns that promise into a serving layer:

  * **batching** — heterogeneous ``(n^(z), k^(z))`` attach requests are
    bucketed by padded point count, padded into fixed ``(B, n_pad, d)``
    shapes with point masks, and served by ONE jitted step that vmaps
    the Algorithm 1 local solve over the request batch and attaches via
    the Theorem 3.2 nearest-center rule. The step (and the fold
    scatter) execute on a ``fed/plane.ServePlane`` — single-host by
    default, shard_mapped over the plan's ``serve_axes`` mesh axes when
    set (the request batch axis is embarrassingly parallel; tau and the
    fold state stay replicated);
  * **online refresh** — each served report (Theta, mask, |S_r|) can be
    folded into the incremental server state
    (``server.aggregate_incremental``), and on a configurable cadence
    the round is re-finalized so the cached tau centers track the
    population (the membership-update problem of Holzer et al. 2023 /
    Garst & Reinders 2023), still with one uplink per device ever. tau
    is double-buffered and versioned (``fed/plane.TauBuffer``):
    ``refresh="sync"`` swaps immediately between batches, while
    ``refresh="async"`` builds the standby buffer without interrupting
    serving and commits the swap — one atomic version bump — at the
    next flush boundary. Every served label records the tau version
    that produced it;
  * **load-adaptive scaling** — at flush boundaries a deterministic
    controller (``fed/autoscale.py``, DESIGN.md §12) may re-select the
    active shard count (within the ``serve_axes`` grant), the serve
    batch size, and the active bucket ladder (re-bucketing queued
    oversized requests into one coalesced rung under load) from a
    queue-depth snapshot; every (shards, batch, bucket) triple's step
    compiles once and is cached, so scaling never recompiles in steady
    state;
  * **crash recovery** — the full service state (both tau buffers +
    version, fold state, counters, key seed, autoscale decision state)
    checkpoints through ``checkpoint/store.py``; restore + serve is
    bitwise identical to the uninterrupted service — including
    mid-refresh-window version assignments and the scaling-decision
    sequence — because request keys are derived from the persisted
    request-id counter and decisions from deterministic queue
    snapshots, never from wall clock.

Fold-slot admission is a pluggable ``FoldPolicy`` (``fed/policy.py``):
``drop`` (slot == request id, over-capacity ids served-not-folded — the
historical behavior), ``lru`` (evict the least-recently-folded slot),
or ``weighted_reservoir`` (A-ES sampling by report mass). Admission is
host-side and shard-deterministic (``FoldPolicy.admit_batch``);
eviction is a slot overwrite, so ``server.aggregate_incremental`` stays
the single fold primitive (the sharded plane runs its collective
sibling ``aggregate_incremental_sharded`` — bitwise the same state).
In-flight (submitted, unflushed) requests are NOT part of a checkpoint
— clients re-submit on failover.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import load_extras, load_pytree, save_pytree
from repro.core import server
from repro.fed.autoscale import (AUTOSCALE_IDS, AutoscaleController,
                                 AutoscaleDecision, FlushTelemetry,
                                 bucket_of, pow2_ceil, shards_for,
                                 snapshot_queue)
from repro.fed.plane import ServePlane, ServePlaneError, TauBuffer
from repro.fed.policy import FoldPolicy, make_policy
from repro.utils.deprecation import warn_legacy

REFRESH_MODES = ("sync", "async")

DRIFT_MODES = ("off", "decay", "split_merge")

# Stable numeric codes for the v4 checkpoint schema (npz stores no
# strings): a drift-enabled checkpoint's fold epochs, mass histogram
# and split/retire counters are only meaningful under the drift mode
# that wrote them.
DRIFT_IDS = {"off": 0, "decay": 1, "split_merge": 2}


class ReproPerfWarning(UserWarning):
    """A configuration is costing performance without affecting results
    (e.g. attach requests padding above the configured bucket ladder).
    Named so ``filterwarnings`` can target exactly this class — silence
    it deliberately with ``ignore::repro.fed.stream.ReproPerfWarning``
    (pytest.ini escalates it to an error in the tier-1 suites)."""


class StreamConfigError(ValueError):
    """A StreamConfig field failed validation (named, with accepted
    values) — raised at construction, never deep inside tracing."""


class ServedPrediction(NamedTuple):
    """One request's routed-serving result (DESIGN.md §16): its
    Theorem 3.2 labels + the tau version that produced them (exactly
    :meth:`AttachService.flush_versioned`'s pair), plus the per-cluster
    head's pooled prediction. ``routed=False`` marks a request that
    overflowed its cluster's dispatch queue — it still has labels and a
    majority-vote ``cluster``, but ``prediction`` is the zero vector."""
    labels: "np.ndarray"      # (n,) int32 per-point labels
    tau_version: int
    prediction: "np.ndarray"  # (d,) f32 pooled head output
    cluster: int              # majority-vote cluster (the head index)
    routed: bool              # False = dispatch-queue overflow


# Key-derivation salt separating the head-init PRNG stream from the
# per-request fold_in streams (which consume request ids).
_HEADS_SALT = 0x48454144  # "HEAD"

# Sibling salt for the ingestion-encoder init stream (DESIGN.md §17):
# distinct from the head stream so enabling one never re-keys the other.
_ENCODER_SALT = 0x454E434F  # "ENCO"

# Smallest token-axis pad rung: sequences bucket to powers of two from
# here up to ``encode_seq_len`` (the submit-time ceiling), bounding the
# distinct compiled (n_pad, seq_pad) shapes to a static grid.
_SEQ_RUNG_FLOOR = 8


class _ServerStateV3(NamedTuple):
    """Restore template for pre-v4 checkpoints: the fold state before
    the drift layer's epoch stamps, with the SAME field names (and so
    the same flattened "server/.<field>" key paths)."""
    centers: jax.Array
    mask: jax.Array
    weights: jax.Array
    received: jax.Array


def _bad(fieldname: str, got, accepted: str) -> None:
    raise StreamConfigError(
        f"StreamConfig.{fieldname}={got!r} is invalid: {accepted}")


@dataclass(frozen=True)
class StreamConfig:
    """Static configuration of the attachment service."""
    k: int                      # global cluster count of the round
    k_prime: int                # per-request k^(z) cap (static pad)
    d: int                      # feature dimension
    capacity: int               # fold-state slots (device ids)
    batch_size: int = 8         # requests per jitted serve step
    bucket_sizes: Tuple[int, ...] = (64, 256, 1024)  # n^(z) pad buckets
    refresh_every: int = 0      # re-finalize after this many folds; 0 = never
    refresh: str = "sync"       # tau swap: sync (immediate) | async
    autoscale: str = "off"      # serve-plane scaling: off|latency|throughput
    fold_reports: bool = True   # fold served reports into the server state
    weight_by_core_counts: bool = False
    fold_policy: str = "drop"   # admission: drop | lru | weighted_reservoir
    policy_seed: int = 0        # weighted_reservoir key seed
    serve_dtype: str = "f32"    # fused-step storage: f32 (bitwise) | bf16
    drift: str = "off"          # drift adaptation: off|decay|split_merge
    drift_half_life: int = 0    # decay half-life in REQUESTS (>= 1 on)
    drift_split_factor: float = 2.0   # split centers above this x mean mass
    drift_retire_frac: float = 0.1    # retire centers below this x mean mass
    drift_max_moves: int = 1    # split/retire moves per flush boundary
    heads: str = "off"          # per-cluster serving heads: off|linear|<config>
    head_capacity: float = 1.25  # dispatch queue slots per cluster, x B/k
    head_arch: str = "ffn"      # head architecture: ffn | transformer
    encoder: str = "off"        # ingestion encoder: off | <config name>
    encode_dtype: str = "f32"   # encoder storage: f32 | bf16 (f32 accum)
    encode_seq_len: int = 64    # token-axis pad ceiling per point
    local_kw: dict = field(default_factory=dict)  # Algorithm 1 options

    def __post_init__(self):
        from repro.fed.policy import POLICIES
        if not isinstance(self.k, int) or self.k < 1:
            _bad("k", self.k, "must be an int >= 1")
        if (not isinstance(self.k_prime, int)
                or not 1 <= self.k_prime <= self.k):
            _bad("k_prime", self.k_prime,
                 f"must satisfy 1 <= k_prime <= k (k={self.k})")
        if not isinstance(self.d, int) or self.d < 1:
            _bad("d", self.d, "must be an int >= 1")
        if self.capacity < 1:
            _bad("capacity", self.capacity, "must be an int >= 1")
        if self.batch_size < 1:
            _bad("batch_size", self.batch_size, "must be an int >= 1")
        if self.refresh_every < 0:
            _bad("refresh_every", self.refresh_every,
                 "must be >= 0 (0 disables the refresh cadence)")
        if self.refresh not in REFRESH_MODES:
            _bad("refresh", self.refresh,
                 f"accepted values are {list(REFRESH_MODES)}")
        from repro.fed.autoscale import AUTOSCALE_POLICIES
        if self.autoscale not in AUTOSCALE_POLICIES:
            _bad("autoscale", self.autoscale,
                 f"accepted values are {list(AUTOSCALE_POLICIES)}")
        if (self.autoscale != "off"
                and self.batch_size & (self.batch_size - 1)):
            _bad("batch_size", self.batch_size,
                 "must be a power of two when autoscale is enabled "
                 "(the controller re-selects power-of-two batch rungs "
                 "within it)")
        if (not self.bucket_sizes
                or any(int(b) < 1 for b in self.bucket_sizes)
                or list(self.bucket_sizes)
                != sorted(set(int(b) for b in self.bucket_sizes))):
            _bad("bucket_sizes", self.bucket_sizes,
                 "must be a non-empty strictly ascending tuple of "
                 "positive point-count pads, e.g. (64, 256, 1024)")
        if self.fold_policy not in POLICIES:
            _bad("fold_policy", self.fold_policy,
                 f"accepted values are {sorted(POLICIES)}")
        if not isinstance(self.policy_seed, int) or self.policy_seed < 0:
            _bad("policy_seed", self.policy_seed,
                 "must be a non-negative int (seeds the "
                 "weighted_reservoir keys)")
        if self.drift not in DRIFT_MODES:
            _bad("drift", self.drift,
                 f"accepted values are {list(DRIFT_MODES)}")
        if self.drift != "off" and (
                not isinstance(self.drift_half_life, int)
                or self.drift_half_life < 1):
            _bad("drift_half_life", self.drift_half_life,
                 "must be an int >= 1 (requests) when drift is enabled")
        if not float(self.drift_split_factor) > 1.0:
            _bad("drift_split_factor", self.drift_split_factor,
                 "must be > 1.0 (multiples of the mean center mass)")
        if not 0.0 <= float(self.drift_retire_frac) < 1.0:
            _bad("drift_retire_frac", self.drift_retire_frac,
                 "must be in [0.0, 1.0) (fraction of the mean mass)")
        if not isinstance(self.drift_max_moves, int) \
                or self.drift_max_moves < 1:
            _bad("drift_max_moves", self.drift_max_moves,
                 "must be an int >= 1 (split/retire moves per boundary)")
        from repro.kernels.ref import SOLVE_ATTACH_DTYPES
        if self.serve_dtype not in SOLVE_ATTACH_DTYPES:
            _bad("serve_dtype", self.serve_dtype,
                 f"accepted values are {list(SOLVE_ATTACH_DTYPES)} "
                 "(f32 keeps the fused serve step bitwise-identical to "
                 "the staged path; bf16 stores points/centers/tau in "
                 "bfloat16 with f32 accumulation — tolerance-bounded, "
                 "see DESIGN.md §13)")
        if not (isinstance(self.head_capacity, (int, float))
                and float(self.head_capacity) > 0.0):
            _bad("head_capacity", self.head_capacity,
                 "must be a float > 0 (per-cluster dispatch queue slots "
                 "as a multiple of batch_size / k; requests past a "
                 "cluster's queue are served labels without a "
                 "prediction — DESIGN.md §16)")
        if self.heads != "off":
            from repro.models import heads as heads_mod
            if self.head_arch not in heads_mod.HEAD_ARCHS:
                _bad("head_arch", self.head_arch,
                     f"accepted values are {list(heads_mod.HEAD_ARCHS)}")
            try:
                heads_mod.resolve_head_spec(self.heads, self.head_arch,
                                            self.d)
            except heads_mod.HeadConfigError as e:
                _bad("heads", self.heads, str(e))
        from repro.models.encoder import ENCODE_DTYPES
        if self.encode_dtype not in ENCODE_DTYPES:
            _bad("encode_dtype", self.encode_dtype,
                 f"accepted values are {list(ENCODE_DTYPES)} (f32 keeps "
                 "the encode stage bitwise-reproducible across restores; "
                 "bf16 stores encoder params/activations in bfloat16 "
                 "with f32 accumulation — DESIGN.md §17)")
        if self.encoder != "off":
            from repro.models import encoder as enc_mod
            if (not isinstance(self.encode_seq_len, int)
                    or self.encode_seq_len < 1):
                _bad("encode_seq_len", self.encode_seq_len,
                     "must be an int >= 1 (the per-point token-sequence "
                     "pad ceiling) when the encoder is enabled")
            try:
                enc_mod.resolve_encoder_spec(self.encoder, self.d)
            except enc_mod.EncoderConfigError as e:
                _bad("encoder", self.encoder, str(e))

    def encoder_spec(self):
        """Resolved :class:`repro.models.encoder.EncoderSpec` for this
        plan (None when the ingestion encoder is off)."""
        if self.encoder == "off":
            return None
        from repro.models import encoder as enc_mod
        return enc_mod.resolve_encoder_spec(self.encoder, self.d)

    def head_spec(self):
        """Resolved :class:`repro.models.heads.HeadSpec` for this plan
        (None when heads are off)."""
        if self.heads == "off":
            return None
        from repro.models import heads as heads_mod
        return heads_mod.resolve_head_spec(self.heads, self.head_arch,
                                           self.d)


class AttachService:
    """Serves batches of late-joining devices against a finalized round.

    Construct with :meth:`from_round` (seeds the fold state with the
    round's own reports) or :meth:`restore` (from a checkpoint). Pass
    ``mesh`` + ``serve_axes`` to run the hot path on the sharded serve
    plane (DESIGN.md §11) — per-request labels are bitwise identical to
    the single-host plane for a fixed tau version.
    """

    def __init__(self, cfg: StreamConfig, tau_centers, *,
                 state: Optional[server.ServerState] = None,
                 policy: Optional[FoldPolicy] = None,
                 seed: int = 0, next_id: int = 0,
                 since_refresh: int = 0, served_devices: int = 0,
                 served_points: int = 0, mesh=None, serve_axes=None,
                 tau_buffer: Optional[TauBuffer] = None, heads=None,
                 encoder=None):
        self.cfg = cfg
        try:
            self.plane = ServePlane(cfg, mesh=mesh, serve_axes=serve_axes)
        except ServePlaneError as e:
            raise StreamConfigError(str(e)) from None
        self._taubuf = (tau_buffer if tau_buffer is not None
                        else TauBuffer.fresh(tau_centers))
        assert self._taubuf.bufs.shape == (2, cfg.k, cfg.d), \
            self._taubuf.bufs.shape
        self.state = (server.init_state(cfg.capacity, cfg.k_prime, cfg.d)
                      if state is None
                      else jax.tree.map(jnp.asarray, state))
        self.policy = policy or make_policy(
            cfg.fold_policy, cfg.capacity, seed=cfg.policy_seed,
            half_life=(cfg.drift_half_life if cfg.drift != "off" else 0))
        # The §12 load-adaptive controller: one decision per non-empty
        # flush, against the devices serve_axes granted. With
        # autoscale="off" its (static) decision reproduces the
        # pre-controller behavior bitwise.
        self.autoscaler = AutoscaleController(
            cfg.autoscale, max_batch=cfg.batch_size,
            granted=self.plane.n_shards,
            n_axes=len(self.plane.axes) if self.plane.axes else 1,
            base_ladder=tuple(cfg.bucket_sizes))
        self._base_seed = int(seed)
        self._base_key = jax.random.PRNGKey(self._base_seed)
        self._next_id = int(next_id)
        self._since_refresh = int(since_refresh)
        self._served_devices = int(served_devices)
        self._served_points = int(served_points)
        self._pending: List[Tuple[int, np.ndarray, int]] = []
        # served, not yet delivered: rid -> (labels, tau version,
        # (prediction, cluster, routed) | None with heads off)
        self._done: Dict[int, tuple] = {}
        # Per-cluster serving heads (DESIGN.md §16): k stacked param
        # sets, deterministically derived from the service seed on a
        # salted PRNG stream (so restores and re-inits agree), unless a
        # v5 checkpoint restore hands the folded params in. A staged
        # split/retire head re-map (``_heads_perm``) commits at the
        # SAME boundary as the tau version bump.
        self._head_spec = cfg.head_spec()
        self._heads_perm = None
        self._routed_served = 0
        self._overflowed = 0
        if self._head_spec is None:
            self.heads = None
        elif heads is not None:
            self.heads = jax.tree.map(jnp.asarray, heads)
        else:
            from repro.models import heads as heads_mod
            self.heads = heads_mod.init_heads(
                jax.random.fold_in(self._base_key, _HEADS_SALT),
                cfg.k, self._head_spec)
        # Ingestion encoder (DESIGN.md §17): one parameter set,
        # deterministically derived from the service seed on its own
        # salted stream (restores and re-inits agree), unless a v6
        # checkpoint restore hands the params in.
        self._enc_spec = cfg.encoder_spec()
        self._encoded_points = 0
        if self._enc_spec is None:
            self.encoder = None
        elif encoder is not None:
            self.encoder = jax.tree.map(jnp.asarray, encoder)
        else:
            from repro.models import encoder as enc_mod
            self.encoder = enc_mod.init_encoder(
                jax.random.fold_in(self._base_key, _ENCODER_SALT),
                self._enc_spec)
        # Warn-once latch keyed on (active ladder, rung): a global bool
        # here either re-fired every flush or went silent for a NEW
        # coalesced ladder after an autoscale switch — each distinct
        # oversized pad shape warns exactly once.
        self._oversized_warned: set = set()
        # Drift bookkeeping (schema v4): per-center decayed fold mass
        # at the last refresh, and the split/retire decision counters —
        # all pure functions of the folded stream, so they replay
        # bitwise from a checkpoint.
        self._drift_mass = np.zeros((cfg.k,), np.float32)
        self._drift_events = 0    # boundaries that moved >= 1 center
        self._drift_moves = 0     # total split/retire moves
        self._drift_last = 0      # moves at the most recent boundary

    # ------------------------------------------------------------- build --

    @classmethod
    def from_round(cls, rr, cfg: StreamConfig, *,
                   seed: int = 0) -> "AttachService":
        """Deprecated: construct a ``fed.api.Session`` and use
        ``Session.attach``/``Session.serve`` instead."""
        warn_legacy("fed.stream.AttachService.from_round",
                    "Session.attach/Session.serve")
        return cls._from_round(rr, cfg, seed=seed)

    @classmethod
    def _from_round(cls, rr, cfg: StreamConfig, *, seed: int = 0,
                    mesh=None, serve_axes=None) -> "AttachService":
        """Seed the service from a finished round result: cache its tau
        centers and fold the participating devices' reports so a later
        refresh re-finalizes over round + streamed devices."""
        Z = int(rr.device_centers.shape[0])
        if cfg.fold_policy == "drop":
            assert cfg.capacity >= Z, (cfg.capacity, Z)
        svc = cls(cfg, rr.agg.tau_centers, seed=seed, next_id=Z,
                  mesh=mesh, serve_axes=serve_axes)
        if cfg.fold_reports:
            ids = np.nonzero(np.asarray(rr.participated))[0]
            if ids.size:
                cw = server.core_weights(rr.core_counts[ids])
                dev_w = (np.asarray(jnp.sum(cw, axis=1))
                         if svc.policy.needs_weight else None)
                svc._admit_and_fold(
                    ids, dev_w, rr.device_centers[ids],
                    rr.center_mask[ids],
                    cw if cfg.weight_by_core_counts else None)
        return svc

    # ------------------------------------------------------------- serve --

    @property
    def tau(self) -> jax.Array:
        """The ACTIVE tau buffer (what the serve step reads)."""
        return self._taubuf.tau

    @property
    def tau_version(self) -> int:
        return self._taubuf.version

    def submit(self, data, k_valid: Optional[int] = None) -> int:
        """Enqueue one device's data; returns its request id (the fold
        slot, and the PRNG stream of its local solve). With the encoder
        off this is the historical (n, d) latent-point contract; with
        ``encoder=<config>`` each point is a raw token/patch sequence —
        (n, seq, d) with seq <= ``encode_seq_len`` — that the plane
        encodes ahead of the solve (DESIGN.md §17)."""
        arr = np.asarray(data, np.float32)
        if self._enc_spec is None:
            assert arr.ndim == 2 and arr.shape[1] == self.cfg.d, arr.shape
        else:
            assert arr.ndim == 3 and arr.shape[2] == self.cfg.d, arr.shape
            if arr.shape[1] < 1 or arr.shape[1] > self.cfg.encode_seq_len:
                raise StreamConfigError(
                    f"submit() got a token sequence of length "
                    f"{arr.shape[1]}: with encoder="
                    f"{self.cfg.encoder!r} every point must carry "
                    f"1 <= seq <= encode_seq_len="
                    f"{self.cfg.encode_seq_len} tokens (raise "
                    f"encode_seq_len in the plan for longer inputs)")
        kv = self.cfg.k_prime if k_valid is None else int(k_valid)
        assert 1 <= kv <= self.cfg.k_prime, kv
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, arr, kv))
        return rid

    def _bucket(self, n: int, ladder: Optional[Tuple[int, ...]] = None
                ) -> int:
        """The pad rung for an n-point request: the flush decision's
        ACTIVE ladder when given (autoscale may have coalesced the
        oversized rungs), else the configured base ladder; geometric
        (doubling) buckets above the top rung bound the distinct jitted
        pad shapes to O(log n_max / top) instead of one recompile per
        distinct rounded-up n."""
        lad = tuple(ladder or self.cfg.bucket_sizes)
        b = bucket_of(n, lad)
        key = (lad, b)
        if n > self.cfg.bucket_sizes[-1] \
                and key not in self._oversized_warned:
            self._oversized_warned.add(key)
            warnings.warn(
                f"attach request with n={n} points exceeds the largest "
                f"configured bucket ({self.cfg.bucket_sizes[-1]}); "
                f"padding to an oversized bucket of {b}. Add larger "
                f"bucket_sizes to the plan to avoid oversized pads.",
                ReproPerfWarning, stacklevel=3)
        return b

    def _seq_rung(self, seq: int) -> int:
        """The token-axis pad rung for one request: the next power of
        two (floored at ``_SEQ_RUNG_FLOOR``), clamped to the
        ``encode_seq_len`` ceiling submit() enforced — so the compiled
        (n_pad, seq_pad) grid stays static per plan and short sequences
        never pad to the full ceiling."""
        return min(int(self.cfg.encode_seq_len),
                   max(_SEQ_RUNG_FLOOR, pow2_ceil(seq)))

    def _bucket_key(self, arr: np.ndarray,
                    ladder: Optional[Tuple[int, ...]] = None):
        """The flush-group key of one request: the point-count rung
        alone with the encoder off (the historical int key — those
        paths stay bitwise-untouched), the (n_pad, seq_pad) pair with
        it on. Keys within one flush are homogeneous, so the sorted
        group order stays deterministic either way."""
        n_pad = self._bucket(arr.shape[0], ladder)
        if self._enc_spec is None:
            return n_pad
        return (n_pad, self._seq_rung(arr.shape[1]))

    def flush(self) -> Dict[int, np.ndarray]:
        """Serve every pending request; returns {request_id: (n,) labels}.
        See :meth:`flush_versioned` for the tau version each request was
        served under."""
        return {rid: lbl
                for rid, (lbl, _) in self.flush_versioned().items()}

    def flush_versioned(self) -> Dict[int, Tuple[np.ndarray, int]]:
        """Serve every pending request; returns
        {request_id: ((n,) labels, tau_version)}. With heads enabled,
        :meth:`flush_predict` additionally returns the per-cluster head
        predictions of the same serve step."""
        return {rid: (lbl, ver)
                for rid, (lbl, ver, _) in self._flush_all().items()}

    def flush_predict(self) -> Dict[int, ServedPrediction]:
        """Serve every pending request through the routed
        personalization step; returns
        {request_id: :class:`ServedPrediction`}. Labels and tau
        versions are the ones :meth:`flush_versioned` would have
        returned (bitwise — the routed step shares the label body)."""
        if self._head_spec is None:
            raise StreamConfigError(
                "flush_predict() needs per-cluster serving heads: set "
                "StreamConfig.heads to 'linear' or a registered model "
                "config (it is 'off')")
        return {rid: ServedPrediction(lbl, ver, pred[0], pred[1],
                                      pred[2])
                for rid, (lbl, ver, pred)
                in self._flush_all().items()}

    def _flush_all(self) -> Dict[int, tuple]:
        """THE flush body: serve every pending request; returns
        {request_id: (labels, tau_version, pred)} where ``pred`` is
        ``(prediction, cluster, routed)`` with heads enabled, None
        otherwise.

        Requests are grouped by pad bucket and served in fixed
        (batch_size, n_pad, d) shapes — short batches pad by repeating
        the last real request (discarded). Served reports fold into the
        incremental server state, triggering a refresh on cadence. A
        flush boundary is where a staged async tau swap commits (and
        with it any staged split/retire head re-map — one atomic
        version bump covers both), so every request in one
        flush-and-refresh window maps to exactly one tau version.
        """
        if self._taubuf.pending:
            self._taubuf = self._taubuf.commit()
            self._commit_heads_perm()
        pending, self._pending = self._pending, []
        # The flush boundary is the ONE place scaling decisions land
        # (§12): snapshot the queue (depth + base-ladder histogram —
        # deterministic functions of the request stream, so a restored
        # service replays the same decision) and let the controller
        # re-select the active (shards, batch, ladder) triple.
        decision = self.autoscaler.decision
        if pending and self.cfg.autoscale != "off":
            # "off" never reads the snapshot — skip building it so the
            # default configuration keeps the pre-controller flush cost.
            # Under drift the snapshot also carries the last refresh's
            # per-center mass histogram (deterministic — it evolves at
            # flush boundaries only), the predictive-scaling hook.
            decision = self.autoscaler.observe(snapshot_queue(
                [item[1].shape[0] for item in pending],
                self.cfg.bucket_sizes,
                mass=(tuple(float(m) for m in self._drift_mass)
                      if self.cfg.drift != "off" else ())))
        buckets: Dict = {}
        for item in pending:
            buckets.setdefault(
                self._bucket_key(item[1], decision.ladder),
                []).append(item)
        out, self._done = self._done, {}  # undelivered earlier results
        # Two-phase pipeline: phase 1 DISPATCHES every batch (serve
        # step, fold scatter, staged refresh — all asynchronous, chained
        # by dataflow), phase 2 materializes labels on host. The host
        # never sits between consecutive device batches, which is what
        # keeps a sharded plane's shards saturated.
        staged: List[tuple] = []
        t0 = time.perf_counter()
        try:
            for bucket in sorted(buckets):
                group = buckets[bucket]
                B = decision.batch_size
                for lo in range(0, len(group), B):
                    self._serve_batch(group[lo:lo + B], bucket, staged,
                                      decision)
            t1 = time.perf_counter()
            self._deliver(staged, out)
            if pending:
                self.autoscaler.record(FlushTelemetry(
                    dispatch_us=int((t1 - t0) * 1e6),
                    materialize_us=int((time.perf_counter() - t1) * 1e6),
                    batches=len(staged), requests=len(pending),
                    points=sum(item[1].shape[0] for item in pending)))
        except BaseException:
            # A failed batch must not lose work: every dispatched batch
            # that still materializes drains into the undelivered
            # buffer; everything else (unserved, or failed async)
            # requeues by request id.
            for entry in staged:
                if entry[0][0][0] in out:
                    continue  # already delivered before the failure
                try:
                    self._deliver([entry], out)
                except Exception:
                    pass  # its rids stay out of `out` -> requeued
            self._done.update(out)
            self._pending = [it for it in pending
                             if it[0] not in out] + self._pending
            raise
        return out

    def _deliver(self, staged, out) -> None:
        """Phase 2 of a flush: gather each dispatched batch's labels
        (and, with heads on, predictions) to host and hand them with
        their tau version to the caller."""
        for entry in staged:
            if len(entry) == 3:
                batch, labels_dev, version = entry
                preds = cl = kept = None
            else:
                (batch, labels_dev, version, preds_dev, cl_dev,
                 kept_dev) = entry
                preds = np.asarray(preds_dev)
                cl = np.asarray(cl_dev)
                kept = np.asarray(kept_dev)
            labels = np.asarray(labels_dev)
            for i, (rid, arr, _) in enumerate(batch):
                if preds is None:
                    out[rid] = (labels[i, :arr.shape[0]], version, None)
                else:
                    routed = bool(kept[i])
                    out[rid] = (labels[i, :arr.shape[0]], version,
                                (preds[i].copy(), int(cl[i]), routed))
                    self._routed_served += int(routed)
                    self._overflowed += int(not routed)
                self._served_devices += 1
                self._served_points += arr.shape[0]

    def serve(self, datas, k_valid=None) -> List[np.ndarray]:
        """Submit + flush convenience: one labels array per input.
        Results of OTHER requests already pending stay queued for the
        next :meth:`flush`."""
        return [lbl for lbl, _ in self.serve_versioned(datas, k_valid)]

    def serve_versioned(self, datas,
                        k_valid=None) -> List[Tuple[np.ndarray, int]]:
        """Like :meth:`serve`, returning (labels, tau_version) pairs —
        the version identifies exactly which tau buffer produced each
        request's attachment."""
        return [(lbl, ver)
                for lbl, ver, _ in self._serve_all(datas, k_valid)]

    def serve_predict(self, datas, k_valid=None) -> List[ServedPrediction]:
        """Submit + flush through the per-cluster heads: one
        :class:`ServedPrediction` per input (same labels/versions as
        :meth:`serve_versioned`)."""
        if self._head_spec is None:
            raise StreamConfigError(
                "serve_predict() needs per-cluster serving heads: set "
                "StreamConfig.heads to 'linear' or a registered model "
                "config (it is 'off')")
        return [ServedPrediction(lbl, ver, pred[0], pred[1], pred[2])
                for lbl, ver, pred in self._serve_all(datas, k_valid)]

    def _serve_all(self, datas, k_valid) -> List[tuple]:
        kvs = ([None] * len(datas) if k_valid is None else list(k_valid))
        assert len(kvs) == len(datas), (len(kvs), len(datas))
        rids = [self.submit(d, kv) for d, kv in zip(datas, kvs)]
        got = self._flush_all()
        mine = [got.pop(r) for r in rids]
        self._done.update(got)
        return mine

    def _serve_batch(self, batch, bucket, staged,
                     decision: AutoscaleDecision) -> None:
        """Phase 1 of a flush: dispatch one batch's serve step + fold
        (+ cadence refresh) at the flush decision's (shards, batch)
        shape and stage its device-side labels. ``bucket`` is the
        ``_bucket_key`` the group was collected under — the point-count
        rung alone (encoder off) or the (n_pad, seq_pad) pair (encoder
        on, where the batch carries raw token sequences the plane
        encodes ahead of the solve). Nothing here waits on the device
        unless the admission policy needs report weights
        (``needs_weight`` policies synchronize once per batch)."""
        cfg = self.cfg
        encoded = self._enc_spec is not None
        n_pad, s_pad = bucket if encoded else (bucket, 0)
        B = decision.batch_size
        shards = decision.shards
        if cfg.autoscale != "off":
            # The decision's batch rung is the FLUSH ceiling; each
            # bucket group (and a group's last slice) right-sizes to
            # its own power-of-two rung so mixed-rung traffic never
            # pads one thin group up to the whole queue's depth —
            # repeat-padding rows are real compute. Deterministic (a
            # function of the group size alone), so replay holds; the
            # active shard count follows the batch down through THE
            # shard rule (a multi-axis grant has no sub-grant, so a
            # right-sized group there drops to one shard).
            B = min(B, pow2_ceil(len(batch)))
            shards = shards_for(B, shards, self.autoscaler.n_axes)
        if encoded:
            data = np.zeros((B, n_pad, s_pad, cfg.d), np.float32)
            tmask = np.zeros((B, n_pad, s_pad), bool)
        else:
            data = np.zeros((B, n_pad, cfg.d), np.float32)
            tmask = None
        pmask = np.zeros((B, n_pad), bool)
        kv = np.full((B,), cfg.k_prime, np.int32)
        rids = np.zeros((B,), np.int64)
        for i in range(B):
            rid, arr, k_valid = batch[min(i, len(batch) - 1)]  # pad=repeat
            n = arr.shape[0]
            if encoded:
                s = arr.shape[1]
                data[i, :n, :s] = arr
                tmask[i, :n, :s] = True
            else:
                data[i, :n] = arr
            pmask[i, :n] = True
            kv[i] = k_valid
            rids[i] = rid
        keys = jax.vmap(lambda r: jax.random.fold_in(self._base_key, r))(
            jnp.asarray(rids, jnp.uint32))
        version = self._taubuf.version
        if encoded:
            self._encoded_points += sum(
                item[1].shape[0] for item in batch)
            if self._head_spec is not None:
                (labels, centers, cmask, weights, preds, cluster,
                 kept) = self.plane.encoded_routed_step(
                    self.tau, self.encoder, self.heads, keys,
                    jnp.asarray(data), jnp.asarray(pmask),
                    jnp.asarray(tmask), jnp.asarray(kv), shards=shards)
                entry = (batch, labels, version, preds, cluster, kept)
            else:
                labels, centers, cmask, weights = self.plane.encode_step(
                    self.tau, self.encoder, keys, jnp.asarray(data),
                    jnp.asarray(pmask), jnp.asarray(tmask),
                    jnp.asarray(kv), shards=shards)
                entry = (batch, labels, version)
        elif self._head_spec is not None:
            (labels, centers, cmask, weights, preds, cluster,
             kept) = self.plane.routed_step(
                self.tau, self.heads, keys, jnp.asarray(data),
                jnp.asarray(pmask), jnp.asarray(kv), shards=shards)
            entry = (batch, labels, version, preds, cluster, kept)
        else:
            labels, centers, cmask, weights = self.plane.step(
                self.tau, keys, jnp.asarray(data), jnp.asarray(pmask),
                jnp.asarray(kv), shards=shards)
            entry = (batch, labels, version)
        if cfg.fold_reports:
            self._fold(batch, rids, centers, cmask, weights,
                       shards=shards)
        staged.append(entry)

    # -------------------------------------------------------------- fold --

    def _admit_and_fold(self, rids, dev_w, centers, cmask, fold_w,
                        total: Optional[int] = None,
                        shards: Optional[int] = None) -> int:
        """THE admission step shared by round seeding and streaming:
        the batch goes through ``FoldPolicy.admit_padded`` (global
        request order, within-batch evictions suppressed, declined and
        padding entries already the out-of-capacity sentinel), and the
        granted reports scatter into their slots through the serve
        plane — ``server.aggregate_incremental`` stays the single fold
        primitive (its collective sibling on the sharded plane).
        ``total`` pads the slot vector past ``len(rids)`` (the serve
        batch's repeat-padding rows, which never fold); ``shards`` is
        the flush decision's active count. Returns the number of
        GRANTED admissions (the refresh-cadence count)."""
        slots, granted = self.policy.admit_padded(rids, dev_w,
                                                  total=total)
        if granted:
            # Stamp each admitted slot with its REQUEST id (the epoch
            # the drift decay is keyed to) — under lru/reservoir the
            # slot and the request id diverge, so the default
            # epochs=slots would mis-age recycled slots. Padding rows
            # carry sentinel slots and never scatter.
            ep = np.zeros((len(slots),), np.int64)
            ep[:len(rids)] = np.asarray(rids, np.int64)
            self.state = self.plane.fold(
                self.state, jnp.asarray(slots, jnp.int32),
                centers, cmask, weights=fold_w, shards=shards,
                epochs=jnp.asarray(ep, jnp.int32))
        return granted

    def _fold(self, batch, rids, centers, cmask, weights, shards=None):
        dev_w = (np.asarray(jnp.sum(weights, axis=1))[:len(batch)]
                 if self.policy.needs_weight else None)
        admitted = self._admit_and_fold(
            rids[:len(batch)], dev_w, centers, cmask,
            weights if self.cfg.weight_by_core_counts else None,
            total=len(rids), shards=shards)
        if not admitted:
            return
        self._since_refresh += admitted
        if self.cfg.refresh_every and (
                self._since_refresh >= self.cfg.refresh_every):
            if self.cfg.refresh == "sync":
                self.refresh()
            else:
                self._stage_refresh()

    # ----------------------------------------------------------- refresh --

    def _refinalize(self):
        """THE re-finalization shared by the sync and async refresh:
        Algorithm 2 over every folded report, with the drift layer on
        top when configured (DESIGN.md §14).

        * ``drift="off"`` — exactly the historical finalize call
          (bitwise: decay never touches the math).
        * ``drift="decay"`` — every slot's fold weight is scaled by
          2^(-age/half_life) (age = requests since its fold, from the
          slot's epoch stamp); fully-decayed slots are masked out so a
          zero mass can never divide into NaN tau. The per-center
          attached mass histogram is recomputed here — the flush
          boundary is where drift state evolves.
        * ``drift="split_merge"`` — additionally, starved centers
          (mass < retire_frac x mean) are retired and re-seeded from
          the residual reports of over-massed centers
          (mass > split_factor x mean), max-min style, followed by one
          ``server.lloyd_round`` — all deterministic, so the decision
          sequence replays bitwise from a checkpoint.

        Returns ``(agg, tau)`` — ``tau`` is what the caller commits
        through the TauBuffer (one atomic versioned bump either way).
        """
        cfg = self.cfg
        if cfg.drift == "off":
            agg = server.finalize(self.state, cfg.k,
                                  weighted=cfg.weight_by_core_counts)
            return agg, agg.tau_centers
        decay = (self._next_id, cfg.drift_half_life)
        agg = server.finalize(self.state, cfg.k, decay=decay)
        mask, w = server.decayed_evidence(self.state, *decay)
        mass = server.center_mass(agg, mask, w)
        tau = agg.tau_centers
        if cfg.drift == "split_merge":
            st = self.state
            # Same sanitization finalize applies: masked slots carry no
            # evidence, so their (possibly garbage) coordinates must
            # not reach the re-seed distances or the Lloyd round.
            flat = jnp.where(mask[..., None], st.centers,
                             jnp.zeros_like(st.centers)
                             ).reshape(-1, cfg.d).astype(jnp.float32)
            tau, take, donors, n_mv = server.split_retire(
                flat, mask.reshape(-1), agg, mass, cfg.k,
                split_factor=cfg.drift_split_factor,
                retire_frac=cfg.drift_retire_frac,
                max_moves=cfg.drift_max_moves, weights=w.reshape(-1))
            moves = int(np.asarray(n_mv))
            self._drift_events += 1 if moves else 0
            self._drift_moves += moves
            self._drift_last = moves
            if moves and self._head_spec is not None:
                # A re-seeded center splits off its donor's traffic, so
                # its head starts as a COPY of the donor's (the model
                # that was serving those requests). Staged here,
                # applied by _commit_heads_perm at the same boundary as
                # the tau version bump — labels and predictions can
                # never disagree about which center generation they
                # came from. Overwrite (not compose): donors index the
                # CURRENT slot-stable heads, and any previously staged
                # perm was committed with its own tau swap.
                perm = np.arange(cfg.k, dtype=np.int64)
                tk = np.asarray(take, bool)
                perm[tk] = np.asarray(donors, np.int64)[tk]
                self._heads_perm = perm
        self._drift_mass = np.asarray(mass, np.float32)
        return agg, tau

    def refresh(self) -> server.KFedAggregate:
        """Re-finalize Algorithm 2 over every folded report (round
        devices + streamed attachments) and swap in the new tau centers
        NOW (one atomic version bump). tau is a traced argument of the
        serve step, so no recompile."""
        agg, tau = self._refinalize()
        self._taubuf = self._taubuf.swap_now(self.plane.localize(tau))
        self._commit_heads_perm()
        self._since_refresh = 0
        return agg

    def _stage_refresh(self) -> None:
        """The async half of the refresh: build the STANDBY tau buffer
        (jax dispatches the re-finalization asynchronously, so serving
        against the active buffer continues while it computes) and
        defer the version-bump swap to the next flush boundary."""
        _, tau = self._refinalize()
        self._taubuf = self._taubuf.stage(self.plane.localize(tau))
        self._since_refresh = 0

    def _commit_heads_perm(self) -> None:
        """Apply a staged split/retire head re-map (§14 x §16): the
        atomic partner of the TauBuffer commit/swap that staged it."""
        if self._heads_perm is None or self._head_spec is None:
            self._heads_perm = None
            return
        perm = jnp.asarray(self._heads_perm, jnp.int32)
        self.heads = jax.tree.map(lambda p: p[perm], self.heads)
        self._heads_perm = None

    # -------------------------------------------------------- checkpoint --

    def _counters(self) -> np.ndarray:
        return np.asarray([self._next_id, self._since_refresh,
                           self._served_devices, self._served_points,
                           self._base_seed], np.int64)

    def save(self, path: str) -> str:
        """Checkpoint both tau buffers + version, fold state, counters,
        admission-policy identity/state, the autoscale controller's
        decision state (schema v3), and — schema v4 — the drift mode,
        its split/retire counters and the per-center mass histogram
        (the fold state's epoch stamps ride inside ``server``), so a
        restore replays labels, tau versions, scaling decisions AND
        split/retire decisions bitwise (npz via ``checkpoint.store``).
        Schema v5 (heads enabled) additionally rides the per-cluster
        head params, the heads/arch tag, the routed-serving counters,
        and any STAGED split/retire head re-map — so a restore
        mid-refresh-window commits the same perm at the same boundary.
        Schema v6 (encoder enabled) rides the ingestion-encoder params
        under an encoder/dtype/seq-len tag plus the encoded-point
        counter, so a restored service embeds submissions bitwise like
        the writer. Pending requests are not persisted."""
        from repro.fed.policy import POLICY_IDS
        extra = {}
        if self._head_spec is not None:
            from repro.checkpoint.store import encode_tag
            extra["heads"] = self.heads
            extra["heads_tag"] = encode_tag(
                f"{self.cfg.heads}|{self.cfg.head_arch}")
            extra["heads_counters"] = np.asarray(
                [self._routed_served, self._overflowed], np.int64)
            if self._heads_perm is not None:
                extra["heads_perm"] = np.asarray(self._heads_perm,
                                                 np.int64)
        if self._enc_spec is not None:
            from repro.checkpoint.store import encode_tag
            extra["encoder"] = self.encoder
            extra["encoder_tag"] = encode_tag(
                f"{self.cfg.encoder}|{self.cfg.encode_dtype}|"
                f"{self.cfg.encode_seq_len}")
            extra["encoder_counters"] = np.asarray(
                [self._encoded_points], np.int64)
        return save_pytree(path, {
            **extra,
            "tau_bufs": self._taubuf.bufs,
            "tau_meta": self._taubuf.meta_array(),
            "server": self.state,
            "counters": self._counters(),
            "policy_id": np.asarray(POLICY_IDS[self.policy.name],
                                    np.int64),
            "policy": self.policy.state_arrays(),
            "autoscale_id": np.asarray(AUTOSCALE_IDS[self.cfg.autoscale],
                                       np.int64),
            "drift_id": np.asarray(DRIFT_IDS[self.cfg.drift], np.int64),
            "drift_state": np.asarray(
                [self._drift_events, self._drift_moves,
                 self._drift_last], np.int64),
            "drift_mass": np.asarray(self._drift_mass, np.float32),
            **self.autoscaler.state_arrays()})

    @classmethod
    def restore(cls, path: str, cfg: StreamConfig) -> "AttachService":
        """Deprecated: use ``fed.api.Session.restore`` instead."""
        warn_legacy("fed.stream.AttachService.restore", "Session.restore")
        return cls._restore(path, cfg)

    @classmethod
    def _restore(cls, path: str, cfg: StreamConfig, *, mesh=None,
                 serve_axes=None) -> "AttachService":
        from repro.fed.policy import POLICY_IDS
        policy = make_policy(
            cfg.fold_policy, cfg.capacity, seed=cfg.policy_seed,
            half_life=(cfg.drift_half_life if cfg.drift != "off" else 0))
        # ONE open reads every generation-specific extra; presence of
        # "tau_bufs" doubles as the v1-vs-v2 schema probe,
        # "server/.epoch" (the fold state's epoch stamps) as the v4
        # server probe.
        extras = load_extras(path, ("policy_id", "autoscale_id",
                                    "autoscale_state",
                                    "autoscale_ladder", "tau_bufs",
                                    "drift_id", "drift_state",
                                    "drift_mass", "server/.epoch",
                                    "heads_tag", "heads_counters",
                                    "heads_perm", "encoder_tag",
                                    "encoder_counters"))
        # Refuse a policy mismatch up front (named error, not a bare
        # KeyError / silent state corruption): the checkpoint's slot
        # bookkeeping is only meaningful under the policy that wrote
        # it. Checkpoints from before the policy layer existed could
        # only have been written under the drop rule.
        saved = (int(extras["policy_id"]) if "policy_id" in extras
                 else POLICY_IDS["drop"])
        if saved != POLICY_IDS[cfg.fold_policy]:
            names = {v: n for n, v in POLICY_IDS.items()}
            raise StreamConfigError(
                f"StreamConfig.fold_policy={cfg.fold_policy!r} does not "
                f"match the checkpoint at {path!r}, which was saved "
                f"under fold_policy={names.get(saved, saved)!r}")
        # Schema v3 additionally carries the autoscale decision state;
        # the controller config must match what wrote it, or the
        # replayed decision sequence (and with it the refresh/version
        # boundaries) would silently diverge. v1/v2 checkpoints predate
        # the controller — any autoscale config restores them with a
        # fresh (static) decision.
        if "autoscale_id" in extras:
            saved_as = int(extras["autoscale_id"])
            if saved_as != AUTOSCALE_IDS[cfg.autoscale]:
                names = {v: n for n, v in AUTOSCALE_IDS.items()}
                raise StreamConfigError(
                    f"StreamConfig.autoscale={cfg.autoscale!r} does not "
                    f"match the checkpoint at {path!r}, which was saved "
                    f"under autoscale={names.get(saved_as, saved_as)!r}")
        # Schema v4 carries the drift mode + state. Pre-v4 checkpoints
        # restore under ANY drift config with drift state
        # default-initialized (drift is strictly additive); a v4
        # checkpoint refuses a drift-mode mismatch — the fold epochs,
        # mass histogram and split/retire counters are only meaningful
        # under the mode that wrote them.
        if "drift_id" in extras:
            saved_dr = int(extras["drift_id"])
            if saved_dr != DRIFT_IDS[cfg.drift]:
                names = {v: n for n, v in DRIFT_IDS.items()}
                raise StreamConfigError(
                    f"StreamConfig.drift={cfg.drift!r} does not match "
                    f"the checkpoint at {path!r}, which was saved under "
                    f"drift={names.get(saved_dr, saved_dr)!r}")
        # Schema v5 carries the per-cluster head params under a
        # heads/arch tag. Mismatch (including heads="off" against a v5
        # archive, or a v5 restore under a different config/arch)
        # refuses up front — the folded label/fold state replays, but
        # the predictions a caller would get could not match the ones
        # the archive's writer served. Pre-v5 archives restore under
        # ANY heads config (additive, like drift): heads start from
        # the deterministic seed-derived init.
        if "heads_tag" in extras:
            from repro.checkpoint.store import decode_tag
            tag = decode_tag(extras["heads_tag"])
            want = f"{cfg.heads}|{cfg.head_arch}"
            if tag != want:
                sv_h, sv_a = tag.split("|", 1)
                raise StreamConfigError(
                    f"StreamConfig.heads={cfg.heads!r}/"
                    f"head_arch={cfg.head_arch!r} does not match the "
                    f"checkpoint at {path!r}, which was saved under "
                    f"heads={sv_h!r}/head_arch={sv_a!r}")
        # Schema v6 carries the ingestion-encoder params under an
        # encoder/dtype/seq-len tag. Mismatch (including encoder="off"
        # against a v6 archive, or a different config/dtype/ceiling)
        # refuses up front — the writer's embeddings, and so its
        # labels, could not be reproduced. Pre-v6 archives restore
        # under ANY encoder config (additive, like heads): the encoder
        # starts from the deterministic seed-derived init.
        if "encoder_tag" in extras:
            from repro.checkpoint.store import decode_tag
            tag = decode_tag(extras["encoder_tag"])
            want = (f"{cfg.encoder}|{cfg.encode_dtype}|"
                    f"{cfg.encode_seq_len}")
            if tag != want:
                sv_e, sv_dt, sv_sl = tag.split("|", 2)
                raise StreamConfigError(
                    f"StreamConfig.encoder={cfg.encoder!r}/"
                    f"encode_dtype={cfg.encode_dtype!r}/"
                    f"encode_seq_len={cfg.encode_seq_len!r} does not "
                    f"match the checkpoint at {path!r}, which was "
                    f"saved under encoder={sv_e!r}/encode_dtype="
                    f"{sv_dt!r}/encode_seq_len={sv_sl}")
        # Schema v2 carries the double-buffered tau; v1 (pre-plane)
        # checkpoints hold one tau — restored as version 0 with both
        # buffers equal, so old checkpoints keep replaying bitwise.
        v2 = "tau_bufs" in extras
        # Pre-v4 archives hold a 4-field server state (no epoch
        # stamps): load those leaves through a template with the SAME
        # attribute key paths ("server/.centers" ...) and default the
        # epochs to zero.
        v4srv = "server/.epoch" in extras
        srv_like = server.init_state(cfg.capacity, cfg.k_prime, cfg.d)
        like = {
            "server": (srv_like if v4srv
                       else _ServerStateV3(*tuple(srv_like)[:4])),
            "counters": np.zeros((5,), np.int64),
            "policy": policy.state_like(),
        }
        if v2:
            like["tau_bufs"] = jnp.zeros((2, cfg.k, cfg.d), jnp.float32)
            like["tau_meta"] = np.zeros((3,), np.int64)
        else:
            like["tau"] = jnp.zeros((cfg.k, cfg.d), jnp.float32)
        if "policy_id" in extras:
            like["policy_id"] = np.zeros((), np.int64)
        if "heads_tag" in extras:
            # The deterministic init doubles as the exact-shape restore
            # template (same spec -> same leaf shapes by construction).
            from repro.models import heads as heads_mod
            like["heads"] = heads_mod.init_heads(
                jax.random.PRNGKey(0), cfg.k, cfg.head_spec())
            like["heads_tag"] = np.zeros_like(
                np.asarray(extras["heads_tag"]))
            like["heads_counters"] = np.zeros((2,), np.int64)
            if "heads_perm" in extras:
                like["heads_perm"] = np.zeros((cfg.k,), np.int64)
        if "encoder_tag" in extras:
            # The deterministic init doubles as the exact-shape restore
            # template (same spec -> same leaf shapes by construction).
            from repro.models import encoder as enc_mod
            like["encoder"] = enc_mod.init_encoder(
                jax.random.PRNGKey(0), cfg.encoder_spec())
            like["encoder_tag"] = np.zeros_like(
                np.asarray(extras["encoder_tag"]))
            like["encoder_counters"] = np.zeros((1,), np.int64)
        tree = load_pytree(path, like)
        if tree["policy"]:
            policy.load_state(tree["policy"])
        taubuf = (TauBuffer.from_arrays(tree["tau_bufs"], tree["tau_meta"])
                  if v2 else TauBuffer.fresh(tree["tau"]))
        srv = (tree["server"] if v4srv else server.ServerState(
            *tree["server"],
            jnp.zeros((cfg.capacity,), jnp.int32)))
        cnt = np.asarray(tree["counters"])
        svc = cls(cfg, taubuf.tau, tau_buffer=taubuf,
                  state=srv, policy=policy,
                  seed=int(cnt[4]), next_id=int(cnt[0]),
                  since_refresh=int(cnt[1]), served_devices=int(cnt[2]),
                  served_points=int(cnt[3]), mesh=mesh,
                  serve_axes=serve_axes,
                  heads=tree.get("heads"),
                  encoder=tree.get("encoder"))
        if "encoder_counters" in extras:
            ec = np.asarray(extras["encoder_counters"], np.int64)
            svc._encoded_points = int(ec[0])
        if "heads_counters" in extras:
            hc = np.asarray(extras["heads_counters"], np.int64)
            svc._routed_served = int(hc[0])
            svc._overflowed = int(hc[1])
        if "heads_perm" in extras:
            svc._heads_perm = np.asarray(extras["heads_perm"],
                                         np.int64).copy()
        if "autoscale_state" in extras:
            svc.autoscaler.load_state(extras["autoscale_state"],
                                      extras["autoscale_ladder"])
        if "drift_state" in extras:
            ds = np.asarray(extras["drift_state"], np.int64)
            svc._drift_events = int(ds[0])
            svc._drift_moves = int(ds[1])
            svc._drift_last = int(ds[2])
        if "drift_mass" in extras:
            dm = np.asarray(extras["drift_mass"], np.float32)
            if dm.shape == (cfg.k,):
                svc._drift_mass = dm.copy()
        return svc

    # ------------------------------------------------------------- stats --

    def _heads_stats(self) -> dict:
        if self._head_spec is None:
            return {"mode": "off"}
        from repro.models.heads import head_param_count
        from repro.fed.plane import route_capacity
        return {
            "mode": self.cfg.heads,
            "arch": self.cfg.head_arch,
            "capacity_factor": float(self.cfg.head_capacity),
            "queue_capacity": route_capacity(
                self.cfg.batch_size, self.cfg.k,
                self.cfg.head_capacity),
            "params_per_head": head_param_count(self._head_spec),
            "routed_served": self._routed_served,
            "overflowed": self._overflowed,
            "remap_pending": self._heads_perm is not None,
        }

    def _encoder_stats(self) -> dict:
        if self._enc_spec is None:
            return {"mode": "off"}
        from repro.models.encoder import encoder_param_count
        return {
            "mode": self.cfg.encoder,
            "dtype": self.cfg.encode_dtype,
            "seq_len": self.cfg.encode_seq_len,
            "layers": self._enc_spec.n_layers,
            "params": encoder_param_count(self._enc_spec),
            "encoded_points": self._encoded_points,
        }

    def stats(self) -> dict:
        return {
            "served_devices": self._served_devices,
            "served_points": self._served_points,
            "folded": int(np.asarray(jnp.sum(self.state.received))),
            "capacity": self.cfg.capacity,
            "fold_policy": self.policy.name,
            "pending": len(self._pending),
            "undelivered": len(self._done),
            "since_refresh": self._since_refresh,
            "tau_version": self._taubuf.version,
            "refresh_pending": self._taubuf.pending,
            "autoscale": self.autoscaler.stats(),
            "heads": self._heads_stats(),
            "encoder": self._encoder_stats(),
            "drift": {
                "mode": self.cfg.drift,
                "half_life": self.cfg.drift_half_life,
                "events": self._drift_events,
                "moves": self._drift_moves,
                "last_moves": self._drift_last,
                "mass": [float(m) for m in self._drift_mass],
            },
            **self.plane.describe(),
        }
