"""IFCA — Iterative Federated Clustering Algorithm (Ghosh et al., 2020).

The iterative baseline the paper compares against in Table 2: the server
keeps k models; every round ALL k models are broadcast, each device picks
the one with lowest local loss, runs local updates on it, and the server
averages per chosen model. Communication per round is k models down + one
model up per device — vs k-FED's single O(d k') message total.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.fed.client import local_sgd
from repro.fed.fedavg import FedAvgConfig, weighted_average


def ifca_round(loss_fn: Callable, models, device_data, cfg: FedAvgConfig,
               *, point_mask=None):
    """models: pytree stacked over leading k axis. Returns (models,
    assignments (Z,), mean_loss)."""
    k = jax.tree.leaves(models)[0].shape[0]
    Z = jax.tree.leaves(device_data)[0].shape[0]
    pm = point_mask if point_mask is not None else \
        jnp.ones(jax.tree.leaves(device_data)[0].shape[:2], bool)

    def client(data, pmz):
        losses = jax.vmap(lambda m: loss_fn(m, data))(models)       # (k,)
        choice = jnp.argmin(losses)
        chosen = jax.tree.map(lambda leaf: leaf[choice], models)
        upd = local_sgd(loss_fn, chosen, data, lr=cfg.lr,
                        epochs=cfg.local_epochs, point_mask=pmz)
        return choice, upd.params, upd.n, upd.loss

    choice, new_params, n, loss = jax.vmap(client)(device_data, pm)

    def per_model(j):
        w = n * (choice == j)
        has = jnp.sum(w) > 0
        avg = weighted_average(new_params, w)
        old = jax.tree.map(lambda leaf: leaf[j], models)
        return jax.tree.map(
            lambda a, o: jnp.where(has, a, o), avg, old)

    updated = [per_model(j) for j in range(k)]
    models = jax.tree.map(lambda *xs: jnp.stack(xs), *updated)
    mean_loss = jnp.sum(loss * n) / jnp.maximum(jnp.sum(n), 1e-9)
    return models, choice, mean_loss
