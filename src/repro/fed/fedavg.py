"""FedAvg (McMahan et al., 2017): the aggregation substrate the paper's
personalization experiment builds on (k-FED clusters first, FedAvg trains
one model per cluster)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.fed.client import ClientUpdate, local_sgd


@dataclass(frozen=True)
class FedAvgConfig:
    lr: float = 0.05
    local_epochs: int = 5
    rounds: int = 20


def make_local_step(loss_fn: Callable, cfg: FedAvgConfig):
    def run(params, data, point_mask=None):
        return local_sgd(loss_fn, params, data, lr=cfg.lr,
                         epochs=cfg.local_epochs, point_mask=point_mask)
    return run


def weighted_average(params_stack, weights):
    """params_stack: pytree with leading client axis; weights: (Z,)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-9)

    def avg(leaf):
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=1).astype(
            leaf.dtype)

    return jax.tree.map(avg, params_stack)


def fedavg_round(loss_fn: Callable, global_params, device_data, cfg:
                 FedAvgConfig, *, point_mask=None, member_mask=None):
    """One synchronous round over the (vmapped) client cohort.

    device_data: pytree with leading (Z, ...) client axis.
    member_mask: (Z,) weights 0/1 — which clients participate (used by the
    per-cluster FedAvg of the personalization pipeline).
    Returns (new_global_params, mean_loss).
    """
    Z = jax.tree.leaves(device_data)[0].shape[0]
    local = make_local_step(loss_fn, cfg)

    def per_client(data, pm):
        return local(global_params, data, pm)

    pm = point_mask if point_mask is not None else \
        jnp.ones(jax.tree.leaves(device_data)[0].shape[:2], bool)
    upd: ClientUpdate = jax.vmap(per_client)(device_data, pm)
    weights = upd.n
    if member_mask is not None:
        weights = weights * member_mask
    new_params = weighted_average(upd.params, weights)
    mean_loss = jnp.sum(upd.loss * weights) / jnp.maximum(
        jnp.sum(weights), 1e-9)
    return new_params, mean_loss
