"""Client-side computation: local SGD steps on a device's data, plus the
summary vectors k-FED clusters (mean embeddings / update sketches)."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class ClientUpdate(NamedTuple):
    params: dict          # updated local params
    n: jax.Array          # local example count (weight for averaging)
    loss: jax.Array


def local_sgd(loss_fn: Callable, params, data, *, lr: float,
              epochs: int, point_mask=None) -> ClientUpdate:
    """``epochs`` full-batch gradient steps on this client's data."""
    n = (jnp.sum(point_mask) if point_mask is not None
         else jnp.asarray(data["x"].shape[0], jnp.float32))

    def step(p, _):
        loss, g = jax.value_and_grad(loss_fn)(p, data)
        p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
        return p, loss

    params, losses = jax.lax.scan(step, params, None, length=epochs)
    return ClientUpdate(params, n, losses[-1])


def summary_vector(embed_fn: Callable, params, data, point_mask=None):
    """Mean embedding of a client's data — the vector Algorithm 1 runs on
    when k-FED clusters clients (rather than raw points)."""
    e = embed_fn(params, data)                       # (n, d)
    if point_mask is None:
        return jnp.mean(e, axis=0)
    w = point_mask.astype(e.dtype)[:, None]
    return jnp.sum(e * w, axis=0) / jnp.maximum(jnp.sum(w), 1.0)


def delta_sketch(old_params, new_params, dim: int = 256):
    """Deterministic low-dim sketch of a model delta (client update
    direction) — an alternative clustering feature for k-FED."""
    leaves = [((a - b).astype(jnp.float32)).ravel()
              for a, b in zip(jax.tree.leaves(new_params),
                              jax.tree.leaves(old_params))]
    v = jnp.concatenate(leaves)
    n = v.shape[0]
    # Strided bucket sums: cheap, deterministic, linear in the delta.
    pad = (-n) % dim
    vb = jnp.pad(v, (0, pad)).reshape(-1, dim)
    return jnp.sum(vb, axis=0) / jnp.sqrt(jnp.maximum(n, 1))
