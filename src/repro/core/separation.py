"""Separation / heterogeneity analysis utilities (Section 3 of the paper).

Implements the deterministic quantities the theory is stated in:

  ||A - C||                  spectral norm of the data-minus-means matrix
  tilde_Delta_r = sqrt(k) ||A-C|| / sqrt(n_r)      (eq. 2, centralized)
  Delta_r       = k'      ||A-C|| / sqrt(n_r)      (eq. 4)
  lambda        = sqrt(k')||A-C|| / sqrt(n_min)    (eq. 4)

plus active/inactive pair detection (Definition 3.4), the active/inactive
separation requirements (Definition 3.5 / Theorem 3.1), the proximity
condition (Definition 3.1), and the c_rs spectra used for the paper's
oracle-clustering construction (Appendix B.2, Figure 5).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


def spectral_norm(M: jax.Array, iters: int = 100) -> jax.Array:
    """||M|| by power iteration on M^T M (deterministic start vector)."""
    Mf = M.astype(jnp.float32)
    d = Mf.shape[1]
    v = jnp.ones((d,)) + 1e-3 * jnp.arange(d, dtype=jnp.float32)
    v = v / jnp.linalg.norm(v)

    def body(_, v):
        w = Mf.T @ (Mf @ v)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(Mf @ v)


def cluster_means(A: jax.Array, labels: jax.Array, k: int):
    """Returns (means (k, d), sizes (k,)); labels -1 ignored."""
    sums, cnt = ops.kmeans_update(A.astype(jnp.float32), labels, k)
    return sums / jnp.maximum(cnt, 1.0)[:, None], cnt


def a_minus_c_norm(A: jax.Array, labels: jax.Array, k: int) -> jax.Array:
    """||A - C|| where C_i = mu(T_{c(A_i)})."""
    mu, _ = cluster_means(A, labels, k)
    safe = jnp.clip(labels, 0, k - 1)
    C = mu[safe]
    diff = (A.astype(jnp.float32) - C) * (labels >= 0)[:, None]
    return spectral_norm(diff)


def deltas(norm_ac: jax.Array, sizes: jax.Array, k_prime: int):
    """Delta_r (eq. 4) for every cluster."""
    return k_prime * norm_ac / jnp.sqrt(jnp.maximum(sizes, 1.0))


def tilde_deltas(norm_ac: jax.Array, sizes: jax.Array, k: int):
    """tilde_Delta_r (eq. 2), the centralized analogue."""
    return jnp.sqrt(float(k)) * norm_ac / jnp.sqrt(jnp.maximum(sizes, 1.0))


def lam(norm_ac: jax.Array, n_min_device, k_prime: int):
    """lambda (eq. 4); n_min_device = min_z n^(z)."""
    return jnp.sqrt(float(k_prime)) * norm_ac / jnp.sqrt(
        jnp.maximum(jnp.asarray(n_min_device, jnp.float32), 1.0))


def active_pairs(presence: jax.Array) -> jax.Array:
    """Definition 3.4. presence: (Z, k) bool — cluster r has points on z.
    Returns (k, k) bool, True where some device holds both r and s."""
    co = jnp.einsum("zr,zs->rs", presence.astype(jnp.float32),
                    presence.astype(jnp.float32))
    act = co > 0
    return act & ~jnp.eye(presence.shape[1], dtype=bool)


class SeparationReport(NamedTuple):
    norm_ac: jax.Array          # ||A - C||
    sizes: jax.Array            # (k,) n_r
    means: jax.Array            # (k, d)
    delta: jax.Array            # (k,) Delta_r
    lam: jax.Array              # () lambda
    c_rs: jax.Array             # (k, k) ||mu_r-mu_s|| / (sqrt(m0)(D_r+D_s))
    active: jax.Array           # (k, k) bool
    active_satisfied: jax.Array     # fraction of active pairs with c_rs >= c
    inactive_satisfied: jax.Array   # fraction of inactive pairs meeting
                                    # ||mu_r-mu_s|| >= 10 sqrt(m0) lambda


def separation_report(A: jax.Array, labels: jax.Array, k: int,
                      presence: jax.Array, n_min_device, *,
                      k_prime: int, m0: float, c: float) -> SeparationReport:
    mu, sizes = cluster_means(A, labels, k)
    norm_ac = a_minus_c_norm(A, labels, k)
    D = deltas(norm_ac, sizes, k_prime)
    lm = lam(norm_ac, n_min_device, k_prime)

    dmu = jnp.sqrt(jnp.maximum(ops.pairwise_sq_dists(mu, mu), 0.0))
    denom = jnp.sqrt(m0) * (D[:, None] + D[None, :])
    c_rs = dmu / jnp.maximum(denom, 1e-30)
    act = active_pairs(presence)
    off = ~jnp.eye(k, dtype=bool)
    inact = off & ~act

    act_ok = jnp.sum((c_rs >= c) & act) / jnp.maximum(jnp.sum(act), 1)
    inact_ok = jnp.sum((dmu >= 10.0 * jnp.sqrt(m0) * lm) & inact) / \
        jnp.maximum(jnp.sum(inact), 1)
    return SeparationReport(norm_ac, sizes, mu, D, lm, c_rs, act,
                            act_ok, inact_ok)


def proximity_satisfied(A: jax.Array, labels: jax.Array, k: int,
                        norm_ac=None) -> jax.Array:
    """Definition 3.1 per point: for i in T_s and every r != s the scalar
    projection of A_i on the mu_r -> mu_s line must favor mu_s by
    (1/sqrt(n_r) + 1/sqrt(n_s)) ||A - C||. Returns (n,) bool."""
    n, d = A.shape
    Af = A.astype(jnp.float32)
    mu, sizes = cluster_means(A, labels, k)
    if norm_ac is None:
        norm_ac = a_minus_c_norm(A, labels, k)
    inv_sqrt = 1.0 / jnp.sqrt(jnp.maximum(sizes, 1.0))

    s = jnp.clip(labels, 0, k - 1)                     # (n,)
    mu_s = mu[s]                                       # (n, d)
    # For every r: unit vector u = (mu_r - mu_s)/||.||, t = (A_i - mu_s).u
    diff_centers = mu[None, :, :] - mu_s[:, None, :]   # (n, k, d)
    sep = jnp.linalg.norm(diff_centers, axis=-1)       # (n, k)
    u = diff_centers / jnp.maximum(sep, 1e-30)[..., None]
    t = jnp.einsum("nd,nkd->nk", Af - mu_s, u)         # proj coordinate
    # ||bar A - mu_s|| = |t|; ||bar A - mu_r|| = |t - sep|
    margin = jnp.abs(t - sep) - jnp.abs(t)             # >= thresh required
    thresh = (inv_sqrt[None, :] + inv_sqrt[s][:, None]) * norm_ac
    same = jax.nn.one_hot(s, k, dtype=bool)
    ok_rs = (margin >= thresh) | same | (sizes[None, :] == 0)
    ok = jnp.all(ok_rs, axis=1) & (labels >= 0)
    return ok
