"""Algorithm 1 — the local (per-device) k-means solve of k-FED.

Faithful to Awasthi & Sheffet (2012) as stated in the paper:

  1. Project the device data A^(z) onto the span of its top-k^(z) right
     singular vectors.
  2. Run a standard approximation algorithm on the projected data
     (k-means++ seeding + a few Lloyd polish steps — any O(1)-approx
     qualifies for the paper's "10-approximation" role).
  3. Form the 1/3-margin core sets
        S_r = { i : ||Ahat_i - nu_r|| <= (1/3) ||Ahat_i - nu_s||  forall s }
     and re-center on their means theta_r = mu(S_r).
  4. Run Lloyd steps on the ORIGINAL data until convergence.

Fixed-shape + masked so it vmaps over devices with heterogeneous k^(z)
(k_valid) and n^(z) (point_mask).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.lloyd import kmeans_pp_init, lloyd, update_centers
from repro.kernels import ops
from repro.kernels.ref import MASKED_DIST


def project_top_k(A: jax.Array, k_valid, k_max: int,
                  point_mask: Optional[jax.Array] = None) -> jax.Array:
    """Projection of rows of A onto the top-k_valid right singular subspace.

    Exact SVD path; see ``subspace_project`` for the iterative TPU-friendly
    variant used at large n*d.
    """
    n, d = A.shape
    Af = A.astype(jnp.float32)
    Am = Af if point_mask is None else Af * point_mask[:, None]
    Vt = jnp.linalg.svd(Am, full_matrices=False)[2]  # (min(n,d), d)
    rows = min(k_max, Vt.shape[0])
    V = jnp.zeros((k_max, d), jnp.float32).at[:rows].set(Vt[:rows])
    rmask = jnp.arange(k_max) < jnp.asarray(k_valid, jnp.int32)
    V = V * rmask[:, None]
    return ((Af @ V.T) @ V).astype(A.dtype)


def subspace_project(A: jax.Array, k_valid, k_max: int,
                     point_mask: Optional[jax.Array] = None,
                     iters: int = 12) -> jax.Array:
    """Block power (subspace) iteration on A^T A — the TPU-native variant
    of the SVD projection (matmul-only; no LAPACK on-device)."""
    n, d = A.shape
    Af = A.astype(jnp.float32)
    Am = Af if point_mask is None else Af * point_mask[:, None]

    # Deterministic full-rank start.
    i = jnp.arange(d, dtype=jnp.float32)[:, None]
    j = jnp.arange(k_max, dtype=jnp.float32)[None, :]
    V = jnp.cos(0.37 * (i + 1.0) * (j + 1.0)) + 1e-3 * (i - j)

    def body(_, V):
        W = Am.T @ (Am @ V)
        Q, _ = jnp.linalg.qr(W)
        return Q

    V = jax.lax.fori_loop(0, iters, body, jnp.linalg.qr(V)[0])  # (d, k_max)
    rmask = (jnp.arange(k_max) < jnp.asarray(k_valid, jnp.int32))
    V = V * rmask[None, :]
    return ((Af @ V) @ V.T).astype(A.dtype)


class LocalKMeansResult(NamedTuple):
    centers: jax.Array       # (k_max, d)  Theta^(z)
    center_mask: jax.Array   # (k_max,) bool
    assign: jax.Array        # (n,) int32 local cluster ids, -1 masked
    core_counts: jax.Array   # (k_max,) |S_r| from the 1/3-margin step


class LocalPrepared(NamedTuple):
    """Steps 1-3 of Algorithm 1: the core-set re-centered seeds that the
    step-4 convergence loop (now fused with the Theorem 3.2 attach in
    ``core.lloyd.lloyd_attach`` on the serve path) starts from."""
    theta: jax.Array         # (k_max, d) f32 core-set means
    center_mask: jax.Array   # (k_max,) bool
    core_counts: jax.Array   # (k_max,) |S_r| from the 1/3-margin step


def split_local_kw(local_kw: dict):
    """Split a ``local_kmeans``-style kwargs dict into the kwargs of
    :func:`local_prepare` (steps 1-3) and the step-4 ``max_iters``
    bound consumed by the fused solve+attach."""
    kw = dict(local_kw)
    return kw, int(kw.pop("max_iters", 100))


def local_prepare(key: jax.Array, A: jax.Array, *, k_max: int,
                  k_valid: Optional[jax.Array] = None,
                  point_mask: Optional[jax.Array] = None,
                  approx_iters: int = 8,
                  use_subspace_iteration: bool = False) -> LocalPrepared:
    """Algorithm 1 steps 1-3 on one device: spectral projection,
    k-means++ + approximate Lloyd on the projected data, and the
    1/3-margin core-set re-centering. Bitwise-identical to the first
    three steps of :func:`local_kmeans` (it IS them, factored out)."""
    n, d = A.shape
    kv = jnp.asarray(k_max if k_valid is None else k_valid, jnp.int32)
    pm = jnp.ones((n,), bool) if point_mask is None else point_mask

    # -- Step 1: spectral projection.
    proj = subspace_project if use_subspace_iteration else project_top_k
    Ahat = proj(A, kv, k_max, point_mask=pm)

    # -- Step 2: approximation algorithm on projected data.
    nu, cmask = kmeans_pp_init(key, Ahat, k_max, point_mask=pm, k_valid=kv)
    nu = lloyd(Ahat, nu, center_mask=cmask, point_mask=pm,
               max_iters=approx_iters).centers

    # -- Step 3: 1/3-margin core sets (distances, not squared distances).
    d2 = ops.pairwise_sq_dists(Ahat, nu)
    d2 = jnp.where(cmask[None, :], d2, MASKED_DIST)
    dd = jnp.sqrt(d2)
    r = jnp.argmin(dd, axis=1)
    dmin = jnp.min(dd, axis=1)
    second = jnp.min(
        jnp.where(jax.nn.one_hot(r, k_max, dtype=bool), jnp.inf, dd), axis=1)
    in_core = (dmin <= second / 3.0) & pm
    core_assign = jnp.where(in_core, r, -1)
    theta, core_counts = update_centers(A.astype(jnp.float32), core_assign,
                                        k_max, nu.astype(jnp.float32))
    return LocalPrepared(theta, cmask, core_counts)


def local_kmeans(key: jax.Array, A: jax.Array, *, k_max: int,
                 k_valid: Optional[jax.Array] = None,
                 point_mask: Optional[jax.Array] = None,
                 approx_iters: int = 8, max_iters: int = 100,
                 use_subspace_iteration: bool = False) -> LocalKMeansResult:
    """Algorithm 1 on one device. ``k_max`` static; ``k_valid`` may be a
    traced per-device k^(z) <= k_max."""
    n, d = A.shape
    pm = jnp.ones((n,), bool) if point_mask is None else point_mask
    prep = local_prepare(key, A, k_max=k_max, k_valid=k_valid,
                         point_mask=pm, approx_iters=approx_iters,
                         use_subspace_iteration=use_subspace_iteration)

    # -- Step 4: Lloyd on the original data until convergence.
    res = lloyd(A.astype(jnp.float32), prep.theta,
                center_mask=prep.center_mask, point_mask=pm,
                max_iters=max_iters)
    return LocalKMeansResult(res.centers.astype(A.dtype), prep.center_mask,
                             res.assign, prep.core_counts)


def _batched(fn, keys, data, k_max, k_valid, point_mask, kw):
    wrapped = lambda key, A, kv, pm: fn(
        key, A, k_max=k_max, k_valid=kv, point_mask=pm, **kw)
    Z = data.shape[0]
    if k_valid is None:
        k_valid = jnp.full((Z,), k_max, jnp.int32)
    if point_mask is None:
        point_mask = jnp.ones(data.shape[:2], bool)
    return jax.vmap(wrapped)(keys, data, k_valid, point_mask)


def batched_local_kmeans(keys, data, *, k_max: int, k_valid=None,
                         point_mask=None, **kw):
    """vmap of Algorithm 1 over the device axis: data (Z, n, d)."""
    return _batched(local_kmeans, keys, data, k_max, k_valid, point_mask, kw)


def batched_local_prepare(keys, data, *, k_max: int, k_valid=None,
                          point_mask=None, **kw):
    """vmap of Algorithm 1 steps 1-3 over the device axis (the serve
    plane pairs this with the fused ``lloyd_attach``)."""
    return _batched(local_prepare, keys, data, k_max, k_valid, point_mask, kw)
