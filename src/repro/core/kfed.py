"""Algorithm 2 — the one-shot k-FED aggregation at the central server,
plus the induced clustering (Definition 3.3) and the new-device assignment
rule (Theorem 3.2).

The server receives only the device cluster centers Theta^(z) (one message
of size O(d k^(z)) per device — the one-shot property), seeds k centers by
max-min selection starting from one device's centers, runs ONE round of
Lloyd's heuristic on the ~Z*k' device centers, and returns the partition
tau_1..tau_k of device centers. Every data point inherits the tau-label of
its local cluster center.

This module is the stable public surface; the server arithmetic itself
lives in ``core/server.py`` (ONE implementation shared by the vmap
simulation, the replicated shard_map path, and the sharded-server path —
DESIGN.md §4), and the scenario layer (participation masks, async
arrival, weighting) in ``fed/engine.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# Re-exported shared server core (one implementation for all paths).
from repro.core.server import (  # noqa: F401
    KFedAggregate,
    assign_new_device,
    induced_labels,
)
from repro.core import server as S


def aggregate(device_centers: jax.Array, center_mask: jax.Array,
              k: int, weights: Optional[jax.Array] = None) -> KFedAggregate:
    """Steps 2-8 of Algorithm 2. device_centers: (Z, k', d). Routes
    through the shared server core; ``weights`` optionally weights the
    one Lloyd round by per-center mass (e.g. Algorithm 1 core set
    sizes)."""
    return S.aggregate(device_centers, center_mask, k, weights=weights)


class KFedResult(NamedTuple):
    agg: KFedAggregate
    device_centers: jax.Array   # (Z, k', d)
    center_mask: jax.Array      # (Z, k')
    local_assign: jax.Array     # (Z, n)
    labels: jax.Array           # (Z, n) induced clustering, -1 padded


def _kfed_impl(key, device_data, k, k_prime, *, k_valid=None,
               point_mask=None, participation=None,
               weight_by_core_counts=False, **local_kw) -> KFedResult:
    """Internal simulation path (no deprecation warning) — what both
    the legacy :func:`kfed` shim and warning-clean internal callers
    (e.g. ``fed.personalize``) route through."""
    from repro.fed import api  # lazy: core -> fed
    plan = api.FederationPlan(
        k=k, k_prime=k_prime, d=int(device_data.shape[-1]),
        weight_by_core_counts=weight_by_core_counts,
        local_kw=dict(local_kw))
    r = api.Session(plan).run(key, device_data,
                              participation=participation,
                              k_valid=k_valid, point_mask=point_mask)
    rr = r.detail
    return KFedResult(rr.agg, rr.device_centers, rr.center_mask,
                      rr.local_assign, rr.labels)


def kfed(key: jax.Array, device_data: jax.Array, k: int, k_prime: int, *,
         k_valid: Optional[jax.Array] = None,
         point_mask: Optional[jax.Array] = None,
         participation: Optional[jax.Array] = None,
         weight_by_core_counts: bool = False,
         **local_kw) -> KFedResult:
    """Deprecated: use ``fed.api.Session.run`` (this shim routes
    through it with bitwise-identical results).

    device_data: (Z, n, d) padded per-device data. ``participation``:
    optional (Z,) bool — devices that missed the round are excluded from
    aggregation and attached post-hoc via the Theorem 3.2 rule.
    """
    from repro.utils.deprecation import warn_legacy
    warn_legacy("core.kfed.kfed", "Session.run")
    return _kfed_impl(key, device_data, k, k_prime, k_valid=k_valid,
                      point_mask=point_mask, participation=participation,
                      weight_by_core_counts=weight_by_core_counts,
                      **local_kw)


def kmeans_cost_of_labels(data: jax.Array, labels: jax.Array,
                          k: int) -> jax.Array:
    """phi(T) (eq. 1) of an arbitrary labeling. data: (..., n, d) flattened
    internally; labels -1 entries ignored."""
    from repro.kernels import ops
    x = data.reshape(-1, data.shape[-1]).astype(jnp.float32)
    lb = labels.reshape(-1)
    sums, cnt = ops.kmeans_update(x, lb, k)
    mu = sums / jnp.maximum(cnt, 1.0)[:, None]
    safe = jnp.clip(lb, 0, k - 1)
    diff = x - mu[safe]
    per = jnp.sum(diff * diff, axis=1)
    return jnp.sum(jnp.where(lb >= 0, per, 0.0))
