"""Algorithm 2 — the one-shot k-FED aggregation at the central server,
plus the induced clustering (Definition 3.3) and the new-device assignment
rule (Theorem 3.2).

The server receives only the device cluster centers Theta^(z) (one message
of size O(d k^(z)) per device — the one-shot property), seeds k centers by
max-min selection starting from one device's centers, runs ONE round of
Lloyd's heuristic on the ~Z*k' device centers, and returns the partition
tau_1..tau_k of device centers. Every data point inherits the tau-label of
its local cluster center.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import lloyd as L
from repro.core.local_kmeans import batched_local_kmeans


class KFedAggregate(NamedTuple):
    seeds_idx: jax.Array       # (k,) indices into flattened (Z*k') centers
    seed_centers: jax.Array    # (k, d) the set M
    tau_centers: jax.Array     # (k, d) mu(tau_r) after the one Lloyd round
    center_labels: jax.Array   # (Z, k') tau-label of each device center, -1 pad
    z0: jax.Array              # () the device whose centers seeded M


def aggregate(device_centers: jax.Array, center_mask: jax.Array,
              k: int) -> KFedAggregate:
    """Steps 2-8 of Algorithm 2. device_centers: (Z, k', d)."""
    Z, kp, d = device_centers.shape
    flat = device_centers.reshape(Z * kp, d)
    fm = center_mask.reshape(Z * kp)

    # "Pick any z": deterministically pick the device with most local
    # clusters (maximizes the seeded set, minimizes max-min iterations).
    kz = jnp.sum(center_mask, axis=1)
    z0 = jnp.argmax(kz).astype(jnp.int32)
    init_sel = ((jnp.arange(Z) == z0)[:, None] & center_mask).reshape(-1)

    seeds_idx = L.maxmin_seed(flat, fm, init_sel, k)
    M = flat[seeds_idx]

    # One round of Lloyd's heuristic over the device centers.
    labels, _ = L.assign_points(flat, M, point_mask=fm)
    tau_centers, _ = L.update_centers(flat.astype(jnp.float32), labels, k,
                                      M.astype(jnp.float32))
    return KFedAggregate(seeds_idx, M, tau_centers.astype(device_centers.dtype),
                         labels.reshape(Z, kp), z0)


def induced_labels(center_labels: jax.Array,
                   local_assign: jax.Array) -> jax.Array:
    """Definition 3.3: point i on device z with local cluster s gets label
    tau(theta_s^(z)). center_labels: (Z, k'), local_assign: (Z, n)."""
    safe = jnp.clip(local_assign, 0, center_labels.shape[1] - 1)
    lbl = jnp.take_along_axis(center_labels, safe, axis=1)
    return jnp.where(local_assign >= 0, lbl, -1)


def assign_new_device(new_centers: jax.Array, new_mask: jax.Array,
                      ref_centers: jax.Array) -> jax.Array:
    """Theorem 3.2: a device joining after clustering is assigned by
    nearest-neighbor matching of its local centers against the k retained
    server centers — O(k' * k) distance computations, no other device
    involved. new_centers: (k', d); ref_centers: (k, d)."""
    labels, _ = L.assign_points(new_centers, ref_centers,
                                point_mask=new_mask)
    return labels


class KFedResult(NamedTuple):
    agg: KFedAggregate
    device_centers: jax.Array   # (Z, k', d)
    center_mask: jax.Array      # (Z, k')
    local_assign: jax.Array     # (Z, n)
    labels: jax.Array           # (Z, n) induced clustering, -1 padded


def kfed(key: jax.Array, device_data: jax.Array, k: int, k_prime: int, *,
         k_valid: Optional[jax.Array] = None,
         point_mask: Optional[jax.Array] = None,
         **local_kw) -> KFedResult:
    """End-to-end k-FED (simulation path): vmapped Algorithm 1 over the
    device axis followed by the server aggregation.

    device_data: (Z, n, d) padded per-device data.
    """
    Z = device_data.shape[0]
    keys = jax.random.split(key, Z)
    loc = batched_local_kmeans(keys, device_data, k_max=k_prime,
                               k_valid=k_valid, point_mask=point_mask,
                               **local_kw)
    agg = aggregate(loc.centers, loc.center_mask, k)
    labels = induced_labels(agg.center_labels, loc.assign)
    return KFedResult(agg, loc.centers, loc.center_mask, loc.assign, labels)


def kmeans_cost_of_labels(data: jax.Array, labels: jax.Array,
                          k: int) -> jax.Array:
    """phi(T) (eq. 1) of an arbitrary labeling. data: (..., n, d) flattened
    internally; labels -1 entries ignored."""
    from repro.kernels import ops
    x = data.reshape(-1, data.shape[-1]).astype(jnp.float32)
    lb = labels.reshape(-1)
    sums, cnt = ops.kmeans_update(x, lb, k)
    mu = sums / jnp.maximum(cnt, 1.0)[:, None]
    safe = jnp.clip(lb, 0, k - 1)
    diff = x - mu[safe]
    per = jnp.sum(diff * diff, axis=1)
    return jnp.sum(jnp.where(lb >= 0, per, 0.0))
