"""Distributed k-FED: shard_map production path + vmap simulation path.

The paper's protocol maps onto the mesh as follows (DESIGN.md §4):

  * each shard of the ``data`` axis hosts a cohort of federated devices
    (vmapped Algorithm 1 — devices never exchange raw data);
  * the ONE round of communication is literally one ``all_gather`` of the
    (Z, k', d) device-center tensor over the data axis;
  * the server aggregation (steps 2-8 of Algorithm 2, O(Z k' k^2) distance
    computations — Theorem 3.2) is replicated on every shard, which is
    cheaper than any dedicated-server emulation and keeps SPMD semantics.

For comparison benchmarks we also provide ``distributed_lloyd`` — the naive
multi-round parallel Lloyd baseline (one all-reduce of (k, d) sums + (k,)
counts per iteration), whose collective schedule shows T rounds vs k-FED's
single gather.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import kfed as K
from repro.core import lloyd as L
from repro.core.local_kmeans import batched_local_kmeans


def _axes(axis):
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _flat_axis_index(axes, mesh):
    """Linear shard index for a PartitionSpec((*axes,)) sharding — axes
    listed major-to-minor, matching tiled all_gather ordering."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _sharded_server(centers_loc, mask_loc, kz_all, k, axes, mesh):
    """Steps 2-8 of Algorithm 2 with the server itself sharded: each chip
    owns its m_loc = Z_loc*k' slice of the device centers; the greedy
    max-min runs as (local argmax -> two scalar all-reduces -> (d,) psum
    of the winning center) per iteration, so per-chip HBM traffic is
    m_loc*d per iteration instead of Z*k'*d (§Perf k-FED iteration 2).
    Selection order matches the replicated server (first-occurrence
    argmax = smallest global index among ties).

    centers_loc: (Z_loc, k', d); mask_loc: (Z_loc, k'); kz_all: (Z,).
    Returns (M (k, d), tau_centers (k, d), my_labels (Z_loc, k')).
    """
    Z_loc, kp, d = centers_loc.shape
    m_loc = Z_loc * kp
    pf = centers_loc.reshape(m_loc, d).astype(jnp.float32)
    fm = mask_loc.reshape(m_loc)
    shard = _flat_axis_index(axes, mesh)
    base = shard * m_loc
    BIG = jnp.int32(2 ** 30)

    # "Pick any z": the device with most local clusters, first one wins.
    z0 = jnp.argmax(kz_all).astype(jnp.int32)
    own_rows = jnp.arange(m_loc) // kp == (z0 - shard * Z_loc)
    init_loc = own_rows & fm                              # (m_loc,)
    count0 = jax.lax.psum(jnp.sum(init_loc).astype(jnp.int32), axes)

    # Initial chosen indices (global, ascending) and their coordinates.
    cand = jnp.where(init_loc, base + jnp.arange(m_loc, dtype=jnp.int32),
                     BIG)
    cand = jnp.sort(cand)[:k] if m_loc >= k else jnp.sort(
        jnp.pad(cand, (0, k - m_loc), constant_values=BIG))[:k]
    chosen0 = jax.lax.pmin(cand, axes)                    # (k,) owner wins
    # owner scatters its init rows into slot order; others contribute 0
    slot_of = jnp.cumsum(init_loc.astype(jnp.int32)) - 1
    M0 = jnp.zeros((k, d), jnp.float32).at[
        jnp.clip(slot_of, 0, k - 1)].add(
            jnp.where(init_loc[:, None], pf, 0.0))
    M0 = jax.lax.psum(M0, axes)                           # (k, d)

    from repro.kernels import ops
    d2 = ops.pairwise_sq_dists(pf, M0)                    # (m_loc, k)
    ok = jnp.arange(k) < count0
    mind2 = jnp.min(jnp.where(ok[None, :], d2, jnp.inf), axis=1)
    mind2 = jnp.where(fm, mind2, -jnp.inf)
    p2 = jnp.sum(pf * pf, axis=1)
    chosen = jnp.where(jnp.arange(k) < count0, chosen0, -1)

    def body(t, carry):
        chosen, mind2 = carry
        grow = t >= count0
        lmax = jnp.max(mind2)
        larg = jnp.argmax(mind2).astype(jnp.int32)
        gmax = jax.lax.pmax(lmax, axes)
        cand_g = jax.lax.pmin(
            jnp.where(lmax >= gmax, base + larg, BIG), axes)
        chosen = jnp.where(grow, chosen.at[t].set(cand_g), chosen)
        mine = (cand_g >= base) & (cand_g < base + m_loc)
        row = jnp.clip(cand_g - base, 0, m_loc - 1)
        c = jax.lax.psum(jnp.where(mine, pf[row], 0.0), axes)   # (d,)
        nd = jnp.maximum(p2 - 2.0 * (pf @ c) + jnp.sum(c * c), 0.0)
        nd = jnp.where(fm, nd, -jnp.inf)
        mind2 = jnp.where(grow, jnp.minimum(mind2, nd), mind2)
        return chosen, mind2

    chosen, _ = jax.lax.fori_loop(0, k, body, (chosen, mind2))

    # Assemble M from owners; one local Lloyd assignment + global update.
    mine_t = (chosen >= base) & (chosen < base + m_loc)
    rows = jnp.clip(chosen - base, 0, m_loc - 1)
    M = jax.lax.psum(jnp.where(mine_t[:, None], pf[rows], 0.0), axes)
    labels, _ = L.assign_points(pf, M, center_mask=chosen >= 0,
                                point_mask=fm)
    sums, cnt = ops.kmeans_update(pf, labels, k)
    sums = jax.lax.psum(sums, axes)
    cnt = jax.lax.psum(cnt, axes)
    tau = jnp.where((cnt > 0)[:, None],
                    sums / jnp.maximum(cnt, 1.0)[:, None], M)
    return M, tau.astype(centers_loc.dtype), labels.reshape(Z_loc, kp)


def kfed_shard_map(mesh, data: jax.Array, k: int, k_prime: int, *,
                   key: jax.Array, axis="data", server: str = "replicated",
                   k_valid: Optional[jax.Array] = None,
                   point_mask: Optional[jax.Array] = None,
                   **local_kw):
    """One-shot k-FED over a device mesh.

    data: (Z, n, d) with Z divisible by the total shard count. ``axis``
    may be one mesh axis name or a tuple (the federated-device dimension
    is sharded jointly over all of them — e.g. ("data", "model") uses the
    full production pod). ``server``: "replicated" (paper-faithful: ONE
    all-gather of the (Z, k', d) centers, steps 2-8 replicated on every
    chip) or "sharded" (beyond-paper: the server aggregation itself is
    sharded — per-chip traffic drops by the shard count for ~2 MB of tiny
    scalar/(d,) reductions; bitwise-identical output). Returns
    (labels (Z, n), tau_centers (k, d) replicated).
    """
    Z, n, d = data.shape
    axes = _axes(axis)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    assert Z % nshards == 0, (Z, nshards)
    if k_valid is None:
        k_valid = jnp.full((Z,), k_prime, jnp.int32)
    if point_mask is None:
        point_mask = jnp.ones((Z, n), bool)
    keys = jax.random.split(key, Z)

    def shard_fn(keys_b, data_b, kv_b, pm_b):
        # -- Stage 1: local solves for this shard's cohort of devices.
        loc = batched_local_kmeans(keys_b, data_b, k_max=k_prime,
                                   k_valid=kv_b, point_mask=pm_b, **local_kw)
        if server == "sharded":
            # -- Stage 2': sharded server — only tiny reductions cross
            # chips (k scalar pairs + k (d,) psums + one (k, d) psum).
            kz_all = jax.lax.all_gather(
                jnp.sum(loc.center_mask, axis=1).astype(jnp.int32),
                axes, axis=0, tiled=True)                  # (Z,)
            _, tau, my = _sharded_server(loc.centers, loc.center_mask,
                                         kz_all, k, axes, mesh)
            labels_b = K.induced_labels(my, loc.assign)
            return labels_b, tau
        # -- The one-shot communication: gather device centers + masks.
        all_centers = jax.lax.all_gather(loc.centers, axes, axis=0,
                                         tiled=True)       # (Z, k', d)
        all_mask = jax.lax.all_gather(loc.center_mask, axes, axis=0,
                                      tiled=True)           # (Z, k')
        # -- Stage 2: replicated server aggregation.
        agg = K.aggregate(all_centers, all_mask, k)
        zloc = data_b.shape[0]
        my = jax.lax.dynamic_slice_in_dim(
            agg.center_labels, _flat_axis_index(axes, mesh) * zloc, zloc, 0)
        labels_b = K.induced_labels(my, loc.assign)
        return labels_b, agg.tau_centers

    fn = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axes), P(axes), P(axes), P(axes)),
        out_specs=(P(axes), P()),
        check_vma=False)
    return fn(keys, data, k_valid, point_mask)


def assign_new_device_shard(mesh, new_data: jax.Array, tau_centers: jax.Array,
                            k_prime: int, *, key: jax.Array, **local_kw):
    """A device joining after the fact (Theorem 3.2): local solve + O(k'k)
    nearest-center matching against the retained server centers. No
    communication with any other device."""
    from repro.core.local_kmeans import local_kmeans
    loc = local_kmeans(key, new_data, k_max=k_prime, **local_kw)
    lbl = K.assign_new_device(loc.centers, loc.center_mask, tau_centers)
    return K.induced_labels(lbl[None], loc.assign[None])[0]


def distributed_lloyd(mesh, data: jax.Array, k: int, *, key: jax.Array,
                      iters: int = 25, axis="data", init_sub: int = 64):
    """Naive multi-round distributed k-means baseline (Section 4.2.1,
    "Communication-Efficiency"): parallel assignment + one all-reduce of
    per-cluster (sums, counts) per Lloyd round. data: (Z, n, d)."""
    Z, n, d = data.shape
    axes = _axes(axis)

    def shard_fn(data_b):
        x = data_b.reshape(-1, d).astype(jnp.float32)
        xg = jax.lax.all_gather(x, axes, axis=0, tiled=True)
        # Replicated deterministic init: k-means++ on a fixed subsample.
        sub = xg[:: max(1, xg.shape[0] // (init_sub * k))][: init_sub * k]
        c0, _ = L.kmeans_pp_init(key, sub, k)

        def body(c, _):
            a, _ = L.assign_points(x, c)
            sums, cnt = _sums(x, a, k)
            sums = jax.lax.psum(sums, axes)      # the per-round collective
            cnt = jax.lax.psum(cnt, axes)
            new = sums / jnp.maximum(cnt, 1.0)[:, None]
            c = jnp.where((cnt > 0)[:, None], new, c)
            return c, None

        c, _ = jax.lax.scan(body, c0, None, length=iters)
        a, _ = L.assign_points(x, c)
        return a.reshape(data_b.shape[:2]), c

    fn = jax.shard_map(shard_fn, mesh=mesh, in_specs=(P(axes),),
                       out_specs=(P(axes), P()), check_vma=False)
    return fn(data)


def _sums(x, a, k):
    from repro.kernels import ops
    return ops.kmeans_update(x, a, k)


def simulate_kfed(key, device_data, k, k_prime, **kw):
    """Single-host simulation alias (vmap path) — same numerics as the
    shard_map path (see tests/test_distributed.py)."""
    return K.kfed(key, device_data, k, k_prime, **kw)
