"""Distributed k-FED: shard_map production path + vmap simulation path.

The paper's protocol maps onto the mesh as follows (DESIGN.md §4):

  * each shard of the ``data`` axis hosts a cohort of federated devices
    (vmapped Algorithm 1 — devices never exchange raw data);
  * the ONE round of communication is literally one ``all_gather`` of the
    (Z, k', d) device-center tensor over the data axis;
  * the server aggregation (steps 2-8 of Algorithm 2, O(Z k' k^2) distance
    computations — Theorem 3.2) is replicated on every shard, which is
    cheaper than any dedicated-server emulation and keeps SPMD semantics.

Both the ``server="replicated"`` and ``server="sharded"`` branches route
through the ONE shared server core in ``core/server.py`` — the sharded
branch swaps in the collective ``ShardedReducer`` for the same greedy
max-min loop and Lloyd round. ``participation`` and
``weight_by_core_counts`` give the shard_map paths the same beyond-paper
scenarios as ``fed/engine.py`` (partial participation with Theorem 3.2
post-hoc attachment; core-set-weighted aggregation).

For comparison benchmarks we also provide ``distributed_lloyd`` — the naive
multi-round parallel Lloyd baseline (one all-reduce of (k, d) sums + (k,)
counts per iteration), whose collective schedule shows T rounds vs k-FED's
single gather.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import kfed as K
from repro.core import lloyd as L
from repro.core import server as S
from repro.core.local_kmeans import batched_local_kmeans
from repro.utils.compat import shard_map as _shard_map


def _axes(axis):
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _flat_axis_index(axes, mesh):
    """Linear shard index for a PartitionSpec((*axes,)) sharding — axes
    listed major-to-minor, matching tiled all_gather ordering."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def kfed_shard_map_impl(mesh, data: jax.Array, k: int, k_prime: int, *,
                        key: jax.Array, axis="data",
                        server: str = "replicated",
                        participation: Optional[jax.Array] = None,
                        weight_by_core_counts: bool = False,
                        k_valid: Optional[jax.Array] = None,
                        point_mask: Optional[jax.Array] = None,
                        **local_kw):
    """One-shot k-FED over a device mesh (engine internal; the
    declarative surface is ``fed.api.Session`` with topology
    ``replicated`` | ``sharded``).

    data: (Z, n, d) with Z divisible by the total shard count. ``axis``
    may be one mesh axis name or a tuple (the federated-device dimension
    is sharded jointly over all of them — e.g. ("data", "model") uses the
    full production pod). ``server``: "replicated" (paper-faithful: ONE
    all-gather of the (Z, k', d) centers, steps 2-8 replicated on every
    chip) or "sharded" (beyond-paper: the server aggregation itself is
    sharded — per-chip traffic drops by the shard count for ~2 MB of tiny
    scalar/(d,) reductions; bitwise-identical output).

    ``participation``: optional (Z,) bool — devices that missed the round
    are excluded from aggregation and attached post-hoc (Theorem 3.2)
    with zero extra communication rounds. ``weight_by_core_counts``
    weights the server's Lloyd round by the Algorithm 1 core set sizes.
    Returns (labels (Z, n), tau_centers (k, d) replicated).
    """
    if server not in ("replicated", "sharded"):
        raise ValueError(
            f"kfed_shard_map server={server!r} is invalid: accepted "
            f"values are ['replicated', 'sharded']")
    Z, n, d = data.shape
    axes = _axes(axis)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    assert Z % nshards == 0, (Z, nshards)
    if k_valid is None:
        k_valid = jnp.full((Z,), k_prime, jnp.int32)
    if point_mask is None:
        point_mask = jnp.ones((Z, n), bool)
    keys = jax.random.split(key, Z)
    has_part = participation is not None

    def shard_fn(keys_b, data_b, kv_b, pm_b, *rest):
        part_b = jnp.asarray(rest[0], bool) if has_part else None
        # -- Stage 1: local solves for this shard's cohort of devices.
        loc = batched_local_kmeans(keys_b, data_b, k_max=k_prime,
                                   k_valid=kv_b, point_mask=pm_b, **local_kw)
        # -- Stage 2 (transport prep): participation + weighting masks.
        cmask = (loc.center_mask if part_b is None
                 else loc.center_mask & part_b[:, None])
        w_loc = (S.core_weights(loc.core_counts)
                 if weight_by_core_counts else None)
        zloc = data_b.shape[0]
        if server == "sharded":
            # -- Stage 3': sharded server — only tiny reductions cross
            # chips (k scalar pairs + k (d,) psums + one (k, d) psum).
            kz_all = jax.lax.all_gather(
                jnp.sum(cmask, axis=1).astype(jnp.int32),
                axes, axis=0, tiled=True)                  # (Z,)
            base = _flat_axis_index(axes, mesh) * zloc * k_prime
            _, tau, my = S.aggregate_sharded(loc.centers, cmask, kz_all,
                                             k, axes, base,
                                             weights_loc=w_loc)
        else:
            # -- The one-shot communication: gather centers + masks.
            all_centers = jax.lax.all_gather(loc.centers, axes, axis=0,
                                             tiled=True)   # (Z, k', d)
            all_mask = jax.lax.all_gather(cmask, axes, axis=0,
                                          tiled=True)       # (Z, k')
            all_w = (None if w_loc is None else
                     jax.lax.all_gather(w_loc, axes, axis=0, tiled=True))
            # -- Stage 3: replicated shared server aggregation.
            agg = S.aggregate(all_centers, all_mask, k, weights=all_w)
            tau = agg.tau_centers
            my = jax.lax.dynamic_slice_in_dim(
                agg.center_labels, _flat_axis_index(axes, mesh) * zloc,
                zloc, 0)
        if part_b is not None:
            # Theorem 3.2 post-hoc attachment of this shard's absent
            # devices — purely local against the replicated tau centers.
            my = S.attach_absent_devices(my, loc.centers,
                                         loc.center_mask, tau, part_b)
        # -- Stage 4: induced labeling (Definition 3.3).
        labels_b = S.induced_labels(my, loc.assign)
        return labels_b, tau

    in_specs = [P(axes)] * (5 if has_part else 4)
    fn = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(axes), P()))
    args = (keys, data, k_valid, point_mask)
    if has_part:
        args += (jnp.asarray(participation, bool),)
    return fn(*args)


def kfed_shard_map(mesh, data: jax.Array, k: int, k_prime: int, *,
                   key: jax.Array, axis="data", server: str = "replicated",
                   participation: Optional[jax.Array] = None,
                   weight_by_core_counts: bool = False,
                   k_valid: Optional[jax.Array] = None,
                   point_mask: Optional[jax.Array] = None,
                   **local_kw):
    """Deprecated: use ``fed.api.Session`` with
    ``FederationPlan(topology="replicated" | "sharded")`` (this shim
    routes through it with bitwise-identical results). Returns
    (labels (Z, n), tau_centers (k, d) replicated)."""
    from repro.fed import api
    from repro.utils.deprecation import warn_legacy
    warn_legacy("core.distributed.kfed_shard_map", "Session.run")
    if server not in ("replicated", "sharded"):
        raise ValueError(
            f"kfed_shard_map server={server!r} is invalid: accepted "
            f"values are ['replicated', 'sharded']")
    plan = api.FederationPlan(
        k=k, k_prime=k_prime, d=int(data.shape[-1]), topology=server,
        mesh_axes=_axes(axis),
        weight_by_core_counts=weight_by_core_counts,
        local_kw=dict(local_kw))
    r = api.Session(plan, mesh=mesh).run(
        key, data, participation=participation, k_valid=k_valid,
        point_mask=point_mask)
    return r.labels, r.tau_centers


def assign_new_device_shard(mesh, new_data: jax.Array, tau_centers: jax.Array,
                            k_prime: int, *, key: jax.Array, **local_kw):
    """A device joining after the fact (Theorem 3.2): local solve + O(k'k)
    nearest-center matching against the retained server centers. No
    communication with any other device."""
    from repro.core.local_kmeans import local_kmeans
    loc = local_kmeans(key, new_data, k_max=k_prime, **local_kw)
    lbl = K.assign_new_device(loc.centers, loc.center_mask, tau_centers)
    return K.induced_labels(lbl[None], loc.assign[None])[0]


def distributed_lloyd(mesh, data: jax.Array, k: int, *, key: jax.Array,
                      iters: int = 25, axis="data", init_sub: int = 64):
    """Naive multi-round distributed k-means baseline (Section 4.2.1,
    "Communication-Efficiency"): parallel assignment + one all-reduce of
    per-cluster (sums, counts) per Lloyd round. data: (Z, n, d)."""
    Z, n, d = data.shape
    axes = _axes(axis)

    def shard_fn(data_b):
        x = data_b.reshape(-1, d).astype(jnp.float32)
        xg = jax.lax.all_gather(x, axes, axis=0, tiled=True)
        # Replicated deterministic init: k-means++ on a fixed subsample.
        sub = xg[:: max(1, xg.shape[0] // (init_sub * k))][: init_sub * k]
        c0, _ = L.kmeans_pp_init(key, sub, k)

        def body(c, _):
            a, _ = L.assign_points(x, c)
            sums, cnt = _sums(x, a, k)
            sums = jax.lax.psum(sums, axes)      # the per-round collective
            cnt = jax.lax.psum(cnt, axes)
            new = sums / jnp.maximum(cnt, 1.0)[:, None]
            c = jnp.where((cnt > 0)[:, None], new, c)
            return c, None

        c, _ = jax.lax.scan(body, c0, None, length=iters)
        a, _ = L.assign_points(x, c)
        return a.reshape(data_b.shape[:2]), c

    fn = _shard_map(shard_fn, mesh=mesh, in_specs=(P(axes),),
                    out_specs=(P(axes), P()))
    return fn(data)


def _sums(x, a, k):
    from repro.kernels import ops
    return ops.kmeans_update(x, a, k)


def simulate_kfed(key, device_data, k, k_prime, **kw):
    """Deprecated alias of the vmap simulation path — same numerics as
    the shard_map path (see tests/test_distributed.py); use
    ``fed.api.Session`` with the default ``simulated`` topology."""
    from repro.utils.deprecation import warn_legacy
    warn_legacy("core.distributed.simulate_kfed", "Session.run")
    return K._kfed_impl(key, device_data, k, k_prime, **kw)
