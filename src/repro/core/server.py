"""The ONE k-FED server implementation (Algorithm 2 steps 2-8).

Every execution path routes through this module (DESIGN.md §4):

  * ``core.kfed.aggregate``          -> :func:`aggregate`
  * shard_map ``server="replicated"``-> :func:`aggregate` (after gather)
  * shard_map ``server="sharded"``   -> :func:`aggregate_sharded`

The replicated and sharded executions differ ONLY in the reducer handed
to the shared greedy max-min loop (``lloyd.maxmin_grow``) and the shared
one-round Lloyd update (:func:`lloyd_round`); the protocol arithmetic
exists exactly once. The optional per-center ``weights`` (the |S_r| core
set sizes from Algorithm 1) turn the Lloyd round into a weighted mean so
large devices are not diluted by small ones.

On top of the one-shot entry point the server exposes an incremental
fold — :func:`init_state` / :func:`aggregate_incremental` /
:func:`finalize` — so device cohorts can report asynchronously, in any
order, across multiple calls. The fold buffers reports keyed by device
id (the sufficient statistic of the one-shot protocol), which makes the
finalized aggregate bitwise independent of arrival order; the
non-commutative max-min seeding is deferred to :func:`finalize`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import lloyd as L
from repro.kernels import ops


class KFedAggregate(NamedTuple):
    seeds_idx: jax.Array       # (k,) indices into flattened (Z*k') centers
    seed_centers: jax.Array    # (k, d) the set M
    tau_centers: jax.Array     # (k, d) mu(tau_r) after the one Lloyd round
    center_labels: jax.Array   # (Z, k') tau-label of each device center, -1 pad
    z0: jax.Array              # () the device whose centers seeded M


# ---------------------------------------------------------------------------
# Shared stages.
# ---------------------------------------------------------------------------


def lloyd_round(x: jax.Array, fm: jax.Array, M: jax.Array, k: int, *,
                reducer=None, weights: Optional[jax.Array] = None,
                center_mask: Optional[jax.Array] = None):
    """Steps 7-8 of Algorithm 2: ONE Lloyd round of the device centers
    against the seeded set M. With ``weights`` (per-point, e.g. core set
    sizes |S_r|) the update is the weighted mean. ``reducer.psum``
    combines partial (sums, counts) across server shards (identity for
    the replicated server).

    Returns (tau (k, d) f32, labels (m,) int32).
    """
    reducer = reducer or L.LocalReducer()
    labels, _ = L.assign_points(x, M, center_mask=center_mask, point_mask=fm)
    w = None if weights is None else weights.astype(jnp.float32)
    sums, cnt = ops.kmeans_update(x.astype(jnp.float32), labels, k, w)
    sums = reducer.psum(sums)
    cnt = reducer.psum(cnt)
    # Divide by the ACTUAL mass whenever it is positive. Historically
    # this clamped to max(cnt, 1): identical for unweighted counts and
    # the >= 1 core-set weights, but fractional masses (decayed fold
    # weights can land in (0, 1)) would silently shrink the mean toward
    # the origin instead of averaging — and a zero-mass center must
    # keep its seed coordinates, never divide 0/0 into NaN.
    tau = jnp.where((cnt > 0)[:, None],
                    sums / jnp.where(cnt > 0, cnt, 1.0)[:, None],
                    M.astype(jnp.float32))
    return tau, labels


def induced_labels(center_labels: jax.Array,
                   local_assign: jax.Array) -> jax.Array:
    """Definition 3.3: point i on device z with local cluster s gets label
    tau(theta_s^(z)). center_labels: (Z, k'), local_assign: (Z, n)."""
    safe = jnp.clip(local_assign, 0, center_labels.shape[1] - 1)
    lbl = jnp.take_along_axis(center_labels, safe, axis=1)
    return jnp.where(local_assign >= 0, lbl, -1)


def assign_new_device(new_centers: jax.Array, new_mask: jax.Array,
                      ref_centers: jax.Array) -> jax.Array:
    """Theorem 3.2: a device joining after clustering is assigned by
    nearest-neighbor matching of its local centers against the k retained
    server centers — O(k' * k) distance computations, no other device
    involved. new_centers: (k', d); ref_centers: (k, d)."""
    labels, _ = L.assign_points(new_centers, ref_centers,
                                point_mask=new_mask)
    return labels


def core_weights(core_counts: jax.Array) -> jax.Array:
    """Per-center weights for the server Lloyd round: the Algorithm 1
    core set sizes |S_r|, clamped to >= 1 so a degenerate (empty-core)
    center still anchors its own cluster."""
    return jnp.maximum(core_counts.astype(jnp.float32), 1.0)


def attach_absent_devices(center_labels: jax.Array,
                          device_centers: jax.Array,
                          center_mask: jax.Array,
                          tau_centers: jax.Array,
                          participation: jax.Array) -> jax.Array:
    """Post-hoc attachment of devices that missed the round: their center
    labels come from the Theorem 3.2 nearest-center rule against the
    retained tau centers, with zero extra communication rounds."""
    post = jax.vmap(lambda c, m: assign_new_device(c, m, tau_centers))(
        device_centers, center_mask)
    return jnp.where(participation[:, None], center_labels, post)


# ---------------------------------------------------------------------------
# Replicated execution (also the vmap simulation path).
# ---------------------------------------------------------------------------


def aggregate(device_centers: jax.Array, center_mask: jax.Array, k: int, *,
              weights: Optional[jax.Array] = None) -> KFedAggregate:
    """Steps 2-8 of Algorithm 2 on a full (Z, k', d) center tensor.

    ``weights``: optional (Z, k') per-center weights for the Lloyd round
    (masked centers never contribute regardless — their labels are -1).
    """
    Z, kp, d = device_centers.shape
    flat = device_centers.reshape(Z * kp, d)
    fm = center_mask.reshape(Z * kp)

    # "Pick any z": deterministically pick the device with most local
    # clusters (maximizes the seeded set, minimizes max-min iterations).
    kz = jnp.sum(center_mask, axis=1)
    z0 = jnp.argmax(kz).astype(jnp.int32)
    init_sel = ((jnp.arange(Z) == z0)[:, None] & center_mask).reshape(-1)

    seeds_idx = L.maxmin_seed(flat, fm, init_sel, k)
    M = flat[seeds_idx]

    w = None if weights is None else weights.reshape(Z * kp)
    tau, labels = lloyd_round(flat, fm, M, k, weights=w)
    return KFedAggregate(seeds_idx, M, tau.astype(device_centers.dtype),
                         labels.reshape(Z, kp), z0)


# ---------------------------------------------------------------------------
# Sharded execution: same stages, collective reducer.
# ---------------------------------------------------------------------------

_BIG = jnp.int32(2 ** 30)


class ShardedReducer:
    """Collective counterpart of ``lloyd.LocalReducer``: each shard owns
    rows [base, base + m_loc) of the global point set. argmax resolves
    ties to the smallest global index (= first occurrence), matching the
    replicated ``jnp.argmax``."""

    def __init__(self, axes, base, m_loc):
        self.axes, self.base, self.m_loc = axes, base, m_loc

    def argmax(self, vals: jax.Array) -> jax.Array:
        lmax = jnp.max(vals)
        larg = jnp.argmax(vals).astype(jnp.int32)
        gmax = jax.lax.pmax(lmax, self.axes)
        return jax.lax.pmin(
            jnp.where(lmax >= gmax, self.base + larg, _BIG), self.axes)

    def fetch_row(self, points: jax.Array, gidx: jax.Array) -> jax.Array:
        mine = (gidx >= self.base) & (gidx < self.base + self.m_loc)
        row = jnp.clip(gidx - self.base, 0, self.m_loc - 1)
        return jax.lax.psum(jnp.where(mine, points[row], 0.0), self.axes)

    def fetch_rows(self, points: jax.Array, gidx: jax.Array) -> jax.Array:
        """(k,) global indices -> (k, d) rows, owner contributes."""
        mine = (gidx >= self.base) & (gidx < self.base + self.m_loc)
        rows = jnp.clip(gidx - self.base, 0, self.m_loc - 1)
        return jax.lax.psum(
            jnp.where(mine[:, None], points[rows], 0.0), self.axes)

    def psum(self, x: jax.Array) -> jax.Array:
        return jax.lax.psum(x, self.axes)


def aggregate_sharded(centers_loc, mask_loc, kz_all, k, axes, base, *,
                      weights_loc: Optional[jax.Array] = None):
    """Steps 2-8 of Algorithm 2 with the server itself sharded: each chip
    owns its m_loc = Z_loc*k' slice of the device centers; the greedy
    max-min runs as (local argmax -> two scalar all-reduces -> (d,) psum
    of the winning center) per iteration, so per-chip HBM traffic is
    m_loc*d per iteration instead of Z*k'*d (§Perf k-FED iteration 2).
    Selection order matches the replicated server (first-occurrence
    argmax = smallest global index among ties).

    centers_loc: (Z_loc, k', d); mask_loc: (Z_loc, k'); kz_all: (Z,);
    ``base`` = this shard's first global row index.
    Returns (M (k, d), tau_centers (k, d), my_labels (Z_loc, k')).
    """
    Z_loc, kp, d = centers_loc.shape
    m_loc = Z_loc * kp
    pf = centers_loc.reshape(m_loc, d).astype(jnp.float32)
    fm = mask_loc.reshape(m_loc)
    shard = base // m_loc
    red = ShardedReducer(axes, base, m_loc)

    # "Pick any z": the device with most local clusters, first one wins.
    z0 = jnp.argmax(kz_all).astype(jnp.int32)
    own_rows = jnp.arange(m_loc) // kp == (z0 - shard * Z_loc)
    init_loc = own_rows & fm                              # (m_loc,)
    count0 = red.psum(jnp.sum(init_loc).astype(jnp.int32))

    # Initial chosen indices (global, ascending) and their coordinates.
    cand = jnp.where(init_loc, base + jnp.arange(m_loc, dtype=jnp.int32),
                     _BIG)
    cand = jnp.sort(cand)[:k] if m_loc >= k else jnp.sort(
        jnp.pad(cand, (0, k - m_loc), constant_values=_BIG))[:k]
    chosen0 = jax.lax.pmin(cand, axes)                    # (k,) owner wins
    # owner gathers its init rows into slot order via a one-hot matmul;
    # others contribute 0. At most one row feeds each slot, and a
    # fixed-order dot reduction is deterministic — the former
    # scatter-add accumulated colliding zero rows in
    # implementation-defined order (flagged by the §15 determinism
    # auditor's float-scatter-add rule).
    slot_of = jnp.cumsum(init_loc.astype(jnp.int32)) - 1
    sel = ((slot_of[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :])
           & init_loc[:, None]).astype(jnp.float32)       # (m_loc, k)
    M0 = jax.lax.dot_general(sel, jnp.where(init_loc[:, None], pf, 0.0),
                             (((0,), (0,)), ((), ())))    # (k, d)
    M0 = red.psum(M0)

    d2 = ops.pairwise_sq_dists(pf, M0)                    # (m_loc, k)
    ok = jnp.arange(k) < count0
    mind2 = jnp.min(jnp.where(ok[None, :], d2, jnp.inf), axis=1)
    mind2 = jnp.where(fm, mind2, -jnp.inf)
    chosen = jnp.where(jnp.arange(k) < count0, chosen0, -1)

    # The SAME greedy growth loop as the replicated server, with the
    # collective reducer swapped in.
    chosen = L.maxmin_grow(pf, fm, chosen, mind2, count0, k, reducer=red)

    # Assemble M from owners; one local Lloyd assignment + global update.
    M = red.fetch_rows(pf, chosen)
    w = None if weights_loc is None else weights_loc.reshape(m_loc)
    tau, labels = lloyd_round(pf, fm, M, k, reducer=red, weights=w,
                              center_mask=chosen >= 0)
    return M, tau.astype(centers_loc.dtype), labels.reshape(Z_loc, kp)


# ---------------------------------------------------------------------------
# Incremental (asynchronous staged-arrival) server.
# ---------------------------------------------------------------------------


class ServerState(NamedTuple):
    """Fold state of the asynchronous server: device reports buffered by
    device id. Because the buffer position is the device id, folding the
    same cohorts in ANY order yields the same state — and therefore a
    bitwise-identical finalized clustering.

    ``epoch`` timestamps each slot with the request-id epoch its report
    was folded at (default: the id itself). It is inert metadata until a
    finalize asks for ``decay`` — the lazy exponential down-weighting of
    the drift layer (DESIGN.md §14) — so the fold stays one scatter and
    non-drift paths are untouched by its presence."""
    centers: jax.Array    # (Z, k', d) buffered Theta^(z)
    mask: jax.Array       # (Z, k') center validity of received reports
    weights: jax.Array    # (Z, k') f32 per-center weights (1.0 default)
    received: jax.Array   # (Z,) bool — device has reported this round
    epoch: jax.Array      # (Z,) i32 request-id epoch of the fold


def init_state(Z: int, k_prime: int, d: int,
               dtype=jnp.float32) -> ServerState:
    return ServerState(jnp.zeros((Z, k_prime, d), dtype),
                       jnp.zeros((Z, k_prime), bool),
                       jnp.ones((Z, k_prime), jnp.float32),
                       jnp.zeros((Z,), bool),
                       jnp.zeros((Z,), jnp.int32))


def aggregate_incremental(state: ServerState, device_ids, centers,
                          mask, weights=None, epochs=None) -> ServerState:
    """Fold one cohort's report into the server state.

    device_ids: (B,) int; centers: (B, k', d); mask: (B, k'). Cohorts may
    arrive in any order and across any number of calls; re-delivery of a
    device report is idempotent. ``epochs``: optional (B,) request-id
    epochs stamped on the slots (default: the ids themselves — correct
    whenever the slot IS the request id; policies that remap ids to
    slots must pass the real request ids).
    """
    ids = jnp.asarray(device_ids, jnp.int32)
    w = (jnp.ones(jnp.shape(mask), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    e = ids if epochs is None else jnp.asarray(epochs, jnp.int32)
    # mode="drop": an id beyond the state's capacity is ignored instead
    # of clipping onto (and corrupting) the last slot — the streaming
    # service relies on over-capacity reports being served-not-folded.
    return ServerState(state.centers.at[ids].set(centers, mode="drop"),
                       state.mask.at[ids].set(mask, mode="drop"),
                       state.weights.at[ids].set(w, mode="drop"),
                       state.received.at[ids].set(True, mode="drop"),
                       state.epoch.at[ids].set(e, mode="drop"))


def aggregate_incremental_sharded(state: ServerState, device_ids,
                                  centers, mask, axes,
                                  weights=None, epochs=None) -> ServerState:
    """The collective path of :func:`aggregate_incremental` — the fold
    of the sharded serve plane (DESIGN.md §11).

    Runs INSIDE shard_map: ``state`` is replicated, ``device_ids`` /
    ``centers`` / ``mask`` / ``weights`` are this shard's slice of the
    report batch. The batch is transported with one tiled all_gather —
    O(B·k'·d), the reports themselves, NEVER the O(capacity·k'·d) fold
    state — and then every shard applies the identical scatter through
    :func:`aggregate_incremental`, which stays the single fold
    primitive. Gathering preserves the global batch order, so the
    result is BITWISE identical to folding the unsharded batch.

    Ids at or beyond the state capacity are dropped (the declined /
    padding sentinel of the serve plane); negative ids are not allowed
    — they would wrap per numpy indexing rules.
    """
    ids = jax.lax.all_gather(jnp.asarray(device_ids, jnp.int32), axes,
                             axis=0, tiled=True)
    centers = jax.lax.all_gather(centers, axes, axis=0, tiled=True)
    mask = jax.lax.all_gather(mask, axes, axis=0, tiled=True)
    w = (None if weights is None
         else jax.lax.all_gather(weights.astype(jnp.float32), axes,
                                 axis=0, tiled=True))
    e = (None if epochs is None
         else jax.lax.all_gather(jnp.asarray(epochs, jnp.int32), axes,
                                 axis=0, tiled=True))
    return aggregate_incremental(state, ids, centers, mask, weights=w,
                                 epochs=e)


# ---------------------------------------------------------------------------
# Drift layer: lazy exponential decay + mass-driven split/retire
# (DESIGN.md §14). Pure functions of the fold state — the hot-path
# scatter never pays for any of this.
# ---------------------------------------------------------------------------


def decay_factors(epoch: jax.Array, now_epoch, half_life) -> jax.Array:
    """Per-slot exponential decay 2^(-(now - epoch) / half_life): a slot
    folded ``half_life`` requests ago carries half its original mass.
    Deterministic in (epoch, now_epoch) — replays bitwise."""
    age = (jnp.asarray(now_epoch, jnp.int32)
           - epoch.astype(jnp.int32)).astype(jnp.float32)
    return jnp.exp2(-age / jnp.float32(half_life))


def decayed_evidence(state: ServerState, now_epoch, half_life):
    """The (mask, weights) the drift finalize sees: received reports with
    their fold weights scaled by :func:`decay_factors`. Slots whose
    decayed weight underflows to exactly 0 are masked OUT — a zero-mass
    center must never seed or anchor a cluster (it would divide 0/0 into
    NaN and poison tau on the next refresh)."""
    fac = decay_factors(state.epoch, now_epoch, half_life)
    w = state.weights * fac[:, None]
    mask = state.mask & state.received[:, None] & (w > 0)
    return mask, w


def finalize(state: ServerState, k: int, *, weighted: bool = False,
             decay=None) -> KFedAggregate:
    """Run Algorithm 2 over every report received so far. Devices that
    never reported are masked out (their labels come out -1); attach them
    post-hoc with :func:`attach_absent_devices`.

    ``decay``: optional ``(now_epoch, half_life)`` — weight every slot by
    its exponential age factor (always weighted; ``weighted`` then only
    controls whether the core-count weights also participate, which they
    do by construction since decay scales ``state.weights``)."""
    if decay is None:
        mask = state.mask & state.received[:, None]
        return aggregate(state.centers, mask, k,
                         weights=state.weights if weighted else None)
    now_epoch, half_life = decay
    mask, w = decayed_evidence(state, now_epoch, half_life)
    # Zero the masked slots' coordinates as well as their weights: a
    # zero weight alone does not neutralize non-finite garbage (0 * NaN
    # is NaN straight through the weighted Lloyd sums).
    centers = jnp.where(mask[..., None], state.centers,
                        jnp.zeros_like(state.centers))
    return aggregate(centers, mask, k, weights=w)


def center_mass(agg: KFedAggregate, mask: jax.Array,
                weights: jax.Array) -> jax.Array:
    """Per-center attached fold mass: the sum of (decayed) slot weights
    whose device centers labeled into each tau center. (k,) f32."""
    k = agg.tau_centers.shape[0]
    lbl = agg.center_labels.reshape(-1)
    w = jnp.where(mask.reshape(-1) & (lbl >= 0), weights.reshape(-1), 0.0)
    # One-hot matmul segment sum (the kernels/kmeans_update pattern):
    # a float scatter-add over label-derived (colliding) indices sums
    # in implementation-defined order — the drift layer's split/retire
    # decisions threshold this mass, so the reduction must replay
    # bitwise (§15 float-scatter-add rule).
    oh = (lbl[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]
          ).astype(jnp.float32)                           # (m, k)
    return jax.lax.dot_general(w, oh, (((0,), (0,)), ((), ())))


def split_retire(flat: jax.Array, fm: jax.Array, agg: KFedAggregate,
                 mass: jax.Array, k: int, *, split_factor: float,
                 retire_frac: float, max_moves: int,
                 weights: Optional[jax.Array] = None):
    """Mass-driven center split/retire at a flush boundary.

    Centers with mass below ``retire_frac`` of the mean are starved;
    centers above ``split_factor`` times the mean are over-massed. Up to
    ``max_moves`` starved centers (poorest first) are RE-SEEDED from the
    residual report of a donor over-massed center (fattest first): the
    donor's farthest attached report — the Algorithm 2 max-min rule
    restricted to one cluster — becomes the new seed, then ONE
    :func:`lloyd_round` re-anchors all k centers. Deterministic: stable
    sorts, first-occurrence argmax, no RNG — split/retire decisions
    replay bitwise from a checkpoint.

    ``flat``: (Z*k', d) device centers; ``fm``: (Z*k',) evidence mask;
    ``weights``: optional (Z*k',) Lloyd weights. Returns
    ``(tau (k, d) f32, moved (k,) bool, donors (k,) i32, n_moves i32)``
    — with zero moves ``tau`` equals ``agg.tau_centers`` exactly.
    """
    mass = mass.astype(jnp.float32)
    mean = jnp.sum(mass) / jnp.float32(k)
    starved = mass < jnp.float32(retire_frac) * mean
    over = mass > jnp.float32(split_factor) * mean
    n_mv = jnp.minimum(
        jnp.minimum(jnp.sum(starved), jnp.sum(over)),
        jnp.int32(max_moves)).astype(jnp.int32)

    # Rank starved ascending by mass, donors descending; pair rank j of
    # each with rank j of the other. jnp.argsort is stable, so ties
    # resolve to the lowest center index — deterministic.
    skey = jnp.where(starved, mass, jnp.inf)
    okey = jnp.where(over, -mass, jnp.inf)
    sorder = jnp.argsort(skey)
    oorder = jnp.argsort(okey).astype(jnp.int32)
    srank = jnp.zeros((k,), jnp.int32).at[sorder].set(
        jnp.arange(k, dtype=jnp.int32))
    donors = oorder[jnp.clip(srank, 0, k - 1)]
    take = starved & (srank < n_mv)

    # Residual re-seed: within each donor cluster, the attached report
    # farthest from its tau center (max-min restricted to the cluster).
    lbl = agg.center_labels.reshape(-1)
    d2 = ops.pairwise_sq_dists(flat.astype(jnp.float32),
                               agg.tau_centers.astype(jnp.float32))
    attached = (lbl[:, None] == jnp.arange(k)[None, :]) & fm[:, None]
    scores = jnp.where(attached, d2, -jnp.inf)
    reseed_idx = jnp.argmax(scores, axis=0)                # (k,) per center
    M1 = jnp.where(take[:, None], flat[reseed_idx[donors]],
                   agg.tau_centers).astype(jnp.float32)

    tau2, _ = lloyd_round(flat, fm, M1, k, weights=weights)
    tau = jnp.where(n_mv > 0, tau2, agg.tau_centers.astype(jnp.float32))
    return tau, take, jnp.where(take, donors, -1), n_mv
