"""k-FED core: the paper's primary contribution as a composable JAX module.

  lloyd          masked k-means primitives (assignment / update / ++ / maxmin)
  local_kmeans   Algorithm 1 (Awasthi-Sheffet local solve)
  kfed           Algorithm 2 (one-shot server aggregation, induced clustering)
  separation     Definitions 3.1/3.4/3.5, eq. 2/4 analysis quantities
  distributed    shard_map production path and multi-round Lloyd baseline
"""
from repro.core import distributed, kfed, local_kmeans, lloyd, separation  # noqa
from repro.core.kfed import (KFedResult, aggregate, assign_new_device,  # noqa
                             induced_labels)
from repro.core.kfed import kfed as run_kfed  # noqa: F401
from repro.core.local_kmeans import local_kmeans as run_local_kmeans  # noqa
from repro.core.local_kmeans import batched_local_kmeans  # noqa: F401
from repro.core.lloyd import kmeans_cost, kmeans_pp_init, maxmin_seed  # noqa
