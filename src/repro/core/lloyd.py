"""Masked k-means primitives shared by Algorithm 1 (local) and Algorithm 2
(server) of k-FED.

Everything here is fixed-shape and mask-driven so it can be vmapped over
federated devices with heterogeneous ``k^(z)`` and ``n^(z)`` (padded points
carry ``point_mask == False``; padded centers carry ``center_mask ==
False``). This is the TPU-native adaptation of the paper's per-device
variable-size problems (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops


def assign_points(x: jax.Array, centers: jax.Array,
                  center_mask: Optional[jax.Array] = None,
                  point_mask: Optional[jax.Array] = None):
    """Nearest-center assignment; invalid points get label -1.

    Returns (assign (n,) int32, min_sq_dist (n,) f32).
    """
    idx, mind = ops.assign_argmin(x, centers, center_mask)
    if point_mask is not None:
        idx = jnp.where(point_mask, idx, -1)
        mind = jnp.where(point_mask, mind, 0.0)
    return idx, mind


def update_centers(x: jax.Array, assign: jax.Array, k: int,
                   old_centers: jax.Array):
    """Mean of assigned points per center; empty centers keep old value."""
    sums, cnt = ops.kmeans_update(x, assign, k)
    new = sums / jnp.maximum(cnt, 1.0)[:, None]
    new = jnp.where((cnt > 0)[:, None], new, old_centers.astype(jnp.float32))
    return new.astype(old_centers.dtype), cnt


def kmeans_cost(x: jax.Array, centers: jax.Array,
                center_mask: Optional[jax.Array] = None,
                point_mask: Optional[jax.Array] = None) -> jax.Array:
    """The k-means objective phi (eq. 1) of ``x`` against ``centers``."""
    _, mind = assign_points(x, centers, center_mask, point_mask)
    return jnp.sum(mind)


class LloydResult(NamedTuple):
    centers: jax.Array      # (k, d)
    assign: jax.Array       # (n,) int32, -1 for masked points
    iters: jax.Array        # ()
    converged: jax.Array    # () bool


def lloyd(x: jax.Array, centers0: jax.Array, *,
          center_mask: Optional[jax.Array] = None,
          point_mask: Optional[jax.Array] = None,
          max_iters: int = 100) -> LloydResult:
    """Lloyd iterations until the assignment is stable (or max_iters).

    This is the convergence loop of step 4 of Algorithm 1; with
    ``max_iters=1`` it is the single Lloyd round of step 7 of Algorithm 2.
    """
    k = centers0.shape[0]
    a0 = jnp.full((x.shape[0],), -2, jnp.int32)

    def cond(state):
        _, _, it, done = state
        return (~done) & (it < max_iters)

    def body(state):
        centers, prev, it, _ = state
        a, _ = assign_points(x, centers, center_mask, point_mask)
        centers, _ = update_centers(x, a, k, centers)
        return centers, a, it + 1, jnp.all(a == prev)

    centers, assign, iters, done = jax.lax.while_loop(
        cond, body, (centers0, a0, jnp.int32(0), jnp.bool_(False)))
    # One final assignment against the final centers.
    assign, _ = assign_points(x, centers, center_mask, point_mask)
    return LloydResult(centers, assign, iters, done)


def lloyd_attach(x: jax.Array, centers0: jax.Array, tau: jax.Array, *,
                 center_mask: Optional[jax.Array] = None,
                 point_mask: Optional[jax.Array] = None,
                 max_iters: int = 100, serve_dtype: str = "f32"):
    """FUSED serve step (DESIGN.md §13): the ``lloyd`` convergence loop
    of Algorithm 1 step 4, the Theorem 3.2 attach of its converged
    centers against ``tau``, and the Definition 3.3 induced point
    labels — one kernel dispatch per request batch instead of three.

    Batched: x (B, n, d), centers0 (B, k', d), tau (k, d) shared.
    Returns (labels (B, n) i32 — tau-indexed, -1 for masked points;
    min_sq_dist (B, n) f32; centers (B, k', d) f32; center_labels
    (B, k') i32). With ``serve_dtype="f32"`` the outputs are bitwise
    identical to the staged ``lloyd`` -> ``server.assign_new_device``
    -> ``server.induced_labels`` composition; ``"bf16"`` stores
    x/centers/tau in bfloat16 with f32 accumulation (tolerance-bounded,
    see tests/test_solve_attach.py).
    """
    return ops.solve_attach(x, centers0, tau, center_mask, point_mask,
                            max_iters=max_iters, dtype=serve_dtype)


def kmeans_pp_init(key: jax.Array, x: jax.Array, k: int, *,
                   point_mask: Optional[jax.Array] = None,
                   k_valid: Optional[jax.Array] = None):
    """k-means++ seeding (the "standard approximation algorithm" of
    Algorithm 1 step 2), masked and fixed-shape.

    Picks ``k_valid <= k`` centers (rest zero / masked out). Returns
    (centers (k, d), center_mask (k,) bool).
    """
    n, d = x.shape
    pm = jnp.ones((n,), bool) if point_mask is None else point_mask
    kv = jnp.asarray(k if k_valid is None else k_valid, jnp.int32)
    xf = jnp.asarray(x, jnp.float32)  # accept numpy inputs (bench paths)

    keys = jax.random.split(key, k)
    logits0 = jnp.where(pm, 0.0, -jnp.inf)
    i0 = jax.random.categorical(keys[0], logits0)
    c0 = xf[i0]
    centers = jnp.zeros((k, d), jnp.float32).at[0].set(c0)
    mind2 = jnp.where(pm, jnp.sum((xf - c0) ** 2, axis=1), 0.0)

    def body(carry, inp):
        centers, mind2 = carry
        t, kt = inp
        w = jnp.where(pm, mind2, 0.0)
        has_mass = jnp.any(w > 0)
        logits = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), -jnp.inf)
        logits = jnp.where(has_mass, logits, logits0)
        i = jax.random.categorical(kt, logits)
        newc = xf[i]
        take = t < kv
        centers = jnp.where(take, centers.at[t].set(newc), centers)
        d2 = jnp.sum((xf - newc) ** 2, axis=1)
        mind2 = jnp.where(take, jnp.minimum(mind2, d2), mind2)
        return (centers, mind2), None

    (centers, _), _ = jax.lax.scan(
        body, (centers, mind2), (jnp.arange(1, k), keys[1:]))
    center_mask = jnp.arange(k) < kv
    return centers.astype(x.dtype), center_mask


class LocalReducer:
    """Reduction strategy for a server that owns the full point set (the
    replicated execution: argmax/fetch/sum are plain local ops). The
    sharded execution substitutes collective equivalents — see
    ``core/server.ShardedReducer``; the greedy loop itself is shared."""

    def argmax(self, vals: jax.Array) -> jax.Array:
        return jnp.argmax(vals).astype(jnp.int32)

    def fetch_row(self, points: jax.Array, idx: jax.Array) -> jax.Array:
        return points[idx]

    def psum(self, x: jax.Array) -> jax.Array:
        return x


def maxmin_grow(pf: jax.Array, valid: jax.Array, chosen: jax.Array,
                mind2: jax.Array, count0: jax.Array, k: int,
                reducer=None) -> jax.Array:
    """The greedy farthest-point growth loop (steps 4-6 of Algorithm 2),
    shared by every server execution path. ``chosen`` holds the already
    selected (global) indices in slots < count0; ``mind2`` the distance of
    every local point to the current set M (-inf for invalid points).

    Incremental update via the matmul identity ||x||^2 - 2 x.c + ||c||^2
    (one read of ``pf`` per iteration instead of materializing the
    broadcast (x - c)^2). ``reducer`` supplies argmax / row-fetch — local
    for the replicated server, collective for the sharded one.
    """
    reducer = reducer or LocalReducer()
    p2 = jnp.sum(pf * pf, axis=1)                         # (m,)

    def body(t, carry):
        chosen, mind2 = carry
        grow = t >= count0
        cand = reducer.argmax(mind2)
        chosen = jnp.where(grow, chosen.at[t].set(cand), chosen)
        c = reducer.fetch_row(pf, cand)
        nd = jnp.maximum(p2 - 2.0 * (pf @ c) + jnp.sum(c * c), 0.0)
        nd = jnp.where(valid, nd, -jnp.inf)
        mind2 = jnp.where(grow, jnp.minimum(mind2, nd), mind2)
        return chosen, mind2

    chosen, _ = jax.lax.fori_loop(0, k, body, (chosen, mind2))
    return chosen


def maxmin_seed(points: jax.Array, valid: jax.Array, init_sel: jax.Array,
                k: int) -> jax.Array:
    """Farthest-point (max-min) seeding, steps 2-6 of Algorithm 2.

    Starts from the already-selected set ``init_sel`` (one device's local
    centers, per the paper: "Pick any z and let M <- Theta^(z)") and
    greedily adds the point farthest from M until |M| = k.

    points: (m, d); valid/init_sel: (m,) bool. Returns chosen indices (k,).
    """
    pf = points.astype(jnp.float32)

    # Initial selected indices, in order (stable: selected first).
    order = jnp.argsort(jnp.where(init_sel & valid, 0, 1),
                        stable=True)
    count0 = jnp.sum(init_sel & valid).astype(jnp.int32)
    chosen = jnp.where(jnp.arange(k) < count0, order[:k], -1)

    # Distance of every point to the initial set M — against the <= k
    # initial points only (never the full (m, m) pairwise matrix: at
    # Z=4096, k'=16 that is a 17 GB intermediate; §Perf k-FED iter 1).
    init_pts = pf[order[:k]]                              # (k, d)
    init_ok = ((init_sel & valid)[order[:k]])             # (k,)
    d2 = ops.pairwise_sq_dists(pf, init_pts)              # (m, k)
    mind2 = jnp.min(jnp.where(init_ok[None, :], d2, jnp.inf), axis=1)
    mind2 = jnp.where(valid, mind2, -jnp.inf)  # invalid never picked

    return maxmin_grow(pf, valid, chosen, mind2, count0, k)
