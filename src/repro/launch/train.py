"""Training step factory: microbatched grad accumulation + optimizer,
plus a runnable single-host training driver (examples use it; the dry-run
lowers the same train_step on the production mesh)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import DistCtx
from repro.models.model import Model
from repro.optim import build_optimizer, clip_by_global_norm
from repro.optim.optimizers import Optimizer


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def init_state(model: Model, key, optimizer: Optimizer) -> TrainState:
    params = model.init(key)
    return TrainState(params, optimizer.init(params),
                      jnp.zeros((), jnp.int32))


def _split_microbatches(batch, n: int):
    def per(leaf):
        B = leaf.shape[0]
        return leaf.reshape((n, B // n) + leaf.shape[1:])
    return jax.tree.map(per, batch)


def make_train_step(model: Model, ctx: DistCtx, optimizer: Optimizer, *,
                    clip_norm: float = 1.0):
    cfg = model.cfg
    mb = max(1, cfg.microbatch)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, ctx)
        return loss, metrics

    def train_step(state: TrainState, batch):
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            micro = _split_microbatches(batch, mb)

            def acc_step(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mbatch)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss), _ = jax.lax.scan(
                acc_step, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = {}
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt = optimizer.update(grads, state.opt, state.params,
                                       state.step)
        out = TrainState(params, opt, state.step + 1)
        return out, {"loss": loss, "grad_norm": gnorm, **metrics}

    return train_step


def train_loop(model: Model, batches, *, key=None, lr: float = 3e-4,
               steps: int = 100, ctx: DistCtx = None, log_every: int = 10):
    """Simple single-host loop used by examples/quickstart."""
    ctx = ctx or DistCtx.local()
    key = key if key is not None else jax.random.PRNGKey(0)
    optimizer = build_optimizer(model.cfg.optimizer, lr)
    state = init_state(model, key, optimizer)
    step_fn = jax.jit(make_train_step(model, ctx, optimizer))
    history = []
    for i, batch in enumerate(batches):
        if i >= steps:
            break
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            # Explicit materialization (§15 tracer-coercion): the device
            # sync happens here, on the log cadence, and nowhere else.
            history.append((i, float(np.asarray(metrics["loss"]))))
    return state, history
