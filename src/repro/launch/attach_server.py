"""Attachment-server entry point: run one k-FED round, then serve a
stream of late-joining devices — all through one declarative
``FederationPlan`` + ``Session`` (DESIGN.md §10–§11).

Demonstrates the full post-round serving vertical — batched/bucketed
Theorem 3.2 attachment, incremental folding with an online refresh
cadence and a pluggable fold-slot admission policy, checkpointed crash
recovery (the restored session replays the remaining stream
bitwise-identically), and the sharded serve plane: ``--serve-axes``
shard_maps the request batch over a mesh while ``--refresh async``
double-buffers the tau swap so re-finalization overlaps serving.

  PYTHONPATH=src python -m repro.launch.attach_server \
      --requests 48 --batch-size 8 --refresh-every 16 \
      --fold-policy lru --checkpoint /tmp/attach.npz

  # sharded plane over 8 forced host devices, async tau refresh
  PYTHONPATH=src python -m repro.launch.attach_server \
      --force-host-devices 8 --serve-axes data --refresh async

  # cluster-routed personalization serving (DESIGN.md §16): every
  # request is labeled, majority-voted to its cluster and answered by
  # that cluster's head in ONE fused step
  PYTHONPATH=src python -m repro.launch.attach_server \
      --heads qwen1.5-0.5b --head-arch ffn --head-capacity 1.25
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--k-prime", type=int, default=4)
    ap.add_argument("--d", type=int, default=24)
    ap.add_argument("--devices-per-group", type=int, default=4)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--refresh-every", type=int, default=16)
    ap.add_argument("--refresh", default="sync",
                    choices=("sync", "async"),
                    help="tau swap mode: sync swaps between batches; "
                         "async double-buffers and commits the "
                         "versioned swap at the next flush boundary")
    # literal choices (not imported from fed.autoscale) so argparse
    # rejects typos BEFORE jax loads; AUTOSCALE_POLICIES is the source.
    ap.add_argument("--autoscale", default="off",
                    choices=("off", "latency", "throughput"),
                    help="load-adaptive serve plane (DESIGN.md §12): "
                         "re-select active shards / batch size / "
                         "bucket ladder from queue depth at flush "
                         "boundaries (latency tracks the queue both "
                         "ways; throughput holds full batches across "
                         "single-flush dips); --batch-size becomes "
                         "the ceiling and --serve-axes the shard grant")
    ap.add_argument("--serve-axes", default=None, metavar="AXES",
                    help="comma-separated mesh axes to shard the serve "
                         "plane's request batch over (e.g. 'data'); "
                         "default: single-host serving")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    metavar="N",
                    help="force N XLA host-platform devices (must be "
                         "set before the first jax computation; use "
                         "with --serve-axes to shard on CPU)")
    ap.add_argument("--capacity", type=int, default=4096)
    # literal choices (not imported from fed.policy) so argparse rejects
    # typos BEFORE jax loads; fed/policy.py POLICIES is the source.
    ap.add_argument("--fold-policy", default="drop",
                    choices=("drop", "lru", "weighted_reservoir"),
                    help="fold-slot admission: drop (served-not-folded "
                         "past capacity), lru, or weighted_reservoir")
    ap.add_argument("--heads", default="off", metavar="NAME",
                    help="cluster-routed personalization serving "
                         "(DESIGN.md §16): 'off', 'linear', or a "
                         "registered model-config name (e.g. "
                         "'qwen1.5-0.5b') — each cluster gets its own "
                         "head and requests route to it by majority "
                         "vote; bad names fail with a named config "
                         "error listing the registry")
    # literal choices (not imported from models.heads) so argparse
    # rejects typos BEFORE jax loads; HEAD_ARCHS is the source.
    ap.add_argument("--head-arch", default="ffn",
                    choices=("ffn", "transformer"),
                    help="per-cluster head block: the config's FFN, or "
                         "the flag-gated attention+FFN transformer "
                         "block")
    ap.add_argument("--head-capacity", type=float, default=1.25,
                    metavar="F",
                    help="dispatch queue depth factor: each cluster "
                         "gets ceil(batch * F / k) slots per step; "
                         "overflowing requests still get labels, just "
                         "no prediction")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="checkpoint mid-stream and verify the restored "
                         "session serves the remainder bitwise identically")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.force_host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count="
            f"{args.force_host_devices}")

    # jax is imported (and its backend initialized) only AFTER the
    # forced-device flag is in the environment.
    import jax
    import numpy as np

    from repro.data.gaussian import late_device_stream, structured_devices
    from repro.fed.api import FederationPlan, Session
    from repro.utils.compat import make_mesh
    from repro.utils.metrics import clustering_accuracy

    k, kp, d = args.k, args.k_prime, args.d
    fm = structured_devices(jax.random.PRNGKey(args.seed), k=k, d=d,
                            k_prime=kp, m0=args.devices_per_group,
                            n_per_comp_dev=25, sep=60.0)
    serve_axes = (tuple(args.serve_axes.split(","))
                  if args.serve_axes else None)
    # The mesh takes its axis names FROM --serve-axes (all devices on
    # the first named axis), so any axis name the user picks works.
    mesh = (make_mesh((jax.device_count(),)
                      + (1,) * (len(serve_axes) - 1), serve_axes)
            if serve_axes else None)
    plan = FederationPlan(k=k, k_prime=kp, d=d, capacity=args.capacity,
                          batch_size=args.batch_size,
                          refresh_every=args.refresh_every,
                          refresh=args.refresh, serve_axes=serve_axes,
                          autoscale=args.autoscale,
                          fold_policy=args.fold_policy,
                          heads=args.heads, head_arch=args.head_arch,
                          head_capacity=args.head_capacity,
                          checkpoint=args.checkpoint)
    sess = Session(plan, mesh=mesh)
    rr = sess.run(jax.random.PRNGKey(args.seed + 1), fm.data)
    Z = fm.data.shape[0]
    acc0 = clustering_accuracy(np.asarray(rr.labels),
                               np.asarray(fm.labels), k)
    print(f"round: Z={Z} devices, k={k}, k'={kp}, "
          f"accuracy {100 * acc0:.2f}%")

    stream = late_device_stream(fm.means, kp, args.requests, args.seed + 2)

    half = len(stream) // 2
    t0 = time.perf_counter()
    if args.heads != "off":
        preds = sess.serve_predict([r[0] for r in stream[:half]],
                                   [r[2] for r in stream[:half]])
        out = [(p.labels, p.tau_version) for p in preds]
    else:
        out = sess.serve_versioned([r[0] for r in stream[:half]],
                                   [r[2] for r in stream[:half]])
    dt = time.perf_counter() - t0
    pts = sum(r[0].shape[0] for r in stream[:half])
    accs = [clustering_accuracy(lbl, r[1], k)
            for (lbl, _), r in zip(out, stream[:half])]
    st = sess.stats()
    versions = sorted({v for _, v in out})
    print(f"served {half} devices / {pts} points in {dt:.2f}s "
          f"({half / dt:.1f} dev/s, {pts / dt:.0f} pts/s) on "
          f"{st['serve_shards']} serve shard(s), "
          f"tau versions {versions}, "
          f"mean accuracy {100 * float(np.mean(accs)):.2f}%")
    if args.heads != "off":
        h = st["heads"]
        routed = [p for p in preds if p.routed]
        clusters = sorted({p.cluster for p in routed})
        print(f"heads[{h['mode']}/{h['arch']}]: routed "
              f"{len(routed)}/{half} requests over {len(clusters)} "
              f"cluster head(s) ({h['params_per_head']} params/head, "
              f"{h['queue_capacity']} queue slots/cluster, "
              f"{h['overflowed']} overflowed), mean |prediction| "
              f"{float(np.mean([np.abs(p.prediction).mean() for p in routed])):.3f}")

    if args.checkpoint:
        sess.save()
        restored = Session.restore(args.checkpoint, plan, mesh=mesh)
        rest_live = sess.serve_versioned([r[0] for r in stream[half:]],
                                         [r[2] for r in stream[half:]])
        rest_ck = restored.serve_versioned([r[0] for r in stream[half:]],
                                           [r[2] for r in stream[half:]])
        same = all(np.array_equal(a, b) and va == vb
                   for (a, va), (b, vb) in zip(rest_live, rest_ck))
        print(f"checkpoint -> restore -> serve: bitwise identical "
              f"labels AND tau versions vs uninterrupted session: {same}")
        assert same
    else:
        sess.serve([r[0] for r in stream[half:]],
                   [r[2] for r in stream[half:]])

    st = sess.stats()
    print(f"stats: {st['served_devices']} served, {st['folded']} folded "
          f"(capacity {st['capacity']}, policy {st['fold_policy']}), "
          f"refresh cadence {args.refresh_every} ({args.refresh}), "
          f"final tau version {st['tau_version']}")
    a = st["autoscale"]
    print(f"autoscale[{a['policy']}]: active shards {a['shards']}/"
          f"{a['granted_shards']}, batch {a['batch_size']}/"
          f"{a['max_batch']}, ladder {a['ladder']}, "
          f"{a['decisions']} decisions, "
          f"{st['plane_compiles']} compiled signatures, last flush "
          f"dispatch {a['last_dispatch_us']}us / materialize "
          f"{a['last_materialize_us']}us")


if __name__ == "__main__":
    main()
