"""§Perf profiling view over the compiled dry-run: lower one
(arch x shape x mesh), print the largest trip-multiplied per-instruction
contributions to bytes / flops, and the collective schedule. This is the
"profile" available without hardware — reasoning happens on the lowered IR.

  PYTHONPATH=src python -m repro.launch.perf_probe --arch deepseek-v3-671b \
      --shape train_4k [--mesh single] [--metric bytes] [-n 30]
"""
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS first)

import argparse

from repro.launch.hlo_analysis import analyze, top_contributors


def probe(arch: str, shape: str, multi_pod: bool = False, n: int = 30,
          metrics=("bytes", "flops")):
    import jax
    # Reuse the dry-run lowering but keep the compiled text.
    rec, compiled = lower_compiled(arch, shape, multi_pod)
    hlo = compiled.as_text()
    out = {"record": rec}
    for metric in metrics:
        rows = top_contributors(hlo, n=n, metric=metric)
        out[metric] = rows
        total = analyze(hlo)["flops" if metric == "flops" else "bytes"]
        print(f"\n== top {metric} contributors "
              f"(total {total:.3e}/device) ==")
        for contrib, mult, comp, op, name in rows:
            print(f"  {contrib:12.3e} (x{mult:7.0f}) {op:22s} {name[:48]:48s}"
                  f" in {comp[:40]}")
    coll = analyze(hlo)["coll"]
    print("\n== collective schedule ==")
    for kind, v in sorted(coll.items()):
        print(f"  {kind:20s} count={v['count']:8.0f} bytes={v['bytes']:.3e}")
    return out


def lower_compiled(arch: str, shape: str, multi_pod: bool):
    """dryrun.lower_one, but returning the compiled object. Kept in sync by
    calling into the same builder with a capture hook."""
    captured = {}
    orig = dryrun.time.time
    import jax

    # small shim: rebuild the jitted/lowered path exactly as lower_one does
    # by temporarily wrapping compile. Simpler: call the internals.
    rec = dryrun.lower_one(arch, shape, multi_pod, verbose=False,
                           keep=captured)
    return rec, captured["compiled"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--metric", default=None)
    ap.add_argument("-n", type=int, default=30)
    args = ap.parse_args()
    metrics = (args.metric,) if args.metric else ("bytes", "flops")
    probe(args.arch, args.shape, args.mesh == "multi", n=args.n,
          metrics=metrics)


if __name__ == "__main__":
    main()
