"""Serving entry points: batched prefill + decode step (what the decode
dry-run shapes lower) and a tiny batched request loop for examples."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import DistCtx
from repro.models.model import Model


def make_serve_step(model: Model, ctx: DistCtx):
    def serve_step(params, cache, tokens):
        return model.serve_step(params, cache, tokens, ctx)
    return serve_step


def make_prefill(model: Model, ctx: DistCtx):
    def prefill(params, batch):
        return model.prefill(params, batch, ctx)
    return prefill


def make_kfed_attach(tau_centers, k_prime: int, **local_kw):
    """Deprecated: use ``fed.api.Session.attach_fn`` (this shim builds
    a serving-only Session over the given tau centers and returns the
    identical jitted ``(key, device_data) -> point labels`` step)."""
    from repro.fed import api
    from repro.utils.deprecation import warn_legacy
    warn_legacy("launch.serve.make_kfed_attach", "Session.attach_fn")
    tau = jnp.asarray(tau_centers)
    k, d = int(tau.shape[0]), int(tau.shape[1])
    plan = api.FederationPlan(k=k, k_prime=k_prime, d=d,
                              local_kw=dict(local_kw))
    return api.Session.from_tau(plan, tau).attach_fn()


def generate(model: Model, params, batch, *, steps: int,
             ctx: DistCtx = None, greedy: bool = True,
             key=None):
    """Prefill then decode ``steps`` tokens (single-host examples)."""
    ctx = ctx or DistCtx.local()
    model.decode_room = steps + 1
    prefill = jax.jit(make_prefill(model, ctx))
    step = jax.jit(make_serve_step(model, ctx))
    logits, cache = prefill(params, batch)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(steps):
        toks.append(tok)
        logits, cache = step(params, cache, tok)
        if greedy:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits).astype(jnp.int32)
    return jnp.stack(toks, axis=1)
