"""Serving entry points: batched prefill + decode step (what the decode
dry-run shapes lower) and a tiny batched request loop for examples."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import DistCtx
from repro.models.model import Model


def make_serve_step(model: Model, ctx: DistCtx):
    def serve_step(params, cache, tokens):
        return model.serve_step(params, cache, tokens, ctx)
    return serve_step


def make_prefill(model: Model, ctx: DistCtx):
    def prefill(params, batch):
        return model.prefill(params, batch, ctx)
    return prefill


def make_kfed_attach(tau_centers, k_prime: int, **local_kw):
    """Serving path for late-joining federated devices (Theorem 3.2,
    DESIGN.md §4): given the retained tau centers of a finished k-FED
    round, returns a jitted step ``(key, device_data) -> point labels``
    that attaches one new device with a local Algorithm 1 solve plus
    O(k' k) distance computations — no communication with any other
    device and no recomputation of the round."""
    from repro.core import server as S
    from repro.core.local_kmeans import local_kmeans
    tau = jnp.asarray(tau_centers)

    def attach(key, device_data):
        loc = local_kmeans(key, device_data, k_max=k_prime, **local_kw)
        lbl = S.assign_new_device(loc.centers, loc.center_mask, tau)
        return S.induced_labels(lbl[None], loc.assign[None])[0]

    return jax.jit(attach)


def generate(model: Model, params, batch, *, steps: int,
             ctx: DistCtx = None, greedy: bool = True,
             key=None):
    """Prefill then decode ``steps`` tokens (single-host examples)."""
    ctx = ctx or DistCtx.local()
    model.decode_room = steps + 1
    prefill = jax.jit(make_prefill(model, ctx))
    step = jax.jit(make_serve_step(model, ctx))
    logits, cache = prefill(params, batch)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(steps):
        toks.append(tok)
        logits, cache = step(params, cache, tok)
        if greedy:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits).astype(jnp.int32)
    return jnp.stack(toks, axis=1)
