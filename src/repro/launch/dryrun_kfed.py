import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production dry-run of the PAPER'S TECHNIQUE itself: lower + compile the
one-shot k-FED pipeline (and the naive multi-round distributed-Lloyd
baseline it is compared against in Section 4.2.1) on the production mesh,
and record roofline terms + the collective schedule.

This is the §Perf "most representative of the paper" pair. The collective
schedule makes the one-shot property checkable in HLO: k-FED must show
exactly ONE all-gather of the (Z, k', d) center tensor (+ its mask), while
the baseline shows one all-reduce per Lloyd round inside a trip-count-T
while loop.

  PYTHONPATH=src python -m repro.launch.dryrun_kfed --mesh both --out results_kfed.jsonl

Scenario (production-scale federated network):
  Z=4096 federated devices, n=4096 points each, d=1024, k=256, k'=16=sqrt(k)
  -> 16.8M points, 17.2 GB of federated data, 16 fed-devices per chip
     (single pod) / 8 per chip (two pods).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import distributed_lloyd
from repro.fed.api import FederationPlan, Session
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms

SCENARIO = dict(Z=4096, n=4096, d=1024, k=256, k_prime=16)


# TPU-native Algorithm 1 defaults (matmul-only SVD) shared by every
# lowered scenario so the dryrun comparison stays apples-to-apples.
DEFAULT_LOCAL_KW = dict(approx_iters=8, max_iters=32,
                        use_subspace_iteration=True)


def _session(mesh, axes, *, k, k_prime, d, server="replicated",
             weight_by_core_counts=False, **local_kw):
    """The production deployment as ONE declarative plan — the same
    Session surface the serving/examples paths use, lowered here at
    Z=4096 scale."""
    kw = dict(DEFAULT_LOCAL_KW)
    kw.update(local_kw)
    plan = FederationPlan(k=k, k_prime=k_prime, d=d, topology=server,
                          mesh_axes=tuple(axes),
                          weight_by_core_counts=weight_by_core_counts,
                          local_kw=kw)
    return Session(plan, mesh=mesh)


def lower_kfed(mesh, axes, *, Z, n, d, k, k_prime, verbose=True,
               server="replicated", weight_by_core_counts=False,
               **local_kw):
    data = jax.ShapeDtypeStruct((Z, n, d), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    sess = _session(mesh, axes, k=k, k_prime=k_prime, d=d, server=server,
                    weight_by_core_counts=weight_by_core_counts,
                    **local_kw)

    def fn(key, data):
        r = sess.run(key, data)
        return r.labels, r.tau_centers

    return jax.jit(fn).lower(key, data)


def lower_kfed_sharded(mesh, axes, **kw):
    return lower_kfed(mesh, axes, server="sharded", **kw)


def lower_kfed_partial(mesh, axes, *, Z, n, d, k, k_prime, **local_kw):
    """Partial-participation scenario (DESIGN.md §4): a (Z,) bool mask is
    an extra tiny operand; absent devices are attached post-hoc via the
    Theorem 3.2 rule inside the same lowered program — the collective
    schedule stays one-shot (one extra (Z,) bool gather at most)."""
    data = jax.ShapeDtypeStruct((Z, n, d), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    part = jax.ShapeDtypeStruct((Z,), jnp.bool_)
    sess = _session(mesh, axes, k=k, k_prime=k_prime, d=d, **local_kw)

    def fn(key, data, part):
        r = sess.run(key, data, participation=part)
        return r.labels, r.tau_centers

    return jax.jit(fn).lower(key, data, part)


def lower_kfed_weighted(mesh, axes, **kw):
    """Core-set-weighted aggregation through the shared server core; the
    weights ride the existing one-shot gather as one extra (Z, k') f32."""
    return lower_kfed(mesh, axes, weight_by_core_counts=True, **kw)


def lower_lloyd_baseline(mesh, axes, *, Z, n, d, k, iters=25, **_):
    data = jax.ShapeDtypeStruct((Z, n, d), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def fn(key, data):
        return distributed_lloyd(mesh, data, k, key=key, iters=iters,
                                 axis=axes, init_sub=4)

    return jax.jit(fn).lower(key, data)


def analyze_one(name, lowered, mesh, verbose=True, hw=None):
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    hc = analyze(compiled.as_text())
    terms = roofline_terms(hc["flops"] + hc.get("flops_f32", 0.0),
                           hc["bytes"], hc["coll_bytes"], hw=hw)
    mem = compiled.memory_analysis()
    chips = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": name, "shape": "fedcluster_prod",
        "mesh": "multi" if "pod" in mesh.shape else "single",
        "status": "ok", "chips": chips, **SCENARIO,
        "flops_per_device": float(hc["flops"]),
        "bytes_per_device": float(hc["bytes"]),
        "collectives": hc["coll"], "collective_bytes": float(hc["coll_bytes"]),
        **terms,
        "bytes_peak_est": int(mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes) if mem else None,
        "t_compile_s": round(t_compile, 2),
    }
    if verbose:
        coll = {kind: (int(v["count"]), f"{v['bytes']:.3e}B")
                for kind, v in hc["coll"].items()}
        print(f"[{name} x {rec['mesh']}] OK compute={terms['compute_s']:.4f}s "
              f"memory={terms['memory_s']:.4f}s "
              f"collective={terms['collective_s']:.6f}s "
              f"bottleneck={terms['bottleneck']} (compile {t_compile:.1f}s)")
        print(f"  collective schedule: {coll}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-baseline", action="store_true")
    from repro.launch.roofline import HW_PROFILES
    ap.add_argument("--hw-profile", default=None,
                    choices=sorted(HW_PROFILES),
                    help="hardware profile for the roofline terms "
                         "(default: REPRO_HW_PROFILE or tpu_v5e)")
    args = ap.parse_args()
    multis = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for mp in multis:
        mesh = make_production_mesh(multi_pod=mp)
        axes = tuple(mesh.shape.keys())  # shard fed-devices over ALL axes
        todo = [("kfed-oneshot", lower_kfed),
                ("kfed-oneshot-shardedserver", lower_kfed_sharded),
                ("kfed-partial-participation", lower_kfed_partial),
                ("kfed-weighted", lower_kfed_weighted)]
        if not args.skip_baseline:
            todo.append(("distributed-lloyd-baseline", lower_lloyd_baseline))
        for name, make in todo:
            try:
                lowered = make(mesh, axes, **SCENARIO)
                rec = analyze_one(name, lowered, mesh, hw=args.hw_profile)
            except Exception as e:
                import traceback
                rec = {"arch": name, "shape": "fedcluster_prod",
                       "mesh": "multi" if mp else "single",
                       "status": "error", "error": repr(e),
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[{name}] FAILED: {e!r}")
            results.append(rec)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{ok} ok / {len(results) - ok} failed of {len(results)}")
    return results


if __name__ == "__main__":
    main()
