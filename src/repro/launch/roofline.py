"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Terms per (arch, shape, mesh), all in seconds per step, per chip:

  compute    = HLO_FLOPs            / peak_FLOPs
  memory     = HLO_bytes_accessed   / HBM_bandwidth
  collective = collective_bytes     / ICI_link_bandwidth

``cost_analysis()`` on the compiled executable is already per-device
(post-SPMD-partitioning). Collective bytes are NOT in cost_analysis: we
parse the partitioned HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

Hardware constants come from named profiles (``HW_PROFILES``); pass
``hw=`` to :func:`roofline_terms`, a profile name to
:func:`hw_profile`, or set ``REPRO_HW_PROFILE`` (the dry-run CLIs also
take ``--hw-profile``). The module-level ``HW`` dict remains the
default-profile alias for back-compat.
"""
from __future__ import annotations

import os
import re
from collections import defaultdict
from typing import Dict, Optional, Union

import numpy as np

# Peak dense-matmul FLOPs (bf16), HBM bytes/s per chip, bytes/s per
# interconnect link, and per-core VMEM budget (the ~16 MiB Pallas block
# working set the §15 kernel checker gates against). Public vendor
# numbers; "cpu_ci" is a deliberately round model of the 2-core CI box
# so its rows are stable — it carries the TPU VMEM budget so the
# static-analysis gate checks the same limits everywhere.
_VMEM = float(16 * 2 ** 20)
HW_PROFILES: Dict[str, Dict[str, float]] = {
    "tpu_v5e": {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9,
                "vmem_bytes": _VMEM},
    "tpu_v5p": {"peak_flops": 459e12, "hbm_bw": 2765e9, "link_bw": 100e9,
                "vmem_bytes": _VMEM},
    "tpu_v4": {"peak_flops": 275e12, "hbm_bw": 1228e9, "link_bw": 50e9,
               "vmem_bytes": _VMEM},
    "cpu_ci": {"peak_flops": 1e11, "hbm_bw": 10e9, "link_bw": 1e9,
               "vmem_bytes": _VMEM},
}
DEFAULT_HW_PROFILE = "tpu_v5e"


def hw_profile(name: Optional[str] = None) -> Dict[str, float]:
    """Resolve a named hardware profile. ``None`` falls back to the
    ``REPRO_HW_PROFILE`` env var, then to ``tpu_v5e``."""
    name = name or os.environ.get("REPRO_HW_PROFILE") or DEFAULT_HW_PROFILE
    if name not in HW_PROFILES:
        raise KeyError(
            f"unknown hardware profile {name!r}: accepted profiles are "
            f"{sorted(HW_PROFILES)}")
    return HW_PROFILES[name]


# Back-compat alias: the historical module constant IS the default
# profile's table (same dict object — monkeypatching HW still works for
# callers that predate profiles).
HW = HW_PROFILES[DEFAULT_HW_PROFILE]

# The HLO shape/dtype/collective tables live in analysis.visitor (ONE
# copy, shared with launch.hlo_analysis); the module-level aliases keep
# the historical names for external callers.
from repro.analysis.visitor import (COLLECTIVES as _COLL,  # noqa: E402
                                    DTYPE_BYTES as _DTYPE_BYTES,
                                    SHAPE_RE as _SHAPE_RE)

_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|[a-z0-9_]+\[[^\]]*\]\S*)\s+"
    r"((?:" + "|".join(_COLL) + r")(?:-start)?)\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count and summed operand bytes (per
    device)."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1).replace("-start", "")
        operand_str = line[m.end():]
        total = 0
        for dt, dims in _SHAPE_RE.findall(operand_str):
            total += _shape_bytes(dt, dims)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += total
    return dict(stats)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float,
                   hw: Union[None, str, Dict[str, float]] = None
                   ) -> Dict[str, float]:
    """Roofline time terms. ``hw``: a profile name, a profile dict, or
    None (the ``REPRO_HW_PROFILE``/default resolution of
    :func:`hw_profile`; historically the hardcoded v5e table)."""
    if not isinstance(hw, dict):
        hw = hw_profile(hw)
    compute = flops / hw["peak_flops"]
    memory = bytes_accessed / hw["hbm_bw"]
    collective = collective_bytes / hw["link_bw"]
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    terms["total_s"] = max(compute, memory, collective)
    return terms


def model_flops(cfg, shape, n_params: int, active_params: int) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for training;
    2 N D for inference steps. D = tokens processed globally."""
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    n = active_params if cfg.moe else n_params
    return mult * n * tokens


def active_param_count(cfg, params_shape) -> int:
    """Active params = total minus the (1 - top_k/E) share of routed
    expert weights."""
    import jax
    total = sum(int(np.prod(l.shape))
                for l in jax.tree.leaves(params_shape))
    if not cfg.moe:
        return total
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        names = [str(getattr(p, "key", "")) for p in path]
        if "moe" in names and names[-1] in ("w1", "w2", "w3"):
            expert += int(np.prod(leaf.shape))
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return total - int(expert * (1.0 - frac))
