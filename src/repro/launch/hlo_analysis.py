"""Compiled-HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — a
scan-over-layers program reports ~1/L of its true FLOPs (verified on this
jax/XLA build; see EXPERIMENTS.md §Dry-run methodology). This module
parses the post-SPMD-partitioning HLO text and computes, per device:

  * flops            — dot ops: 2 * prod(result dims) * prod(contraction
                       dims), recursively through call/fusion/while with
                       while TRIP COUNTS extracted from the loop condition
                       (lax.scan lowers to `compare(iv, constant(L)), LT`).
                       ``flops_f32`` separately tracks dots with f32(+)
                       output — the MXU runs those at ~half rate, so the
                       roofline compute term charges them twice.
  * bytes accessed   — per top-level instruction: operand + result bytes,
                       an HBM traffic estimate. Fusions are NOT opaque:
                       a fused parameter whose only users are
                       dynamic-slice/gather is charged the slice bytes
                       (a scan stash read per trip is one layer slice,
                       not the whole stacked array), an in-place
                       dynamic-update-slice root aliases its buffer
                       (charged update-region bytes only).
  * collective bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       trip-multiplied like everything else.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ONE copy of the HLO shape/dtype/collective tables, shared with
# launch.roofline — see analysis.visitor. The historical local names
# stay as aliases for external callers/monkeypatchers.
from repro.analysis.visitor import (COLLECTIVES,  # noqa: F401, E402
                                    DTYPE_BYTES as _DTYPE_BYTES,
                                    SHAPE_RE as _SHAPE_RE)


def _shape_list(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, shape in _shape_list(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr] = field(default_factory=dict)
    root: Optional[str] = None


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")


def _split_type_op(rest: str) -> Tuple[str, str, str]:
    """'bf16[2,3]{1,0} dot(%a, %b), attrs' -> (type, opcode, tail)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest2 = rest[:i + 1], rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        type_str, rest2 = rest[:sp], rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest2)
    if not m:
        return type_str, "", ""
    return type_str, m.group(1), m.group(2)


def _operands(tail: str) -> List[str]:
    """Names of %operands in the top-level argument list of ``tail``
    (which starts right after the opcode's '(')."""
    depth = 1
    args = []
    cur = []
    for ch in tail:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args.append("".join(cur))
                break
        if depth >= 1 and (ch != "," or depth > 1):
            cur.append(ch)
        elif ch == "," and depth == 1:
            args.append("".join(cur))
            cur = []
    names = []
    for a in args:
        m = re.search(r"%([\w.\-]+)", a)
        if m:
            names.append(m.group(1))
    return names


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        is_root, name, rest = bool(m.group(1)), m.group(2), m.group(3)
        type_str, opcode, tail = _split_type_op(rest)
        if not opcode:
            continue
        ins = Instr(name, type_str, opcode, _operands(tail), line, is_root)
        cur.instrs[name] = ins
        if is_root:
            cur.root = name
    return comps, entry


def _while_parts(line: str) -> Tuple[Optional[str], Optional[str]]:
    mc = re.search(r"condition=%([\w.\-]+)", line)
    mb = re.search(r"body=%([\w.\-]+)", line)
    return (mc.group(1) if mc else None, mb.group(1) if mb else None)


def _attr_computations(line: str) -> List[str]:
    """Names referenced via calls= / branch_computations= attributes."""
    out = []
    for m in re.finditer(r"calls=%([\w.\-]+)", line):
        out.append(m.group(1))
    for m in re.finditer(r"branch_computations=\{([^}]*)\}", line):
        out.extend(re.findall(r"%([\w.\-]+)", m.group(1)))
    return out


def _trip_count(comps, cond_name: str) -> int:
    """Extract the scan trip count from a while condition computation:
    walk from the ROOT compare to its constant operand."""
    comp = comps.get(cond_name)
    if comp is None or comp.root is None:
        return 1
    consts = []

    def walk(name, depth=0):
        ins = comp.instrs.get(name)
        if ins is None or depth > 6:
            return
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                consts.append(int(m.group(1)))
        if ins.opcode == "fusion":
            # compare may live in the fused computation; constants are the
            # fusion's operands in this computation.
            pass
        for op in ins.operands:
            walk(op, depth + 1)

    walk(comp.root)
    if consts:
        return max(max(consts), 1)
    # fallback: any integer constant in the computation
    for ins in comp.instrs.values():
        m = re.search(r"s(?:32|64)\[\] constant\((\d+)\)", ins.line)
        if m:
            return max(int(m.group(1)), 1)
    return 1


def _dot_flops(ins: Instr, comp: Computation,
               comps: Dict[str, Computation]) -> float:
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if not m:
        return 0.0
    cdims = [int(d) for d in m.group(1).split(",") if d]
    lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
    if lhs is None:
        return 0.0
    shapes = _shape_list(lhs.type_str)
    if not shapes:
        return 0.0
    lhs_shape = shapes[0][1]
    csize = 1
    for d in cdims:
        if d < len(lhs_shape):
            csize *= lhs_shape[d]
    out = 1
    for _, shape in _shape_list(ins.type_str):
        for d in shape:
            out *= d
        break
    return 2.0 * out * csize


def _fusion_call_ref(line: str) -> Optional[str]:
    m = re.search(r"calls=%([\w.\-]+)", line)
    return m.group(1) if m else None


def _fusion_bytes(ins: Instr, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    """HBM bytes for one fusion call: reads of each fused parameter
    (slice-only parameters charged at slice size; the aliased buffer of an
    in-place DUS root charged at the update region) + result writes (DUS
    roots write their update region, everything else its full result)."""
    fname = _fusion_call_ref(ins.line)
    fcomp = comps.get(fname) if fname else None
    if fcomp is None:
        opnds = sum(_bytes_of(comp.instrs[o].type_str)
                    for o in ins.operands if o in comp.instrs)
        return opnds + _bytes_of(ins.type_str)

    # Map parameter index -> Instr inside the fused computation.
    params: Dict[int, Instr] = {}
    for fi in fcomp.instrs.values():
        if fi.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", fi.line)
            if m:
                params[int(m.group(1))] = fi
    users: Dict[str, List[Instr]] = {}
    for fi in fcomp.instrs.values():
        for op in fi.operands:
            users.setdefault(op, []).append(fi)

    def _through_converts(name: str, depth: int = 0) -> List[Instr]:
        """Users of ``name``, looking through dtype converts/bitcasts (the
        CPU backend wraps every bf16 value feeding a dot in a convert; the
        TPU program has no such op, so slice-pattern detection must see
        through them)."""
        out: List[Instr] = []
        for u in users.get(name, []):
            if u.opcode in ("convert", "bitcast", "copy") and depth < 3:
                out.extend(_through_converts(u.name, depth + 1))
            else:
                out.append(u)
        return out

    def _unwrap(name: str, depth: int = 0) -> Optional[Instr]:
        """The instruction behind a chain of converts/bitcasts."""
        ins2 = fcomp.instrs.get(name)
        if ins2 is None:
            return None
        if ins2.opcode in ("convert", "bitcast", "copy") and depth < 3 \
                and ins2.operands:
            return _unwrap(ins2.operands[0], depth + 1)
        return ins2

    # Which fused values are DUS roots (possibly through a root tuple)?
    dus_aliased: set = set()   # parameter names aliased in-place by a DUS
    write_bytes = 0.0
    root = fcomp.instrs.get(fcomp.root) if fcomp.root else None
    root_elems: List[Instr] = []
    if root is not None:
        if root.opcode == "tuple":
            root_elems = [fcomp.instrs[o] for o in root.operands
                          if o in fcomp.instrs]
        else:
            root_elems = [root]
    for re_ins in root_elems:
        re_base = re_ins
        if re_ins.opcode in ("convert", "bitcast", "copy") and re_ins.operands:
            u = _unwrap(re_ins.name)
            if u is not None:
                re_base = u
        if re_base.opcode == "dynamic-update-slice" and re_base.operands:
            buf = re_base.operands[0]
            upd = (fcomp.instrs[re_base.operands[1]].type_str
                   if len(re_base.operands) > 1
                   and re_base.operands[1] in fcomp.instrs else None)
            ub = _bytes_of(upd) if upd else _bytes_of(re_base.type_str)
            write_bytes += ub
            # In-place if the buffer is a parameter, possibly behind a
            # convert (a CPU-backend dtype promotion the TPU program
            # doesn't have — there the DUS aliases its buffer).
            b = _unwrap(buf)
            if b is not None and b.opcode == "parameter":
                dus_aliased.add(b.name)
        else:
            write_bytes += _bytes_of(re_base.type_str)

    read_bytes = 0.0
    for idx, p in params.items():
        if p.name in dus_aliased:
            continue                      # aliased in-place buffer
        pu = _through_converts(p.name)
        if pu and all(u.opcode in ("dynamic-slice", "gather",
                                   "dynamic-update-slice")
                      for u in pu):
            # slice-reads at slice size; a DUS user means this param is
            # the update value (full size) or offset (scalar) — charge
            # its own size capped by the DUS update
            total = 0.0
            for u in pu:
                if u.opcode == "dynamic-update-slice":
                    total += min(_bytes_of(p.type_str),
                                 _bytes_of(u.type_str))
                else:
                    total += _bytes_of(u.type_str)
            read_bytes += min(total, _bytes_of(p.type_str))
        else:
            read_bytes += _bytes_of(p.type_str)
    return read_bytes + write_bytes


class CostResult(dict):
    pass


def analyze(hlo: str) -> CostResult:
    comps, entry = parse_module(hlo)
    if entry is None:
        # entry is usually the last computation in scheduled modules
        entry = list(comps)[-1] if comps else None
    memo: Dict[str, dict] = {}

    def comp_cost(name: str, stack=()) -> dict:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {"flops": 0.0, "flops_f32": 0.0, "bytes": 0.0,
                    "coll": {}, "coll_bytes": 0.0}
        comp = comps[name]
        total = {"flops": 0.0, "flops_f32": 0.0, "bytes": 0.0, "coll": {},
                 "coll_bytes": 0.0}

        def add(sub, mult=1.0):
            total["flops"] += mult * sub["flops"]
            total["flops_f32"] += mult * sub["flops_f32"]
            total["bytes"] += mult * sub["bytes"]
            total["coll_bytes"] += mult * sub["coll_bytes"]
            for k, v in sub["coll"].items():
                e = total["coll"].setdefault(k, {"count": 0.0, "bytes": 0.0})
                e["count"] += mult * v["count"]
                e["bytes"] += mult * v["bytes"]

        for ins in comp.instrs.values():
            op = ins.opcode
            # instruction-local bytes: operands + result, with in-place /
            # slice-op corrections (a dynamic-update-slice writes only the
            # update region; counting the whole aliased buffer would
            # inflate scan-stash traffic by the trip count).
            opnds = [_bytes_of(comp.instrs[o].type_str)
                     for o in ins.operands if o in comp.instrs]
            opnd_bytes = sum(opnds)
            res_bytes = _bytes_of(ins.type_str)
            tag = ins.name + " " + op
            if op in ("parameter", "constant", "get-tuple-element",
                      "tuple", "bitcast"):
                pass
            elif op == "fusion":
                total["bytes"] += _fusion_bytes(ins, comp, comps)
            elif "dynamic-update-slice" in tag or "scatter" in tag:
                total["bytes"] += 2.0 * (opnd_bytes - max(opnds, default=0))
            elif "dynamic-slice" in tag or "gather" in tag:
                total["bytes"] += 2.0 * res_bytes
            elif op == "copy":
                total["bytes"] += res_bytes
            else:
                total["bytes"] += opnd_bytes + res_bytes
            if op == "dot":
                f = _dot_flops(ins, comp, comps)
                total["flops"] += f
                if ins.type_str.split("[")[0] in ("f32", "f64"):
                    total["flops_f32"] += f
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                e = total["coll"].setdefault(
                    base, {"count": 0.0, "bytes": 0.0})
                e["count"] += 1
                e["bytes"] += opnd_bytes
                total["coll_bytes"] += opnd_bytes
            if op == "while":
                cond, body = _while_parts(ins.line)
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    add(comp_cost(body, stack + (name,)), trips)
            elif op in ("fusion", "call", "conditional", "async-start"):
                for ref in _attr_computations(ins.line):
                    if ref in comps:
                        sub = comp_cost(ref, stack + (name,))
                        # fusions: only flops descend (bytes counted at the
                        # call site above)
                        add({"flops": sub["flops"],
                             "flops_f32": sub["flops_f32"], "bytes": 0.0,
                             "coll": sub["coll"],
                             "coll_bytes": sub["coll_bytes"]})
        memo[name] = total
        return total

    res = comp_cost(entry) if entry else {
        "flops": 0.0, "flops_f32": 0.0, "bytes": 0.0, "coll": {},
        "coll_bytes": 0.0}
    out = CostResult(res)
    out["n_computations"] = len(comps)
    return out


def top_contributors(hlo: str, n: int = 20, metric: str = "bytes"):
    """The §Perf profiling view: largest per-instruction contributions to
    the trip-multiplied byte (or flop) total, with their loop multiplier.
    Returns [(contribution, multiplier, computation, opcode, name), ...]."""
    comps, entry = parse_module(hlo)
    if entry is None:
        entry = list(comps)[-1] if comps else None
    items = []

    def walk(name: str, mult: float, stack=()):
        if name not in comps or name in stack:
            return
        comp = comps[name]
        for ins in comp.instrs.values():
            op = ins.opcode
            opnds = [_bytes_of(comp.instrs[o].type_str)
                     for o in ins.operands if o in comp.instrs]
            res_bytes = _bytes_of(ins.type_str)
            tag = ins.name + " " + op
            if op in ("parameter", "constant", "get-tuple-element",
                      "tuple", "bitcast"):
                contrib = 0.0
            elif op == "fusion":
                contrib = _fusion_bytes(ins, comp, comps)
            elif "dynamic-update-slice" in tag or "scatter" in tag:
                contrib = 2.0 * (sum(opnds) - max(opnds, default=0))
            elif "dynamic-slice" in tag or "gather" in tag:
                contrib = 2.0 * res_bytes
            elif op == "copy":
                contrib = res_bytes
            else:
                contrib = sum(opnds) + res_bytes
            if metric == "flops":
                contrib = _dot_flops(ins, comp, comps) if op == "dot" else 0.0
            if contrib > 0:
                items.append((contrib * mult, mult, name, op, ins.name))
            if op == "while":
                cond, body = _while_parts(ins.line)
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    walk(body, mult * trips, stack + (name,))
            elif op in ("fusion", "call", "conditional"):
                if metric == "flops":
                    for ref in _attr_computations(ins.line):
                        walk(ref, mult, stack + (name,))

    if entry:
        walk(entry, 1.0)
    items.sort(reverse=True)
    return items[:n]
