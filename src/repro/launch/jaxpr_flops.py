"""Dtype-aware matmul FLOP counting at the JAXPR level.

Why not from the compiled HLO: the CPU backend (the only one in this
container) rewrites every bf16 dot to f32, so compiled-HLO dot dtypes say
nothing about what the TPU would run. The jaxpr preserves the program's
own dtypes and scan trip counts exactly, so

    compute_term = (flops_bf16 / peak_bf16 + flops_f32 / (peak_bf16 / 2))
                   / chips

charges genuinely-f32 matmuls (which the MXU runs at ~half rate) twice,
without being fooled by backend promotion.

Counts are GLOBAL (whole-program): a shard_map body is multiplied by the
mesh size (SPMD runs it on every device). Divide by chips for per-chip.

The traversal itself (scan trip counts, shard_map mesh multipliers,
cond branch selection, open-vs-closed sub-jaxpr normalization) is the
shared ``analysis.visitor`` engine — this module only supplies the
per-equation FLOP arithmetic.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.analysis import visitor


def _sub_jaxprs(eqn):
    """(open sub-jaxpr, extra multiplier) pairs — the historical local
    helper, now a thin alias over ``visitor.sub_jaxprs`` with the cost
    model's one-branch cond policy."""
    return [(j, m) for j, m, _ in visitor.sub_jaxprs(eqn, branches="one")]


def _prod(xs) -> float:
    out = 1.0
    for x in xs:
        out *= x
    return out


def _dot_flops(eqn):
    lhs = eqn.invars[0].aval
    rhs = eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = _prod(lhs.shape[i] for i in lb)
    contract = _prod(lhs.shape[i] for i in lc)
    lfree = _prod(lhs.shape[i] for i in range(len(lhs.shape))
                  if i not in lc and i not in lb)
    rfree = _prod(rhs.shape[i] for i in range(len(rhs.shape))
                  if i not in rc and i not in rb)
    return 2.0 * batch * contract * lfree * rfree, lhs.dtype


def _conv_flops(eqn):
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval          # kernel (O, I/g, *spatial) in HLO order
    groups = eqn.params.get("feature_group_count", 1)
    k_spatial = _prod(rhs.shape[2:])
    in_ch = rhs.shape[1]
    return 2.0 * _prod(out.shape) * in_ch * k_spatial / max(groups, 1), \
        eqn.invars[0].aval.dtype


def _dtype_key(dt) -> str:
    return "f32" if np.dtype(dt) in (np.dtype("float32"),
                                     np.dtype("float64")) else "bf16"


def flops_by_dtype(closed_jaxpr) -> Dict[str, float]:
    """{"bf16": ..., "f32": ...} global matmul+conv flops."""
    out = {"bf16": 0.0, "f32": 0.0}

    def visit(site):
        name = site.eqn.primitive.name
        if name == "dot_general":
            f, dt = _dot_flops(site.eqn)
            out[_dtype_key(dt)] += site.mult * f
        elif name == "conv_general_dilated":
            f, dt = _conv_flops(site.eqn)
            out[_dtype_key(dt)] += site.mult * f

    visitor.walk(closed_jaxpr, visit, branches="one")
    return out


def trace_flops(fn, *args) -> Dict[str, float]:
    """flops_by_dtype of fn traced against ShapeDtypeStruct args."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return flops_by_dtype(jaxpr)


def effective_flops(fl: Dict[str, float]) -> float:
    """bf16-equivalent flops: f32 matmuls charged twice (half MXU rate)."""
    return fl.get("bf16", 0.0) + 2.0 * fl.get("f32", 0.0)
