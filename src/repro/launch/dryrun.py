import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct stand-ins (no allocation), print
memory/cost analysis, and record roofline inputs to a JSONL artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl

The forced 512-device host platform is set above BEFORE any jax import —
do not import this module from test/bench processes (they must see the
single real CPU device).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs, list_archs
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (active_param_count, model_flops,
                                   roofline_terms)
from repro.launch.sharding import (batch_specs, cache_specs_tree, make_ctx,
                                   opt_specs, param_specs, to_shardings)
from repro.launch.train import TrainState, init_state, make_train_step
from repro.models.model import build_model
from repro.optim import build_optimizer

# (arch, shape) pairs that are skipped BY DESIGN (DESIGN.md §5).
SKIPS = {
    ("whisper-base", "long_500k"):
        "enc-dec with a 448-token decoder context by construction; no "
        "faithful sub-quadratic decoder variant exists for this arch",
}

# Dense/VLM archs run long_500k as their sliding-window variant.
SWA_FOR_LONG = {"mistral-nemo-12b", "granite-3-2b", "qwen1.5-0.5b",
                "nemotron-4-15b", "internvl2-26b"}


def arch_config(arch: str, shape_name: str):
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch in SWA_FOR_LONG:
        cfg = cfg.with_sliding_window(4096)
    return cfg


def _sds_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_one(arch: str, shape_name: str, multi_pod: bool, *,
              verbose: bool = True, keep: dict | None = None,
              hw: str | None = None):
    """Returns a result dict (ok or error) for one combination.
    ``keep``: optional dict that receives the lowered/compiled objects
    (used by perf_probe). ``hw``: named hardware profile for the
    roofline terms (None = REPRO_HW_PROFILE / tpu_v5e)."""
    t0 = time.time()
    shape = SHAPES[shape_name]
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
    cfg = arch_config(arch, shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh)
    model = build_model(cfg)
    dp_axes = ctx.dp

    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = param_specs(params_shape, cfg, mesh, dp_axes)
    psh = to_shardings(pspecs, mesh)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(params_shape))
    n_active = active_param_count(cfg, params_shape)

    if shape.mode == "train":
        optimizer = build_optimizer(cfg.optimizer, 1e-4)
        state_shape = jax.eval_shape(
            lambda: init_state(model, jax.random.PRNGKey(0), optimizer))
        ospecs = opt_specs(state_shape.opt, pspecs)
        state_sh = TrainState(psh, to_shardings(ospecs, mesh),
                              NamedSharding(mesh, P()))
        batch_shape = input_specs(cfg, shape)
        bsh = to_shardings(batch_specs(batch_shape, mesh, dp_axes), mesh)
        fn = make_train_step(model, ctx, optimizer)
        jitted = jax.jit(fn, in_shardings=(state_sh, bsh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        args = (state_shape, batch_shape)
    elif shape.mode == "prefill":
        model.decode_room = 1
        batch_shape = input_specs(cfg, shape)
        bsh = to_shardings(batch_specs(batch_shape, mesh, dp_axes), mesh)
        cache_shape = jax.eval_shape(
            lambda: _prefill_cache_shape(model, cfg, shape))
        csh = to_shardings(cache_specs_tree(cache_shape, mesh, dp_axes),
                           mesh)
        fn = lambda p, b: model.prefill(p, b, ctx)
        jitted = jax.jit(fn, in_shardings=(psh, bsh),
                         out_shardings=(None, csh))
        args = (params_shape, batch_shape)
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        csh = to_shardings(cache_specs_tree(cache_shape, mesh, dp_axes),
                           mesh)
        tok_shape = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        tsh = to_shardings(batch_specs(tok_shape, mesh, dp_axes), mesh)
        fn = lambda p, c, t: model.serve_step(p, c, t, ctx)
        jitted = jax.jit(fn, in_shardings=(psh, csh, tsh),
                         out_shardings=(None, csh), donate_argnums=(1,))
        args = (params_shape, cache_shape, tok_shape)

    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    if keep is not None:
        keep["lowered"], keep["compiled"] = lowered, compiled

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # Trip-count-aware analysis of the partitioned module (XLA's own
    # cost_analysis counts while bodies once — see hlo_analysis.py).
    hc = analyze(hlo)
    coll = hc["coll"]
    cbytes = float(hc["coll_bytes"])
    flops = float(hc["flops"])
    bytes_accessed = float(hc["bytes"])
    mf = model_flops(cfg, shape, n_params, n_active)
    chips = int(np.prod(list(mesh.shape.values())))
    # Dtype-aware compute term from the jaxpr (the compiled CPU HLO
    # promotes every bf16 dot to f32, so HLO dot dtypes are meaningless
    # here); genuinely-f32 matmuls are charged at half MXU rate.
    try:
        from repro.launch.jaxpr_flops import effective_flops, trace_flops
        jfl = trace_flops(fn, *args)
        flops_eff = effective_flops(jfl) / chips
    except Exception:
        jfl = {}
        flops_eff = flops
    terms = roofline_terms(flops_eff, bytes_accessed, cbytes, hw=hw)
    hlo_total_flops = flops * chips
    mem_fields = {}
    if mem is not None:
        mem_fields = {
            "bytes_args": int(mem.argument_size_in_bytes),
            "bytes_out": int(mem.output_size_in_bytes),
            "bytes_temp": int(mem.temp_size_in_bytes),
            "bytes_alias": int(mem.alias_size_in_bytes),
        }
        mem_fields["bytes_peak_est"] = (
            mem_fields["bytes_args"] + mem_fields["bytes_out"] +
            mem_fields["bytes_temp"] - mem_fields["bytes_alias"])

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single", "status": "ok",
        "chips": chips, "n_params": n_params, "n_active_params": n_active,
        "flops_per_device": flops, "flops_eff_per_device": flops_eff,
        "jaxpr_flops_bf16": jfl.get("bf16", 0.0),
        "jaxpr_flops_f32": jfl.get("f32", 0.0),
        "bytes_per_device": bytes_accessed,
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll, "collective_bytes": cbytes,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(hlo_total_flops, 1.0),
        **terms, **mem_fields,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {result['mesh']}] OK "
              f"compute={terms['compute_s']:.4f}s "
              f"memory={terms['memory_s']:.4f}s "
              f"collective={terms['collective_s']:.4f}s "
              f"bottleneck={terms['bottleneck']} "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        if mem is not None:
            print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
                  f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
                  f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
                  f"alias={mem.alias_size_in_bytes/2**30:.2f}GiB")
        print(f"  cost_analysis: flops/dev={flops:.3e} "
              f"bytes/dev={bytes_accessed:.3e} "
              f"collective_bytes/dev={cbytes:.3e}")
    return result


def _prefill_cache_shape(model, cfg, shape):
    from repro.configs.shapes import input_specs as _is

    # Build via eval_shape on prefill itself is expensive; reuse
    # init_cache layout which matches _pack_cache (tests assert this).
    S = shape.seq_len
    if cfg.family == "encdec":
        S = S - cfg.encoder.n_ctx
    if cfg.family == "vlm":
        pass  # prefix included in seq budget
    return model.init_cache(shape.global_batch, S)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    from repro.launch.roofline import HW_PROFILES
    ap.add_argument("--hw-profile", default=None,
                    choices=sorted(HW_PROFILES),
                    help="hardware profile for the roofline terms "
                         "(default: REPRO_HW_PROFILE or tpu_v5e)")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.mesh == "both" else \
        [args.mesh == "multi"]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    r = lower_one(arch, shape, mp, hw=args.hw_profile)
                except Exception as e:
                    r = {"arch": arch, "shape": shape,
                         "mesh": "multi" if mp else "single",
                         "status": "error", "error": repr(e),
                         "trace": traceback.format_exc()[-2000:]}
                    print(f"[{arch} x {shape} x {r['mesh']}] FAILED: {e!r}")
                results.append(r)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(r) + "\n")
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    print(f"\n{ok} ok / {sk} skipped / {len(results) - ok - sk} failed "
          f"of {len(results)}")
    return results


if __name__ == "__main__":
    main()
