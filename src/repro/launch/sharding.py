"""Sharding rules: parameter, batch, cache and optimizer-state
PartitionSpecs for the production mesh.

Conventions (DESIGN.md §6):
  * "tp"   -> the ``model`` axis on a weight's natural dimension
              (heads / ffn hidden / experts / vocab).
  * "fsdp" -> the data axes ("pod","data") on a non-model dimension, for
              configs with cfg.fsdp (>= ~12B params).
  * Scanned segment leaves carry a leading layer axis (always unsharded).
  * Every rule is divisibility-checked against the mesh; a dimension that
    does not divide falls back to replication (never a lowering error).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import DistCtx

TP = "tp"
FSDP = "fsdp"

# (path-suffix match) -> per-dim template over the leaf's LAST dims.
_RULES = [
    (("attn", "wq"), (FSDP, TP)), (("attn", "wk"), (FSDP, TP)),
    (("attn", "wv"), (FSDP, TP)), (("attn", "wo"), (TP, FSDP)),
    (("attn", "bq"), (TP,)), (("attn", "bk"), (TP,)), (("attn", "bv"), (TP,)),
    (("xattn", "wq"), (FSDP, TP)), (("xattn", "wk"), (FSDP, TP)),
    (("xattn", "wv"), (FSDP, TP)), (("xattn", "wo"), (TP, FSDP)),
    (("attn", "wq_a"), (FSDP, None)), (("attn", "wq_b"), (None, TP)),
    (("attn", "wkv_a"), (FSDP, None)), (("attn", "wk_b"), (None, TP)),
    (("attn", "wv_b"), (None, TP)),
    (("ffn", "w1"), (FSDP, TP)), (("ffn", "w3"), (FSDP, TP)),
    (("ffn", "w2"), (TP, FSDP)), (("ffn", "b1"), (TP,)),
    (("moe", "router"), (FSDP, None)),
    (("shared", "w1"), (FSDP, TP)), (("shared", "w3"), (FSDP, TP)),
    (("shared", "w2"), (TP, FSDP)),
    (("tm", "wr"), (FSDP, TP)), (("tm", "wk"), (FSDP, TP)),
    (("tm", "wv"), (FSDP, TP)), (("tm", "wg"), (FSDP, TP)),
    (("tm", "wo"), (TP, FSDP)), (("tm", "wA"), (FSDP, None)),
    (("tm", "wB"), (None, TP)), (("tm", "u"), (TP, None)),
    (("cm", "wk"), (FSDP, TP)), (("cm", "wv"), (TP, FSDP)),
    (("mix", "in_proj"), (FSDP, None)), (("mix", "out_proj"), (None, FSDP)),
    # embed: vocab on model only — FSDP'ing the d dim makes the token
    # gather unpartitionable (XLA falls back to full rematerialization /
    # replication of the (B,S,d) gather output; observed on deepseek-v3).
    (("embed",), (TP, None)),
    (("unembed",), (FSDP, TP)),
    (("vis_proj",), (FSDP, TP)),
    (("mtp_proj",), (FSDP, TP)),
]


def _moe_expert_template(cfg, name: str):
    if cfg.moe and cfg.moe.impl == "alltoall":
        return (TP, FSDP, None)          # experts on model, d on fsdp
    if name in ("w1", "w3"):
        return (None, FSDP, TP)          # (E, d, ff): ff on model
    return (None, TP, FSDP)              # (E, ff, d)


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _resolve(template, shape, mesh, dp_axes, use_fsdp):
    """Template -> PartitionSpec with divisibility fallbacks, prepending
    None for any extra leading (scan) dims."""
    extra = len(shape) - len(template)
    spec = [None] * extra
    used_model = False
    used_dp = False
    for t, n in zip(template, shape[extra:]):
        if t == TP and not used_model and n % mesh.shape["model"] == 0:
            spec.append("model")
            used_model = True
        elif (t == FSDP and use_fsdp and not used_dp
              and n % int(np.prod([mesh.shape[a] for a in dp_axes])) == 0):
            spec.append(dp_axes)
            used_dp = True
        else:
            spec.append(None)
    return P(*spec)


def param_specs(params_shape, cfg, mesh, dp_axes: Tuple[str, ...]):
    """Pytree of PartitionSpec matching an eval_shape(model.init) tree."""
    def per_leaf(path, leaf):
        names = _path_names(path)
        if "moe" in names and names[-1] in ("w1", "w2", "w3"):
            if (cfg.moe and cfg.moe.impl == "alltoall"
                    and cfg.moe.ep == "2d"):
                # 2-D EP: experts sharded over the same minor-first axis
                # prefix apply_moe selects (model, then data axes inward,
                # product dividing E) — chip-resident experts, no FSDP
                # gather, local grads; replicated over any leftover axis.
                E = leaf.shape[len(leaf.shape) - 3]
                axes = ["model"]
                nsh = mesh.shape["model"]
                for a in reversed(dp_axes):
                    s = mesh.shape[a]
                    if nsh * s <= E and E % (nsh * s) == 0:
                        axes.append(a)
                        nsh *= s
                    else:
                        break
                axes = tuple(reversed(axes))
                extra = len(leaf.shape) - 3
                if E % nsh == 0:
                    return P(*([None] * extra), axes, None, None)
                # not divisible even by the model axis alone: fall back.
            tpl = _moe_expert_template(cfg, names[-1])
            return _resolve(tpl, leaf.shape, mesh, dp_axes, cfg.fsdp)
        for suffix, tpl in _RULES:
            if names[-len(suffix):] == suffix:
                return _resolve(tpl, leaf.shape, mesh, dp_axes, cfg.fsdp)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(per_leaf, params_shape)


def _dp_size(mesh, dp_axes):
    return int(np.prod([mesh.shape[a] for a in dp_axes]))


def batch_specs(batch_shape, mesh, dp_axes):
    """Inputs: shard the batch dim over the data axes when divisible."""
    dp = _dp_size(mesh, dp_axes)

    def per_leaf(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % dp == 0 and leaf.shape[0] > 0:
            return P(*((dp_axes,) + (None,) * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(per_leaf, batch_shape)


def cache_specs_tree(cache_shape, mesh, dp_axes):
    """Decode cache: batch on data axes; if batch doesn't divide (the
    long_500k B=1 case) the SEQUENCE dim shards over data instead (context
    parallelism); kv-heads / rwkv heads on ``model`` when divisible."""
    dp = _dp_size(mesh, dp_axes)
    tp = mesh.shape["model"]

    def per_leaf(path, leaf):
        names = _path_names(path)
        key = names[-1]
        shape = leaf.shape
        if key == "len":
            return P(dp_axes) if shape[0] % dp == 0 else P(None)
        spec = [None] * len(shape)
        if key in ("k", "v", "ck", "cv"):          # (L, B, S, KVH, hd)
            if shape[1] % dp == 0:
                spec[1] = dp_axes
            elif shape[2] % dp == 0:
                spec[2] = dp_axes
            if shape[3] % tp == 0:
                spec[3] = "model"
        elif key in ("latent", "rope"):            # (L, B, S, r)
            if shape[1] % dp == 0:
                spec[1] = dp_axes
            elif shape[2] % dp == 0:
                spec[2] = dp_axes
        elif key in ("pos", "cvalid", "shift", "shift2", "conv"):
            if shape[1] % dp == 0:
                spec[1] = dp_axes
        elif key in ("s", "h"):                    # (L, B, H, ...)
            if shape[1] % dp == 0:
                spec[1] = dp_axes
            if shape[2] % tp == 0:
                spec[2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(per_leaf, cache_shape)


def opt_specs(opt_shape, pspecs):
    """Optimizer-state specs derived from the parameter specs: adamw m/v
    mirror the param; adafactor r drops the last dim, c the second-last."""
    def build(sub, key):
        def per_leaf(path, leaf):
            names = _path_names(path)
            # Walk the param spec tree by the same path minus state keys.
            node = pspecs
            for nm in names:
                if nm in ("m", "v", "f", "r", "c"):
                    continue
                node = node[nm] if isinstance(node, dict) else node[int(nm)]
            spec = tuple(node)
            last = names[-1]
            if last == "r":
                spec = spec[:-1]
            elif last == "c":
                spec = spec[:-2] + spec[-1:]
            if len(spec) != leaf.ndim:
                spec = (None,) * leaf.ndim
            return P(*spec)

        return jax.tree_util.tree_map_with_path(per_leaf, sub)

    return build(opt_shape, None)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_ctx(mesh) -> DistCtx:
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return DistCtx(mesh=mesh, dp=dp, tp="model")
