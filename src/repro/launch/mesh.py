"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state; the dry-run entry point sets the forced host
device count before any jax initialization.
"""
from __future__ import annotations

import jax

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None):
    """Mesh over whatever devices exist (tests / local examples)."""
    n = n_data or len(jax.devices())
    return make_mesh((n,), ("data",))
