from repro.data.gaussian import (make_mixture_means, structured_devices,  # noqa
                                 iid_devices)
from repro.data.partition import partition_structured, partition_iid  # noqa
