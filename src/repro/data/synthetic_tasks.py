"""Synthetic structural proxies for the paper's real-data experiments
(LEAF / MNIST are not downloadable in this offline container — DESIGN §7).

* rotation_tasks  — Table 2 proxy: k rotation clusters of a 10-class
  prototype classification problem (the rotated-MNIST construction with
  synthetic prototypes instead of MNIST digits).
* femnist_like    — Figure 2/4 proxy: 62-class prototype features,
  <=2 classes per device, power-law device sizes.
* shakespeare_like— Figure 2 proxy: per-role character-histogram features
  with role clusters.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class SupervisedFed(NamedTuple):
    x: np.ndarray           # (Z, n, d)
    y: np.ndarray           # (Z, n) class labels
    cluster: np.ndarray     # (Z,) true device cluster (rotation id)
    point_mask: np.ndarray  # (Z, n)


def _rotate_pairs(x, angle):
    """Rotate feature pairs (2D planes) by ``angle`` — the d-dimensional
    analogue of image rotation used to build the k=4 task clusters."""
    d = x.shape[-1]
    c, s = np.cos(angle), np.sin(angle)
    y = x.copy()
    y[..., 0::2] = c * x[..., 0::2] - s * x[..., 1::2]
    y[..., 1::2] = s * x[..., 0::2] + c * x[..., 1::2]
    return y


def rotation_tasks(rng: np.random.Generator, *, Z: int, n_per_dev: int,
                   d: int = 32, n_classes: int = 10, k: int = 4,
                   sigma: float = 0.35, k_prime: int = 1) -> SupervisedFed:
    """k rotation clusters (0/90/180/270 degrees for k=4). Each device
    draws its data from k_prime clusters (k'=1 reproduces the IFCA setup;
    k'=2 the paper's harder mixed-device rows)."""
    protos = rng.normal(size=(n_classes, d)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    angles = [2 * np.pi * j / k for j in range(k)]
    x = np.zeros((Z, n_per_dev, d), np.float32)
    y = np.zeros((Z, n_per_dev), np.int32)
    cluster = np.zeros((Z,), np.int32)
    for z in range(Z):
        devclusters = rng.choice(k, size=k_prime, replace=False)
        cluster[z] = devclusters[0]
        part = np.array_split(np.arange(n_per_dev), k_prime)
        for cj, idx in zip(devclusters, part):
            cls = rng.integers(0, n_classes, size=len(idx))
            base = protos[cls] + sigma * rng.normal(
                size=(len(idx), d)).astype(np.float32)
            x[z, idx] = _rotate_pairs(base, angles[cj])
            y[z, idx] = cls
    return SupervisedFed(x, y, cluster,
                         np.ones((Z, n_per_dev), bool))


def femnist_like(rng: np.random.Generator, *, Z: int = 100, d: int = 64,
                 n_classes: int = 10, classes_per_dev: int = 2,
                 mean_n: int = 80, power: float = 1.5):
    """Class-prototype gaussians; 2 classes/device; power-law sizes
    (Appendix B.1 structure). Returns (X list, y list) per device plus the
    packed DevicePartition-style arrays via repro.data.partition helpers."""
    protos = 3.0 * rng.normal(size=(n_classes, d)).astype(np.float32)
    sizes = np.maximum(8, (mean_n * (rng.pareto(power, Z) + 0.3))
                       .astype(int))
    sizes = np.minimum(sizes, mean_n * 6)
    xs, ys = [], []
    for z in range(Z):
        cls = rng.choice(n_classes, size=classes_per_dev, replace=False)
        per = np.array_split(np.arange(sizes[z]), classes_per_dev)
        xz = np.zeros((sizes[z], d), np.float32)
        yz = np.zeros((sizes[z],), np.int32)
        for c, idx in zip(cls, per):
            xz[idx] = protos[c] + rng.normal(
                size=(len(idx), d)).astype(np.float32)
            yz[idx] = c
        xs.append(xz)
        ys.append(yz)
    return xs, ys, protos


def shakespeare_like(rng: np.random.Generator, *, Z: int = 109, d: int = 53,
                     k_roles: int = 8, n_per_dev: int = 120):
    """Per-device character-histogram features drawn from k role clusters
    (a structural stand-in for LEAF Shakespeare speaking-role devices)."""
    role_dirichlet = rng.dirichlet(np.ones(d) * 0.3, size=k_roles)
    xs, ys = [], []
    roles = rng.integers(0, k_roles, size=Z)
    for z in range(Z):
        p = role_dirichlet[roles[z]]
        counts = rng.multinomial(400, p, size=n_per_dev).astype(np.float32)
        xs.append(counts / 20.0)
        ys.append(np.full(n_per_dev, roles[z], np.int32))
    return xs, ys, roles
