"""Non-IID device partitioners for arbitrary labeled datasets (the
Section 4.2 experiments: structured k'-cluster partitions vs IID random
partitions, with optional power-law device sizes as in Appendix B.1)."""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class DevicePartition(NamedTuple):
    data: np.ndarray        # (Z, n_max, d) zero-padded
    labels: np.ndarray      # (Z, n_max) target labels, -1 padded
    point_mask: np.ndarray  # (Z, n_max) bool
    k_valid: np.ndarray     # (Z,) clusters present per device
    presence: np.ndarray    # (Z, k) bool


def _pack(chunks_x, chunks_y, k) -> DevicePartition:
    Z = len(chunks_x)
    n_max = max(len(c) for c in chunks_x)
    d = chunks_x[0].shape[1]
    data = np.zeros((Z, n_max, d), np.float32)
    labels = np.full((Z, n_max), -1, np.int32)
    mask = np.zeros((Z, n_max), bool)
    for z, (cx, cy) in enumerate(zip(chunks_x, chunks_y)):
        m = len(cx)
        data[z, :m] = cx
        labels[z, :m] = cy
        mask[z, :m] = True
    presence = np.zeros((Z, k), bool)
    for z in range(Z):
        present = np.unique(labels[z][labels[z] >= 0])
        presence[z, present] = True
    k_valid = presence.sum(1).astype(np.int32)
    return DevicePartition(data, labels, mask, k_valid, presence)


def partition_structured(rng: np.random.Generator, X, y, *, k: int, Z: int,
                         k_prime: int, power_law: float = 0.0
                         ) -> DevicePartition:
    """Each device receives data from <= k_prime random clusters
    (Definition 3.2 heterogeneity). Cluster shards are split evenly among
    the devices that own the cluster; power_law > 0 skews device sizes."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y)
    dev_clusters = [rng.choice(k, size=min(k_prime, k), replace=False)
                    for _ in range(Z)]
    # Ensure every cluster is owned by someone: give orphan clusters a slot
    # on a device, swapping out only clusters that keep >= 2 owners so the
    # swap cannot orphan anything else (requires Z * k_prime >= k).
    def _counts():
        c = np.zeros(k, int)
        for dc in dev_clusters:
            c[dc] += 1
        return c
    counts = _counts()
    for r in np.flatnonzero(counts == 0):
        placed = False
        order = rng.permutation(Z)
        for z in order:
            for i, r_old in enumerate(dev_clusters[z]):
                if counts[r_old] >= 2:
                    counts[r_old] -= 1
                    dev_clusters[z][i] = r
                    counts[r] += 1
                    placed = True
                    break
            if placed:
                break
        if not placed:  # pathological (Z*k' < k): force-assign anyway
            z = int(rng.integers(Z))
            counts[dev_clusters[z][0]] -= 1
            dev_clusters[z][0] = r
            counts[r] += 1
    owners = {r: [z for z in range(Z) if r in dev_clusters[z]]
              for r in range(k)}
    chunks_x = [[] for _ in range(Z)]
    chunks_y = [[] for _ in range(Z)]
    for r in range(k):
        idx = np.flatnonzero(y == r)
        rng.shuffle(idx)
        zs = owners[r]
        w = np.ones(len(zs))
        if power_law > 0:
            w = rng.pareto(power_law, size=len(zs)) + 0.2
        w = w / w.sum()
        splits = np.cumsum((w * len(idx)).astype(int))[:-1]
        for z, part in zip(zs, np.split(idx, splits)):
            chunks_x[z].append(X[part])
            chunks_y[z].append(y[part])
    cx = [np.concatenate(c) if c else np.zeros((0, X.shape[1]), np.float32)
          for c in chunks_x]
    cy = [np.concatenate(c) if c else np.zeros((0,), y.dtype)
          for c in chunks_y]
    return _pack(cx, cy, k)


def partition_iid(rng: np.random.Generator, X, y, *, k: int, Z: int
                  ) -> DevicePartition:
    """Random (IID) partition — the paper's comparison case where k' ~= k."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y)
    idx = rng.permutation(len(X))
    parts = np.array_split(idx, Z)
    return _pack([X[p] for p in parts], [y[p] for p in parts], k)
