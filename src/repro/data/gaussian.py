"""Synthetic mixture-of-Gaussians federated data (Section 4.1 of the paper).

Implements the paper's experimental construction: k components; index
groups G_i of k' components each; each group's data split across m0
devices, so every device holds points from exactly k' components, devices
within a group share the same component set (all-active pairs), and
devices across groups share none (inactive pairs). This realizes
Definition 3.2 heterogeneity with k' = sqrt(k) when so configured.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_mixture_means(key: jax.Array, k: int, d: int, *,
                       sep: float) -> jax.Array:
    """k means in R^d with MIN pairwise distance == sep (rescaled random
    gaussian placement)."""
    mu = jax.random.normal(key, (k, d), jnp.float32)
    d2 = jnp.sum((mu[:, None] - mu[None, :]) ** 2, -1)
    d2 = d2 + jnp.eye(k) * 1e30
    min_sep = jnp.sqrt(jnp.min(d2))
    return mu * (sep / jnp.maximum(min_sep, 1e-12))


class FederatedMixture(NamedTuple):
    data: jax.Array         # (Z, n, d)
    labels: jax.Array       # (Z, n) target cluster ids
    k_valid: jax.Array      # (Z,) = k' everywhere here
    presence: jax.Array     # (Z, k) bool
    means: jax.Array        # (k, d)
    group_of_device: jax.Array  # (Z,)


def structured_devices(key: jax.Array, *, k: int, d: int, k_prime: int,
                       m0: int, n_per_comp_dev: int, sep: float,
                       sigma: float = 1.0) -> FederatedMixture:
    """The paper's G_i construction. Z = (k / k') * m0 devices; device z in
    group g holds n_per_comp_dev points from each of the k' components of
    G_g."""
    assert k % k_prime == 0
    n_groups = k // k_prime
    Z = n_groups * m0
    n = k_prime * n_per_comp_dev
    km, kn = jax.random.split(key)
    means = make_mixture_means(km, k, d, sep=sep)

    group = jnp.repeat(jnp.arange(n_groups), m0)                # (Z,)
    comp_in_dev = jnp.tile(jnp.repeat(jnp.arange(k_prime), n_per_comp_dev),
                           (Z, 1))                              # (Z, n)
    labels = group[:, None] * k_prime + comp_in_dev             # global ids
    noise = jax.random.normal(kn, (Z, n, d), jnp.float32) * sigma
    data = means[labels] + noise
    presence = jax.nn.one_hot(labels, k, dtype=bool).any(axis=1)
    k_valid = jnp.full((Z,), k_prime, jnp.int32)
    return FederatedMixture(data, labels, k_valid, presence, means, group)


def late_device_stream(means, k_prime: int, requests: int, seed: int, *,
                       n_range: Tuple[int, int] = (16, 400),
                       kv_min: int = 1, sigma: float = 1.0):
    """Synthetic post-round attach requests (host-side numpy): each late
    device holds a random component subset of size k^(z) in
    [kv_min, k_prime] and a ragged point count drawn from ``n_range`` —
    the heterogeneous shapes the streaming service buckets
    (``fed/stream.py``). Returns [(data (n, d) f32, labels (n,), k^(z))].
    """
    mu = np.asarray(means)
    k, d = mu.shape
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(requests):
        kv = int(rng.integers(kv_min, k_prime + 1))
        comps = rng.choice(k, kv, replace=False)
        n = int(rng.integers(*n_range))
        lab = rng.choice(comps, n)
        data = (mu[lab] + rng.normal(size=(n, d)) * sigma).astype(np.float32)
        out.append((data, lab, kv))
    return out


def iid_devices(key: jax.Array, *, k: int, d: int, Z: int, n_per_dev: int,
                sep: float, sigma: float = 1.0) -> FederatedMixture:
    """IID counterpart: every device samples uniformly from all k
    components (k' == k; no heterogeneity benefit)."""
    km, kl, kn = jax.random.split(key, 3)
    means = make_mixture_means(km, k, d, sep=sep)
    labels = jax.random.randint(kl, (Z, n_per_dev), 0, k)
    noise = jax.random.normal(kn, (Z, n_per_dev, d), jnp.float32) * sigma
    data = means[labels] + noise
    presence = jax.nn.one_hot(labels, k, dtype=bool).any(axis=1)
    k_valid = jnp.minimum(jnp.full((Z,), k, jnp.int32), k)
    return FederatedMixture(data, labels, k_valid, presence, means,
                            jnp.zeros((Z,), jnp.int32))
