"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference semantics*: each Pallas kernel in
``pdist_argmin.py`` / ``kmeans_update.py`` / ``swa_decode.py`` must match
the corresponding function here (see tests/test_kernels.py, which sweeps
shapes and dtypes and asserts allclose in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MASKED_DIST = 1e30  # additive "infinity" that survives f32 matmul paths


def pairwise_sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared euclidean distances. x: (n, d), c: (k, d) -> (n, k)."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return jnp.maximum(d, 0.0)


def assign_argmin(x: jax.Array, c: jax.Array, c_mask: jax.Array | None = None):
    """Nearest-center assignment. Returns (idx (n,) int32, min_sq_dist (n,))."""
    d = pairwise_sq_dists(x, c)
    if c_mask is not None:
        d = jnp.where(c_mask[None, :], d, MASKED_DIST)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1)


def kmeans_update(x: jax.Array, assign: jax.Array, k: int,
                  weights: jax.Array | None = None):
    """Per-cluster sums and counts.

    ``assign`` entries equal to -1 (padded / invalid points) contribute
    nothing. Returns (sums (k, d) f32, counts (k,) f32).
    """
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # -1 rows are all-zero
    if weights is not None:
        oh = oh * weights[:, None].astype(jnp.float32)
    sums = oh.T @ x.astype(jnp.float32)
    counts = jnp.sum(oh, axis=0)
    return sums, counts


def swa_decode_attention(q: jax.Array, kw: jax.Array, vw: jax.Array,
                         bias: jax.Array, scale: float) -> jax.Array:
    """Sliding-window decode attention (one query token per sequence).

    q: (b, h, dh); kw/vw: (b, W, kvh, dh) -- the *windowed* KV slice;
    bias: (b, W) additive mask (0 valid / -inf invalid).
    Returns (b, h, dh).
    """
    b, h, dh = q.shape
    kvh = kw.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh).astype(jnp.float32)
    kf = kw.astype(jnp.float32)
    vf = vw.astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, kf) * scale
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, vf)
    return o.reshape(b, h, dh).astype(q.dtype)


def moe_dispatch(x: jax.Array, src: jax.Array, valid: jax.Array):
    """Oracle for kernels/moe_dispatch.moe_dispatch: queue slot s pulls
    token row src[s] (zeroed when invalid). x: (T, d); src/valid: (S,)."""
    rows = x[jnp.clip(src, 0, x.shape[0] - 1)]
    return jnp.where(valid[:, None], rows, 0).astype(x.dtype)


def moe_combine(ybuf: jax.Array, slot: jax.Array, gates: jax.Array,
                top_k: int):
    """Oracle for kernels/moe_dispatch.moe_combine. ybuf: (S, d);
    slot/gates: (T*top_k,). Returns (T, d) f32."""
    rows = ybuf[jnp.clip(slot, 0, ybuf.shape[0] - 1)].astype(jnp.float32)
    w = gates.astype(jnp.float32)[:, None]
    T = slot.shape[0] // top_k
    return jnp.sum((rows * w).reshape(T, top_k, -1), axis=1)
