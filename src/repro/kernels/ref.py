"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference semantics*: each Pallas kernel in
``pdist_argmin.py`` / ``kmeans_update.py`` / ``swa_decode.py`` must match
the corresponding function here (see tests/test_kernels.py, which sweeps
shapes and dtypes and asserts allclose in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MASKED_DIST = 1e30  # additive "infinity" that survives f32 matmul paths


def pairwise_sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared euclidean distances. x: (n, d), c: (k, d) -> (n, k)."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return jnp.maximum(d, 0.0)


def assign_argmin(x: jax.Array, c: jax.Array, c_mask: jax.Array | None = None):
    """Nearest-center assignment. Returns (idx (n,) int32, min_sq_dist (n,))."""
    d = pairwise_sq_dists(x, c)
    if c_mask is not None:
        d = jnp.where(c_mask[None, :], d, MASKED_DIST)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1)


def kmeans_update(x: jax.Array, assign: jax.Array, k: int,
                  weights: jax.Array | None = None):
    """Per-cluster sums and counts.

    ``assign`` entries equal to -1 (padded / invalid points) contribute
    nothing. Returns (sums (k, d) f32, counts (k,) f32).
    """
    oh = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # -1 rows are all-zero
    if weights is not None:
        oh = oh * weights[:, None].astype(jnp.float32)
    sums = oh.T @ x.astype(jnp.float32)
    counts = jnp.sum(oh, axis=0)
    return sums, counts


SOLVE_ATTACH_DTYPES = ("f32", "bf16")


def solve_attach(x: jax.Array, centers0: jax.Array, tau: jax.Array,
                 center_mask: jax.Array | None = None,
                 point_mask: jax.Array | None = None,
                 *, max_iters: int = 100, dtype: str = "f32"):
    """Oracle for ``kernels/solve_attach.solve_attach_fused`` — the FUSED
    serve step (DESIGN.md §13): bounded Lloyd local solve (Algorithm 1
    step 4) + Theorem 3.2 attach of the converged local centers against
    ``tau`` + Definition 3.3 induced point labels, as one primitive.

    x: (B, n, d); centers0: (B, k', d); tau: (k, d) — shared across the
    batch; center_mask: (B, k') bool; point_mask: (B, n) bool.
    Returns (labels (B, n) i32, min_sq_dist (B, n) f32,
    centers (B, k', d) f32, center_labels (B, k') i32).

    ``dtype="f32"`` is bitwise-identical to the staged composition
    ``core.lloyd.lloyd`` -> ``server.assign_new_device`` ->
    ``server.induced_labels`` on this backend (same primitives, same
    order). ``dtype="bf16"`` stores x / centers / tau in bfloat16
    between iterations and accumulates every distance and center-sum
    contraction in f32 (tolerance-bounded against the f32 oracle; see
    tests/test_solve_attach.py).
    """
    assert dtype in SOLVE_ATTACH_DTYPES, dtype
    store = jnp.float32 if dtype == "f32" else jnp.bfloat16
    B, n, _ = x.shape
    kp = centers0.shape[1]
    cm = jnp.ones((B, kp), bool) if center_mask is None else center_mask
    pm = jnp.ones((B, n), bool) if point_mask is None else point_mask
    taus = tau.astype(store)

    def one(x1, c0, cm1, pm1):
        def assign(centers):
            idx, mind = assign_argmin(x1, centers, cm1)
            return jnp.where(pm1, idx, -1), jnp.where(pm1, mind, 0.0)

        def cond(state):
            _, _, it, done = state
            return (~done) & (it < max_iters)

        def body(state):
            centers, prev, it, _ = state
            a, _ = assign(centers)
            sums, cnt = kmeans_update(x1, a, kp)
            new = sums / jnp.maximum(cnt, 1.0)[:, None]
            new = jnp.where((cnt > 0)[:, None], new,
                            centers.astype(jnp.float32))
            return (new.astype(centers.dtype), a, it + 1,
                    jnp.all(a == prev))

        a0 = jnp.full((x1.shape[0],), -2, jnp.int32)
        centers, _, _, _ = jax.lax.while_loop(
            cond, body, (c0, a0, jnp.int32(0), jnp.bool_(False)))
        a, mind = assign(centers)
        ctr, _ = assign_argmin(centers, taus)
        ctr = jnp.where(cm1, ctr, -1)
        safe = jnp.clip(a, 0, kp - 1)
        lbl = jnp.where(a >= 0, ctr[safe], -1)
        return lbl, mind, centers.astype(jnp.float32), ctr

    return jax.vmap(one)(x.astype(store), centers0.astype(store), cm, pm)


def swa_decode_attention(q: jax.Array, kw: jax.Array, vw: jax.Array,
                         bias: jax.Array, scale: float) -> jax.Array:
    """Sliding-window decode attention (one query token per sequence).

    q: (b, h, dh); kw/vw: (b, W, kvh, dh) -- the *windowed* KV slice;
    bias: (b, W) additive mask (0 valid / -inf invalid).
    Returns (b, h, dh).
    """
    b, h, dh = q.shape
    kvh = kw.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh).astype(jnp.float32)
    kf = kw.astype(jnp.float32)
    vf = vw.astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, kf) * scale
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, vf)
    return o.reshape(b, h, dh).astype(q.dtype)


def moe_dispatch(x: jax.Array, src: jax.Array, valid: jax.Array):
    """Oracle for kernels/moe_dispatch.moe_dispatch: queue slot s pulls
    token row src[s] (zeroed when invalid). x: (T, d); src/valid: (S,)."""
    rows = x[jnp.clip(src, 0, x.shape[0] - 1)]
    return jnp.where(valid[:, None], rows, 0).astype(x.dtype)


def moe_combine(ybuf: jax.Array, slot: jax.Array, gates: jax.Array,
                top_k: int):
    """Oracle for kernels/moe_dispatch.moe_combine. ybuf: (S, d);
    slot/gates: (T*top_k,). Returns (T, d) f32."""
    rows = ybuf[jnp.clip(slot, 0, ybuf.shape[0] - 1)].astype(jnp.float32)
    w = gates.astype(jnp.float32)[:, None]
    T = slot.shape[0] // top_k
    return jnp.sum((rows * w).reshape(T, top_k, -1), axis=1)
