"""Fused solve+attach Pallas TPU kernel (DESIGN.md §13).

One kernel invocation per request runs the ENTIRE serve hot path that
used to be three separate dispatches round-tripping HBM every Lloyd
iteration:

    bounded Lloyd local solve (Algorithm 1 step 4)
      -> Theorem 3.2 attach of the converged local centers against tau
      -> Definition 3.3 induced point labels

The request's points, the evolving (k', d) centers, the per-iteration
assignments, and the (n, k') distance block all stay resident in VMEM
across the whole while loop — x is read from HBM exactly once and the
only HBM writes are the four outputs. The legacy staged path re-read x
twice per Lloyd iteration (once for the assignment kernel, once for the
center update) and spilled the (n,) assignment each round; see
:func:`hbm_bytes` / :func:`hbm_bytes_legacy` for the exact
kernel-boundary traffic model the roofline perf-gate pins.

Mixed precision: ``dtype="bf16"`` stores points / centers / tau in
bfloat16 (halving the resident bytes and the MXU input width) while
every distance and center-sum contraction accumulates in f32 via
``preferred_element_type``; ``dtype="f32"`` executes the oracle's
arithmetic (``kernels.ref.solve_attach``) in the oracle's order — the
only deviation is float reduction order across the zero-padded lane
axis of the dots, so labels / centers / center-labels match the oracle
exactly on the parity sweeps and min-dists to reduction-order
tolerance (tests/test_solve_attach.py). The serve plane's §9/§11
bitwise-replay contract is carried by the default ref backend, where
``ops.solve_attach`` IS the oracle.

Capacity: everything for one request lives in VMEM at once, so the
kernel targets serve-bucket shapes — (n=1024, d=1024) f32 is ~6 MB,
comfortably under the ~16 MB/core budget. Million-point inputs go
through the chunked ``ops.assign_argmin`` path, not this kernel.
Padding: tau / theta pad k and k' up to 128 lanes and d up to 128;
``x`` is only copied when d % 128 != 0 (or n is not sublane-aligned —
never true for the power-of-two serve buckets).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import MASKED_DIST, SOLVE_ATTACH_DTYPES


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _kernel(x_ref, c0_ref, tau_ref, cm_ref, pm_ref,
            lbl_ref, mind_ref, ctr_ref, clbl_ref,
            *, max_iters: int, k_real: int):
    x = x_ref[0]                                  # (n_p, d_p) store dtype
    xf = x.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=1)                 # (n_p,)
    cm = cm_ref[0] != 0                           # (kp_p,) bool
    pm = pm_ref[0] != 0                           # (n_p,) bool
    taus = tau_ref[...]                           # (k_p, d_p) store dtype
    n_p, kp_p = x.shape[0], c0_ref.shape[1]

    def assign(centers):
        # Same expression, same order as ref.assign_argmin: the bf16
        # dot with preferred f32 equals the oracle's upcast-then-dot.
        cf = centers.astype(jnp.float32)
        cn = jnp.sum(cf * cf, axis=1)
        d = xn[:, None] - 2.0 * jax.lax.dot_general(
            x, centers, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) + cn[None, :]
        d = jnp.maximum(d, 0.0)
        d = jnp.where(cm[None, :], d, MASKED_DIST)
        idx = jnp.where(pm, jnp.argmin(d, axis=1).astype(jnp.int32), -1)
        return idx, jnp.where(pm, jnp.min(d, axis=1), 0.0)

    def cond(state):
        _, _, it, done = state
        return (~done) & (it < max_iters)

    def body(state):
        centers, prev, it, _ = state
        a, _ = assign(centers)
        # one_hot(-1) is all-zero, exactly like ref.kmeans_update.
        oh = (a[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (n_p, kp_p), 1)).astype(jnp.float32)
        sums = jax.lax.dot_general(
            oh, xf, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        cnt = jnp.sum(oh, axis=0)
        new = sums / jnp.maximum(cnt, 1.0)[:, None]
        new = jnp.where((cnt > 0)[:, None], new,
                        centers.astype(jnp.float32))
        return (new.astype(centers.dtype), a, it + 1,
                jnp.all(a == prev))

    a0 = jnp.full((n_p,), -2, jnp.int32)
    centers, _, _, _ = jax.lax.while_loop(
        cond, body, (c0_ref[0], a0, jnp.int32(0), jnp.bool_(False)))
    a, mind = assign(centers)

    # Theorem 3.2 attach: nearest tau center per converged local center.
    # Padded tau columns (>= k_real) are a layout artifact the oracle
    # never sees — mask them out; real columns are bitwise identical.
    cf = centers.astype(jnp.float32)
    tf = taus.astype(jnp.float32)
    dt = jnp.sum(cf * cf, axis=1)[:, None] - 2.0 * jax.lax.dot_general(
        centers, taus, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + jnp.sum(tf * tf, axis=1)[None, :]
    dt = jnp.maximum(dt, 0.0)
    dt = jnp.where(jax.lax.broadcasted_iota(jnp.int32, dt.shape, 1) < k_real,
                   dt, MASKED_DIST)
    ctr = jnp.where(cm, jnp.argmin(dt, axis=1).astype(jnp.int32), -1)

    # Definition 3.3 induced labels: ctr[clip(a, 0, k'-1)] as an exact
    # one-hot integer select (vector gather is MXU-hostile on TPU).
    safe = jnp.clip(a, 0, kp_p - 1)
    oh2 = safe[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (n_p, kp_p), 1)
    lbl = jnp.sum(jnp.where(oh2, ctr[None, :], 0), axis=1)

    lbl_ref[0] = jnp.where(a >= 0, lbl, -1).astype(jnp.int32)
    mind_ref[0] = mind
    ctr_ref[0] = centers.astype(jnp.float32)
    clbl_ref[0] = ctr


@functools.partial(jax.jit,
                   static_argnames=("max_iters", "dtype", "interpret"))
def _solve_attach(x, c0, tau, cm, pm, *, max_iters: int, dtype: str,
                  interpret: bool):
    B, n, d = x.shape
    kp = c0.shape[1]
    k = tau.shape[0]
    store = jnp.float32 if dtype == "f32" else jnp.bfloat16
    sub = 8 if dtype == "f32" else 16
    n_p, d_p = _round_up(n, sub), _round_up(d, 128)
    kp_p, k_p = _round_up(kp, 128), _round_up(k, 128)

    xs = x.astype(store)
    if (n_p, d_p) != (n, d):
        xs = jnp.zeros((B, n_p, d_p), store).at[:, :n, :d].set(xs)
    cs = c0.astype(store)
    if (kp_p, d_p) != (kp, d):
        cs = jnp.zeros((B, kp_p, d_p), store).at[:, :kp, :d].set(cs)
    ts = tau.astype(store)
    if (k_p, d_p) != (k, d):
        ts = jnp.zeros((k_p, d_p), store).at[:k, :d].set(ts)
    cmi = jnp.zeros((B, kp_p), jnp.int32).at[:, :kp].set(
        cm.astype(jnp.int32))
    pmi = jnp.zeros((B, n_p), jnp.int32).at[:, :n].set(pm.astype(jnp.int32))

    lbl, mind, ctr, clbl = pl.pallas_call(
        functools.partial(_kernel, max_iters=max_iters, k_real=k),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, n_p, d_p), lambda b: (b, 0, 0)),   # x
            pl.BlockSpec((1, kp_p, d_p), lambda b: (b, 0, 0)),  # theta0
            pl.BlockSpec((k_p, d_p), lambda b: (0, 0)),         # tau (resident)
            pl.BlockSpec((1, kp_p), lambda b: (b, 0)),          # center mask
            pl.BlockSpec((1, n_p), lambda b: (b, 0)),           # point mask
        ],
        out_specs=[
            pl.BlockSpec((1, n_p), lambda b: (b, 0)),
            pl.BlockSpec((1, n_p), lambda b: (b, 0)),
            pl.BlockSpec((1, kp_p, d_p), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, kp_p), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_p), jnp.int32),
            jax.ShapeDtypeStruct((B, n_p), jnp.float32),
            jax.ShapeDtypeStruct((B, kp_p, d_p), jnp.float32),
            jax.ShapeDtypeStruct((B, kp_p), jnp.int32),
        ],
        interpret=interpret,
    )(xs, cs, ts, cmi, pmi)
    return (lbl[:, :n], mind[:, :n], ctr[:, :kp, :d], clbl[:, :kp])


def solve_attach_fused(x: jax.Array, centers0: jax.Array, tau: jax.Array,
                       center_mask: jax.Array | None = None,
                       point_mask: jax.Array | None = None,
                       *, max_iters: int = 100, dtype: str = "f32",
                       interpret: bool | None = None):
    """Fused serve step. Same contract as ``ref.solve_attach``:
    x (B, n, d), centers0 (B, k', d), tau (k, d) ->
    (labels (B, n) i32, min_sq_dist (B, n) f32, centers (B, k', d) f32,
    center_labels (B, k') i32). ``interpret=None`` uses the
    ``kernels.ops`` platform auto-detection."""
    from repro.kernels import ops
    assert dtype in SOLVE_ATTACH_DTYPES, dtype
    B, n, _ = x.shape
    kp = centers0.shape[1]
    cm = (jnp.ones((B, kp), bool) if center_mask is None else center_mask)
    pm = jnp.ones((B, n), bool) if point_mask is None else point_mask
    return _solve_attach(x, centers0, tau, cm, pm,
                         max_iters=int(max_iters), dtype=dtype,
                         interpret=ops.resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# Analytic kernel-boundary HBM traffic model (the roofline perf-gate's
# deterministic "bytes accessed per attached point" source — see
# benchmarks/bench_roofline.py and DESIGN.md §13). Pure arithmetic over
# the padded shapes above: no compilation, no hardware, no noise.
# ---------------------------------------------------------------------------

def _padded(n, d, k_prime, k, dtype):
    sub = 8 if dtype == "f32" else 16
    return (_round_up(n, sub), _round_up(d, 128),
            _round_up(k_prime, 128), _round_up(k, 128))


def block_plan(B: int, n: int, d: int, k_prime: int, k: int,
               dtype: str = "f32") -> dict:
    """Static BlockSpec/grid metadata of :func:`_solve_attach` for the
    §15 kernel checker: every VMEM-resident block with its shape,
    dtype, and whether its index map is grid-constant (resident blocks
    are single-buffered; streaming blocks double-buffer). Mirrors the
    padding arithmetic of the pallas_call above exactly — changing one
    without the other trips the checker's hand-computed footprints."""
    store = "f32" if dtype == "f32" else "bf16"
    n_p, d_p, kp_p, k_p = _padded(n, d, k_prime, k, dtype)
    blk = [
        dict(name="x", shape=(1, n_p, d_p), dtype=store, kind="in",
             resident=False, array_shape=(B, n_p, d_p)),
        dict(name="theta0", shape=(1, kp_p, d_p), dtype=store, kind="in",
             resident=False, array_shape=(B, kp_p, d_p)),
        # tau's index map is (0, 0) for every grid step: fetched once,
        # resident for the whole grid.
        dict(name="tau", shape=(k_p, d_p), dtype=store, kind="in",
             resident=True, array_shape=(k_p, d_p)),
        dict(name="center_mask", shape=(1, kp_p), dtype="i32", kind="in",
             resident=False, array_shape=(B, kp_p)),
        dict(name="point_mask", shape=(1, n_p), dtype="i32", kind="in",
             resident=False, array_shape=(B, n_p)),
        dict(name="labels", shape=(1, n_p), dtype="i32", kind="out",
             resident=False, array_shape=(B, n_p)),
        dict(name="min_dists", shape=(1, n_p), dtype="f32", kind="out",
             resident=False, array_shape=(B, n_p)),
        dict(name="centers", shape=(1, kp_p, d_p), dtype="f32",
             kind="out", resident=False, array_shape=(B, kp_p, d_p)),
        dict(name="center_labels", shape=(1, kp_p), dtype="i32",
             kind="out", resident=False, array_shape=(B, kp_p)),
    ]
    return dict(kernel="solve_attach", grid=(B,), storage=store,
                accum="f32", blocks=blk)


def hbm_bytes(B: int, n: int, d: int, k_prime: int, k: int,
              dtype: str = "f32") -> int:
    """HBM traffic of the FUSED kernel for one (B, n, d) serve batch:
    every input block is fetched once (tau's block index is constant
    across the grid, so it stays resident and is fetched once total),
    every output written once. Independent of the Lloyd iteration count
    — that is the entire point of the fusion."""
    store = 2 if dtype == "bf16" else 4
    n_p, d_p, kp_p, k_p = _padded(n, d, k_prime, k, dtype)
    reads = B * (n_p * d_p * store        # x: ONE read, ever
                 + kp_p * d_p * store     # theta0
                 + kp_p * 4 + n_p * 4)    # masks (i32)
    reads += k_p * d_p * store            # tau: resident constant block
    writes = B * (n_p * 4                 # labels
                  + n_p * 4               # min dists
                  + kp_p * d_p * 4        # converged centers (f32)
                  + kp_p * 4)             # center labels
    return reads + writes


def hbm_bytes_legacy(B: int, n: int, d: int, k_prime: int, k: int,
                     max_iters: int, dtype: str = "f32") -> int:
    """Kernel-boundary HBM traffic of the PRE-FUSION three-dispatch
    serve path for the same batch, at its Lloyd iteration bound: each
    iteration the assignment kernel re-reads x + centers and writes the
    (n,) assignment and min-dist, then the update kernel re-reads x and
    the assignment and writes (k', d) sums + counts, then the
    elementwise center step round-trips the centers again. After the
    loop: one final assignment, the (k', k) attach, and the
    induced-label gather. ``max_iters`` (not the data-dependent actual
    trip count) keeps the model deterministic; it is the same bound the
    fused kernel's while loop carries."""
    store = 2 if dtype == "bf16" else 4
    n_p, d_p, kp_p, k_p = _padded(n, d, k_prime, k, dtype)
    x_bytes = n_p * d_p * store
    c_bytes = kp_p * d_p * 4
    assign_rw = (x_bytes + c_bytes        # assignment kernel reads
                 + n_p * 4 + n_p * 4)     # writes idx + min-dist
    update_rw = (x_bytes + n_p * 4        # update kernel reads x, assign
                 + c_bytes + kp_p * 4)    # writes sums + counts
    center_step = 2 * c_bytes + kp_p * 4  # read sums+old, write new
    per_iter = assign_rw + update_rw + center_step
    final_assign = assign_rw
    attach = c_bytes + k_p * d_p * store + kp_p * 4       # (k', k) argmin
    induced = n_p * 4 + kp_p * 4 + n_p * 4                # gather in/out
    return B * (max_iters * per_iter + final_assign + attach + induced)


def kernel_flops(B: int, n: int, d: int, k_prime: int, k: int,
                 max_iters: int, dtype: str = "f32") -> int:
    """MXU contraction FLOPs for one serve batch at the iteration bound
    (identical for fused and legacy — fusion changes traffic, not math):
    per iteration one (n, d) x (d, k') distance dot and one (k', n) x
    (n, d) center-sum dot, plus the final assignment and the (k', k)
    attach dot. Elementwise/argmin FLOPs are excluded (sub-percent)."""
    n_p, d_p, kp_p, k_p = _padded(n, d, k_prime, k, dtype)
    per_iter = 2 * n_p * d_p * kp_p + 2 * kp_p * n_p * d_p
    final = 2 * n_p * d_p * kp_p
    attach = 2 * kp_p * d_p * k_p
    return B * (max_iters * per_iter + final + attach + 2 * n_p * d_p)
