"""Sliding-window flash-decode attention Pallas TPU kernel.

Serving fast path for the long-context decode shapes: one query token per
sequence attends to a W-token window of the KV cache with GQA head
grouping. The window is provided pre-sliced (the caller performs the cheap
``lax.dynamic_slice`` of the ring-buffer cache); the kernel runs an online
softmax over window blocks so the (h, W) score matrix never materializes in
HBM. Grid: (batch, kv_head, window_block); scratch keeps the running max,
denominator and weighted-value accumulator per (group, head_dim) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _make_kernel(scale: float):
    def kernel(q_ref, k_ref, v_ref, b_ref, o_ref, m_ref, l_ref, acc_ref):
        w = pl.program_id(2)
        nw = pl.num_programs(2)

        @pl.when(w == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, -1e30)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0, 0].astype(jnp.float32)           # (g, dh)
        kk = k_ref[0, :, 0].astype(jnp.float32)       # (bw, dh)
        vv = v_ref[0, :, 0].astype(jnp.float32)       # (bw, dh)
        bias = b_ref[0].astype(jnp.float32)           # (bw,)

        s = jax.lax.dot_general(
            q, kk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale + bias[None, :]

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])               # (g, bw)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

        @pl.when(w == nw - 1)
        def _finalize():
            o_ref[0, 0] = (acc_ref[...] /
                           jnp.maximum(l_ref[...], 1e-30)[:, None]
                           ).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("scale", "bw", "interpret"))
def swa_decode_attention(q: jax.Array, kw: jax.Array, vw: jax.Array,
                         bias: jax.Array, scale: float,
                         *, bw: int = 128, interpret: bool = True):
    """q: (b, h, dh); kw/vw: (b, W, kvh, dh); bias: (b, W) additive mask.

    Returns (b, h, dh). Matches ``ref.swa_decode_attention``.
    """
    b, h, dh = q.shape
    W, kvh = kw.shape[1], kw.shape[2]
    g = h // kvh
    wp = _round_up(W, bw)

    qg = q.reshape(b, kvh, g, dh)
    kp = jnp.zeros((b, wp, kvh, dh), kw.dtype).at[:, :W].set(kw)
    vp = jnp.zeros((b, wp, kvh, dh), vw.dtype).at[:, :W].set(vw)
    bp = jnp.full((b, wp), -1e30, jnp.float32).at[:, :W].set(
        bias.astype(jnp.float32))

    out = pl.pallas_call(
        _make_kernel(scale),
        grid=(b, kvh, wp // bw),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda i, hh, w: (i, hh, 0, 0)),
            pl.BlockSpec((1, bw, 1, dh), lambda i, hh, w: (i, w, hh, 0)),
            pl.BlockSpec((1, bw, 1, dh), lambda i, hh, w: (i, w, hh, 0)),
            pl.BlockSpec((1, bw), lambda i, hh, w: (i, w)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda i, hh, w: (i, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kp, vp, bp)
    return out.reshape(b, h, dh)
