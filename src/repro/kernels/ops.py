"""Jit'd dispatch layer over the Pallas kernels and their jnp oracles.

The framework's numerical code calls these entry points; the backend is
selected globally (``set_backend``) or per-call. Interpret mode is
auto-detected from the platform: on TPU the kernels run compiled, on any
other backend (e.g. this CPU container) they run in interpret mode (the
kernel body executes in Python for correctness validation). Override
with ``REPRO_KERNEL_INTERPRET=0|1`` or ``set_backend(..., interpret=)``.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

_STATE = {
    "impl": os.environ.get("REPRO_KERNEL_IMPL", "ref"),  # "ref" | "pallas"
    "interpret": None,  # None = auto-detect on first kernel call
    # Row count above which assign_argmin streams fixed-size chunks
    # through the kernel instead of one monolithic call (bounds the
    # padded/intermediate footprint for million-point labeling).
    "chunk_rows": int(os.environ.get("REPRO_ASSIGN_CHUNK_ROWS", 1 << 18)),
}


def _auto_interpret() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    # Compiled Pallas only on TPU; interpret everywhere else. Deferred to
    # first kernel call so importing this module never initializes a
    # backend.
    return jax.default_backend() != "tpu"


def _interpret() -> bool:
    if _STATE["interpret"] is None:
        _STATE["interpret"] = _auto_interpret()
    return _STATE["interpret"]


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Per-call override -> resolved interpret flag. Kernel modules call
    this so a direct kernel invocation (bypassing the dispatchers below)
    still gets the platform auto-detection instead of a hardcoded
    default."""
    return _interpret() if interpret is None else interpret


def set_backend(impl: str, interpret: Optional[bool] = None,
                chunk_rows: Optional[int] = None) -> None:
    """Select the kernel implementation. ``interpret=None`` re-enables
    platform auto-detection (compiled on TPU, interpret elsewhere).
    ``chunk_rows`` sets the auto-chunking threshold of
    :func:`assign_argmin` (0 disables)."""
    assert impl in ("ref", "pallas"), impl
    _STATE["impl"] = impl
    _STATE["interpret"] = interpret
    if chunk_rows is not None:
        _STATE["chunk_rows"] = chunk_rows


def get_backend() -> str:
    return _STATE["impl"]


def pairwise_sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    # Full distance matrix is only used by analysis paths; always jnp.
    return _ref.pairwise_sq_dists(x, c)


def _assign_argmin_one(x: jax.Array, c: jax.Array,
                       c_mask: Optional[jax.Array] = None):
    if _STATE["impl"] == "pallas":
        from repro.kernels.pdist_argmin import pairwise_argmin
        return pairwise_argmin(x, c, c_mask, interpret=_interpret())
    return _ref.assign_argmin(x, c, c_mask)


def assign_argmin_chunked(x: jax.Array, c: jax.Array,
                          c_mask: Optional[jax.Array] = None,
                          *, chunk: int = 1 << 18):
    """Streaming nearest-center assignment: rows of ``x`` are processed
    in fixed ``chunk``-size tiles (``lax.map`` — one kernel launch per
    tile, sequential), so the working set stays O(chunk * d) no matter
    how many points are labeled. Same (idx, min_sq_dist) contract as
    :func:`assign_argmin`."""
    n, d = x.shape
    if n <= chunk:
        return _assign_argmin_one(x, c, c_mask)
    # Whole chunks stream through lax.map; the ragged tail gets its own
    # call — no full zero-padded copy of x (that would double peak
    # memory on exactly the inputs chunking exists to bound).
    nfull = (n // chunk) * chunk
    idx, val = jax.lax.map(
        lambda xb: _assign_argmin_one(xb, c, c_mask),
        x[:nfull].reshape(-1, chunk, d))
    idx, val = idx.reshape(-1), val.reshape(-1)
    if nfull < n:
        ti, tv = _assign_argmin_one(x[nfull:], c, c_mask)
        idx = jnp.concatenate([idx, ti])
        val = jnp.concatenate([val, tv])
    return idx, val


def assign_argmin(x: jax.Array, c: jax.Array,
                  c_mask: Optional[jax.Array] = None):
    chunk = _STATE["chunk_rows"]
    if chunk and x.shape[0] > chunk:
        return assign_argmin_chunked(x, c, c_mask, chunk=chunk)
    return _assign_argmin_one(x, c, c_mask)


# Floor of the per-shard chunk budget: below this the per-launch
# overhead of lax.map tiles dominates any footprint saving.
_MIN_CHUNK_ROWS = 4096


def plan_chunk_rows(n_shards: int = 1) -> int:
    """Row-chunk budget for shard-parallel callers (the serve plane,
    DESIGN.md §11): ``n_shards`` concurrent shards each streaming
    assignment chunks should divide the global ``chunk_rows`` threshold
    between them, so the AGGREGATE in-flight footprint stays bounded by
    one single-host chunk no matter how wide the mesh. Floored at
    ``_MIN_CHUNK_ROWS`` so tiny per-shard batches never degenerate into
    per-row kernel launches."""
    base = _STATE["chunk_rows"] or (1 << 18)
    return max(_MIN_CHUNK_ROWS, base // max(1, int(n_shards)))


def solve_attach(x: jax.Array, centers0: jax.Array, tau: jax.Array,
                 center_mask: Optional[jax.Array] = None,
                 point_mask: Optional[jax.Array] = None,
                 *, max_iters: int = 100, dtype: str = "f32"):
    """Fused serve-step primitive (DESIGN.md §13): bounded Lloyd local
    solve + Theorem 3.2 attach against ``tau`` + Definition 3.3 induced
    labels for a (B, n, d) request batch, in one dispatch. ``dtype``:
    "f32" (bitwise vs the staged composition) or "bf16" (bf16 storage,
    f32 accumulation). Returns (labels, min_sq_dist, centers,
    center_labels)."""
    if _STATE["impl"] == "pallas":
        from repro.kernels.solve_attach import solve_attach_fused
        return solve_attach_fused(x, centers0, tau, center_mask,
                                  point_mask, max_iters=max_iters,
                                  dtype=dtype, interpret=_interpret())
    return _ref.solve_attach(x, centers0, tau, center_mask, point_mask,
                             max_iters=max_iters, dtype=dtype)


def kmeans_update(x: jax.Array, assign: jax.Array, k: int,
                  weights: Optional[jax.Array] = None):
    if _STATE["impl"] == "pallas":
        from repro.kernels.kmeans_update import kmeans_update as _pk
        return _pk(x, assign, k, weights, interpret=_interpret())
    return _ref.kmeans_update(x, assign, k, weights)


def swa_decode_attention(q, kw, vw, bias, scale):
    if _STATE["impl"] == "pallas":
        from repro.kernels.swa_decode import swa_decode_attention as _pk
        return _pk(q, kw, vw, bias, scale, interpret=_interpret())
    return _ref.swa_decode_attention(q, kw, vw, bias, scale)


def moe_dispatch(x, src, valid):
    """Queue-order row gather for MoE-style dispatch (scalar-prefetch
    DMA gather on TPU). The serve plane's routed personalization step
    (DESIGN.md §16) rides this with clusters as the experts: whole
    requests gather into per-cluster head queues, no (k, C, d)
    scatter."""
    if _STATE["impl"] == "pallas":
        from repro.kernels.moe_dispatch import moe_dispatch as _pd
        return _pd(x, src, valid, interpret=_interpret())
    return _ref.moe_dispatch(x, src, valid)


def moe_combine(ybuf, slot, gates, top_k: int):
    """Weighted queue->request re-assembly, the combine sibling of
    :func:`moe_dispatch` (routed serving uses top_k=1 with the keep
    mask as gates, so overflowed requests combine to zero)."""
    if _STATE["impl"] == "pallas":
        from repro.kernels.moe_dispatch import moe_combine as _pc
        return _pc(ybuf, slot, gates, top_k=top_k,
                   interpret=_interpret())
    return _ref.moe_combine(ybuf, slot, gates, top_k)
