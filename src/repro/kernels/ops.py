"""Jit'd dispatch layer over the Pallas kernels and their jnp oracles.

The framework's numerical code calls these entry points; the backend is
selected globally (``set_backend``) or per-call. On this CPU container the
Pallas path runs in interpret mode (the kernels target TPU; interpret mode
executes the kernel body in Python for correctness validation).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref as _ref

_STATE = {
    "impl": os.environ.get("REPRO_KERNEL_IMPL", "ref"),  # "ref" | "pallas"
    "interpret": True,
}


def set_backend(impl: str, interpret: bool = True) -> None:
    assert impl in ("ref", "pallas"), impl
    _STATE["impl"] = impl
    _STATE["interpret"] = interpret


def get_backend() -> str:
    return _STATE["impl"]


def pairwise_sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    # Full distance matrix is only used by analysis paths; always jnp.
    return _ref.pairwise_sq_dists(x, c)


def assign_argmin(x: jax.Array, c: jax.Array,
                  c_mask: Optional[jax.Array] = None):
    if _STATE["impl"] == "pallas":
        from repro.kernels.pdist_argmin import pairwise_argmin
        return pairwise_argmin(x, c, c_mask, interpret=_STATE["interpret"])
    return _ref.assign_argmin(x, c, c_mask)


def kmeans_update(x: jax.Array, assign: jax.Array, k: int,
                  weights: Optional[jax.Array] = None):
    if _STATE["impl"] == "pallas" and weights is None:
        from repro.kernels.kmeans_update import kmeans_update as _pk
        return _pk(x, assign, k, interpret=_STATE["interpret"])
    return _ref.kmeans_update(x, assign, k, weights)


def swa_decode_attention(q, kw, vw, bias, scale):
    if _STATE["impl"] == "pallas":
        from repro.kernels.swa_decode import swa_decode_attention as _pk
        return _pk(q, kw, vw, bias, scale, interpret=_STATE["interpret"])
    return _ref.swa_decode_attention(q, kw, vw, bias, scale)


def moe_dispatch(x, src, valid):
    """Queue-order token gather for MoE dispatch (scalar-prefetch DMA
    gather on TPU)."""
    if _STATE["impl"] == "pallas":
        from repro.kernels.moe_dispatch import moe_dispatch as _pd
        return _pd(x, src, valid, interpret=_STATE["interpret"])
    return _ref.moe_dispatch(x, src, valid)


def moe_combine(ybuf, slot, gates, top_k: int):
    if _STATE["impl"] == "pallas":
        from repro.kernels.moe_dispatch import moe_combine as _pc
        return _pc(ybuf, slot, gates, top_k=top_k,
                   interpret=_STATE["interpret"])
    return _ref.moe_combine(ybuf, slot, gates, top_k)
