"""MoE dispatch / combine Pallas TPU kernels (scalar-prefetch gather).

The §Perf deepseek/mixtral profiles put the residual cost of the MoE
layer in the dispatch data movement: building the (E, C, d) expert
queues from routed tokens and re-assembling token outputs. On GPU this
is a warp-level shuffle/scatter; the TPU-native mechanism is a
**scalar-prefetched DMA gather** — the routing indices are prefetched to
SMEM before the grid runs, and each grid step's BlockSpec *index_map*
uses them to point the DMA engine at the right source row, so tokens
stream HBM->VMEM exactly once, already in queue order. No scatter, no
(E, C, d) read-modify-write.

  dispatch:  queue[s, :] = x[src[s], :] * valid[s]         s in [E*C)
  combine:   y[t, :]     = sum_j gates[t, j] * ybuf[slot[t, j], :]

Validated in interpret mode against the pure-jnp oracles
(ref.moe_dispatch / ref.moe_combine); see tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _dispatch_kernel(src_ref, valid_ref, x_ref, out_ref):
    s = pl.program_id(0)
    keep = (valid_ref[s] > 0).astype(out_ref.dtype)
    out_ref[...] = x_ref[...] * keep


def moe_dispatch(x: jax.Array, src: jax.Array, valid: jax.Array,
                 *, bd: int = 512, interpret: bool | None = None):
    """Gather routed tokens into queue order.

    x: (T, d); src: (S,) int32 source row per queue slot (clipped to
    [0, T)); valid: (S,) bool. Returns (S, d) with invalid slots zeroed.
    The caller reshapes to (E, C, d). ``interpret=None`` resolves via
    the same platform auto-detection as ``kernels.ops`` (compiled on
    TPU, interpret elsewhere, ``REPRO_KERNEL_INTERPRET`` override)
    instead of a hardcoded interpret default that silently never
    compiles.
    """
    from repro.kernels import ops
    return _moe_dispatch(x, src, valid, bd=bd,
                         interpret=ops.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def _moe_dispatch(x: jax.Array, src: jax.Array, valid: jax.Array,
                  *, bd: int, interpret: bool):
    T, d = x.shape
    S = src.shape[0]
    dp = _round_up(d, bd)
    xp = jnp.zeros((T, dp), x.dtype).at[:, :d].set(x)
    src_c = jnp.clip(src, 0, T - 1).astype(jnp.int32)
    val_i = valid.astype(jnp.int32)

    grid = (S, dp // bd)
    out = pl.pallas_call(
        _dispatch_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                # one source row per grid step, chosen by the prefetched
                # routing index — the DMA gather
                pl.BlockSpec((1, bd), lambda s, j, src, val: (src[s], j)),
            ],
            out_specs=pl.BlockSpec((1, bd), lambda s, j, src, val: (s, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((S, dp), x.dtype),
        interpret=interpret,
    )(src_c, val_i, xp)
    return out[:, :d]


def dispatch_block_plan(T: int, d: int, S: int, *, bd: int = 512,
                        dtype: str = "f32") -> dict:
    """Static BlockSpec/grid metadata of :func:`moe_dispatch` for the
    §15 kernel checker. The (1, bd) row blocks are the scalar-prefetch
    DMA gather granule: a 1-row sublane window is the intended stream
    shape here, not a partial-tile relayout. Routing indices live in
    SMEM (kind="scalar")."""
    store = "f32" if dtype == "f32" else "bf16"
    dp = _round_up(d, bd)
    blk = [
        dict(name="src", shape=(S,), dtype="i32", kind="scalar",
             resident=True, array_shape=(S,)),
        dict(name="valid", shape=(S,), dtype="i32", kind="scalar",
             resident=True, array_shape=(S,)),
        dict(name="x", shape=(1, bd), dtype=store, kind="in",
             resident=False, array_shape=(T, dp)),
        dict(name="queues", shape=(1, bd), dtype=store, kind="out",
             resident=False, array_shape=(S, dp)),
    ]
    return dict(kernel="moe_dispatch", grid=(S, dp // bd), storage=store,
                accum=store, blocks=blk)


def combine_block_plan(S: int, d: int, T: int, *, top_k: int = 2,
                       bd: int = 512, dtype: str = "f32") -> dict:
    """Static BlockSpec/grid metadata of :func:`moe_combine` for the
    §15 kernel checker — the gather-and-weighted-sum sibling of
    :func:`dispatch_block_plan`, always f32-accumulating."""
    store = "f32" if dtype == "f32" else "bf16"
    dp = _round_up(d, bd)
    blk = [
        dict(name="slot", shape=(T * top_k,), dtype="i32", kind="scalar",
             resident=True, array_shape=(T * top_k,)),
        dict(name="gates", shape=(T * top_k,), dtype="f32",
             kind="scalar", resident=True, array_shape=(T * top_k,)),
        dict(name="ybuf", shape=(1, bd), dtype=store, kind="in",
             resident=False, array_shape=(S, dp)),
        dict(name="out", shape=(1, bd), dtype="f32", kind="out",
             resident=False, array_shape=(T, dp)),
    ]
    return dict(kernel="moe_combine", grid=(T, top_k, dp // bd),
                storage=store, accum="f32", blocks=blk)


def moe_combine(ybuf: jax.Array, slot: jax.Array, gates: jax.Array,
                *, top_k: int, bd: int = 512,
                interpret: bool | None = None):
    """Weighted re-assembly of token outputs from expert queues.

    ybuf: (S, d) flat queues; slot: (T*top_k,) int32 queue slot per
    (token, choice), already clipped, with dropped entries pointing at
    any slot; gates: (T*top_k,) f32, zero for dropped entries.
    Returns (T, d) f32. ``interpret=None`` resolves like
    :func:`moe_dispatch`.
    """
    from repro.kernels import ops
    return _moe_combine(ybuf, slot, gates, top_k=top_k, bd=bd,
                        interpret=ops.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("top_k", "bd", "interpret"))
def _moe_combine(ybuf: jax.Array, slot: jax.Array, gates: jax.Array,
                 *, top_k: int, bd: int, interpret: bool):
    S, d = ybuf.shape
    N = slot.shape[0]
    T = N // top_k
    dp = _round_up(d, bd)
    yp = jnp.zeros((S, dp), ybuf.dtype).at[:, :d].set(ybuf)

    def kernel(slot_ref, gate_ref, y_ref, out_ref):
        t = pl.program_id(0)
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        g = gate_ref[t * top_k + j]
        out_ref[...] += y_ref[...].astype(jnp.float32) * g

    grid = (T, top_k, dp // bd)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bd),
                             lambda t, j, b, slot, gate:
                             (slot[t * top_k + j], b)),
            ],
            out_specs=pl.BlockSpec((1, bd),
                                   lambda t, j, b, slot, gate: (t, b)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, dp), jnp.float32),
        interpret=interpret,
    )(slot.astype(jnp.int32), gates.astype(jnp.float32), yp)
    return out[:, :d]
