"""Fused pairwise-distance + argmin Pallas TPU kernel.

The assignment step of Lloyd's method (the compute hot-spot of both
Algorithm 1 and the one-round server Lloyd of k-FED) is matmul-shaped:

    d(i, r) = ||x_i||^2 - 2 x_i . c_r + ||c_r||^2

We tile (n, d) into (bn, bd) VMEM blocks and the center axis into bk
blocks, drive the -2 x @ c^T term through the MXU (128-aligned tiles),
accumulate partial dot products over d-blocks in a (bn, bk) VMEM scratch
accumulator, and fuse the argmin so the (n, k) distance matrix never
round-trips to HBM. The per-point running (idx, val) best lives in the
output block (resident across the k/d grid axes), so VMEM usage is fixed
at O(bn * (bd + bk)) regardless of k — large-k center sets (the induced
labeling of a production round with thousands of retained centers)
stream through in tiles instead of materializing one (bn, k) scratch.
Outputs are the assignment indices and the min squared distance per
point; ties resolve to the smallest center index (first occurrence),
matching ``jnp.argmin``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MASKED_DIST


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _kernel(x_ref, c_ref, cn_ref, idx_ref, val_ref, acc_ref, xn_ref):
    kb = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    bk = acc_ref.shape[1]

    @pl.when((kb == 0) & (j == 0))
    def _init_best():
        idx_ref[...] = jnp.zeros_like(idx_ref)
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        xn_ref[...] = jnp.zeros_like(xn_ref)

    @pl.when(j == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    # -2 * x @ c.T on the MXU, accumulated over d-blocks.
    acc_ref[...] += -2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # ||x||^2 depends only on the row block: accumulate it on the first
    # k-block pass and reuse the scratch for the rest.
    @pl.when(kb == 0)
    def _xnorm():
        xn_ref[...] += jnp.sum(x * x, axis=1)

    @pl.when(j == nj - 1)
    def _merge():
        d = acc_ref[...] + cn_ref[...][None, :] + xn_ref[...][:, None]
        d = jnp.maximum(d, 0.0)
        bidx = jnp.argmin(d, axis=1).astype(jnp.int32)
        bval = jnp.min(d, axis=1)
        # Strict < keeps the earlier k-block on ties; within a block
        # argmin picks the first — together: smallest global index.
        better = bval < val_ref[...]
        idx_ref[...] = jnp.where(better, kb * bk + bidx, idx_ref[...])
        val_ref[...] = jnp.where(better, bval, val_ref[...])


@functools.partial(jax.jit, static_argnames=("bn", "bd", "bk", "interpret"))
def _pairwise_argmin(x, c, c_mask, *, bn: int, bd: int, bk: int,
                     interpret: bool):
    n, d = x.shape
    k = c.shape[0]
    # Shrink the d-tile to the data (128-aligned) so a narrow feature
    # dim never pads x out to a full default-width tile. Single-tile
    # reductions are unchanged bitwise (only the zero tail shrinks).
    bd = min(bd, _round_up(d, 128))
    dp = _round_up(d, bd)
    bk = min(_round_up(bk, 128), _round_up(k, 128))
    kp = _round_up(_round_up(k, 128), bk)

    cp = jnp.zeros((kp, dp), c.dtype).at[:k, :d].set(c)
    cn = jnp.sum(cp.astype(jnp.float32) ** 2, axis=1)
    valid = jnp.arange(kp) < k
    if c_mask is not None:
        valid = valid & jnp.pad(c_mask, (0, kp - k), constant_values=False)
    cn = jnp.where(valid, cn, MASKED_DIST)

    def call(xp):
        np_ = xp.shape[0]
        grid = (np_ // bn, kp // bk, dp // bd)  # d innermost: acc stays hot
        return pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, bd), lambda i, kb, j: (i, j)),  # x tile
                pl.BlockSpec((bk, bd), lambda i, kb, j: (kb, j)),  # centers
                pl.BlockSpec((bk,), lambda i, kb, j: (kb,)),  # masked norms
            ],
            out_specs=[
                pl.BlockSpec((bn,), lambda i, kb, j: (i,)),
                pl.BlockSpec((bn,), lambda i, kb, j: (i,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((np_,), jnp.int32),
                jax.ShapeDtypeStruct((np_,), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((bn, bk), jnp.float32),
                pltpu.VMEM((bn,), jnp.float32),
            ],
            interpret=interpret,
        )(xp, cp, cn)

    def pad_d(xs):
        if d == dp:
            return xs
        return jnp.zeros((xs.shape[0], dp), x.dtype).at[:, :d].set(xs)

    # Row padding: ONLY the ragged tail block (if any) is copied into a
    # zero-padded (bn, dp) buffer. The aligned prefix streams through
    # the kernel as-is — never a full (np_, dp) duplicate of x, which
    # doubled peak memory on exactly the million-point inputs the
    # chunked dispatcher exists to bound. (A d-pad copy still happens
    # when d is ragged vs the 128-lane tile; rows are independent, so
    # the split is bitwise-invisible.)
    nfull = (n // bn) * bn
    if nfull == n:
        return call(pad_d(x))
    tail = jnp.zeros((bn, dp), x.dtype).at[:n - nfull, :d].set(x[nfull:])
    ti, tv = call(tail)
    if not nfull:
        return ti[:n], tv[:n]
    idx, val = call(pad_d(x[:nfull]))
    return (jnp.concatenate([idx, ti[:n - nfull]]),
            jnp.concatenate([val, tv[:n - nfull]]))


def block_plan(n: int, d: int, k: int, *, bn: int = 128, bd: int = 512,
               bk: int = 512, dtype: str = "f32") -> dict:
    """Static BlockSpec/grid metadata of :func:`_pairwise_argmin` for
    the §15 kernel checker — the same tile-shrinking arithmetic as the
    dispatch above, including the (bn, bk) accumulator and (bn,) x-norm
    VMEM scratch that bound the footprint independently of k."""
    store = "f32" if dtype == "f32" else "bf16"
    bd = min(bd, _round_up(d, 128))
    dp = _round_up(d, bd)
    bk = min(_round_up(bk, 128), _round_up(k, 128))
    kp = _round_up(_round_up(k, 128), bk)
    np_ = _round_up(n, bn)
    blk = [
        dict(name="x", shape=(bn, bd), dtype=store, kind="in",
             resident=False, array_shape=(np_, dp)),
        dict(name="centers", shape=(bk, bd), dtype=store, kind="in",
             resident=False, array_shape=(kp, dp)),
        dict(name="center_norms", shape=(bk,), dtype="f32", kind="in",
             resident=False, array_shape=(kp,)),
        dict(name="idx", shape=(bn,), dtype="i32", kind="out",
             resident=False, array_shape=(np_,)),
        dict(name="val", shape=(bn,), dtype="f32", kind="out",
             resident=False, array_shape=(np_,)),
        dict(name="acc", shape=(bn, bk), dtype="f32", kind="scratch",
             resident=True, array_shape=(bn, bk)),
        dict(name="xn", shape=(bn,), dtype="f32", kind="scratch",
             resident=True, array_shape=(bn,)),
    ]
    return dict(kernel="pdist_argmin",
                grid=(np_ // bn, kp // bk, dp // bd), storage=store,
                accum="f32", blocks=blk)


def pairwise_argmin(x: jax.Array, c: jax.Array,
                    c_mask: jax.Array | None = None,
                    *, bn: int = 128, bd: int = 512, bk: int = 512,
                    interpret: bool | None = None):
    """Fused nearest-center assignment. x: (n, d), c: (k, d).

    Returns (idx (n,) int32, min_sq_dist (n,) f32). Matches
    ``ref.assign_argmin`` (masked centers excluded via an additive
    MASKED_DIST on their norm term). ``bk`` tiles the center axis so
    VMEM stays fixed for large k. ``interpret=None`` uses the same
    platform auto-detection as ``kernels.ops`` (compiled on TPU,
    interpret elsewhere) instead of silently interpreting on TPU.
    """
    from repro.kernels import ops
    return _pairwise_argmin(x, c, c_mask, bn=bn, bd=bd, bk=bk,
                            interpret=ops.resolve_interpret(interpret))
