"""Fused pairwise-distance + argmin Pallas TPU kernel.

The assignment step of Lloyd's method (the compute hot-spot of both
Algorithm 1 and the one-round server Lloyd of k-FED) is matmul-shaped:

    d(i, r) = ||x_i||^2 - 2 x_i . c_r + ||c_r||^2

We tile (n, d) into (bn, bd) VMEM blocks, drive the -2 x @ c^T term through
the MXU (128-aligned tiles), accumulate partial dot products over d-blocks
in a VMEM scratch accumulator, and fuse the argmin so the (n, k) distance
matrix never round-trips to HBM. Outputs are the assignment indices and
the min squared distance per point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MASKED_DIST


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _kernel(x_ref, c_ref, cn_ref, idx_ref, val_ref, acc_ref, xn_ref):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xn_ref[...] = jnp.zeros_like(xn_ref)

    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    # -2 * x @ c.T on the MXU, accumulated over d-blocks.
    acc_ref[...] += -2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    xn_ref[...] += jnp.sum(x * x, axis=1)

    @pl.when(j == nj - 1)
    def _finalize():
        d = acc_ref[...] + cn_ref[...][None, :] + xn_ref[...][:, None]
        d = jnp.maximum(d, 0.0)
        idx_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
        val_ref[...] = jnp.min(d, axis=1)


@functools.partial(jax.jit, static_argnames=("bn", "bd", "interpret"))
def pairwise_argmin(x: jax.Array, c: jax.Array,
                    c_mask: jax.Array | None = None,
                    *, bn: int = 128, bd: int = 512,
                    interpret: bool = True):
    """Fused nearest-center assignment. x: (n, d), c: (k, d).

    Returns (idx (n,) int32, min_sq_dist (n,) f32). Matches
    ``ref.assign_argmin`` (masked centers excluded via an additive
    MASKED_DIST on their norm term).
    """
    n, d = x.shape
    k = c.shape[0]
    np_, dp = _round_up(n, bn), _round_up(min(d, bd) if d < bd else d, bd)
    dp = max(dp, bd)
    kp = _round_up(k, 128)

    xp = jnp.zeros((np_, dp), x.dtype).at[:n, :d].set(x)
    cp = jnp.zeros((kp, dp), c.dtype).at[:k, :d].set(c)
    cn = jnp.sum(cp.astype(jnp.float32) ** 2, axis=1)
    valid = jnp.arange(kp) < k
    if c_mask is not None:
        valid = valid & jnp.pad(c_mask, (0, kp - k), constant_values=False)
    cn = jnp.where(valid, cn, MASKED_DIST)

    grid = (np_ // bn, dp // bd)
    idx, val = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),   # x tile
            pl.BlockSpec((kp, bd), lambda i, j: (0, j)),   # all centers, d tile
            pl.BlockSpec((kp,), lambda i, j: (0,)),        # masked center norms
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, kp), jnp.float32),
            pltpu.VMEM((bn,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, cn)
    return idx[:n], val[:n]
