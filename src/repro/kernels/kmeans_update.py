"""Center-update (segment sum) Pallas TPU kernel.

Computes per-cluster sums and counts from an assignment vector by turning
the scatter into a one-hot matmul per (bn, d) tile, accumulated across the
sequential TPU grid directly into the (k, d) output block. Padded / invalid
points carry ``assign == -1`` and match no one-hot column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _make_kernel(bn: int, kp: int):
    def kernel(x_ref, a_ref, w_ref, sums_ref, cnt_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            sums_ref[...] = jnp.zeros_like(sums_ref)
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

        x = x_ref[...].astype(jnp.float32)
        a = a_ref[...]
        w = w_ref[...]
        cols = jax.lax.broadcasted_iota(jnp.int32, (bn, kp), 1)
        # Weighted one-hot rows (weight 1.0 for the unweighted update).
        oh = (a[:, None] == cols).astype(jnp.float32) * w[:, None]
        # one-hot^T @ x on the MXU: (kp, bn) x (bn, d) -> (kp, d)
        sums_ref[...] += jax.lax.dot_general(
            oh, x, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        cnt_ref[...] += jnp.sum(oh, axis=0)

    return kernel


def block_plan(n: int, d: int, k: int, *, bn: int = 256,
               dtype: str = "f32") -> dict:
    """Static BlockSpec/grid metadata of :func:`kmeans_update` for the
    §15 kernel checker. The (kp, d) output blocks have grid-constant
    index maps (the sequential-grid accumulation target), so they are
    resident — single-buffered — for the whole grid."""
    store = "f32" if dtype == "f32" else "bf16"
    np_ = _round_up(n, bn)
    kp = _round_up(k, 128)
    blk = [
        dict(name="x", shape=(bn, d), dtype=store, kind="in",
             resident=False, array_shape=(np_, d)),
        dict(name="assign", shape=(bn,), dtype="i32", kind="in",
             resident=False, array_shape=(np_,)),
        dict(name="weights", shape=(bn,), dtype="f32", kind="in",
             resident=False, array_shape=(np_,)),
        dict(name="sums", shape=(kp, d), dtype="f32", kind="out",
             resident=True, array_shape=(kp, d)),
        dict(name="counts", shape=(kp,), dtype="f32", kind="out",
             resident=True, array_shape=(kp,)),
    ]
    return dict(kernel="kmeans_update", grid=(np_ // bn,), storage=store,
                accum="f32", blocks=blk)


@functools.partial(jax.jit, static_argnames=("k", "bn", "interpret"))
def kmeans_update(x: jax.Array, assign: jax.Array, k: int,
                  weights: jax.Array | None = None,
                  *, bn: int = 256, interpret: bool = True):
    """Per-cluster (weighted) sums/counts. x: (n, d), assign: (n,) int32
    in [-1, k); weights: optional (n,) per-point mass.

    Returns (sums (k, d) f32, counts (k,) f32). Matches
    ``ref.kmeans_update`` (including the optional weights argument).
    """
    n, d = x.shape
    np_ = _round_up(n, bn)
    kp = _round_up(k, 128)

    xp = jnp.zeros((np_, d), x.dtype).at[:n].set(x)
    ap = jnp.full((np_,), -1, jnp.int32).at[:n].set(assign.astype(jnp.int32))
    w = (jnp.ones((n,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    wp = jnp.zeros((np_,), jnp.float32).at[:n].set(w)

    sums, cnt = pl.pallas_call(
        _make_kernel(bn, kp),
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((kp, d), lambda i: (0, 0)),
            pl.BlockSpec((kp,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, d), jnp.float32),
            jax.ShapeDtypeStruct((kp,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, ap, wp)
    return sums[:k], cnt[:k]
