from repro.checkpoint.store import (  # noqa: F401
    checkpoint_step,
    load_pytree,
    save_pytree,
)
