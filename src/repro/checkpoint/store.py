"""Pytree checkpointing (npz, flattened key paths, sharding-aware gather).

Small and dependency-free: leaves are fetched to host (fully replicated
form) and stored under their tree paths; restore rebuilds the exact tree
structure and re-places onto the target sharding if given.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import numpy as np


# npz cannot faithfully round-trip extended dtypes (bfloat16, fp8 …): they
# save as raw void bytes with no cast back. Store such leaves as a uint8
# byte view plus a "<key>__dtype__" marker and reconstruct on load.
_NATIVE_KINDS = set("biufc")


def _is_native(dtype: np.dtype) -> bool:
    return np.dtype(dtype).kind in _NATIVE_KINDS


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if not _is_native(arr.dtype):
            flat[key + "__dtype__"] = np.asarray(str(arr.dtype))
            arr = arr.view(np.uint8)
        flat[key] = arr
    return flat


def save_pytree(path: str, tree, step: Optional[int] = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    # Atomic: write beside the target, then rename over it, so a crash
    # mid-save (the scenario checkpoints exist for) can never leave a
    # truncated file where the previous good checkpoint was.
    final = path if path.endswith(".npz") else path + ".npz"
    tmp = final + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, final)
    return final


def load_pytree(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    jax.sharding.Sharding for placement."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    with np.load(path if path.endswith(".npz")
                 else path + ".npz") as data:
        for path_keys, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path_keys)
            arr = data[key]
            if key + "__dtype__" in data:  # stored as a uint8 byte view
                import ml_dtypes  # noqa: F401 (registers ext. dtypes)
                arr = arr.view(np.dtype(str(data[key + "__dtype__"])))
            assert arr.shape == tuple(leaf.shape), (key, arr.shape,
                                                    leaf.shape)
            target = np.dtype(leaf.dtype)
            if arr.dtype != target and not (_is_native(arr.dtype)
                                            and _is_native(target)):
                # cross-family cast (e.g. bf16 -> f32) goes via float32
                arr = arr.astype(np.float32)
            leaves.append(arr.astype(target))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def encode_tag(s: str) -> np.ndarray:
    """A short string as a 1-d uint8 byte array — the form a schema tag
    rides ``save_pytree`` in (npz 0-d unicode arrays cannot take the
    non-native-dtype byte-view path, so tags are stored pre-encoded;
    the v5 ``heads_tag`` is the first user)."""
    return np.frombuffer(s.encode("utf-8"), np.uint8).copy()


def decode_tag(arr) -> str:
    """Inverse of :func:`encode_tag`."""
    return np.asarray(arr, np.uint8).tobytes().decode("utf-8")


def npz_keys(path: str) -> set:
    """The flattened key paths present in a checkpoint — how restore
    paths branch between schema generations (e.g. the streaming
    service's single-tau v1 npz, the double-buffered ``tau_bufs`` /
    ``tau_meta`` v2 schema of DESIGN.md §11, the v3 schema that
    adds the ``autoscale_*`` decision arrays of §12, v4's drift/epoch
    arrays, and v5's ``heads*`` per-cluster head params of §16)
    without loading any array data."""
    with np.load(path if path.endswith(".npz")
                 else path + ".npz") as data:
        return set(data.files)


def load_extras(path: str, keys) -> dict:
    """Fetch schema-dependent metadata arrays by flattened key in ONE
    file open, without a structural template (missing keys are simply
    omitted — presence doubles as the schema-generation probe). This
    is how restore paths read generation-specific extras whose shape
    is not known until the file is opened — the streaming service's
    ``policy_id`` and the v3 ``autoscale_state`` / ``autoscale_ladder``
    arrays (the active bucket ladder's length is itself part of the
    recorded decision) — while ``load_pytree`` keeps its exact-shape
    contract for the structural state."""
    with np.load(path if path.endswith(".npz")
                 else path + ".npz") as data:
        return {k: data[k] for k in keys if k in data.files}


def checkpoint_step(path: str) -> Optional[int]:
    with np.load(path if path.endswith(".npz")
                 else path + ".npz") as data:
        return int(data["__step__"]) if "__step__" in data else None
