"""Import-graph dead-code report (DESIGN.md §15, report-only).

Builds the static import graph of ``src/repro`` (AST ``import`` /
``from ... import`` statements — including imports nested inside
functions, which is how the lazy-loading modules here pull heavy deps)
plus ``benchmarks/*.py`` as external entry points, then reports which
modules of the model zoo (``repro.models.*`` and ``repro.configs.*``)
are actually reachable from the live entry points. The zoo is no
longer dormant: the §16 routed-serving heads (``models/heads.py``,
reached through ``fed.api`` -> ``fed.stream`` -> ``fed.plane``) pull
in the ``models`` building blocks, and ``repro.configs`` statically
imports every registered architecture module — so the report now
certifies the zoo STAYS load-bearing (a config module falling out of
the reachable set is a regression the head-config tests assert
against):

  entry points = benchmarks/*.py, repro.launch.*, repro.fed.api,
                 repro.analysis (this gate itself)

Each reachable module gets one shortest via-path so a reader can see
WHY it is still live; unreachable modules are candidates for retirement
in a future PR. This pass NEVER gates CI — import reachability is
necessary, not sufficient, evidence of death (configs are also loaded
by name through ``configs.base.load_config``), so it reports and exits
clean.
"""
from __future__ import annotations

import ast
import os
from collections import deque
from typing import Dict, List, Optional, Set, Tuple

PASS = "imports"

_ENTRY_PREFIXES = ("repro.launch.", "benchmarks.")
_ENTRY_MODULES = ("repro.fed.api", "repro.analysis")
_ZOO_PREFIXES = ("repro.models.", "repro.configs.")


def _module_name(path: str, src_root: str) -> Tuple[Optional[str], bool]:
    """(dotted module name, is_package) of a .py file under a root."""
    rel = os.path.relpath(path, src_root)
    if not rel.endswith(".py"):
        return None, False
    parts = rel[:-3].replace(os.sep, "/").split("/")
    is_pkg = parts[-1] == "__init__"
    if is_pkg:
        parts = parts[:-1]
    return ".".join(parts), is_pkg


def _imports_of(tree: ast.AST, module: str, is_pkg: bool) -> Set[str]:
    """All absolute module names this module imports (relative imports
    resolved against its own package)."""
    # The package a relative import is anchored at: the module itself
    # for an __init__.py, its parent otherwise.
    pkg_parts = module.split(".") if is_pkg else module.split(".")[:-1]
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out.add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[:len(pkg_parts) - (node.level - 1)]
                root = ".".join(base + (node.module.split(".")
                                        if node.module else []))
            else:
                root = node.module or ""
            if root:
                out.add(root)
                for a in node.names:
                    out.add(f"{root}.{a.name}")
    return out


def build_graph(src_root: Optional[str] = None,
                bench_root: Optional[str] = None
                ) -> Tuple[Dict[str, Set[str]], List[str]]:
    """(adjacency: module -> imported modules, known module list)."""
    if src_root is None:
        src_root = os.path.normpath(
            os.path.join(os.path.dirname(__file__), "..", ".."))
    if bench_root is None:
        cand = os.path.normpath(os.path.join(src_root, "..", "benchmarks"))
        bench_root = cand if os.path.isdir(cand) else None

    files: List[Tuple[str, str, bool]] = []   # (module, path, is_pkg)
    for dirpath, _, names in sorted(os.walk(os.path.join(src_root, "repro"))):
        for name in sorted(names):
            if name.endswith(".py"):
                p = os.path.join(dirpath, name)
                m, is_pkg = _module_name(p, src_root)
                if m:
                    files.append((m, p, is_pkg))
    if bench_root:
        for name in sorted(os.listdir(bench_root)):
            if name.endswith(".py"):
                files.append((f"benchmarks.{name[:-3]}",
                              os.path.join(bench_root, name), False))

    known = {m for m, _, _ in files}
    graph: Dict[str, Set[str]] = {}
    for mod, path, is_pkg in files:
        with open(path, "r", encoding="utf-8") as fh:
            try:
                tree = ast.parse(fh.read())
            except SyntaxError:
                graph[mod] = set()
                continue
        deps = set()
        for imp in _imports_of(tree, mod, is_pkg):
            # Resolve "repro.fed.api.Session" -> longest known prefix.
            parts = imp.split(".")
            for cut in range(len(parts), 0, -1):
                cand = ".".join(parts[:cut])
                if cand in known and cand != mod:
                    deps.add(cand)
                    break
        graph[mod] = deps
    return graph, sorted(known)


def reachability(graph: Dict[str, Set[str]]
                 ) -> Dict[str, Optional[List[str]]]:
    """module -> shortest via-path from an entry point (None when
    unreachable). BFS from all entry points at once."""
    entries = [m for m in graph
               if m.startswith(_ENTRY_PREFIXES) or m in _ENTRY_MODULES]
    via: Dict[str, Optional[List[str]]] = {m: None for m in graph}
    q = deque()
    for e in sorted(entries):
        via[e] = [e]
        q.append(e)
    while q:
        cur = q.popleft()
        for nxt in sorted(graph.get(cur, ())):
            if via.get(nxt) is None:
                via[nxt] = via[cur] + [nxt]
                q.append(nxt)
    return via


def report(src_root: Optional[str] = None) -> dict:
    """The dead-code report over the dormant zoo: reachable modules
    with their shortest via-path, and unreachable candidates."""
    graph, known = build_graph(src_root)
    via = reachability(graph)
    zoo = [m for m in known if m.startswith(_ZOO_PREFIXES)]
    reachable = {m: via[m] for m in zoo if via.get(m)}
    dead = [m for m in zoo if not via.get(m)]
    return {
        "modules": len(known),
        "zoo": len(zoo),
        "reachable": {m: " -> ".join(p) for m, p in sorted(
            reachable.items())},
        "unreachable": dead,
    }


def render(rep: dict) -> str:
    lines = [f"import graph: {rep['modules']} modules, "
             f"{rep['zoo']} in the models/configs zoo",
             f"  reachable from entry points: {len(rep['reachable'])}"]
    for m, path in rep["reachable"].items():
        lines.append(f"    {m}  (via {path})")
    lines.append(f"  unreachable (retirement candidates, report-only): "
                 f"{len(rep['unreachable'])}")
    for m in rep["unreachable"]:
        lines.append(f"    {m}")
    return "\n".join(lines)
