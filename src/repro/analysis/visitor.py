"""The ONE jaxpr traversal engine + shared HLO shape tables.

Before this module existed the repo walked jaxprs in
``launch/jaxpr_flops.py`` and parsed HLO shapes with duplicated
``_DTYPE_BYTES`` / ``_SHAPE_RE`` / collective tables in
``launch/hlo_analysis.py`` AND ``launch/roofline.py`` — three private
copies of the same substrate. Every consumer (the FLOP counter, the
HLO cost parser, the determinism auditor) now routes through here:

  * :func:`sub_jaxprs` — the higher-order-primitive descent rules
    (scan trip counts, shard_map mesh multipliers, cond branches,
    pjit/closed-call bodies, pallas_call kernel bodies) in one place.
    scan/while/cond/shard_map carry OPEN jaxprs or ClosedJaxprs
    depending on the primitive — callers never need to know which.
  * :func:`walk` — depth-first equation visitor carrying the static
    repetition multiplier and the enclosing higher-order path.
  * :func:`backward_slice` — operand provenance (which jaxpr inputs /
    constants / primitives a value depends on), the substrate of the
    determinism auditor's index-uniqueness and RNG-key-threading rules.
  * ``DTYPE_BYTES`` / ``SHAPE_RE`` / ``COLLECTIVES`` — the HLO text
    tables, exactly the superset of the two former private copies.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, List, Tuple

# --------------------------------------------------------------------------
# Shared HLO text tables (consumed by launch.hlo_analysis and
# launch.roofline; kept here so there is exactly one copy).
# --------------------------------------------------------------------------

DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
               "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
               "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
               "s32": 4, "u32": 4, "f32": 4,
               "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
               "token": 0, "opaque": 0}

SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


# --------------------------------------------------------------------------
# The shared finding record (determinism / kernels / lint all emit these;
# the CLI serializes them uniformly).
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    """One analysis finding. ``suppressed`` findings are reported but
    never gate; anything else fails the static-analysis CI job."""
    pass_name: str        # "determinism" | "kernels" | "lint"
    rule: str             # rule id, e.g. "float-scatter-add"
    where: str            # artifact/eqn path or file:line
    message: str
    suppressed: bool = False

    def to_dict(self) -> dict:
        return {"pass": self.pass_name, "rule": self.rule,
                "where": self.where, "message": self.message,
                "suppressed": self.suppressed}

    def __str__(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return (f"[{self.pass_name}:{self.rule}]{tag} {self.where}: "
                f"{self.message}")


# --------------------------------------------------------------------------
# Jaxpr traversal.
# --------------------------------------------------------------------------


def _prod(xs) -> float:
    out = 1.0
    for x in xs:
        out *= x
    return out


def as_open(j):
    """A ClosedJaxpr or an open Jaxpr -> the open Jaxpr. shard_map /
    scan / pjit disagree on which form ``eqn.params`` carries; every
    consumer normalizes through here."""
    # ClosedJaxpr delegates .eqns, so test for the wrapper attribute:
    # only the closed form carries .jaxpr.
    return j.jaxpr if hasattr(j, "jaxpr") else j


def sub_jaxprs(eqn, *, branches: str = "all"
               ) -> List[Tuple[object, float, str]]:
    """(open sub-jaxpr, static multiplier, tag) triples of one
    higher-order equation.

    ``branches="all"`` descends into every cond branch (an auditor must
    see hazards on any path); ``branches="one"`` takes a single branch
    (a cost model counts alternatives once — the historical
    ``jaxpr_flops`` behavior).
    """
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        return [(as_open(p["jaxpr"]), float(p["length"]), "scan")]
    if name == "while":
        # trip count unknown at jaxpr level; fori_loop carries no static
        # bound here — callers that care pass bounded loops as scan.
        out = [(as_open(p["body_jaxpr"]), 1.0, "while")]
        if branches == "all" and "cond_jaxpr" in p:
            out.append((as_open(p["cond_jaxpr"]), 1.0, "while"))
        return out
    if name == "cond":
        subs = [(as_open(b), 1.0, "cond") for b in p["branches"]]
        return subs if branches == "all" else subs[-1:]
    if name == "shard_map":
        mesh = p.get("mesh")
        size = 1.0
        if mesh is not None:
            size = float(_prod(mesh.shape.values()))
        return [(as_open(p["jaxpr"]), size, "shard_map")]
    for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if k in p:
            return [(as_open(p[k]), 1.0, name)]
    return []


@dataclass(frozen=True)
class EqnSite:
    """One visited equation: the eqn itself, the open jaxpr that owns
    it, the static repetition multiplier accumulated from enclosing
    scan lengths / shard_map mesh sizes, and the path of enclosing
    higher-order primitives (outermost first)."""
    eqn: object
    jaxpr: object
    mult: float
    path: Tuple[str, ...]

    @property
    def path_str(self) -> str:
        return "/".join(self.path) if self.path else "<top>"


def walk(jaxpr, visit: Callable[[EqnSite], None], *,
         branches: str = "all", mult: float = 1.0,
         path: Tuple[str, ...] = ()) -> None:
    """Depth-first visit of every equation reachable from ``jaxpr`` (a
    ClosedJaxpr or open Jaxpr), descending through the
    :func:`sub_jaxprs` rules."""
    j = as_open(jaxpr)
    for eqn in j.eqns:
        visit(EqnSite(eqn, j, mult, path))
        for sub, extra, tag in sub_jaxprs(eqn, branches=branches):
            walk(sub, visit, branches=branches, mult=mult * extra,
                 path=path + (tag,))


def iter_eqns(jaxpr, *, branches: str = "all") -> List[EqnSite]:
    """Every reachable equation site, in visit order."""
    out: List[EqnSite] = []
    walk(jaxpr, out.append, branches=branches)
    return out


# --------------------------------------------------------------------------
# Backward provenance slices.
# --------------------------------------------------------------------------


@dataclass
class Slice:
    """The backward dependency slice of one value within ONE (open)
    jaxpr level: which of the jaxpr's inputs it reaches, whether it
    touches constants/literals, and which primitives lie on the slice.
    Sub-jaxprs are opaque at this level — an invar of the enclosing
    jaxpr fed through a scan still shows up as input-reaching."""
    invar_positions: set = field(default_factory=set)
    primitives: set = field(default_factory=set)
    reaches_const: bool = False
    reaches_literal: bool = False

    @property
    def reaches_input(self) -> bool:
        return bool(self.invar_positions)


def backward_slice(jaxpr, var) -> Slice:
    """Provenance of ``var`` inside ``jaxpr`` (ClosedJaxpr or open)."""
    j = as_open(jaxpr)
    producers = {}
    for eqn in j.eqns:
        for ov in eqn.outvars:
            producers[ov] = eqn
    invar_pos = {v: i for i, v in enumerate(j.invars)}
    constvars = set(j.constvars)

    out = Slice()
    seen = set()
    stack = [var]
    while stack:
        v = stack.pop()
        if hasattr(v, "val"):               # Literal (Vars carry no .val)
            out.reaches_literal = True
            continue
        if id(v) in seen:
            continue
        seen.add(id(v))
        if v in invar_pos:
            out.invar_positions.add(invar_pos[v])
            continue
        if v in constvars:
            out.reaches_const = True
            continue
        eqn = producers.get(v)
        if eqn is None:                     # dropvar / unknown origin
            out.reaches_const = True
            continue
        out.primitives.add(eqn.primitive.name)
        stack.extend(eqn.invars)
    return out


def statically_unique_indices(jaxpr, index_var) -> bool:
    """True when a scatter's index operand is provably duplicate-free at
    trace time: its backward slice is built purely from ``iota`` /
    literals (an arange permutation), never from data-dependent inputs
    or constants. Data-derived indices (labels, slots) may collide, and
    a float scatter-add over colliding indices applies its updates in
    implementation-defined order."""
    sl = backward_slice(jaxpr, index_var)
    if sl.reaches_input or sl.reaches_const:
        return False
    return "iota" in sl.primitives or not sl.primitives
