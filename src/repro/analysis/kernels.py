"""Pallas kernel static checker (DESIGN.md §15, pass 2).

Every kernel module in ``kernels/`` publishes a ``block_plan`` — the
static BlockSpec/grid/scratch metadata of its ``pallas_call``, computed
by the same padding arithmetic as the dispatch itself. This pass
evaluates those plans across the REGISTERED bucket ladder shapes (the
``StreamConfig`` default rungs x representative serve dims) and gates:

  * ``vmem-overflow`` — the VMEM footprint implied by the plan must fit
    the ``launch.roofline`` ``HW_PROFILES`` per-core VMEM budget.
    Streaming blocks are double-buffered by the Pallas pipeline (x2);
    grid-constant (resident) blocks and scratch are single-buffered;
    scalar-prefetch operands live in SMEM and are counted once.
  * ``lane-misaligned`` / ``sublane-misaligned`` — a dimension that the
    grid PARTITIONS (block extent < array extent) must tile cleanly:
    the minor (lane) axis in multiples of 128, the second-minor
    (sublane) axis in multiples of 8 for 4-byte / 16 for 2-byte
    elements. Single-row (extent-1) sublane windows are exempt — they
    are the scalar-prefetch DMA gather granule, not a partial-tile
    relayout. Unpartitioned dims only pad, never relayout.
  * ``bf16-accum`` — sub-4-byte storage must declare f32 accumulation
    (the ``preferred_element_type`` contract of every matmul kernel
    here); bf16-accumulating reductions drift from the f32 oracles.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis.visitor import Finding

PASS = "kernels"

_ITEMSIZE = {"f32": 4, "i32": 4, "bf16": 2, "f16": 2, "i8": 1}
_LANE = 128


def _sublane(dtype: str) -> int:
    return 16 if _ITEMSIZE.get(dtype, 4) == 2 else 8


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def footprint_bytes(plan: dict) -> int:
    """VMEM bytes implied by one block plan: 2x each streaming in/out
    block (pipeline double-buffering), 1x resident blocks, scratch, and
    scalar-prefetch operands."""
    total = 0
    for b in plan["blocks"]:
        nbytes = _prod(b["shape"]) * _ITEMSIZE[b["dtype"]]
        streams = (b["kind"] in ("in", "out")) and not b.get("resident")
        total += nbytes * (2 if streams else 1)
    return total


def check_plan(plan: dict, hw: dict, shape_tag: str = "") -> List[Finding]:
    """All checker findings for one kernel block plan against one
    hardware profile (needs ``hw["vmem_bytes"]``)."""
    findings: List[Finding] = []
    where = f"{plan['kernel']}{'[' + shape_tag + ']' if shape_tag else ''}"

    used = footprint_bytes(plan)
    budget = int(hw["vmem_bytes"])
    if used > budget:
        findings.append(Finding(
            PASS, "vmem-overflow", where,
            f"VMEM footprint {used / 2**20:.2f} MiB exceeds the "
            f"{budget / 2**20:.0f} MiB per-core budget (grid "
            f"{plan['grid']}): shrink the block tiles"))

    for b in plan["blocks"]:
        if b["kind"] == "scalar" or len(b["shape"]) < 2:
            continue
        shape, arr = b["shape"], b["array_shape"]
        lane, sub = int(shape[-1]), int(shape[-2])
        lane_part = lane < int(arr[-1])
        sub_part = sub < int(arr[-2])
        if lane_part and lane % _LANE:
            findings.append(Finding(
                PASS, "lane-misaligned", where,
                f"block {b['name']}{shape} partitions the lane axis at "
                f"{lane}, not a multiple of {_LANE}: partial lane tiles "
                f"force a relayout copy per grid step"))
        sl = _sublane(b["dtype"])
        if sub_part and sub != 1 and sub % sl:
            findings.append(Finding(
                PASS, "sublane-misaligned", where,
                f"block {b['name']}{shape} partitions the sublane axis "
                f"at {sub}, not a multiple of {sl} for {b['dtype']}: "
                f"partial sublane tiles force a relayout copy"))

    if _ITEMSIZE[plan["storage"]] < 4 and plan["accum"] != "f32":
        findings.append(Finding(
            PASS, "bf16-accum", where,
            f"{plan['storage']} storage with {plan['accum']} "
            f"accumulation: sub-4-byte matmuls must accumulate in f32 "
            f"(preferred_element_type)"))
    return findings


# --------------------------------------------------------------------------
# The registered shape ladder: StreamConfig's default bucket rungs x
# representative serve dims (the CI smoke dims and a production-ish
# wide config), both storage dtypes where the kernel supports them.
# --------------------------------------------------------------------------

# (d, k_prime, k) columns the ladder rungs are crossed with.
DIM_COLUMNS: Tuple[Tuple[int, int, int], ...] = ((64, 4, 16),
                                                 (512, 8, 128))


def ladder() -> Tuple[int, ...]:
    """The registered serve bucket rungs — read from the StreamConfig
    default, so a ladder change re-registers the checker shapes."""
    import dataclasses
    from repro.fed.stream import StreamConfig
    for f in dataclasses.fields(StreamConfig):
        if f.name == "bucket_sizes":
            return tuple(f.default)
    raise AssertionError("StreamConfig.bucket_sizes default not found")


def ladder_plans() -> List[Tuple[str, dict]]:
    """Every (shape_tag, block_plan) the gate evaluates."""
    from repro.fed.stream import StreamConfig
    import dataclasses
    from repro.kernels import (kmeans_update, moe_dispatch, pdist_argmin,
                               solve_attach)
    from repro.kernels.ref import SOLVE_ATTACH_DTYPES

    B = next(f.default for f in dataclasses.fields(StreamConfig)
             if f.name == "batch_size")
    plans: List[Tuple[str, dict]] = []
    for n in ladder():
        for d, kp, k in DIM_COLUMNS:
            for dt in SOLVE_ATTACH_DTYPES:
                plans.append((f"B{B},n{n},d{d},k'{kp},k{k},{dt}",
                              solve_attach.block_plan(B, n, d, kp, k,
                                                      dtype=dt)))
            # the chunked large-k attach path: n rows per chunk against
            # the rung-sized retained center set
            plans.append((f"n4096,d{d},k{n}",
                          pdist_argmin.block_plan(4096, d, n)))
            plans.append((f"n{n * B},d{d},k{k}",
                          kmeans_update.block_plan(n * B, d, k)))
    for d, _, _ in DIM_COLUMNS:
        plans.append((f"T1024,d{d},S2048",
                      moe_dispatch.dispatch_block_plan(1024, d, 2048)))
        plans.append((f"S2048,d{d},T1024",
                      moe_dispatch.combine_block_plan(2048, d, 1024)))
    # The §16 routed-serving dispatch/combine shapes: whole (n_pad * d)
    # requests gather into k * C queue slots (C from the default
    # head_capacity), and (S, d) pooled head outputs combine back to
    # request order with top_k=1 — per bucket rung x dim column.
    from repro.fed.plane import route_capacity
    cap = next(f.default for f in dataclasses.fields(StreamConfig)
               if f.name == "head_capacity")
    for n in ladder():
        for d, kp, k in DIM_COLUMNS:
            C = route_capacity(B, k, cap)
            S = k * C
            plans.append((f"route,B{B},n{n},d{d},k{k},C{C}",
                          moe_dispatch.dispatch_block_plan(B, n * d, S)))
            plans.append((f"route,S{S},d{d},B{B}",
                          moe_dispatch.combine_block_plan(S, d, B,
                                                          top_k=1)))
    # The §17 ingestion-encoder forward: B * n_pad flattened token
    # sequences per step at the default encode_seq_len, through the
    # reduced zoo spec re-dimensioned to each dim column — both storage
    # dtypes (the plan's encode_dtype choices).
    from repro.models import encoder as enc_mod
    sq = next(f.default for f in dataclasses.fields(StreamConfig)
              if f.name == "encode_seq_len")
    for n in (ladder()[0], ladder()[-1]):
        for d, _, _ in DIM_COLUMNS:
            spec = enc_mod.resolve_encoder_spec("qwen1.5-0.5b", d)
            for dt in ("f32", "bf16"):
                plans.append(
                    (f"encode,T{B * n},S{sq},d{d},ff{spec.d_ff},{dt}",
                     enc_mod.block_plan(B * n, sq, d, spec.d_ff,
                                        spec.n_heads, dtype=dt)))
    return plans


def audit_all(hw: Optional[Dict] = None
              ) -> Tuple[List[Finding], int]:
    """(findings, number of plans checked) across the whole ladder."""
    if hw is None or isinstance(hw, str):
        from repro.launch.roofline import hw_profile
        hw = hw_profile(hw)
    findings: List[Finding] = []
    plans = ladder_plans()
    for tag, plan in plans:
        findings.extend(check_plan(plan, hw, tag))
    return findings, len(plans)
