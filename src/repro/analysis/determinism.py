"""Jaxpr determinism auditor (DESIGN.md §15, pass 1).

The repo's replay guarantees (bitwise labels / tau versions / fold
state / drift decisions, DESIGN.md §9-§14) are runtime-tested at a few
shapes; this pass certifies them STRUCTURALLY on every CI run by
tracing the real serving artifacts — the serve step, the §16 routed
personalization step (label -> dispatch -> per-cluster head ->
combine; its routing scatters are int/bool overwrites onto unique
slots, which is exactly what this pass proves stays true), the §17
encode+serve step (the zoo encoder fused ahead of the label body), the
fold, the finalize, and the drift split/retire refresh, via the same
``ServePlane`` construction the service runs — and walking their
jaxprs with the shared :mod:`analysis.visitor` engine.

Rule catalog (ids are what ``# repro: allow(...)`` and the JSON report
use; determinism findings are suppressed by artifact CONTRACT, never
by comment — a hazard in a traced artifact has no source line):

  * ``float-scatter-add`` — an accumulating scatter (scatter-add /
    scatter-mul) on float data whose indices are not provably
    duplicate-free. XLA applies colliding scatter updates in
    implementation-defined order, so float accumulation over data-
    derived indices (labels, slots) is a replay hazard. Indices whose
    backward slice is pure iota/literal (an arange) are statically
    unique and pass; so does ``unique_indices=True`` (the caller's
    explicit promise).
  * ``implicit-rng`` — ``rng_uniform`` / ``rng_bit_generator``: XLA's
    stateful or backend-defined RNG, not reproducible across backends
    or replays. All randomness must thread explicit PRNG keys.
  * ``rng-unthreaded-key`` — a keyed RNG primitive (threefry,
    random_bits, ...) whose key derives only from baked-in constants,
    never from the artifact's inputs: every trace re-uses the same
    stream, silently correlating what should be per-request keys.
  * ``unordered-collective`` — a float cross-replica reduction (psum /
    psum_scatter): FP addition is non-associative and the replica
    reduction order is unspecified. Integer psum and idempotent
    pmax/pmin are exact; all_gather/ppermute/all_to_all move data in
    fixed order and are allowed per contract.
  * ``contract-collective`` — a collective outside the artifact's
    allowlist (the serve step is embarrassingly parallel: NONE; the
    sharded fold transports reports with all_gather ONLY).
  * ``fold-single-scatter`` — the §11 invariant, structurally: the
    fold jaxpr contains EXACTLY one overwrite scatter per
    ``ServerState`` leaf, all in drop mode (out-of-capacity slots
    ignored, never clipped onto a live slot), all indexed by the same
    slot vector, and no accumulating scatter anywhere. The sharded
    fold must satisfy the identical contract inside its shard_map
    body. A second scatter, a scatter-add, a clip-mode scatter, or a
    diverging index source each violate it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.visitor import (Finding, backward_slice, iter_eqns,
                                    statically_unique_indices)

PASS = "determinism"

# Accumulating scatters: colliding updates combine in impl-defined
# order. scatter-min/max are idempotent+commutative — exact — and the
# overwrite "scatter" is covered by the fold contract instead.
ACCUM_SCATTERS = ("scatter-add", "scatter-mul")
OVERWRITE_SCATTER = "scatter"
IMPLICIT_RNG = ("rng_uniform", "rng_bit_generator")
KEYED_RNG = ("threefry2x32", "random_seed", "random_wrap", "random_bits",
             "random_fold_in", "random_gamma", "random_split")
UNORDERED_FLOAT_REDUCE = ("psum", "psum_scatter", "reduce_scatter")
COLLECTIVE_PRIMS = ("psum", "psum_scatter", "reduce_scatter", "pmax",
                    "pmin", "all_gather", "all_to_all", "ppermute",
                    "pbroadcast")


@dataclass(frozen=True)
class Contract:
    """Per-artifact allowances: which collectives may appear, and (for
    fold artifacts) the exact overwrite-scatter census the §11
    invariant demands (= the number of ``ServerState`` leaves)."""
    allow_collectives: frozenset = frozenset()
    fold_leaves: Optional[int] = None


def _is_float(var) -> bool:
    return jnp.issubdtype(var.aval.dtype, jnp.floating)


def audit_jaxpr(closed_jaxpr, artifact: str,
                contract: Contract = Contract()) -> List[Finding]:
    """All determinism findings of one traced artifact."""
    findings: List[Finding] = []
    sites = iter_eqns(closed_jaxpr, branches="all")

    def emit(rule, site, msg):
        findings.append(Finding(PASS, rule,
                                f"{artifact}:{site.path_str}", msg))

    scatter_sites = []
    for site in sites:
        eqn = site.eqn
        name = eqn.primitive.name
        if name in ACCUM_SCATTERS:
            scatter_sites.append(site)
            if not any(_is_float(v) for v in eqn.outvars):
                continue                       # integer accumulation: exact
            if eqn.params.get("unique_indices"):
                continue                       # caller-promised unique
            if statically_unique_indices(site.jaxpr, eqn.invars[1]):
                continue                       # iota-derived: provably unique
            emit("float-scatter-add", site,
                 f"{name} on {eqn.outvars[0].aval.dtype} with possibly-"
                 f"overlapping data-derived indices: XLA applies "
                 f"colliding updates in implementation-defined order")
        elif name == OVERWRITE_SCATTER:
            scatter_sites.append(site)
        elif name in IMPLICIT_RNG:
            emit("implicit-rng", site,
                 f"{name} uses XLA's stateful/backend-defined RNG; "
                 f"thread an explicit PRNG key instead")
        elif name in KEYED_RNG:
            reaches = any(backward_slice(site.jaxpr, v).reaches_input
                          for v in eqn.invars)
            if not reaches:
                emit("rng-unthreaded-key", site,
                     f"{name} key derives only from baked-in constants "
                     f"— every invocation replays the same stream; "
                     f"thread the key through the artifact's inputs")
        if name in COLLECTIVE_PRIMS:
            if name not in contract.allow_collectives:
                emit("contract-collective", site,
                     f"collective {name} is outside this artifact's "
                     f"allowlist {sorted(contract.allow_collectives)}")
            if name in UNORDERED_FLOAT_REDUCE and \
                    any(_is_float(v) for v in eqn.outvars):
                # An allowlisted float reduce stays VISIBLE in the
                # report but does not gate — the contract author has
                # accepted its reduction-order semantics.
                findings.append(Finding(
                    PASS, "unordered-collective",
                    f"{artifact}:{site.path_str}",
                    f"float {name}: cross-replica FP reduction order "
                    f"is unspecified (non-associative)",
                    suppressed=name in contract.allow_collectives))

    if contract.fold_leaves is not None:
        findings.extend(_check_fold_contract(artifact, contract,
                                             scatter_sites))
    return findings


def _check_fold_contract(artifact, contract, scatter_sites):
    """The ``fold-single-scatter`` structural assertion."""
    out: List[Finding] = []
    want = contract.fold_leaves

    def emit(site_or_none, msg):
        where = (f"{artifact}:{site_or_none.path_str}"
                 if site_or_none is not None else artifact)
        out.append(Finding(PASS, "fold-single-scatter", where, msg))

    overwrite = [s for s in scatter_sites
                 if s.eqn.primitive.name == OVERWRITE_SCATTER]
    accum = [s for s in scatter_sites
             if s.eqn.primitive.name in ACCUM_SCATTERS]
    for s in accum:
        emit(s, f"accumulating {s.eqn.primitive.name} on the fold path "
                f"— the fold must be pure overwrite scatters")
    if len(overwrite) != want:
        emit(None, f"fold contains {len(overwrite)} overwrite scatters, "
                   f"expected exactly {want} (one per ServerState leaf)")
        return out

    # All scatters must drop out-of-range slots (mode="drop"): a
    # clipping scatter would corrupt the last live slot instead.
    for s in overwrite:
        mode = str(s.eqn.params.get("mode"))
        if "FILL_OR_DROP" not in mode:
            emit(s, f"fold scatter mode is {mode}, expected "
                    f"FILL_OR_DROP (out-of-capacity ids must drop)")

    # ... and must all consume the SAME slot vector: one admission
    # decision drives every leaf. Diverging index provenance means two
    # leaves could disagree about which slot a report landed in.
    by_level: Dict[int, list] = {}
    for s in overwrite:
        by_level.setdefault(id(s.jaxpr), []).append(s)
    if len(by_level) != 1:
        emit(None, "fold scatters span multiple jaxpr scopes — the "
                   "fold must be one primitive at one level")
        return out
    sources = []
    for s in overwrite:
        sl = backward_slice(s.jaxpr, s.eqn.invars[1])
        sources.append(frozenset(sl.invar_positions))
    if not sources[0] or any(src != sources[0] for src in sources):
        emit(None, f"fold scatter index provenance diverges across "
                   f"leaves ({sorted(map(sorted, sources))}) — all "
                   f"leaves must scatter by the same slot vector")
    return out


# --------------------------------------------------------------------------
# The real artifacts, traced at CI smoke shapes via the same
# ServePlane/StreamConfig construction the service runs.
# --------------------------------------------------------------------------

SMOKE = dict(k=16, k_prime=4, d=32, capacity=64, batch_size=8, n=64,
             drift_half_life=8, heads="qwen1.5-0.5b", head_arch="ffn",
             encoder="qwen1.5-0.5b", encode_seq_len=16)


@dataclass
class Artifact:
    name: str
    closed_jaxpr: object
    contract: Contract


def _smoke_cfg(heads: bool = False, encoder: bool = False):
    from repro.fed.stream import StreamConfig
    kw = ({"heads": SMOKE["heads"], "head_arch": SMOKE["head_arch"]}
          if heads else {})
    if encoder:
        kw.update(encoder=SMOKE["encoder"],
                  encode_seq_len=SMOKE["encode_seq_len"])
    return StreamConfig(k=SMOKE["k"], k_prime=SMOKE["k_prime"],
                        d=SMOKE["d"], capacity=SMOKE["capacity"],
                        batch_size=SMOKE["batch_size"],
                        bucket_sizes=(SMOKE["n"],), **kw)


def _heads_struct(cfg):
    """Abstract (shape/dtype) stacked head params for tracing the
    routed step without materializing an init."""
    from repro.models import heads as heads_mod
    return jax.eval_shape(lambda: heads_mod.init_heads(
        jax.random.PRNGKey(0), cfg.k, cfg.head_spec()))


def _encoder_struct(cfg):
    """Abstract (shape/dtype) encoder params for tracing the §17
    encode step without materializing an init."""
    from repro.models import encoder as enc_mod
    return jax.eval_shape(lambda: enc_mod.init_encoder(
        jax.random.PRNGKey(0), cfg.encoder_spec()))


def _encode_args(cfg):
    """The (B, n, seq, d) raw-sequence batch + token mask the encode
    step prepends to the plain step arguments."""
    S = jax.ShapeDtypeStruct
    B, n, sq = cfg.batch_size, SMOKE["n"], cfg.encode_seq_len
    return (S((B, n, sq, cfg.d), jnp.float32),       # token sequences
            S((B, n, sq), jnp.bool_))                # token mask


def _step_args(cfg):
    S = jax.ShapeDtypeStruct
    B, n = cfg.batch_size, SMOKE["n"]
    return (S((cfg.k, cfg.d), jnp.float32),          # tau
            S((B, 2), jnp.uint32),                   # per-request keys
            S((B, n, cfg.d), jnp.float32),           # data
            S((B, n), jnp.bool_),                    # point mask
            S((B,), jnp.int32))                      # k_valid


def _state_struct(cfg):
    from repro.core import server
    S = jax.ShapeDtypeStruct
    cap, kp, d = cfg.capacity, cfg.k_prime, cfg.d
    return server.ServerState(S((cap, kp, d), jnp.float32),
                              S((cap, kp), jnp.bool_),
                              S((cap, kp), jnp.float32),
                              S((cap,), jnp.bool_),
                              S((cap,), jnp.int32))


def _fold_args(cfg):
    S = jax.ShapeDtypeStruct
    B, kp, d = cfg.batch_size, cfg.k_prime, cfg.d
    return (_state_struct(cfg),
            S((B,), jnp.int32),                      # slots
            S((B, kp, d), jnp.float32),              # centers
            S((B, kp), jnp.bool_),                   # center mask
            S((B, kp), jnp.float32),                 # weights
            S((B,), jnp.int32))                      # epochs


def n_fold_leaves() -> int:
    from repro.core import server
    return len(server.ServerState._fields)


def trace_artifacts(include_sharded: Optional[bool] = None
                    ) -> Tuple[List[Artifact], List[str]]:
    """(artifacts, skipped-names). ``include_sharded=None`` auto-detects
    from ``jax.device_count()`` — the CI static-analysis job forces 8
    host devices so the shard_mapped serve/fold contracts are audited
    structurally, not just on the mesh test legs."""
    from repro.core import server
    from repro.fed import plane as plane_mod

    cfg = _smoke_cfg()
    leaves = n_fold_leaves()
    arts: List[Artifact] = []
    skipped: List[str] = []

    step = plane_mod._make_step(cfg)
    arts.append(Artifact(
        "serve_step", jax.make_jaxpr(step)(*_step_args(cfg)), Contract()))

    # The §16 routed personalization step: same label body + routing
    # scatters + per-cluster head forwards. Single-host: no collectives
    # allowed; the audit also proves every routing scatter is an
    # int/bool overwrite (an accumulating float scatter here would be
    # a replay hazard).
    hcfg = _smoke_cfg(heads=True)
    routed = plane_mod._make_routed_step(hcfg)
    tau_s, keys_s, data_s, pmask_s, kv_s = _step_args(hcfg)
    arts.append(Artifact(
        "routed_step",
        jax.make_jaxpr(routed)(tau_s, _heads_struct(hcfg), keys_s,
                               data_s, pmask_s, kv_s),
        Contract()))

    # The §17 encode+serve step: the zoo encoder forward fused ahead of
    # the label body. Encoding is pure matmul/softmax on its inputs —
    # no RNG, no scatters, no collectives — so the artifact's contract
    # is the plain serve step's (the solve's keyed RNG still threads
    # from the request keys).
    ecfg = _smoke_cfg(encoder=True)
    enc_step = plane_mod._make_encode_step(ecfg)
    tau_e, keys_e, _, pmask_e, kv_e = _step_args(ecfg)
    data_e, tmask_e = _encode_args(ecfg)
    arts.append(Artifact(
        "encode_step",
        jax.make_jaxpr(enc_step)(tau_e, _encoder_struct(ecfg), keys_e,
                                 data_e, pmask_e, tmask_e, kv_e),
        Contract()))

    def fold(state, slots, centers, cmask, weights, epochs):
        return server.aggregate_incremental(state, slots, centers, cmask,
                                            weights=weights, epochs=epochs)

    arts.append(Artifact(
        "fold", jax.make_jaxpr(fold)(*_fold_args(cfg)),
        Contract(fold_leaves=leaves)))

    def finalize(state):
        return server.finalize(state, cfg.k,
                               weighted=cfg.weight_by_core_counts)

    arts.append(Artifact(
        "finalize", jax.make_jaxpr(finalize)(_state_struct(cfg)),
        Contract()))

    def refresh_split_retire(state, now_epoch):
        # The drift="split_merge" refresh, composed exactly as
        # AttachService._refinalize does at a flush boundary.
        decay = (now_epoch, SMOKE["drift_half_life"])
        agg = server.finalize(state, cfg.k, decay=decay)
        mask, w = server.decayed_evidence(state, *decay)
        mass = server.center_mass(agg, mask, w)
        flat = jnp.where(mask[..., None], state.centers,
                         jnp.zeros_like(state.centers)
                         ).reshape(-1, cfg.d).astype(jnp.float32)
        return server.split_retire(
            flat, mask.reshape(-1), agg, mass, cfg.k,
            split_factor=2.0, retire_frac=0.1, max_moves=1,
            weights=w.reshape(-1))

    arts.append(Artifact(
        "split_retire",
        jax.make_jaxpr(refresh_split_retire)(
            _state_struct(cfg), jax.ShapeDtypeStruct((), jnp.int32)),
        Contract()))

    ndev = jax.device_count()
    if include_sharded is None:
        include_sharded = ndev > 1
    if include_sharded and ndev > 1:
        from repro.utils.compat import make_mesh
        s = ndev if cfg.batch_size % ndev == 0 else 2
        mesh = make_mesh((s,), ("data",))
        plane = plane_mod.ServePlane(cfg, mesh=mesh, serve_axes=("data",))
        step_sh, fold_sh = plane._plane_for(s)[:2]
        arts.append(Artifact(
            "serve_step_sharded",
            jax.make_jaxpr(step_sh)(*_step_args(cfg)), Contract()))
        arts.append(Artifact(
            "fold_sharded",
            jax.make_jaxpr(fold_sh)(*_fold_args(cfg)),
            Contract(allow_collectives=frozenset({"all_gather"}),
                     fold_leaves=leaves)))
        plane_h = plane_mod.ServePlane(hcfg, mesh=mesh,
                                       serve_axes=("data",))
        routed_sh = plane_h._routed_plane_for(s)[0]
        # Sharded: the global keep/overflow ranking all_gathers the
        # int32 cluster votes (deterministic shard-order tiling) —
        # exactly the fold's collective allowance, nothing else.
        arts.append(Artifact(
            "routed_step_sharded",
            jax.make_jaxpr(routed_sh)(tau_s, _heads_struct(hcfg),
                                      keys_s, data_s, pmask_s, kv_s),
            Contract(allow_collectives=frozenset({"all_gather"}))))
        # Sharded §17 encode+serve: the batch axis stays embarrassingly
        # parallel through the encode stage (encoder params replicated
        # like tau), so no collective is allowed here either.
        plane_e = plane_mod.ServePlane(ecfg, mesh=mesh,
                                       serve_axes=("data",))
        enc_sh = plane_e._encode_plane_for(s)[0]
        arts.append(Artifact(
            "encode_step_sharded",
            jax.make_jaxpr(enc_sh)(tau_e, _encoder_struct(ecfg),
                                   keys_e, data_e, pmask_e, tmask_e,
                                   kv_e),
            Contract()))
    else:
        skipped.extend(["serve_step_sharded", "fold_sharded",
                        "routed_step_sharded", "encode_step_sharded"])
    return arts, skipped


def audit_all(include_sharded: Optional[bool] = None
              ) -> Tuple[List[Finding], List[str], List[str]]:
    """(findings, audited artifact names, skipped artifact names)."""
    arts, skipped = trace_artifacts(include_sharded)
    findings: List[Finding] = []
    for a in arts:
        findings.extend(audit_jaxpr(a.closed_jaxpr, a.name, a.contract))
    return findings, [a.name for a in arts], skipped
