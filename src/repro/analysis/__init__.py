"""Static analysis of the serving system's compiled artifacts
(DESIGN.md §15).

Three passes, one CLI (``python -m repro.analysis``), one CI gate:

  * ``analysis.determinism`` — traces the REAL serve-step / fold /
    finalize / split-retire jaxprs (single-host and shard_mapped) and
    walks them with the shared :mod:`analysis.visitor` engine, flagging
    nondeterministic float scatter-adds, unkeyed RNG, unordered float
    collectives, and structurally asserting the §11 "hot fold path is
    exactly one scatter per state leaf" invariant.
  * ``analysis.kernels`` — computes each Pallas kernel's VMEM footprint
    from its published :func:`block_plan` across the registered bucket
    ladder shapes and gates it against the ``launch.roofline``
    ``HW_PROFILES`` VMEM budget, plus lane/sublane tiling alignment and
    bf16-storage/f32-accumulate rules.
  * ``analysis.lint`` — an AST pass over ``src/repro`` for recompile
    hazards (Python branches on tracer values, ``float()``/``int()``
    tracer coercion, unhashable static args) and checkpoint writes that
    bypass ``checkpoint/store.py``; ``# repro: allow(<rule>)`` comments
    suppress intentional exceptions visibly.

``analysis.imports`` is a fourth, report-only pass (never gates): the
reachability inventory of the dormant ``models/`` + ``configs/`` zoo.
"""
from repro.analysis.visitor import Finding  # noqa: F401 (public re-export)
