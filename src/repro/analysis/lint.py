"""Recompile-hazard and contract lint (DESIGN.md §15, pass 3).

An AST pass over ``src/repro`` for the hazards that NEVER show up in a
traced jaxpr — they bite at trace time (recompiles, ConcretizationError)
or behind the checkpoint schema's back:

  * ``tracer-branch`` — Python ``if``/``while`` on a value produced by
    a ``jnp.``/``jax.`` expression. Inside jit this is a concretization
    error; outside it forces a device sync per call and turns
    data-dependent values into trace constants.
  * ``tracer-coercion`` — ``float()``/``int()``/``bool()`` directly on
    a ``jnp.``/``jax.`` expression. The blessed spelling is
    ``float(np.asarray(x))``: the materialization is explicit, greppable
    and outside any traced region.
  * ``static-unhashable`` — a parameter named in ``jax.jit(...,
    static_argnames=...)`` whose default is a mutable literal
    (list/dict/set): unhashable statics fail at call time, and mutable
    defaults silently alias across calls.
  * ``checkpoint-bypass`` — ``np.save``/``np.savez*`` outside
    ``checkpoint/store.py``. Every persisted artifact must go through
    the schema-versioned store (DESIGN.md §9) or restores cannot be
    replay-audited.

Taint model (deliberately shallow — one forward pass per function):
names assigned from expressions that call into ``jnp.``/``jax.`` are
tracer-tainted; wrapping in ``np.asarray``/``np.array``/
``jax.device_get``/``.item()`` materializes and clears the taint.
Function parameters are NOT tainted (host-level modules take arrays as
arguments everywhere; flagging them would drown the signal), so this
pass catches locally-introduced hazards, not inter-procedural flows —
the determinism pass audits the traced artifacts themselves.

Suppression: a ``# repro: allow(<rule>)`` comment on the flagged line
or the line above keeps the finding visible in the diff but un-gated.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.visitor import Finding

PASS = "lint"
RULES = ("tracer-branch", "tracer-coercion", "static-unhashable",
         "checkpoint-bypass")

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([\w\-,\s]+)\)")

# Attribute roots whose call results are tracer-valued.
_TRACER_ROOTS = ("jnp", "jax", "lax")
# ... and the materializing wrappers that clear the taint.
_MATERIALIZERS = {("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
                  ("numpy", "array"), ("jax", "device_get")}
# jax/jnp entry points that return HOST values (ints, bools, device
# lists), not tracers — calling them never taints.
_HOST_FNS = {"device_count", "local_device_count", "devices",
             "local_devices", "process_index", "process_count",
             "default_backend", "issubdtype", "result_type"}
# Array attributes that are static trace-time metadata, not data.
_STATIC_ATTRS = {"shape", "dtype", "ndim", "aval", "sharding"}
_STORE_MODULE = os.path.join("checkpoint", "store.py")


def _attr_chain(node) -> Tuple[str, ...]:
    """x.y.z -> ("x", "y", "z"); non-name roots -> ()."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _is_materializer(call: ast.Call) -> bool:
    chain = _attr_chain(call.func)
    if len(chain) >= 2 and (chain[0], chain[-1]) in _MATERIALIZERS:
        return True
    # x.item() — explicit scalar materialization
    return bool(chain) and chain[-1] == "item"


class _FnLinter(ast.NodeVisitor):
    """One function body: ordered taint pass + rule checks."""

    def __init__(self, path: str, findings: List[Finding]):
        self.path = path
        self.findings = findings
        self.tainted: Set[str] = set()

    # -- taint helpers -------------------------------------------------

    def _expr_tainted(self, node) -> bool:
        """True when the expression's value flows from a jnp/jax call or
        an already-tainted name, with materializers as taint breaks."""
        if isinstance(node, ast.Call):
            if _is_materializer(node):
                return False
            chain = _attr_chain(node.func)
            if chain and chain[0] in _TRACER_ROOTS:
                return chain[-1] not in _HOST_FNS
            return any(self._expr_tainted(a) for a in node.args)
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` never read the tracer's
            # value — identity checks are host-safe.
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self._expr_tainted(node.left)
                    or any(self._expr_tainted(c) for c in node.comparators))
        if isinstance(node, ast.Attribute):
            # x.shape / x.dtype are static metadata even on tracers.
            if node.attr in _STATIC_ATTRS:
                return False
            return self._expr_tainted(node.value)
        if isinstance(node, (ast.BoolOp, ast.BinOp, ast.UnaryOp,
                             ast.Subscript, ast.IfExp, ast.Tuple, ast.List)):
            return any(self._expr_tainted(c) for c in ast.iter_child_nodes(node))
        return False

    def _emit(self, rule: str, node, msg: str) -> None:
        self.findings.append(Finding(
            PASS, rule, f"{self.path}:{node.lineno}", msg))

    # -- statements ----------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        tainted = self._expr_tainted(node.value)
        for tgt in node.targets:
            for name in ast.walk(tgt):
                if isinstance(name, ast.Name):
                    if tainted:
                        self.tainted.add(name.id)
                    else:
                        self.tainted.discard(name.id)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.target, ast.Name) and \
                self._expr_tainted(node.value):
            self.tainted.add(node.target.id)
        self.generic_visit(node)

    def visit_If(self, node: ast.If) -> None:
        if self._expr_tainted(node.test):
            self._emit("tracer-branch", node,
                       "Python `if` on a tracer-valued expression: a "
                       "concretization error under jit, a device sync "
                       "and shape-specialized trace outside it — decide "
                       "with jnp.where/lax.cond, or materialize "
                       "explicitly with np.asarray first")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if self._expr_tainted(node.test):
            self._emit("tracer-branch", node,
                       "Python `while` on a tracer-valued expression — "
                       "use lax.while_loop, or materialize explicitly")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Name) and \
                node.func.id in ("float", "int", "bool") and node.args:
            arg = node.args[0]
            direct = (isinstance(arg, ast.Call)
                      and not _is_materializer(arg)
                      and bool(_attr_chain(arg.func))
                      and _attr_chain(arg.func)[0] in _TRACER_ROOTS)
            if direct or self._expr_tainted(arg):
                self._emit("tracer-coercion", node,
                           f"{node.func.id}() directly on a tracer-"
                           f"valued expression forces an implicit "
                           f"device sync (and breaks under jit); spell "
                           f"the materialization as "
                           f"{node.func.id}(np.asarray(...))")
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # Nested defs get their own _FnLinter (fresh taint scope) from
        # scan_source's walk; descending here would double-report them.
        pass

    visit_AsyncFunctionDef = visit_FunctionDef


def _static_names(call: ast.Call) -> List[str]:
    """The static_argnames of one jax.jit(...) call, when literal."""
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return [v.value]
            if isinstance(v, (ast.Tuple, ast.List)):
                return [e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)]
    return []


def _check_static_args(tree: ast.AST, path: str,
                       findings: List[Finding]) -> None:
    """static-unhashable: a static_argnames parameter whose default is
    a mutable literal on the decorated function."""
    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        statics: List[str] = []
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call):
                chain = _attr_chain(dec.func)
                if chain and chain[-1] in ("jit", "partial"):
                    statics.extend(_static_names(dec))
        if not statics:
            continue
        args = fn.args.args + fn.args.kwonlyargs
        defaults = ([None] * (len(fn.args.args) - len(fn.args.defaults))
                    + list(fn.args.defaults) + list(fn.args.kw_defaults))
        for a, dflt in zip(args, defaults):
            if a.arg in statics and isinstance(
                    dflt, (ast.List, ast.Dict, ast.Set)):
                findings.append(Finding(
                    PASS, "static-unhashable", f"{path}:{a.lineno}",
                    f"static arg {a.arg!r} defaults to a mutable "
                    f"{type(dflt).__name__.lower()} literal: statics "
                    f"must be hashable (use a tuple / frozenset / "
                    f"None-sentinel)"))


def _check_checkpoint_bypass(tree: ast.AST, path: str,
                             findings: List[Finding]) -> None:
    if path.replace(os.sep, "/").endswith("checkpoint/store.py"):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if len(chain) >= 2 and chain[0] in ("np", "numpy") and \
                chain[-1] in ("save", "savez", "savez_compressed"):
            findings.append(Finding(
                PASS, "checkpoint-bypass", f"{path}:{node.lineno}",
                f"np.{chain[-1]} outside checkpoint/store.py bypasses "
                f"the schema-versioned store (DESIGN.md §9): persisted "
                f"artifacts must round-trip through store.save_pytree"))


def _suppressions(source: str) -> Dict[int, Set[str]]:
    """line number -> rule names allowed there (the comment's own line
    and the line below it, so the comment can ride above the hazard)."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out.setdefault(i, set()).update(rules)
            out.setdefault(i + 1, set()).update(rules)
    return out


def scan_source(source: str, path: str) -> List[Finding]:
    """All lint findings for one file's source text, suppression
    comments applied."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(PASS, "syntax-error", f"{path}:{e.lineno}",
                        str(e))]
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _FnLinter(path, findings).generic_visit(node)
    _check_static_args(tree, path, findings)
    _check_checkpoint_bypass(tree, path, findings)

    allow = _suppressions(source)
    out = []
    for f in findings:
        line = int(f.where.rsplit(":", 1)[1])
        rules = allow.get(line, set())
        if f.rule in rules or "*" in rules:
            f = Finding(f.pass_name, f.rule, f.where, f.message,
                        suppressed=True)
        out.append(f)
    return out


def default_root() -> str:
    return os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def audit_all(root: Optional[str] = None
              ) -> Tuple[List[Finding], int]:
    """(findings, files scanned) over every .py under ``root``
    (default: the installed ``repro`` package tree)."""
    root = root or default_root()
    findings: List[Finding] = []
    n = 0
    for dirpath, _, names in sorted(os.walk(root)):
        for name in sorted(names):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, os.path.dirname(root))
            with open(path, "r", encoding="utf-8") as fh:
                findings.extend(scan_source(fh.read(), rel))
            n += 1
    return findings, n
